"""Migration replay benchmark -> ``BENCH_migrate.json`` at repo root.

One entry per run, on the paper's case-study fleet (2x2 A100 + 1x2 V100,
5 Gbps cross): one A100 node is reclaimed at step E (the running plan no
longer fits — forced replan) and returns at step E+K (voluntary replan,
gated by the amortization rule).  The same scripted trace drives:

- **elastic/priced**: the controller prices each migration with the layout
  differ + fair-share netsim (``repro.migrate``) — moved bytes only, each
  from the nearest surviving replica (checkpoint only for shards whose
  replicas all sat on the lost node), overlapped with the old plan's drain;
- **elastic/legacy**: same controller, the old params-over-the-cross-link
  migration guess (``migration_pricing="legacy"``) — recorded to show the
  guess and the exact price genuinely differ;
- **static**: the initial plan is never changed; infeasible steps earn zero
  tokens (stall-and-wait reference).

The acceptance axes (gated under ``--fail-on-regression``):

1. **charge == price**: the wall clock the elastic replay charges beyond
   productive steps matches the decisions' priced downtime within 5%;
2. **differ engaged**: every adoption shipped bytes, and strictly fewer
   than the full state (live migration moves only what moved);
3. **migration beats checkpoint-restart**: the priced downtime of the
   forced migration undercuts restarting from the newest checkpoint
   (full-state restore at the same ``restore_bw`` + re-running the steps
   since the last save);
4. **overlap never hurts**: overlapped downtime <= stop-the-world serial.

``--tiny`` shrinks the horizon to CI size.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit_csv                        # noqa: E402

from repro import api                                         # noqa: E402
from repro.core.cluster import (                              # noqa: E402
    paper_case_study_cluster, remove_nodes,
)
from repro.core.planner import PlannerConfig                  # noqa: E402
from repro.migrate import DEFAULT_RESTORE_BW                  # noqa: E402
from repro.runtime.controller import (                        # noqa: E402
    ControllerConfig, ElasticController,
)
from repro.runtime.events import EventTrace, Preemption       # noqa: E402
from repro.runtime.replay import run_replay                   # noqa: E402

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_migrate.json")

ARCH = "gpt-2b"
SEQ_LEN = 512
GLOBAL_BATCH = 16
CKPT_EVERY = 20          # steps between checkpoints for the restart baseline


def _pcfg() -> PlannerConfig:
    return PlannerConfig(granularity=16, n_microbatches=16)


def _controller(n_steps: int, pricing: str) -> ElasticController:
    return ElasticController(
        paper_case_study_cluster(), ARCH, planner_cfg=_pcfg(),
        cfg=ControllerConfig(
            total_steps=n_steps, seq_len=SEQ_LEN, global_batch=GLOBAL_BATCH,
            migration_pricing=pricing))


def run(tiny: bool = False, label: Optional[str] = None) -> Dict:
    n_steps, e_step, k_steps = (40, 10, 15) if tiny else (120, 30, 45)
    trace = EventTrace([Preemption(step=e_step, subcluster="meshA100",
                                   n_nodes=1, duration_steps=k_steps)])

    t0 = time.perf_counter()
    ctrl = _controller(n_steps, "priced")
    ctrl.bootstrap()
    ideal_tput = ctrl.strategy.tokens_per_step() / ctrl.strategy.est_step_time
    ideal_step_s = ctrl.strategy.est_step_time
    init_strategy, init_cluster = ctrl.strategy, ctrl.plan_cluster
    layers = ctrl.layers
    elastic = run_replay(trace, n_steps, controller=ctrl)

    ctrl_legacy = _controller(n_steps, "legacy")
    ctrl_legacy.bootstrap()
    legacy = run_replay(trace, n_steps, controller=ctrl_legacy)

    static = run_replay(trace, n_steps, strategy=init_strategy,
                        plan_cluster=init_cluster, layers=layers)

    # standalone pricing of the forced move (the facade path the CLI takes):
    # differ + netsim vs restarting from the newest checkpoint
    cfg = api.HarpConfig(seq_len=SEQ_LEN, global_batch=GLOBAL_BATCH,
                         planner=_pcfg())
    exe = api.compile(ARCH, paper_case_study_cluster(), cfg)
    shrunk = remove_nodes(paper_case_study_cluster(), "meshA100", 1)
    mig = exe.migrate_to(shrunk).plan.migration
    restart_s = (mig["total_bytes"] / DEFAULT_RESTORE_BW
                 + (CKPT_EVERY / 2.0) * ideal_step_s)
    wall_s = time.perf_counter() - t0

    charged = elastic.wall_total_s - sum(s.step_time_s
                                         for s in elastic.samples)
    priced = elastic.migration_s + elastic.search_s
    adoptions = [d for d in elastic.decisions if d.migration_s > 0
                 or d.migration_bytes > 0]

    lost_elastic = elastic.tokens_lost(ideal_tput)
    lost_static = static.tokens_lost(ideal_tput)

    case = {
        "cluster": paper_case_study_cluster().describe(),
        "arch": ARCH,
        "n_steps": n_steps,
        "preempt_step": e_step,
        "outage_steps": k_steps,
        "ideal_tokens_per_s": round(ideal_tput, 1),
        "priced_migration_s": round(elastic.migration_s, 4),
        "priced_search_s": round(elastic.search_s, 4),
        "charged_downtime_s": round(charged, 4),
        "migration_mbytes": round(elastic.migration_bytes / 1e6, 1),
        "n_adoptions": len(adoptions),
        "legacy_migration_s": round(legacy.migration_s, 4),
        "forced_move": {
            "moved_mbytes": round(mig["moved_bytes"] / 1e6, 1),
            "ckpt_mbytes": round(mig["ckpt_bytes"] / 1e6, 1),
            "local_mbytes": round(mig["local_bytes"] / 1e6, 1),
            "total_mbytes": round(mig["total_bytes"] / 1e6, 1),
            "n_transfers": mig["n_transfers"],
            "downtime_s": round(mig["downtime_s"], 4),
            "serial_s": round(mig["serial_s"], 4),
            "drain_s": round(mig["drain_s"], 4),
            "ckpt_restart_s": round(restart_s, 4),
            "speedup_vs_restart": round(restart_s / mig["downtime_s"], 3)
            if mig["downtime_s"] > 0 else 0.0,
        },
        "elastic_tokens_lost": round(lost_elastic, 1),
        "legacy_tokens_lost": round(legacy.tokens_lost(ideal_tput), 1),
        "static_tokens_lost": round(lost_static, 1),
        "static_stalled_steps": static.stalled_steps,
        "charge_matches_pricing": abs(charged - priced)
            <= 0.05 * max(priced, 1e-9),
        "differ_engaged": len(adoptions) > 0
            and all(d.migration_bytes > 0 for d in adoptions)
            and mig["moved_bytes"] + mig["ckpt_bytes"] < mig["total_bytes"],
        "migration_beats_restart": mig["downtime_s"] < restart_s,
        "overlap_no_worse": mig["downtime_s"] <= mig["serial_s"] + 1e-9,
        "bench_seconds": round(wall_s, 3),
    }
    return {"label": label or "HEAD",
            "mode": "tiny" if tiny else "full",
            "cases": {"preemption_cycle": case}}


def extend_trajectory(entry: Dict, path: str = BENCH_PATH) -> Dict:
    """Append one run to the migration trajectory (creates the file on
    first use)."""
    doc = {"schema": 1,
           "description": "Migration-replay trajectory; one entry per "
                          "benchmarks/migrate_replay.py run — see "
                          "docs/migration.md.",
           "runs": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    doc["runs"].append(entry)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return doc


def rows_from_entry(entry: Dict) -> List[Dict]:
    rows = []
    for name, c in entry["cases"].items():
        fm = c["forced_move"]
        rows.append({
            "label": f"{name}.migrate",
            "step_time_s": fm["downtime_s"],
            "derived": f"moved_mb={fm['moved_mbytes']};"
                       f"ckpt_mb={fm['ckpt_mbytes']};"
                       f"transfers={fm['n_transfers']};"
                       f"serial={fm['serial_s']}"})
        rows.append({
            "label": f"{name}.restart",
            "step_time_s": fm["ckpt_restart_s"],
            "derived": f"speedup={fm['speedup_vs_restart']}x;"
                       f"total_mb={fm['total_mbytes']}"})
        rows.append({
            "label": f"{name}.replay",
            "step_time_s": c["charged_downtime_s"],
            "derived": f"priced={c['priced_migration_s']};"
                       f"legacy={c['legacy_migration_s']};"
                       f"elastic_lost={c['elastic_tokens_lost']};"
                       f"static_lost={c['static_tokens_lost']}"})
    return rows


def main() -> None:
    """benchmarks/run.py contract: full measurement, CSV on stdout, one
    trajectory entry appended to BENCH_migrate.json."""
    entry = run(tiny=False)
    extend_trajectory(entry)
    emit_csv(rows_from_entry(entry))


def cli(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized horizon (seconds, not minutes)")
    ap.add_argument("--label", default=None,
                    help="trajectory entry label (default HEAD)")
    ap.add_argument("--out", default=BENCH_PATH,
                    help="trajectory JSON path (default repo root)")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 unless the charged downtime matches the "
                         "priced downtime (±5%%), adoptions shipped bytes, "
                         "the priced migration undercuts checkpoint-restart, "
                         "and overlap never exceeds serial")
    args = ap.parse_args(argv)

    entry = run(tiny=args.tiny, label=args.label)
    extend_trajectory(entry, args.out)
    emit_csv(rows_from_entry(entry))
    print(f"# trajectory entry appended to {os.path.abspath(args.out)}",
          file=sys.stderr)

    bad = [name for name, c in entry["cases"].items()
           if not (c["charge_matches_pricing"] and c["differ_engaged"]
                   and c["migration_beats_restart"]
                   and c["overlap_no_worse"])]
    if bad:
        print(f"# migration replay regressed on: {bad}", file=sys.stderr)
        if args.fail_on_regression:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(cli())
