"""Observability benchmark -> ``BENCH_obs.json`` at repo root.

Two cases, both gated under ``--fail-on-regression``:

- **trace_export**: lower one compiled plan's simulation through the
  ``repro.obs`` span model and export Chrome-trace JSON, twice.  Gates:

  1. **adapter exactness** — per-stage compute-span duration sums equal
     ``SimResult.stage_compute`` bit for bit and the comm-span sum equals
     ``comm_total`` (the whole point of lowering instead of
     re-simulating);
  2. **byte determinism** — both exports are byte-identical;
  3. **bounded overhead** — lower + export wall stays under an absolute
     budget (tracing must never cost more than the simulation it
     describes is worth).

- **drift_detection**: feed a :class:`repro.obs.DriftLedger` the plan's
  own prediction, then (a) faithful samples and (b) samples with a 20%
  uniform slowdown.  Gates: the clean run is *not* flagged, the slowed
  run *is*, and the slowdown is attributed to every hosting pool.

``--tiny`` shrinks the export round-trip count to CI size.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import tempfile                                               # noqa: E402

from benchmarks.common import emit_csv                        # noqa: E402

from repro import api                                         # noqa: E402
from repro.core.cluster import paper_case_study_cluster       # noqa: E402
from repro.core.planner import PlannerConfig                  # noqa: E402
from repro.obs import DriftLedger, trace_from_sim, trace_to_chrome  # noqa: E402

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_obs.json")

ARCH = "gpt-2b"
SEQ_LEN = 512
GLOBAL_BATCH = 16
EXPORT_BUDGET_S = 5.0        # absolute wall budget per lower+export round


def _compile():
    cfg = api.HarpConfig(
        seq_len=SEQ_LEN, global_batch=GLOBAL_BATCH,
        planner=PlannerConfig(granularity=16, n_microbatches=16))
    return api.compile(ARCH, paper_case_study_cluster(), cfg)


def trace_export_case(exe, rounds: int) -> Dict:
    res = exe.simulate(priced=False)
    t0 = time.perf_counter()
    paths: List[str] = []
    with tempfile.TemporaryDirectory() as d:
        for k in range(rounds):
            tr = trace_from_sim(res, name=ARCH)
            p = os.path.join(d, f"t{k}.json")
            trace_to_chrome(tr, p)
            paths.append(p)
        blobs = [open(p, "rb").read() for p in paths]
    wall = time.perf_counter() - t0

    tr = trace_from_sim(res, name=ARCH)
    compute = [s for s in tr.spans if s.cat == "compute"]
    exact = all(
        sum(s.dur for s in compute if s.args["stage"] == i) == expected
        for i, expected in enumerate(res.stage_compute))
    comm = sum(s.dur for s in tr.spans
               if s.cat == "comm" and s.args.get("kind") in ("CF", "CB"))
    return {
        "rounds": rounds,
        "n_spans": len(tr.spans),
        "export_wall_s": round(wall, 4),
        "export_wall_per_round_s": round(wall / rounds, 4),
        "adapter_exact": bool(exact and comm == res.comm_total),
        "export_deterministic": len(set(blobs)) == 1,
        "overhead_bounded": wall / rounds < EXPORT_BUDGET_S,
    }


def drift_detection_case(exe, n_steps: int) -> Dict:
    res = exe.simulate(priced=False)
    predicted = {"makespan_s": res.makespan,
                 "stage_compute_s": list(res.stage_compute)}
    pools = exe._stage_pools()

    def fold(scale: float):
        led = DriftLedger(threshold=0.15, window=8)
        led.register_plan(predicted, stage_pools=pools)
        for step in range(n_steps):
            led.observe_step(step, res.makespan * scale,
                             stage_times=[t * scale
                                          for t in res.stage_compute])
        return led.report()

    clean, slowed = fold(1.0), fold(1.2)
    return {
        "n_steps": n_steps,
        "clean_rel_error": round(clean.rel_error, 6),
        "slowed_rel_error": round(slowed.rel_error, 6),
        "slowed_flagged_pools": slowed.flagged_pools,
        "clean_not_flagged": not clean.flagged,
        "slowdown_flagged": slowed.flagged,
        "pools_attributed":
            slowed.flagged_pools == sorted(set(pools.values())),
    }


def run(tiny: bool = False, label: Optional[str] = None) -> Dict:
    rounds = 3 if tiny else 20
    n_steps = 20 if tiny else 100
    t0 = time.perf_counter()
    exe = _compile()
    cases = {
        "trace_export": trace_export_case(exe, rounds),
        "drift_detection": drift_detection_case(exe, n_steps),
    }
    cases["trace_export"]["bench_seconds"] = round(
        time.perf_counter() - t0, 3)
    return {"label": label or "HEAD",
            "mode": "tiny" if tiny else "full",
            "cases": cases}


def extend_trajectory(entry: Dict, path: str = BENCH_PATH) -> Dict:
    """Append one run to the obs trajectory (creates the file on first
    use)."""
    doc = {"schema": 1,
           "description": "Observability trajectory; one entry per "
                          "benchmarks/obs_bench.py run — see "
                          "docs/observability.md.",
           "runs": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    doc["runs"].append(entry)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return doc


def rows_from_entry(entry: Dict) -> List[Dict]:
    te = entry["cases"]["trace_export"]
    dd = entry["cases"]["drift_detection"]
    return [
        {"label": "trace_export",
         "step_time_s": te["export_wall_per_round_s"],
         "derived": f"spans={te['n_spans']};exact={te['adapter_exact']};"
                    f"deterministic={te['export_deterministic']}"},
        {"label": "drift_detection",
         "step_time_s": 0.0,
         "derived": f"slowed_rel={dd['slowed_rel_error']};"
                    f"flagged={dd['slowdown_flagged']};"
                    f"pools={dd['slowed_flagged_pools']}"},
    ]


def main() -> None:
    """benchmarks/run.py contract: full measurement, CSV on stdout, one
    trajectory entry appended to BENCH_obs.json."""
    entry = run(tiny=False)
    extend_trajectory(entry)
    emit_csv(rows_from_entry(entry))


def cli(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized round count")
    ap.add_argument("--label", default=None,
                    help="trajectory entry label (default HEAD)")
    ap.add_argument("--out", default=BENCH_PATH,
                    help="trajectory JSON path (default repo root)")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 unless adapters are exact, exports are "
                         "byte-deterministic and within budget, and the "
                         "drift ledger flags the injected slowdown (and "
                         "only it)")
    args = ap.parse_args(argv)

    entry = run(tiny=args.tiny, label=args.label)
    extend_trajectory(entry, args.out)
    emit_csv(rows_from_entry(entry))
    print(f"# trajectory entry appended to {os.path.abspath(args.out)}",
          file=sys.stderr)

    bad = [name for name, c in entry["cases"].items()
           if not all(v for k, v in c.items() if isinstance(v, bool))]
    if bad:
        print(f"# obs bench regressed on: {bad}", file=sys.stderr)
        if args.fail_on_regression:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(cli())
