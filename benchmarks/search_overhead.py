"""Paper §6.6: planning overhead with/without HAPT's optimizations.

Measures wall-clock of profiling and DP search at fine granularity:
  - zero-redundant aliasing ON vs OFF (unique-evaluation counts);
  - bidirectional t_max pruning + batched parallel eval ON vs naive
    (evaluate every candidate serially).
Paper: optimizations cut planning from >100 h to ~23 min at #L=146."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import cached, emit_csv, hetero_cluster
from repro.configs import get_config
from repro.core.dp_search import SearchConfig, _DPContext, _dp_eval, search
from repro.core.layering import build_layers
from repro.core.opgraph import build_op_sequence
from repro.core.profiler import ZeroRedundantProfiler

ARCH = "gpt-30b"
DIMS = (2, 8, 2, 8)
GRAN = 96


def run():
    cluster = hetero_cluster(*DIMS)
    ops = build_op_sequence(get_config(ARCH), seq_len=1024)
    layers = build_layers(ops, GRAN)
    mb_tokens = 8192

    def bench():
        out = {}
        t0 = time.time()
        prof = ZeroRedundantProfiler(cluster, layers, mb_tokens,
                                     min_submesh_devices=2)
        tables = prof.profile()
        out["profile_s"] = time.time() - t0
        out["stats"] = {
            "candidates": tables.stats.n_candidates,
            "unique": tables.stats.n_unique_profiled,
            "aliased": tables.stats.n_aliased,
            "dedup_ratio": tables.stats.dedup_ratio,
        }

        # optimized search (pruning + parallel batches)
        scfg = SearchConfig(n_microbatches=128, n_workers=6)
        t0 = time.time()
        strat = search(cluster, tables, mb_tokens, scfg)
        out["search_optimized_s"] = time.time() - t0
        out["n_tmax_evaluated"] = strat.planner_meta["n_tmax_evaluated"]

        # naive search: every candidate t_max, serial (capped sample for
        # tractability; extrapolated)
        ctx = _DPContext(cluster, tables, scfg)
        vals = np.unique(ctx.t_tab[tables.feasible].round(6))
        sample = vals[:: max(1, len(vals) // 24)][:24]
        t0 = time.time()
        for t in sample:
            _dp_eval(ctx, float(t))
        per_eval = (time.time() - t0) / len(sample)
        out["search_naive_extrapolated_s"] = per_eval * len(vals)
        out["n_tmax_naive"] = int(len(vals))
        return out

    r = cached("search_overhead", bench)
    rows = [
        {"label": "profiling", "step_time_s": r["profile_s"],
         "derived": f"dedup={r['stats']['dedup_ratio'] * 100:.0f}%;"
                    f"unique={r['stats']['unique']}/"
                    f"{r['stats']['candidates']}"},
        {"label": "search_optimized", "step_time_s": r["search_optimized_s"],
         "derived": f"tmax_evaluated={r['n_tmax_evaluated']}"},
        {"label": "search_naive", "step_time_s":
         r["search_naive_extrapolated_s"],
         "derived": f"tmax_candidates={r['n_tmax_naive']} (extrapolated)"},
        {"label": "search_speedup", "step_time_s": 0.0,
         "derived": f"{r['search_naive_extrapolated_s'] / max(r['search_optimized_s'], 1e-9):.0f}x"
                    " (paper: >100h -> 133s)"},
    ]
    return rows


def main():
    emit_csv(run())


if __name__ == "__main__":
    main()
