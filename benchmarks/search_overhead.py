"""Paper §6.6: planning overhead — and the repo's perf-trajectory emitter.

Measures wall-clock of the three planner phases (Zero-Redundant profiling,
DP search, pipesim validation) through the *public* observability surface
(``dp_search.instrumented_search`` — no private imports) and records the
result as one trajectory entry in ``BENCH_search.json`` at the repo root,
so every future PR extends the same time series:

- ``gpt30b_gran96``  — the paper's fine-granularity case: full-search and
  per-DP-solve wall clock for the scalar oracle vs. the vectorized engine
  (bit-identical strategies, asserted), plus closed-form vs. graph pipesim;
- ``scale_4subclusters`` — a 4-pool mixed fleet the scalar oracle cannot
  represent at all (its DP state hardcodes two device-unit axes): planning
  it is newly feasible with the vectorized engine, so the entry records the
  vectorized wall clock and pins the oracle's unsupportedness.

CLI:  python benchmarks/search_overhead.py [--tiny] [--label L]
          [--out PATH] [--fail-on-fallback]

``--tiny`` runs CI-sized configs (seconds); ``--fail-on-fallback`` exits
non-zero when the vectorized engine fell back to the oracle on any case —
the canonical clusters must stay on the fast path.
Paper: optimizations cut planning from >100 h to ~23 min at #L=146."""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional

from benchmarks.common import emit_csv, hetero_cluster
from repro.configs import get_config
from repro.core.cluster import (
    A100_40G, GBPS, V100_32G, HeteroCluster, SubCluster,
    paper_case_study_cluster,
)
from repro.core.dp_search import SearchConfig, instrumented_search
from repro.core.layering import build_layers
from repro.core.opgraph import build_op_sequence
from repro.core.pipesim import simulate
from repro.core.profiler import ZeroRedundantProfiler

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_search.json")

ARCH = "gpt-30b"
DIMS = (2, 8, 2, 8)
GRAN = 96
MB_TOKENS = 8192
B = 128


def four_subcluster_fleet(tiny: bool = False) -> HeteroCluster:
    """The scale case: four pools (two A100 generations, two V100 pools)
    — one more sub-cluster than the scalar oracle's DP state can track."""
    n = 1 if tiny else 2
    per = 4 if tiny else 8
    return HeteroCluster(
        subclusters=(
            SubCluster("A100-a", n, per, A100_40G, 300e9, 200 * GBPS),
            SubCluster("A100-b", 1, per, A100_40G, 300e9, 200 * GBPS),
            SubCluster("V100-a", n, per, V100_32G, 150e9, 200 * GBPS),
            SubCluster("V100-b", 1, per, V100_32G, 150e9, 200 * GBPS),
        ),
        cross_bw=5.0 * GBPS)


def _profile(cluster, arch, gran, mb_tokens, min_submesh):
    ops = build_op_sequence(get_config(arch), seq_len=1024)
    layers = build_layers(ops, gran)
    t0 = time.perf_counter()
    tables = ZeroRedundantProfiler(
        cluster, layers, mb_tokens, min_submesh_devices=min_submesh).profile()
    return layers, tables, time.perf_counter() - t0


def _time_pipesim(strategy, reps: int = 25) -> Dict[str, float]:
    """Closed-form vs. graph engine on the searched schedule (memo off)."""
    t_f = [s.t_f for s in strategy.stages]
    t_b = [s.t_b for s in strategy.stages]
    args = (t_f, t_b, strategy.c_links, strategy.n_microbatches,
            strategy.warmup_counts)
    res = {}
    makespans = []
    for label, fast in (("pipesim_graph_s", False), ("pipesim_fast_s", True)):
        best = float("inf")
        for _ in range(reps):          # min over reps: scheduler-noise-robust
            t0 = time.perf_counter()
            sim = simulate(*args, fast=fast, cache=False)
            best = min(best, time.perf_counter() - t0)
        res[label] = best
        makespans.append(sim.makespan)
    assert makespans[0] == makespans[1], \
        "closed-form pipesim diverged from the graph simulator"
    res["pipesim_speedup"] = res["pipesim_graph_s"] / \
        max(res["pipesim_fast_s"], 1e-12)
    return res


def bench_headline(tiny: bool) -> Dict:
    """Oracle vs. vectorized on the §6.6 heterogeneous case."""
    if tiny:
        cluster, arch, gran, mbt, mins, nmb = (
            paper_case_study_cluster(), "gpt-2b", 16, 1024, 1, 16)
    else:
        cluster, arch, gran, mbt, mins, nmb = (
            hetero_cluster(*DIMS), ARCH, GRAN, MB_TOKENS, 2, B)
    layers, tables, profile_s = _profile(cluster, arch, gran, mbt, mins)

    cfg_v = SearchConfig(n_microbatches=nmb, engine="vectorized")
    cfg_o = SearchConfig(n_microbatches=nmb, engine="oracle")
    # best-of-N full searches: wall-clock minima are robust to scheduler
    # noise on shared machines (both engines are deterministic, so repeat
    # runs do identical work)
    vec_s, oracle_s = float("inf"), float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        strat_v, stats_v = instrumented_search(cluster, tables, mbt, cfg_v)
        vec_s = min(vec_s, time.perf_counter() - t0)
    for _ in range(2):
        t0 = time.perf_counter()
        strat_o, stats_o = instrumented_search(cluster, tables, mbt, cfg_o)
        oracle_s = min(oracle_s, time.perf_counter() - t0)

    identical = strat_v.to_json() == strat_o.to_json()
    assert identical, "vectorized strategy diverged from the scalar oracle"

    n_solves_v = stats_v.n_evaluated + stats_v.prune_evals
    n_solves_o = stats_o.n_evaluated + stats_o.prune_evals
    per_o = stats_o.eval_seconds / max(stats_o.n_evaluated, 1)
    per_v = stats_v.eval_seconds / max(stats_v.n_evaluated, 1)
    out = {
        "cluster": cluster.describe(),
        "arch": arch, "granularity": gran, "n_layers": len(layers),
        "n_mesh_rows": len(tables.meshes),
        "profile_s": round(profile_s, 4),
        "profiler_dedup_ratio": round(tables.stats.dedup_ratio, 4),
        "search_oracle_s": round(oracle_s, 3),
        "search_vectorized_s": round(vec_s, 3),
        "search_speedup": round(oracle_s / max(vec_s, 1e-12), 2),
        "dp_eval_oracle_s": round(per_o, 6),
        "dp_eval_vectorized_s": round(per_v, 6),
        # ratio from the unrounded values (display rounding would divide
        # by 0.0 once the vectorized per-solve dips below the precision)
        "dp_eval_speedup": round(per_o / max(per_v, 1e-12), 2),
        "n_dp_solves": n_solves_v,
        "n_dp_solves_oracle": n_solves_o,
        "n_tmax_candidates": stats_v.n_tmax_candidates,
        "engine": stats_v.engine,
        "oracle_fallbacks": stats_v.oracle_fallbacks,
        "strategy_json_identical": identical,
        "n_stages": strat_v.n_stages,
        "est_step_time_s": round(strat_v.est_step_time, 5),
    }
    out.update({k: round(v, 6) for k, v in _time_pipesim(strat_v).items()})
    return out


def bench_scale(tiny: bool) -> Dict:
    """The 4-sub-cluster fleet: representable only by the vectorized DP."""
    cluster = four_subcluster_fleet(tiny)
    arch, gran, mbt, nmb = ("gpt-2b", 16, 1024, 16) if tiny \
        else ("gpt-30b", 48, MB_TOKENS, B)
    layers, tables, profile_s = _profile(cluster, arch, gran, mbt,
                                         1 if tiny else 2)
    cfg = SearchConfig(n_microbatches=nmb, engine="vectorized")
    t0 = time.perf_counter()
    strat, stats = instrumented_search(cluster, tables, mbt, cfg)
    vec_s = time.perf_counter() - t0
    # the oracle cannot even represent this fleet — pin that fact
    try:
        instrumented_search(cluster, tables, mbt,
                            SearchConfig(n_microbatches=nmb, engine="oracle"))
        oracle = "unexpectedly supported"
    except ValueError as e:
        oracle = f"unsupported ({e})"
    return {
        "cluster": cluster.describe(),
        "arch": arch, "granularity": gran, "n_layers": len(layers),
        "n_subclusters": len(cluster.subclusters),
        "profile_s": round(profile_s, 4),
        "search_vectorized_s": round(vec_s, 3),
        "oracle": oracle,
        "engine": stats.engine,
        "oracle_fallbacks": stats.oracle_fallbacks,
        "n_dp_solves": stats.n_evaluated + stats.prune_evals,
        "n_stages": strat.n_stages,
        "est_step_time_s": round(strat.est_step_time, 5),
        "clusters_in_pipeline": sorted({s.cluster_idx
                                        for s in strat.stages}),
    }


def run(tiny: bool = False, label: Optional[str] = None) -> Dict:
    cases = {
        "gpt30b_gran96" if not tiny else "tiny_case_study":
            bench_headline(tiny),
        "scale_4subclusters": bench_scale(tiny),
    }
    return {"label": label or "HEAD",
            "mode": "tiny" if tiny else "full",
            "cases": cases}


def extend_trajectory(entry: Dict, path: str = BENCH_PATH) -> Dict:
    """Append one run to the perf trajectory (creates the file on first
    use).  Returns the whole document."""
    doc = {"schema": 1,
           "description": "Planner perf trajectory; one entry per "
                          "benchmarks/search_overhead.py run — see "
                          "docs/planner.md#planner-performance.",
           "runs": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    doc["runs"].append(entry)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return doc


def rows_from_entry(entry: Dict) -> List[Dict]:
    rows = []
    for name, c in entry["cases"].items():
        if "search_oracle_s" in c:
            rows.append({
                "label": f"{name}.search_oracle",
                "step_time_s": c["search_oracle_s"],
                "derived": f"per_eval={c['dp_eval_oracle_s']}s"})
            rows.append({
                "label": f"{name}.search_vectorized",
                "step_time_s": c["search_vectorized_s"],
                "derived": f"speedup={c['search_speedup']}x;"
                           f"per_eval={c['dp_eval_speedup']}x;"
                           f"identical={c['strategy_json_identical']}"})
            rows.append({
                "label": f"{name}.pipesim",
                "step_time_s": c["pipesim_fast_s"],
                "derived": f"graph={c['pipesim_graph_s']}s;"
                           f"speedup={round(c['pipesim_speedup'], 1)}x"})
        else:
            rows.append({
                "label": f"{name}.search_vectorized",
                "step_time_s": c["search_vectorized_s"],
                "derived": f"C={c['n_subclusters']};oracle={c['oracle']}"})
    return rows


def main() -> None:
    """benchmarks/run.py contract: full measurement, CSV on stdout, and one
    trajectory entry appended to BENCH_search.json."""
    entry = run(tiny=False)
    extend_trajectory(entry)
    emit_csv(rows_from_entry(entry))


def cli(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized configs (seconds, not minutes)")
    ap.add_argument("--label", default=None,
                    help="trajectory entry label (default HEAD)")
    ap.add_argument("--out", default=BENCH_PATH,
                    help="trajectory JSON path (default repo root)")
    ap.add_argument("--fail-on-fallback", action="store_true",
                    help="exit 1 if the vectorized engine fell back to the "
                         "oracle on any case")
    args = ap.parse_args(argv)

    entry = run(tiny=args.tiny, label=args.label)
    extend_trajectory(entry, args.out)
    emit_csv(rows_from_entry(entry))
    print(f"# trajectory entry appended to {os.path.abspath(args.out)}",
          file=sys.stderr)

    fellback = [name for name, c in entry["cases"].items()
                if c.get("oracle_fallbacks", 0) or c.get("engine") != "vectorized"]
    if fellback:
        print(f"# vectorized path fell back to the oracle on: {fellback}",
              file=sys.stderr)
        if args.fail_on_fallback:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(cli())
