"""Paper Fig. 11: (a) layer-granularity ablation {8, 16, 48, fine};
(b) joint-optimization ablation — plan with C(i)=0 (communication-blind),
then evaluate under real link costs (paper: 1.4x-3.3x slowdown);
(c) two-level ablation — inter-op-only vs. joint inter+intra search on a
mixed-efficiency sub-cluster (both plans referee-priced identically via
``sync_priced_step`` so the comparison is accounting-fair)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    GLOBAL_BATCH, N_MICROBATCHES, SEQ_LEN, cached, emit_csv, hetero_cluster,
    plan_hapt,
)
from repro import api
from repro.configs import get_config
from repro.core.cluster import set_node_efficiencies
from repro.core.dp_search import SearchConfig, search
from repro.core.h1f1b import h1f1b_counts
from repro.core.layering import build_layers
from repro.core.opgraph import build_op_sequence
from repro.core.pipesim import simulate
from repro.core.planner import PlannerConfig
from repro.core.profiler import ZeroRedundantProfiler
from repro.runtime.replay import sync_priced_step

ARCH = "gpt-30b"
DIMS = (2, 8, 2, 8)

# (c): both sub-clusters are *mixed* — one throttled node in each pool
INTRA_ARCH = "gpt-15b"
INTRA_DIMS = (2, 4, 2, 4)
INTRA_NODE_EFFS = {"A100": (1.0, 0.8), "V100": (1.0, 0.6)}
INTRA_GRAN = 48
INTRA_B = 64


def run():
    cluster = hetero_cluster(*DIMS)
    rows = []

    # (a) granularity ablation
    for gran in [8, 16, 48, 96]:
        def fn(g=gran):
            s = plan_hapt(cluster, ARCH, granularity=g)
            return {"t": s.est_step_time, "eta": s.eta,
                    "n_layers": s.planner_meta["granularity"]}
        r = cached(f"fig11a_gran{gran}", fn)
        rows.append({"label": f"fig11a/granularity_{gran}",
                     "step_time_s": r["t"],
                     "derived": f"eta={r['eta']:.3f};L={r['n_layers']}"})
    base = cached("fig11a_gran8", lambda: None)
    fine = cached("fig11a_gran96", lambda: None)
    rows.append({"label": "fig11a/fine_vs_L8_speedup", "step_time_s": 0.0,
                 "derived": f"{base['t'] / fine['t']:.2f}x (paper: 1.2-1.6x)"})

    # (b) joint optimization: plan with C(i)=0, evaluate with real comm
    def fn_b():
        ops = build_op_sequence(get_config(ARCH), seq_len=SEQ_LEN)
        layers = build_layers(ops, 96)
        mb_tokens = GLOBAL_BATCH * SEQ_LEN // N_MICROBATCHES
        prof = ZeroRedundantProfiler(cluster, layers, mb_tokens,
                                     min_submesh_devices=2)
        tables = prof.profile()
        # communication-blind search
        blind_tables = tables
        real_cut = tables.cut_bytes.copy()
        tables.cut_bytes = np.zeros_like(tables.cut_bytes)
        scfg = SearchConfig(n_microbatches=N_MICROBATCHES, n_workers=6)
        blind = search(cluster, tables, mb_tokens, scfg)
        tables.cut_bytes = real_cut
        # re-simulate the blind plan under REAL link costs
        c_links = []
        for i in range(blind.n_stages - 1):
            cut = real_cut[blind.stages[i].layer_end]
            bw = cluster.link_bw(blind.stages[i].cluster_idx,
                                 blind.stages[i + 1].cluster_idx)
            c_links.append(float(cut / bw))
        t_per = [s.t for s in blind.stages]
        counts = h1f1b_counts(t_per, c_links, N_MICROBATCHES)
        res = simulate([s.t_f for s in blind.stages],
                       [s.t_b for s in blind.stages],
                       c_links, N_MICROBATCHES, counts)
        return {"blind_step": res.makespan, "blind_eta": blind.eta}

    rb = cached("fig11b_blind", fn_b)
    joint = cached("fig11a_gran96", lambda: None)
    rows.append({"label": "fig11b/comm_blind_planning",
                 "step_time_s": rb["blind_step"],
                 "derived": f"eta={rb['blind_eta']:.3f}"})
    rows.append({"label": "fig11b/joint_vs_blind", "step_time_s": 0.0,
                 "derived": f"blind is {rb['blind_step'] / joint['t']:.2f}x"
                            " slower (paper: 1.4x-3.3x)"})

    # (c) inter-op-only vs. joint inter+intra search (mixed-efficiency fleet)
    def fn_c():
        cl = hetero_cluster(*INTRA_DIMS)
        for name, effs in INTRA_NODE_EFFS.items():
            cl = set_node_efficiencies(cl, name, effs)
        arch = get_config(INTRA_ARCH)
        ops = build_op_sequence(arch, seq_len=SEQ_LEN)
        layers = build_layers(ops, INTRA_GRAN)
        pcfg = PlannerConfig(granularity=INTRA_GRAN, n_microbatches=INTRA_B,
                             min_submesh_devices=2)
        pcfg.search.n_workers = 6
        hc = api.HarpConfig(seq_len=SEQ_LEN, global_batch=GLOBAL_BATCH,
                            planner=pcfg)
        s_inter = api.plan(arch, cl, hc).strategy
        import dataclasses
        hc_joint = api.HarpConfig(
            seq_len=SEQ_LEN, global_batch=GLOBAL_BATCH,
            planner=dataclasses.replace(pcfg, intra_op=True))
        s_joint = api.plan(arch, cl, hc_joint).strategy
        t_inter = sync_priced_step(s_inter, cl, layers).makespan
        t_joint = sync_priced_step(s_joint, cl, layers).makespan
        tokens = s_joint.tokens_per_step()
        return {"inter_step": t_inter, "joint_step": t_joint,
                "inter_tok_s": tokens / t_inter,
                "joint_tok_s": tokens / t_joint,
                "n_uneven_stages": sum(
                    1 for s in s_joint.stages
                    if s.intra_op is not None and s.intra_op.is_uneven)}

    rc = cached("fig11c_intra", fn_c)
    rows.append({"label": "fig11c/inter_only", "step_time_s": rc["inter_step"],
                 "derived": f"tok/s={rc['inter_tok_s']:.0f}"})
    rows.append({"label": "fig11c/joint_inter_intra",
                 "step_time_s": rc["joint_step"],
                 "derived": f"tok/s={rc['joint_tok_s']:.0f};"
                            f"uneven_stages={rc['n_uneven_stages']}"})
    effs = " ".join(f"{n}={'/'.join(f'{e:g}' for e in v)}"
                    for n, v in INTRA_NODE_EFFS.items())
    rows.append({"label": "fig11c/joint_vs_inter_only", "step_time_s": 0.0,
                 "derived": f"joint {rc['inter_step'] / rc['joint_step']:.2f}x"
                            f" faster on mixed nodes ({effs})"})
    return rows


def main():
    emit_csv(run())


if __name__ == "__main__":
    main()
