"""Paper Fig. 11: (a) layer-granularity ablation {8, 16, 48, fine};
(b) joint-optimization ablation — plan with C(i)=0 (communication-blind),
then evaluate under real link costs (paper: 1.4x-3.3x slowdown)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    GLOBAL_BATCH, N_MICROBATCHES, SEQ_LEN, cached, emit_csv, hetero_cluster,
    plan_hapt,
)
from repro.configs import get_config
from repro.core.dp_search import SearchConfig, search
from repro.core.h1f1b import h1f1b_counts
from repro.core.layering import build_layers
from repro.core.opgraph import build_op_sequence
from repro.core.pipesim import simulate
from repro.core.profiler import ZeroRedundantProfiler

ARCH = "gpt-30b"
DIMS = (2, 8, 2, 8)


def run():
    cluster = hetero_cluster(*DIMS)
    rows = []

    # (a) granularity ablation
    for gran in [8, 16, 48, 96]:
        def fn(g=gran):
            s = plan_hapt(cluster, ARCH, granularity=g)
            return {"t": s.est_step_time, "eta": s.eta,
                    "n_layers": s.planner_meta["granularity"]}
        r = cached(f"fig11a_gran{gran}", fn)
        rows.append({"label": f"fig11a/granularity_{gran}",
                     "step_time_s": r["t"],
                     "derived": f"eta={r['eta']:.3f};L={r['n_layers']}"})
    base = cached("fig11a_gran8", lambda: None)
    fine = cached("fig11a_gran96", lambda: None)
    rows.append({"label": "fig11a/fine_vs_L8_speedup", "step_time_s": 0.0,
                 "derived": f"{base['t'] / fine['t']:.2f}x (paper: 1.2-1.6x)"})

    # (b) joint optimization: plan with C(i)=0, evaluate with real comm
    def fn_b():
        ops = build_op_sequence(get_config(ARCH), seq_len=SEQ_LEN)
        layers = build_layers(ops, 96)
        mb_tokens = GLOBAL_BATCH * SEQ_LEN // N_MICROBATCHES
        prof = ZeroRedundantProfiler(cluster, layers, mb_tokens,
                                     min_submesh_devices=2)
        tables = prof.profile()
        # communication-blind search
        blind_tables = tables
        real_cut = tables.cut_bytes.copy()
        tables.cut_bytes = np.zeros_like(tables.cut_bytes)
        scfg = SearchConfig(n_microbatches=N_MICROBATCHES, n_workers=6)
        blind = search(cluster, tables, mb_tokens, scfg)
        tables.cut_bytes = real_cut
        # re-simulate the blind plan under REAL link costs
        c_links = []
        for i in range(blind.n_stages - 1):
            cut = real_cut[blind.stages[i].layer_end]
            bw = cluster.link_bw(blind.stages[i].cluster_idx,
                                 blind.stages[i + 1].cluster_idx)
            c_links.append(float(cut / bw))
        t_per = [s.t for s in blind.stages]
        counts = h1f1b_counts(t_per, c_links, N_MICROBATCHES)
        res = simulate([s.t_f for s in blind.stages],
                       [s.t_b for s in blind.stages],
                       c_links, N_MICROBATCHES, counts)
        return {"blind_step": res.makespan, "blind_eta": blind.eta}

    rb = cached("fig11b_blind", fn_b)
    joint = cached("fig11a_gran96", lambda: None)
    rows.append({"label": "fig11b/comm_blind_planning",
                 "step_time_s": rb["blind_step"],
                 "derived": f"eta={rb['blind_eta']:.3f}"})
    rows.append({"label": "fig11b/joint_vs_blind", "step_time_s": 0.0,
                 "derived": f"blind is {rb['blind_step'] / joint['t']:.2f}x"
                            " slower (paper: 1.4x-3.3x)"})
    return rows


def main():
    emit_csv(run())


if __name__ == "__main__":
    main()
