"""Serving replay benchmark -> ``BENCH_serve.json`` at repo root.

One entry per run (same append-style as ``BENCH_comm.json``), replaying a
seeded Poisson trace on the fig10 mixed fleet (2x8 A100 + 2x8 V100, 5 Gbps
cross) through two plans at **equal offered QPS**:

- **searched**: the disaggregated prefill/decode placement from
  ``serving.placement.search_placement`` (prefill on the compute-rich
  pools, decode on the KV-capacity-rich ones, handoffs priced over the
  comm subsystem's cross link);
- **colocated**: the placement-unaware baseline — every pool ``mixed``,
  uniform round-robin routing.

Recorded per case: p99/p50 TTFT and TPOT, goodput (output tokens/s of
requests meeting both SLOs), rejections, KV-handoff traffic, and the
p99-TTFT speedup — the acceptance metric.  ``kv_violations`` must be 0 on
both plans (admission control rejects, never OOMs).

``--tiny`` shrinks the trace to CI size (seconds).  ``--fail-on-regression``
exits 1 when the searched plan fails to beat colocated-uniform on p99 TTFT
or violates the KV bound — CI runs this.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit_csv                        # noqa: E402

from repro.configs import get_config                          # noqa: E402
from repro.core.cluster import paper_eval_cluster             # noqa: E402
from repro.serving.batching import simulate_trace             # noqa: E402
from repro.serving.placement import (                         # noqa: E402
    ServingConfig, colocated_plan, search_placement,
)
from repro.serving.workload import poisson_trace              # noqa: E402

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")

ARCH = "gemma-2b"
FLEET = dict(n_a100_nodes=2, n_v100_nodes=2)   # the fig10 mixed fleet


def _scfg(tiny: bool) -> ServingConfig:
    # qps 1600 with 256-token prompts is the queueing-dominated regime where
    # uniform routing saturates the V100 pool's prefill
    duration, sample = (0.25, 100) if tiny else (1.0, 400)
    return ServingConfig(qps=1600.0, duration_s=duration, seed=0,
                         prompt_mean=256, output_mean=64,
                         search_sample=sample)


def _metrics(res) -> Dict:
    s = res.summary()
    return {
        "n_completed": s["n_completed"],
        "n_rejected": s["n_rejected"],
        "p50_ttft_ms": round(s["p50_ttft_s"] * 1e3, 3),
        "p99_ttft_ms": round(s["p99_ttft_s"] * 1e3, 3),
        "p50_tpot_ms": round(s["p50_tpot_s"] * 1e3, 4),
        "p99_tpot_ms": round(s["p99_tpot_s"] * 1e3, 4),
        "goodput_tokens_per_s": round(s["goodput_tokens_per_s"], 1),
        "throughput_tokens_per_s": round(s["throughput_tokens_per_s"], 1),
        "kv_violations": s["kv_violations"],
        "n_handoffs": s["n_handoffs"],
        "handoff_bytes": s["handoff_bytes"],
    }


def run(tiny: bool = False, label: Optional[str] = None) -> Dict:
    cluster = paper_eval_cluster(**FLEET)
    arch = get_config(ARCH)
    scfg = _scfg(tiny)
    trace = poisson_trace(scfg.qps, scfg.duration_s, seed=scfg.seed,
                          prompt_mean=scfg.prompt_mean,
                          output_mean=scfg.output_mean)

    t0 = time.perf_counter()
    best = search_placement(arch, cluster, scfg, trace=trace)
    t_search = time.perf_counter() - t0
    base = colocated_plan(arch, cluster, scfg)

    # the recorded comparison replays the FULL trace (the search scored a
    # search_sample-request prefix) at equal offered QPS
    searched = _metrics(simulate_trace(best, trace))
    colocated = _metrics(simulate_trace(base, trace))

    case = {
        "cluster": cluster.describe(),
        "arch": ARCH,
        "qps": scfg.qps,
        "n_requests": trace.n_requests,
        "prompt_mean": scfg.prompt_mean,
        "output_mean": scfg.output_mean,
        "roles": {p.name: p.role for p in best.pools},
        "routing": best.routing,
        "searched": searched,
        "colocated": colocated,
        "p99_ttft_speedup": round(
            colocated["p99_ttft_ms"] / searched["p99_ttft_ms"], 4)
        if searched["p99_ttft_ms"] > 0 else 0.0,
        "searched_beats_colocated":
            searched["p99_ttft_ms"] < colocated["p99_ttft_ms"],
        "kv_bound_held": searched["kv_violations"] == 0
            and colocated["kv_violations"] == 0,
        "search_seconds": round(t_search, 3),
    }
    return {"label": label or "HEAD",
            "mode": "tiny" if tiny else "full",
            "cases": {"fig10_serve": case}}


def extend_trajectory(entry: Dict, path: str = BENCH_PATH) -> Dict:
    """Append one run to the serving trajectory (creates the file on first
    use)."""
    doc = {"schema": 1,
           "description": "Serving-replay trajectory; one entry per "
                          "benchmarks/serve_replay.py run — see "
                          "docs/serving.md.",
           "runs": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    doc["runs"].append(entry)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return doc


def rows_from_entry(entry: Dict) -> List[Dict]:
    rows = []
    for name, c in entry["cases"].items():
        s, b = c["searched"], c["colocated"]
        rows.append({
            "label": f"{name}.searched",
            "step_time_s": s["p99_ttft_ms"] / 1e3,
            "derived": f"p99_tpot={s['p99_tpot_ms']}ms;"
                       f"goodput={s['goodput_tokens_per_s']};"
                       f"rej={s['n_rejected']}"})
        rows.append({
            "label": f"{name}.colocated",
            "step_time_s": b["p99_ttft_ms"] / 1e3,
            "derived": f"p99_tpot={b['p99_tpot_ms']}ms;"
                       f"goodput={b['goodput_tokens_per_s']};"
                       f"rej={b['n_rejected']}"})
        rows.append({
            "label": f"{name}.speedup",
            "step_time_s": c["search_seconds"],
            "derived": f"p99_ttft_speedup={c['p99_ttft_speedup']}x;"
                       f"roles={'+'.join(f'{k}:{v}' for k, v in sorted(c['roles'].items()))}"})
    return rows


def main() -> None:
    """benchmarks/run.py contract: full measurement, CSV on stdout, one
    trajectory entry appended to BENCH_serve.json."""
    entry = run(tiny=False)
    extend_trajectory(entry)
    emit_csv(rows_from_entry(entry))


def cli(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized trace (seconds, not minutes)")
    ap.add_argument("--label", default=None,
                    help="trajectory entry label (default HEAD)")
    ap.add_argument("--out", default=BENCH_PATH,
                    help="trajectory JSON path (default repo root)")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 unless the searched placement beats "
                         "colocated-uniform on p99 TTFT with the KV bound "
                         "held")
    args = ap.parse_args(argv)

    entry = run(tiny=args.tiny, label=args.label)
    extend_trajectory(entry, args.out)
    emit_csv(rows_from_entry(entry))
    print(f"# trajectory entry appended to {os.path.abspath(args.out)}",
          file=sys.stderr)

    bad = [name for name, c in entry["cases"].items()
           if not (c["searched_beats_colocated"] and c["kv_bound_held"])]
    if bad:
        print(f"# serving placement regressed on: {bad}", file=sys.stderr)
        if args.fail_on_regression:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(cli())
