"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per row (scaffold contract).
Strategy planning results are cached under results/bench_cache/ so re-runs
are fast; delete the cache to re-plan."""
import importlib
import sys
import time
import traceback

MODULES = [
    "benchmarks.table1_case_study",    # Table 1 + Fig. 3 case study
    "benchmarks.fig7_end_to_end",      # Fig. 7 end-to-end vs baselines
    "benchmarks.fig8_breakdown",       # Fig. 8 stage breakdown + eta
    "benchmarks.fig9_homo_vs_hetero",  # Fig. 9 / §6.2
    "benchmarks.fig10_bandwidth",      # Fig. 10 bandwidth sensitivity
    "benchmarks.fig11_ablations",      # Fig. 11 granularity + joint opt
    "benchmarks.search_overhead",      # §6.6 planning overhead; appends a
                                       # run to BENCH_search.json (repo root)
    "benchmarks.comm_bench",           # comm subsystem: algorithm selection,
                                       # compression, contention; appends a
                                       # run to BENCH_comm.json (repo root)
    "benchmarks.serve_replay",         # serving: disaggregated vs colocated
                                       # replay on the fig10 fleet; appends a
                                       # run to BENCH_serve.json (repo root)
    "benchmarks.kbench_bench",         # measured-kernel pricing: autotune
                                       # speedups, interpolation error,
                                       # planner integration; appends a run
                                       # to BENCH_kbench.json (repo root)
    "benchmarks.roofline",             # repo-specific: dry-run roofline
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failures = []
    for name in MODULES:
        if only and only not in name:
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            importlib.import_module(name).main()
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"# ({name}: {time.time() - t0:.1f}s)", flush=True)
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
