"""Elastic runtime replay: scripted disruption on the paper's evaluation
cluster, elastic controller vs. static-plan baseline.

The trace is the canonical fleet-dynamics story: one node of the weakest
sub-cluster fails, the cross link congests, then both recover.  The static
baseline (checkpoint-restart: keep the original plan, wait out infeasible
periods) loses the whole outage; the elastic controller replans — warm-up
retune for the bandwidth shift, incremental DP re-search (warm profiler
tables) for topology changes — and keeps tokens flowing.

  PYTHONPATH=src python benchmarks/elastic_replay.py
"""
from __future__ import annotations

import sys

from common import emit_csv  # noqa: E402  (adds src to sys.path)

from repro.core import paper_eval_cluster                              # noqa: E402
from repro.core.planner import PlannerConfig                           # noqa: E402
from repro.runtime import (                                            # noqa: E402
    ControllerConfig, ElasticController, paper_trace, run_replay,
)

N_STEPS = 200
FAIL_STEP, BW_STEP, RECOVER_STEP = 60, 100, 150


def build_controller(cluster):
    pcfg = PlannerConfig(granularity=24, n_microbatches=32,
                         min_submesh_devices=8)
    ccfg = ControllerConfig(total_steps=N_STEPS, seq_len=1024,
                            global_batch=256)
    return ElasticController(cluster, "gpt-15b", planner_cfg=pcfg, cfg=ccfg)


def main() -> int:
    cluster = paper_eval_cluster()
    trace = paper_trace(cluster, fail_step=FAIL_STEP, bw_step=BW_STEP,
                        recover_step=RECOVER_STEP)
    print(f"# cluster: {cluster.describe()}", file=sys.stderr)
    print(f"# trace:   {trace.describe()}", file=sys.stderr)

    elastic_ctrl = build_controller(cluster)
    elastic_ctrl.bootstrap()
    elastic = run_replay(trace, N_STEPS, controller=elastic_ctrl)

    static_ctrl = build_controller(cluster)
    static_plan = static_ctrl.bootstrap()
    static = run_replay(trace, N_STEPS, strategy=static_plan,
                        plan_cluster=cluster, layers=static_ctrl.layers)

    print("# replan decisions (elastic):", file=sys.stderr)
    for d in elastic_ctrl.decisions:
        print(f"#   {d.describe()}", file=sys.stderr)

    ideal = static_plan.throughput_tokens_per_s()
    rows = []
    for label, res in (("elastic", elastic), ("static", static)):
        post = res.throughput_between(FAIL_STEP, N_STEPS)
        stalled, stall_s = res.recovery_latency(FAIL_STEP)
        rows.append({
            "label": label,
            "post_event_tput_tok_s": post,
            "overall_tput_tok_s": res.throughput(),
            "tokens_lost": res.tokens_lost(ideal),
            "stalled_steps": res.stalled_steps,
            "recovery_after_failure_s": stall_s,
        })
        print(f"# {label}: post-event {post:,.0f} tok/s, overall "
              f"{res.throughput():,.0f} tok/s, lost "
              f"{res.tokens_lost(ideal):,.0f} tokens, "
              f"{res.stalled_steps} stalled steps", file=sys.stderr)

    post_e = rows[0]["post_event_tput_tok_s"]
    post_s = rows[1]["post_event_tput_tok_s"]
    ok = post_e > post_s
    print(f"# elastic > static post-event: {ok} "
          f"({post_e:,.0f} vs {post_s:,.0f} tok/s, "
          f"{post_e / post_s:.2f}x)", file=sys.stderr)

    # scaffold contract: name,us_per_call,derived — us = s/token * 1e6 keeps
    # the column meaningful (microseconds per post-event token)
    emit_csv([{
        "label": r["label"],
        "us_per_tok": 1e6 / r["post_event_tput_tok_s"]
        if r["post_event_tput_tok_s"] else float("inf"),
        "derived": f"overall={r['overall_tput_tok_s']:.0f}tok/s"
        f";stalled={r['stalled_steps']}",
    } for r in rows], us_key="us_per_tok")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
