"""Shared benchmark substrate: paper cluster configs, cached planning,
CSV emission."""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Callable, Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import api                                                 # noqa: E402
from repro.core.cluster import (                                      # noqa: E402
    A100_40G, GBPS, HeteroCluster, SubCluster, V100_32G,
)
from repro.core.planner import PlannerConfig                          # noqa: E402

CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench_cache")
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def hetero_cluster(a_nodes: int, a_per: int, v_nodes: int, v_per: int,
                   cross_gbps: float = 5.0) -> HeteroCluster:
    return HeteroCluster(
        subclusters=(
            SubCluster("A100", a_nodes, a_per, A100_40G, 300e9, 200 * GBPS),
            SubCluster("V100", v_nodes, v_per, V100_32G, 150e9, 200 * GBPS),
        ),
        cross_bw=cross_gbps * GBPS)


# paper Fig. 7 heterogeneous configurations (label -> cluster ctor args)
HETERO_CASES = {
    "hc1_2x4A+4x4V": (2, 4, 4, 4),
    "hc2_1x8A+4x8V": (1, 8, 4, 8),
    "hc3_2x8A+2x8V": (2, 8, 2, 8),
    "hc4_4x8A+4x8V": (4, 8, 4, 8),
}

# model per case (paper scales model with cluster)
CASE_MODEL = {
    "hc1_2x4A+4x4V": "gpt-15b",
    "hc2_1x8A+4x8V": "gpt-15b",
    "hc3_2x8A+2x8V": "gpt-30b",
    "hc4_4x8A+4x8V": "gpt-39b",
}

GLOBAL_BATCH = 1024
SEQ_LEN = 1024
N_MICROBATCHES = 128


def cached(name: str, fn: Callable[[], Dict]) -> Dict:
    os.makedirs(CACHE_DIR, exist_ok=True)
    path = os.path.join(CACHE_DIR, f"{name}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    out = fn()
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    return out


def plan_hapt(cluster: HeteroCluster, arch: str, granularity: int = 96,
              n_microbatches: int = N_MICROBATCHES,
              n_workers: int = 6, min_submesh: int = 2, intra_op: bool = False,
              comm=None):
    """``comm``: None = legacy scalar pricing; a
    ``repro.comm.selector.CommConfig`` = heterogeneity-aware collective
    pricing (the fig10/comm benchmarks pass auto vs. forced-ring configs)."""
    pcfg = PlannerConfig(granularity=granularity,
                         n_microbatches=n_microbatches,
                         min_submesh_devices=min_submesh, intra_op=intra_op,
                         comm=comm)
    pcfg.search.n_workers = n_workers
    # the paper's setting: every device participates (idle-devices-allowed is
    # this repo's extension; measured separately in EXPERIMENTS.md)
    pcfg.search.require_all_devices = True
    cfg = api.HarpConfig(seq_len=SEQ_LEN, global_batch=GLOBAL_BATCH,
                         planner=pcfg)
    try:
        return api.plan(arch, cluster, cfg).strategy
    except (RuntimeError, AssertionError):
        pcfg.search.require_all_devices = False
        return api.plan(arch, cluster, cfg).strategy


def strategy_row(label: str, strat) -> Dict:
    return {
        "label": label,
        "step_time_s": strat.est_step_time,
        "throughput_tok_s": strat.throughput_tokens_per_s(),
        "eta": strat.eta,
        "n_stages": strat.n_stages,
        "warmup_counts": strat.warmup_counts,
        "t_max_ms": strat.t_max * 1e3,
    }


def emit_csv(rows: List[Dict], name_key: str = "label",
             us_key: str = "step_time_s", derived_key: str = "derived"):
    """Scaffold contract: ``name,us_per_call,derived`` CSV on stdout."""
    for r in rows:
        us = r[us_key] * 1e6 if us_key.endswith("_s") else r[us_key]
        print(f"{r[name_key]},{us:.1f},{r.get(derived_key, '')}")
