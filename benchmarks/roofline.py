"""Roofline analysis per (arch x shape x mesh) from the dry-run artifacts.

Terms (seconds, per step, from per-device compiled analyses):
  t_compute    = HLO_FLOPs_per_device / peak_FLOPs
  t_memory     = HLO_bytes_per_device / HBM_bw
  t_collective = collective_bytes_per_device / link_bw

Peak/HBM/link numbers come from the named ``DeviceProfile`` registry in
``repro.core.cluster`` (``--device``, default TPUv5e) rather than hardcoded
constants, so the same analysis reprices for any canonical fleet device.

Also reports MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference) with N = active
params, the useful-compute ratio MODEL_FLOPS / (HLO_FLOPs * chips), and the
roofline fraction = t_compute / max(terms) (attainable MFU bound under the
dominant resource)."""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.common import RESULTS_DIR, emit_csv
from repro.configs import get_config, get_shape
from repro.core.cluster import DEVICE_LINK_BW, DEVICE_PROFILES, DeviceProfile

DEFAULT_DEVICE = "TPUv5e"
CHIPS = {"single": 256, "multi": 512}

# ICI is 4 links/chip on the TPUs; the registry records the aggregate, the
# per-link roofline divides back out.  GPUs use the NVLink aggregate as-is.
_LINKS_PER_CHIP = {"TPUv5e": 4, "TPUv4": 4}


def link_bw(device: DeviceProfile, override_gbps: Optional[float] = None
            ) -> float:
    """Per-link bytes/s for the collective roofline term."""
    if override_gbps is not None:
        return override_gbps * 1e9
    agg = DEVICE_LINK_BW.get(device.name, 50e9)
    return agg / _LINKS_PER_CHIP.get(device.name, 1)


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def load_cells(out_dir: Optional[str] = None) -> List[Dict]:
    out_dir = out_dir or os.path.join(RESULTS_DIR, "dryrun")
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def analyze(rec: Dict, device: Optional[DeviceProfile] = None,
            link_gbps: Optional[float] = None) -> Optional[Dict]:
    if not rec.get("ok") or "flops_per_device" not in rec:
        return None
    device = device or DEVICE_PROFILES[DEFAULT_DEVICE]
    chips = CHIPS[rec["mesh"]]
    t_comp = rec["flops_per_device"] / device.peak_flops
    t_mem = rec["bytes_per_device"] / device.hbm_bw
    t_coll = rec["collective_bytes_per_device"] / link_bw(device, link_gbps)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(rec["flops_per_device"] * chips, 1.0)
    frac = t_comp / max(max(terms.values()), 1e-30)
    # attainable MFU: useful fraction of peak while bound by dominant term
    mfu_bound = (mf / chips / device.peak_flops) / max(terms.values())
    return {
        "label": f'{rec["arch"]}/{rec["shape"]}/{rec["mesh"]}',
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "device": device.name,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "useful_flops_ratio": useful,
        "roofline_fraction": frac,
        "mfu_bound": mfu_bound,
        "peak_mem_gib": rec["memory"]["peak_per_device"] / 2 ** 30,
        "fits_mem": rec["memory"]["peak_per_device"] <= device.mem_bytes,
        "step_time_s": max(terms.values()),
    }


def run(device: Optional[DeviceProfile] = None,
        link_gbps: Optional[float] = None) -> List[Dict]:
    rows = []
    for rec in load_cells():
        if rec.get("mesh") != "single":
            continue  # roofline scope is single-pod (multi = compile proof)
        a = analyze(rec, device=device, link_gbps=link_gbps)
        if a is None:
            status = ("compile-only" if rec.get("ok")
                      else f"FAIL:{rec.get('error', '?')[:60]}")
            rows.append({"label": f'{rec["arch"]}/{rec["shape"]}/{rec["mesh"]}',
                         "step_time_s": 0.0, "derived": status})
            continue
        a["derived"] = (f"dom={a['dominant']};mfu_bound={a['mfu_bound']:.2f};"
                        f"useful={a['useful_flops_ratio']:.2f};"
                        f"mem={a['peak_mem_gib']:.1f}GiB"
                        f"{'' if a['fits_mem'] else '(OVER)'}")
        rows.append(a)
    return rows


def table(device: Optional[DeviceProfile] = None,
          link_gbps: Optional[float] = None) -> str:
    """Markdown roofline table for EXPERIMENTS.md."""
    lines = ["| arch | shape | mesh | t_comp (ms) | t_mem (ms) | t_coll (ms) |"
             " dominant | useful | MFU bound | mem GiB |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in run(device=device, link_gbps=link_gbps):
        if "dominant" not in r:
            lines.append(f"| {r['label']} | | | | | | FAIL | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s'] * 1e3:.1f} | {r['t_memory_s'] * 1e3:.1f} "
            f"| {r['t_collective_s'] * 1e3:.1f} | {r['dominant']} "
            f"| {r['useful_flops_ratio']:.2f} | {r['mfu_bound']:.2f} "
            f"| {r['peak_mem_gib']:.1f}{'' if r['fits_mem'] else ' (!)'} |")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--device", default=DEFAULT_DEVICE,
                    choices=sorted(DEVICE_PROFILES),
                    help="DeviceProfile whose peak/HBM/link specs price the "
                         "roofline terms")
    ap.add_argument("--link-gbps", type=float, default=None,
                    help="override the per-link bandwidth (GB/s)")
    args = ap.parse_args(argv)
    emit_csv(run(device=DEVICE_PROFILES[args.device],
                 link_gbps=args.link_gbps))


if __name__ == "__main__":
    main()
