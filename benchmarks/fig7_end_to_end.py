"""Paper Fig. 7: end-to-end training latency, HAPT vs baselines, across
heterogeneous configurations (5 Gbps cross-cluster).

Baselines (all on the same cost model + simulator, isolating the planning/
scheduling deltas exactly like the paper):
  uniform-1f1b  (Megatron-like) — may FAIL on irregular clusters (Fig. 7a);
  coarse-eager  (Alpa-like, #L=8);
  coarse-sync   (HexiScale-like, #L=48, no overlap).
Paper claim: HAPT 1.3x-1.6x over the best baseline (HexiScale)."""
from __future__ import annotations

from benchmarks.common import (
    CASE_MODEL, GLOBAL_BATCH, HETERO_CASES, N_MICROBATCHES, SEQ_LEN,
    cached, emit_csv, hetero_cluster, plan_hapt, strategy_row,
)
from repro.configs import get_config
from repro.core.baselines import (
    plan_blind_eager, plan_coarse, plan_coarse_sync, plan_uniform,
)


def run():
    rows = []
    for case, dims in HETERO_CASES.items():
        arch = CASE_MODEL[case]
        cluster = hetero_cluster(*dims)

        def bench():
            out = {}
            hapt = plan_hapt(cluster, arch)
            out["hapt"] = strategy_row(f"{case}/{arch}/hapt", hapt)
            try:
                u = plan_uniform(cluster, get_config(arch), seq_len=SEQ_LEN,
                                 global_batch=GLOBAL_BATCH,
                                 n_microbatches=N_MICROBATCHES)
                out["uniform"] = strategy_row(f"{case}/{arch}/uniform-1f1b", u)
            except (ValueError, RuntimeError) as e:
                out["uniform"] = {"label": f"{case}/{arch}/uniform-1f1b",
                                  "step_time_s": float("inf"),
                                  "error": str(e)}
            be = plan_blind_eager(cluster, get_config(arch), seq_len=SEQ_LEN,
                                  global_batch=GLOBAL_BATCH,
                                  n_microbatches=N_MICROBATCHES,
                                  min_submesh_devices=2)
            out["blind_eager"] = strategy_row(f"{case}/{arch}/blind-eager", be)
            ce = plan_coarse(cluster, get_config(arch), seq_len=SEQ_LEN,
                             global_batch=GLOBAL_BATCH,
                             n_microbatches=N_MICROBATCHES,
                             min_submesh_devices=2)
            out["coarse_eager"] = strategy_row(
                f"{case}/{arch}/coarse-eager(ablation)", ce)
            cs = plan_coarse_sync(cluster, get_config(arch), seq_len=SEQ_LEN,
                                  global_batch=GLOBAL_BATCH,
                                  n_microbatches=N_MICROBATCHES,
                                  min_submesh_devices=2)
            out["coarse_sync"] = strategy_row(f"{case}/{arch}/coarse-sync", cs)
            return out

        res = cached(f"fig7_{case}", bench)
        hapt_t = res["hapt"]["step_time_s"]
        # paper baselines only (coarse_eager is OUR scheduler ablation)
        best_base = min(v["step_time_s"] for k, v in res.items()
                        if k in ("uniform", "blind_eager", "coarse_sync"))
        for k, v in res.items():
            v = dict(v)
            t = v["step_time_s"]
            v["derived"] = ("baseline" if k != "hapt" else
                            f"speedup_vs_best_baseline={best_base / hapt_t:.2f}x")
            if t == float("inf"):
                v["step_time_s"] = 0.0
                v["derived"] = "UNSUPPORTED-CONFIG"
            rows.append(v)
    return rows


def main():
    emit_csv(run())


if __name__ == "__main__":
    main()
