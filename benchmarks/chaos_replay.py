"""Chaos replay benchmark -> ``BENCH_chaos.json`` at repo root.

The same seeded fault storm (``repro.chaos.chaos_storm``: flapping nodes,
rack failures, preemptions, stragglers, WAN brownouts) plus injected
planner faults (timeouts / infeasible returns) is folded through two
controllers on the paper's case-study fleet:

- **hardened**: event debounce + replan hysteresis, the degraded-mode
  ladder (cached plan -> pool drop -> half batch -> checkpoint-restart),
  restart retries.  Contract: completes the whole horizon with zero
  uncaught exceptions and never commits a strategy referencing a removed
  node.
- **unhardened**: the PR-8 controller semantics (``degraded_ladder=False``,
  no debounce) under the *same* storm and fault stream.  A planner fault on
  a forced replan is an uncaught exception: the job dies and earns zero
  tokens for the rest of the horizon (the clock keeps running) — the real
  cost of an unhardened controller in production.

Both runs use the same simplified goodput fold (``project_step`` per step,
``downtime_s`` charged per decision, stalls at the last step time), so the
comparison isolates the hardening, not the accounting.

The acceptance axes (gated under ``--fail-on-regression``):

1. **hardened is crash-free**: the full storm replays with zero uncaught
   exceptions;
2. **no dead-node commits**: after every decision the committed strategy's
   mesh footprint fits the live fleet (``feasible_under``);
3. **hardening pays**: hardened goodput-under-churn strictly exceeds the
   unhardened baseline's;
4. **storm control**: committed replans < storm events (flapping and event
   bursts coalesce instead of each costing a replan).

``--tiny`` shrinks the horizon to CI size.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit_csv                        # noqa: E402

from repro.chaos import ChaosConfig, FaultInjector, chaos_storm  # noqa: E402
from repro.core.cluster import paper_case_study_cluster       # noqa: E402
from repro.core.planner import PlannerConfig                  # noqa: E402
from repro.runtime.controller import (                        # noqa: E402
    ControllerConfig, ElasticController,
)
from repro.runtime.events import EventTrace                   # noqa: E402
from repro.runtime.replay import feasible_under, project_step  # noqa: E402

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_chaos.json")

ARCH = "gpt-2b"
SEQ_LEN = 256
GLOBAL_BATCH = 32
# seed 5 puts plan-breaking node failures inside even the tiny horizon —
# the storm must actually break the plan for the comparison to mean anything
STORM_SEED = 5
STORM_INTENSITY = 2.0
CHAOS = ChaosConfig(seed=0, p_planner_timeout=0.3,
                    p_planner_infeasible=0.3, planner_timeout_s=1.0)


def _pcfg() -> PlannerConfig:
    return PlannerConfig(granularity=8, n_microbatches=8,
                         min_submesh_devices=2)


def _controller(n_steps: int, *, hardened: bool) -> ElasticController:
    cfg = ControllerConfig(
        total_steps=n_steps, seq_len=SEQ_LEN, global_batch=GLOBAL_BATCH,
        debounce_steps=3 if hardened else 0,
        min_steps_between_replans=5 if hardened else 0,
        replan_deadline_s=2.0 if hardened else 0.0,
        degraded_ladder=hardened)
    ctrl = ElasticController(
        paper_case_study_cluster(), ARCH, planner_cfg=_pcfg(), cfg=cfg)
    ctrl.bootstrap()
    # the injector arms AFTER bootstrap: the storm hits a healthy running
    # job, not the initial planning (which both variants need to survive)
    ctrl.injector = FaultInjector(CHAOS)
    return ctrl


def storm_fold(trace: EventTrace, n_steps: int,
               ctrl: ElasticController) -> Dict:
    """Fold the storm through ``ctrl`` step by step, checking the dead-node
    invariant after every decision.  An uncaught exception kills the job:
    zero tokens for the remaining horizon while the clock keeps running."""
    by_step: Dict[int, List] = {}
    for e in trace.events:
        by_step.setdefault(e.step, []).append(e)
    tokens = 0
    wall = 0.0
    stalled = 0
    violations: List[int] = []
    decisions = []
    crash = None
    last_step = ctrl.strategy.est_step_time

    def check(step: int) -> None:
        if ctrl.strategy is not None and not feasible_under(
                ctrl.strategy, ctrl.plan_cluster, ctrl.cluster):
            violations.append(step)

    for step in range(n_steps):
        for ev in by_step.get(step, ()):
            try:
                d = ctrl.handle(ev, step=step)
            except Exception as exc:               # noqa: BLE001 — the point
                crash = {"step": step,
                         "error": f"{type(exc).__name__}: {exc}"}
                break
            decisions.append(d)
            wall += d.downtime_s
            check(step)
        if crash is not None:
            break
        d = ctrl.poll(step)
        if d is not None:
            decisions.append(d)
            wall += d.downtime_s
            check(step)
        if ctrl.strategy is None:                  # checkpoint-restart stall
            stalled += 1
            wall += last_step
            continue
        sim = project_step(ctrl.strategy, ctrl.plan_cluster, ctrl.cluster,
                           ctrl.layers)
        if sim is not None:
            last_step = sim.makespan
        wall += last_step
        tokens += ctrl.strategy.tokens_per_step()
    if crash is not None:
        wall += (n_steps - crash["step"]) * last_step
    replans = sum(1 for d in decisions
                  if d.action not in ("none", "deferred", "ignored"))
    downtime = sum(d.downtime_s for d in decisions)
    degraded = sum(1 for d in decisions if d.action.startswith("degraded")
                   or d.action in ("checkpoint_restart", "restart"))
    return {
        "tokens": int(tokens),
        "wall_s": round(wall, 3),
        "goodput_tokens_per_s": round(tokens / wall, 1) if wall else 0.0,
        "replans": replans,
        "degraded_actions": degraded,
        "recovery_s": round(downtime, 3),
        "stalled_steps": stalled,
        "dead_node_commits": len(violations),
        "crash": crash,
        "injected_faults": ctrl.injector.stats(),
    }


def run(tiny: bool = False, label: Optional[str] = None) -> Dict:
    n_steps = 60 if tiny else 240
    cluster = paper_case_study_cluster()
    trace = chaos_storm(cluster, n_steps, seed=STORM_SEED,
                        intensity=STORM_INTENSITY)

    t0 = time.perf_counter()
    hardened = storm_fold(trace, n_steps, _controller(n_steps, hardened=True))
    unhardened = storm_fold(trace, n_steps,
                            _controller(n_steps, hardened=False))
    wall_s = time.perf_counter() - t0

    case = {
        "cluster": cluster.describe(),
        "arch": ARCH,
        "n_steps": n_steps,
        "storm_seed": STORM_SEED,
        "storm_intensity": STORM_INTENSITY,
        "storm_events": len(trace.events),
        "chaos": CHAOS.to_dict(),
        "hardened": hardened,
        "unhardened": unhardened,
        "hardened_crash_free": hardened["crash"] is None,
        "zero_dead_node_commits": hardened["dead_node_commits"] == 0,
        "hardened_beats_unhardened":
            hardened["goodput_tokens_per_s"]
            > unhardened["goodput_tokens_per_s"],
        "storm_controlled": hardened["replans"] < max(1, len(trace.events)),
        "bench_seconds": round(wall_s, 3),
    }
    return {"label": label or "HEAD",
            "mode": "tiny" if tiny else "full",
            "cases": {"chaos_storm": case}}


def extend_trajectory(entry: Dict, path: str = BENCH_PATH) -> Dict:
    """Append one run to the chaos trajectory (creates the file on first
    use)."""
    doc = {"schema": 1,
           "description": "Chaos-replay trajectory; one entry per "
                          "benchmarks/chaos_replay.py run — see "
                          "docs/chaos.md.",
           "runs": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    doc["runs"].append(entry)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return doc


def rows_from_entry(entry: Dict) -> List[Dict]:
    rows = []
    for name, c in entry["cases"].items():
        for variant in ("hardened", "unhardened"):
            v = c[variant]
            rows.append({
                "label": f"{name}.{variant}",
                "step_time_s": v["recovery_s"],
                "derived": f"goodput={v['goodput_tokens_per_s']};"
                           f"replans={v['replans']};"
                           f"stalled={v['stalled_steps']};"
                           f"crashed={v['crash'] is not None}"})
    return rows


def main() -> None:
    """benchmarks/run.py contract: full measurement, CSV on stdout, one
    trajectory entry appended to BENCH_chaos.json."""
    entry = run(tiny=False)
    extend_trajectory(entry)
    emit_csv(rows_from_entry(entry))


def cli(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized horizon (seconds, not minutes)")
    ap.add_argument("--label", default=None,
                    help="trajectory entry label (default HEAD)")
    ap.add_argument("--out", default=BENCH_PATH,
                    help="trajectory JSON path (default repo root)")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 unless the hardened replay is crash-free "
                         "with zero dead-node commits, beats the unhardened "
                         "baseline on goodput, and coalesces the storm into "
                         "fewer replans than events")
    args = ap.parse_args(argv)

    entry = run(tiny=args.tiny, label=args.label)
    extend_trajectory(entry, args.out)
    emit_csv(rows_from_entry(entry))
    print(f"# trajectory entry appended to {os.path.abspath(args.out)}",
          file=sys.stderr)

    bad = [name for name, c in entry["cases"].items()
           if not (c["hardened_crash_free"] and c["zero_dead_node_commits"]
                   and c["hardened_beats_unhardened"]
                   and c["storm_controlled"])]
    if bad:
        print(f"# chaos replay regressed on: {bad}", file=sys.stderr)
        if args.fail_on_regression:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(cli())
