"""Paper Fig. 9 / §6.2: HAPT on a heterogeneous cluster vs Megatron-like
planning on a homogeneous cluster of comparable peak FLOP/s (paper: HAPT
sustains ~83% of homogeneous-Megatron throughput at 93% of its peak, despite
the 5 Gbps cross-link)."""
from __future__ import annotations

from benchmarks.common import (
    GLOBAL_BATCH, N_MICROBATCHES, SEQ_LEN, cached, emit_csv, hetero_cluster,
    plan_hapt, strategy_row,
)
from repro.configs import get_config
from repro.core.baselines import plan_uniform
from repro.core.cluster import A100_40G, GBPS, HeteroCluster, SubCluster

ARCH = "gpt-30b"


def run():
    # hetero: 2x8 A100 + 2x8 V100 = 2x8x312 + 2x8x125 = 6.99 PFLOP/s
    het = hetero_cluster(2, 8, 2, 8, cross_gbps=5.0)
    # homo: 3x8 A100 fully-connected 200 Gbps = 7.49 PFLOP/s (het = 93.3%)
    homo = HeteroCluster(
        subclusters=(SubCluster("A100", 3, 8, A100_40G, 300e9, 200 * GBPS),),
        cross_bw=200 * GBPS)
    ratio = het.peak_flops / homo.peak_flops

    def bench():
        h = plan_hapt(het, ARCH)
        try:
            m = plan_uniform(homo, get_config(ARCH), seq_len=SEQ_LEN,
                             global_batch=GLOBAL_BATCH,
                             n_microbatches=N_MICROBATCHES)
            m_row = strategy_row("homo-3x8A100/uniform-1f1b", m)
        except ValueError:
            m_row = None
        hm = plan_hapt(homo, ARCH)
        return {"het": strategy_row("hetero-2x8A+2x8V/hapt", h),
                "homo_uniform": m_row,
                "homo_hapt": strategy_row("homo-3x8A100/hapt", hm),
                "peak_ratio": ratio}

    res = cached("fig9", bench)
    rows = []
    het_tput = res["het"]["throughput_tok_s"]
    ref = res["homo_uniform"] or res["homo_hapt"]
    sustained = het_tput / ref["throughput_tok_s"]
    normalized = sustained / res["peak_ratio"]
    for key in ("het", "homo_uniform", "homo_hapt"):
        if res.get(key):
            r = dict(res[key])
            r["derived"] = ""
            rows.append(r)
    rows.append({"label": "hetero_sustained_fraction", "step_time_s": 0.0,
                 "derived": f"{sustained * 100:.1f}% of homogeneous at "
                            f"{res['peak_ratio'] * 100:.0f}% peak "
                            f"(normalized {normalized * 100:.1f}%; paper ~83%)"})
    return rows


def main():
    emit_csv(run())


if __name__ == "__main__":
    main()
