"""kbench-subsystem trajectory benchmark -> ``BENCH_kbench.json`` at repo root.

One entry per run (same append-style as ``BENCH_comm.json``), recording what
measured-kernel pricing buys and that its invariants hold:

- **autotune**: block-size sweeps over every op in the harness registry —
  winner-vs-default speedup per op (>= 1.0 by construction: the default
  config is a sweep member and the winner is the argmin over the same
  measurements);
- **price_error**: the table's nearest-bucket + FLOP-ratio interpolation
  priced against a *fresh* measurement at a shape the table never saw —
  the honest "how wrong is the measured cost model off-grid" number;
- **planner**: a synthetic hardware table (plausible A100/V100 achieved
  throughputs) changes the DP search's stage prices vs. the analytic model,
  while an EMPTY table prices bit-identically to ``kbench=None`` (fallback
  invariant) without erroring.

``--tiny`` keeps collection interpret-mode/CI-sized (it already is; the flag
also shrinks trials).  ``--fail-on-regression`` exits 1 when any autotune
speedup dips below 1.0, the empty-table fallback diverges from analytic, or
the synthetic table fails to move prices — CI runs this.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit_csv                        # noqa: E402

from repro import api                                         # noqa: E402
from repro.core.cluster import (                              # noqa: E402
    DEVICE_PROFILES, paper_case_study_cluster,
)
from repro.core.planner import PlannerConfig                  # noqa: E402
from repro.kbench.bridge import KBenchConfig                  # noqa: E402
from repro.kbench.table import (                              # noqa: E402
    KernelMeasurement, LatencyTable,
)

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_kbench.json")

ARCH = "gpt-2b"

# off-bucket query shapes for the interpolation-error case (dims chosen so
# the power-of-two bucket differs from the collected tiny shapes)
PERTURBED = {
    "flash_attention": (1, 192, 192, 2, 2, 32),
    "rmsnorm": (384, 128),
    "ssd_intra": (1, 3, 96, 2, 32, 32),
}

# plausible achieved FLOP/s for the synthetic hardware table (order of the
# published MFU sweet spots; the point is "changes prices", not accuracy)
SYNTH_ACHIEVED = {"A100-40G": 140e12, "V100-32G": 45e12}


def _harp_cfg(kbench: Optional[KBenchConfig]) -> api.HarpConfig:
    return api.HarpConfig(
        seq_len=512, global_batch=16,
        planner=PlannerConfig(granularity=16, n_microbatches=16,
                              kbench=kbench))


def synthetic_table() -> LatencyTable:
    """Hardware-shaped cells keyed directly by DeviceProfile names."""
    from repro.kbench import harness

    table = LatencyTable()
    for dev, achieved in SYNTH_ACHIEVED.items():
        for op, spec in harness.OPS.items():
            shape = spec.default_shape
            flops = spec.flops(shape)
            table.add(KernelMeasurement(
                device=dev, op=op, shape=shape,
                median_s=flops / achieved, trials=5, flops=flops,
                blocks=spec.default_blocks, collected_at=1.7e9,
                host="synthetic"))
    return table


def run(tiny: bool = False, label: Optional[str] = None) -> Dict:
    from repro.kbench import autotune, harness

    trials, warmup = (2, 1) if tiny else (5, 2)

    # -- autotune: sweep every op's block grid at the tiny shapes ----------
    t0 = time.perf_counter()
    table, sweeps = autotune.collect_autotuned(
        None, shapes="tiny", trials=trials, warmup=warmup)
    collect_s = time.perf_counter() - t0
    device = harness.device_fingerprint()
    autotune_case = {
        "device": device,
        "cells": len(table),
        "collect_seconds": round(collect_s, 3),
        "ops": {sw.op: {
            "shape": list(sw.shape),
            "best_blocks": None if sw.best_blocks is None
            else list(sw.best_blocks),
            "best_s": sw.best_s, "default_s": sw.default_s,
            "speedup": round(sw.speedup, 4),
        } for sw in sweeps},
        "all_speedups_ok": all(
            sw.speedup >= 1.0 and sw.speedup == sw.speedup for sw in sweeps),
    }

    # -- price_error: interpolated estimate vs. fresh off-bucket truth -----
    errors = {}
    for op, shape in PERTURBED.items():
        spec = harness.OPS[op]
        est = table.estimate_s(device, op, shape, flops=spec.flops(shape))
        res = harness.bench_op(op, shape, blocks=None, trials=trials,
                               warmup=warmup)
        errors[op] = {
            "shape": list(shape),
            "estimate_s": est, "measured_s": res.median_s,
            "rel_error": (None if not est
                          else round(abs(est - res.median_s) / res.median_s,
                                     4)),
        }
    finite = [e["rel_error"] for e in errors.values()
              if e["rel_error"] is not None]
    price_case = {
        "per_op": errors,
        "mean_rel_error": (round(sum(finite) / len(finite), 4)
                           if finite else None),
        "all_covered": all(e["rel_error"] is not None
                           for e in errors.values()),
    }

    # -- planner: measured pricing moves the search, empty table doesn't --
    cluster = paper_case_study_cluster()
    analytic = api.compile(ARCH, cluster, _harp_cfg(None))
    synth_cfg = KBenchConfig(table=synthetic_table().to_dict())
    measured = api.compile(ARCH, cluster, _harp_cfg(synth_cfg))
    empty = api.compile(ARCH, cluster,
                        _harp_cfg(KBenchConfig(table=LatencyTable().to_dict())))
    # a table covering NO fleet device must also fall through cleanly
    alien = LatencyTable([KernelMeasurement(
        device="tpu:uncovered", op="rmsnorm", shape=(256, 128),
        median_s=1e-4, trials=1, flops=4.0 * 256 * 128, blocks=None,
        collected_at=1.7e9, host="synthetic")])
    uncovering = api.compile(ARCH, cluster,
                             _harp_cfg(KBenchConfig(table=alien.to_dict())))

    def stage_times(exe):
        return [s.t for s in exe.strategy.stages]

    planner_case = {
        "analytic_step_s": analytic.strategy.est_step_time,
        "measured_step_s": measured.strategy.est_step_time,
        "measured_vs_analytic": round(
            measured.strategy.est_step_time
            / analytic.strategy.est_step_time, 4),
        "synthetic_mfu": {
            dev: round(SYNTH_ACHIEVED[dev] / DEVICE_PROFILES[dev].peak_flops,
                       4)
            for dev in SYNTH_ACHIEVED},
        "measured_changes_prices":
            stage_times(measured) != stage_times(analytic),
        "empty_matches_analytic":
            stage_times(empty) == stage_times(analytic)
            and empty.strategy.est_step_time
            == analytic.strategy.est_step_time,
        "uncovering_matches_analytic":
            stage_times(uncovering) == stage_times(analytic),
    }

    return {"label": label or "HEAD",
            "mode": "tiny" if tiny else "full",
            "cases": {"autotune": autotune_case,
                      "price_error": price_case,
                      "planner": planner_case}}


def extend_trajectory(entry: Dict, path: str = BENCH_PATH) -> Dict:
    """Append one run to the kbench trajectory (creates the file on first
    use)."""
    doc = {"schema": 1,
           "description": "kbench-subsystem trajectory; one entry per "
                          "benchmarks/kbench_bench.py run — see "
                          "docs/kbench.md.",
           "runs": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    doc["runs"].append(entry)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return doc


def rows_from_entry(entry: Dict) -> List[Dict]:
    c = entry["cases"]
    rows = []
    for op, r in c["autotune"]["ops"].items():
        rows.append({
            "label": f"autotune.{op}",
            "step_time_s": r["best_s"],
            "derived": f"default={r['default_s']:.2e}s;"
                       f"speedup={r['speedup']}x;"
                       f"blocks={r['best_blocks']}"})
    rows.append({
        "label": "price_error",
        "step_time_s": 0.0,
        "derived": f"mean_rel_error={c['price_error']['mean_rel_error']};"
                   f"covered={c['price_error']['all_covered']}"})
    rows.append({
        "label": "planner.measured",
        "step_time_s": c["planner"]["measured_step_s"],
        "derived": f"analytic={c['planner']['analytic_step_s']:.3f}s;"
                   f"ratio={c['planner']['measured_vs_analytic']};"
                   f"fallback_ok={c['planner']['empty_matches_analytic']}"})
    return rows


def gates(entry: Dict) -> List[str]:
    """Names of the invariants this entry violates (empty = healthy)."""
    c = entry["cases"]
    bad = []
    if not c["autotune"]["all_speedups_ok"]:
        bad.append("autotune_speedup_below_1")
    if not c["planner"]["empty_matches_analytic"]:
        bad.append("empty_table_diverges_from_analytic")
    if not c["planner"]["uncovering_matches_analytic"]:
        bad.append("uncovering_table_diverges_from_analytic")
    if not c["planner"]["measured_changes_prices"]:
        bad.append("synthetic_table_did_not_move_prices")
    if not c["price_error"]["all_covered"]:
        bad.append("interpolation_missed_a_recorded_op")
    return bad


def main() -> None:
    """benchmarks/run.py contract: full measurement, CSV on stdout, one
    trajectory entry appended to BENCH_kbench.json."""
    entry = run(tiny=False)
    extend_trajectory(entry)
    emit_csv(rows_from_entry(entry))


def cli(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized trial counts (interpret mode is automatic "
                         "off-TPU)")
    ap.add_argument("--label", default=None,
                    help="trajectory entry label (default HEAD)")
    ap.add_argument("--out", default=BENCH_PATH,
                    help="trajectory JSON path (default repo root)")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 when a kbench invariant breaks (speedup "
                         "< 1, fallback divergence, prices unmoved)")
    args = ap.parse_args(argv)

    entry = run(tiny=args.tiny, label=args.label)
    extend_trajectory(entry, args.out)
    emit_csv(rows_from_entry(entry))
    print(f"# trajectory entry appended to {os.path.abspath(args.out)}",
          file=sys.stderr)

    bad = gates(entry)
    if bad:
        print(f"# kbench invariants violated: {bad}", file=sys.stderr)
        if args.fail_on_regression:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(cli())
