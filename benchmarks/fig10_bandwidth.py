"""Paper Fig. 10: sensitivity to cross-cluster bandwidth (3-10 Gbps).

HAPT's step time should stay ~flat until c approaches t_max (paper: knee at
~3 Gbps), while the no-overlap baseline degrades ~1/bandwidth.

Comm-aware rows (``hapt_comm`` / ``hapt_comm_ring``) re-run the joint search
under ``repro.comm``'s selected-algorithm pricing vs. a forced flat ring:
at the 3 Gbps knee the auto-selected two-level hierarchical gradient sync is
the acceptance case — the selected plan must beat the forced ring's.
(Results are cached; delete results/bench_cache/fig10_* to regenerate on the
current pricing.)"""
from __future__ import annotations

from benchmarks.common import (
    CASE_MODEL, GLOBAL_BATCH, N_MICROBATCHES, SEQ_LEN, cached, emit_csv,
    hetero_cluster, plan_hapt,
)
from repro.comm.selector import CommConfig
from repro.configs import get_config
from repro.core.baselines import plan_coarse, plan_coarse_sync

ARCH = "gpt-30b"
DIMS = (2, 8, 2, 8)
BWS = [3, 4, 5, 7, 10]


def _sync_algos(strategy) -> str:
    algos = {s.intra_op.sync_algo for s in strategy.stages
             if s.intra_op is not None and s.dp > 1}
    return "+".join(sorted(a or "ring*" for a in algos)) or "-"


def run():
    rows = []
    for bw in BWS:
        cluster = hetero_cluster(*DIMS, cross_gbps=bw)

        def bench(bw=bw, cluster=cluster):
            h = plan_hapt(cluster, ARCH)
            hc = plan_hapt(cluster, ARCH, intra_op=True, comm=CommConfig())
            hr = plan_hapt(cluster, ARCH, intra_op=True,
                           comm=CommConfig(algorithms=("ring",)))
            cs = plan_coarse_sync(cluster, get_config(ARCH), seq_len=SEQ_LEN,
                                  global_batch=GLOBAL_BATCH,
                                  n_microbatches=N_MICROBATCHES,
                                  min_submesh_devices=2)
            ce = plan_coarse(cluster, get_config(ARCH), seq_len=SEQ_LEN,
                             global_batch=GLOBAL_BATCH,
                             n_microbatches=N_MICROBATCHES,
                             min_submesh_devices=2)
            return {"hapt": h.est_step_time,
                    "hapt_comm": hc.est_step_time,
                    "hapt_comm_ring": hr.est_step_time,
                    "sync": cs.est_step_time,
                    "eager": ce.est_step_time,
                    "hapt_counts": h.warmup_counts,
                    "comm_sync_algos": _sync_algos(hc)}

        r = cached(f"fig10_bw{bw}", bench)
        for sysname in ("hapt", "hapt_comm", "hapt_comm_ring", "eager",
                        "sync"):
            if sysname not in r:
                continue    # pre-comm cache entry; delete it to regenerate
            derived = ""
            if sysname == "hapt":
                derived = f"counts={r['hapt_counts']}"
            elif sysname == "hapt_comm":
                derived = f"sync={r.get('comm_sync_algos', '?')}"
            rows.append({"label": f"bw{bw}gbps/{sysname}",
                         "step_time_s": r[sysname],
                         "derived": derived})
    # degradation ratios 10 -> 3 Gbps
    r10 = cached("fig10_bw10", lambda: None)
    r3 = cached("fig10_bw3", lambda: None)
    rows.append({
        "label": "degradation_10to3gbps", "step_time_s": 0.0,
        "derived": f"hapt={r3['hapt'] / r10['hapt']:.2f}x;"
                   f"sync={r3['sync'] / r10['sync']:.2f}x (paper: hapt ~flat,"
                   " sync ~1/bw)"})
    if "hapt_comm" in r3:
        rows.append({
            "label": "comm_selected_vs_ring_3gbps", "step_time_s": 0.0,
            "derived": f"auto={r3['hapt_comm']:.3f}s<"
                       f"ring={r3['hapt_comm_ring']:.3f}s;"
                       f"algos={r3['comm_sync_algos']}"})
    return rows


def main():
    emit_csv(run())


if __name__ == "__main__":
    main()
