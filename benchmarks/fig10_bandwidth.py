"""Paper Fig. 10: sensitivity to cross-cluster bandwidth (3-10 Gbps).

HAPT's step time should stay ~flat until c approaches t_max (paper: knee at
~3 Gbps), while the no-overlap baseline degrades ~1/bandwidth."""
from __future__ import annotations

from benchmarks.common import (
    CASE_MODEL, GLOBAL_BATCH, N_MICROBATCHES, SEQ_LEN, cached, emit_csv,
    hetero_cluster, plan_hapt,
)
from repro.configs import get_config
from repro.core.baselines import plan_coarse, plan_coarse_sync

ARCH = "gpt-30b"
DIMS = (2, 8, 2, 8)
BWS = [3, 4, 5, 7, 10]


def run():
    rows = []
    for bw in BWS:
        cluster = hetero_cluster(*DIMS, cross_gbps=bw)

        def bench(bw=bw, cluster=cluster):
            h = plan_hapt(cluster, ARCH)
            cs = plan_coarse_sync(cluster, get_config(ARCH), seq_len=SEQ_LEN,
                                  global_batch=GLOBAL_BATCH,
                                  n_microbatches=N_MICROBATCHES,
                                  min_submesh_devices=2)
            ce = plan_coarse(cluster, get_config(ARCH), seq_len=SEQ_LEN,
                             global_batch=GLOBAL_BATCH,
                             n_microbatches=N_MICROBATCHES,
                             min_submesh_devices=2)
            return {"hapt": h.est_step_time, "sync": cs.est_step_time,
                    "eager": ce.est_step_time,
                    "hapt_counts": h.warmup_counts}

        r = cached(f"fig10_bw{bw}", bench)
        for sysname in ("hapt", "eager", "sync"):
            rows.append({"label": f"bw{bw}gbps/{sysname}",
                         "step_time_s": r[sysname],
                         "derived": f"counts={r['hapt_counts']}"
                         if sysname == "hapt" else ""})
    # degradation ratios 10 -> 3 Gbps
    r10 = cached("fig10_bw10", lambda: None)
    r3 = cached("fig10_bw3", lambda: None)
    rows.append({
        "label": "degradation_10to3gbps", "step_time_s": 0.0,
        "derived": f"hapt={r3['hapt'] / r10['hapt']:.2f}x;"
                   f"sync={r3['sync'] / r10['sync']:.2f}x (paper: hapt ~flat,"
                   " sync ~1/bw)"})
    return rows


def main():
    emit_csv(run())


if __name__ == "__main__":
    main()
