"""Paper Table 1 + Fig. 3: the §2.2.2 case study.

GPT on DeviceMesh_A100(2,2) + DeviceMesh_V100(1,2), 5 Gbps cross-link.
Coarse (#L=8) vs fine (#L~128) inter-op planning; classic vs Eager vs H-1F1B
scheduling.  The paper reports ~40% throughput gain from fine granularity
(assuming full overlap) and bubble-free steady phase under the tailored
schedule (Fig. 3d)."""
from __future__ import annotations

from benchmarks.common import cached, emit_csv, strategy_row
from repro import api
from repro.core import paper_case_study_cluster
from repro.core.h1f1b import classic_1f1b_counts, eager_1f1b_counts, h1f1b_counts
from repro.core.pipesim import ascii_timeline, simulate
from repro.core.planner import PlannerConfig

ARCH = "gpt-2b"   # the 6-GPU case-study cluster bounds the model scale
B = 128


def _plan(granularity: int):
    # the paper's case study restricts candidate meshes to (1,2) submeshes
    # (Table 1) -> exactly 3 stages: mesh_V100(1,2) + 2x mesh_A100(1,2)
    cluster = paper_case_study_cluster(cross_gbps=5.0)
    pcfg = PlannerConfig(granularity=granularity, n_microbatches=B,
                         min_submesh_devices=2, max_submesh_devices=2)
    pcfg.search.n_workers = 4
    cfg = api.HarpConfig(seq_len=1024, global_batch=B, planner=pcfg)
    return api.plan(ARCH, cluster, cfg).strategy


def run():
    rows = []
    strats = {}
    for gran, label in [(8, "coarse_L8"), (128, "fine_L128")]:
        def fn(g=gran, lab=label):
            s = _plan(g)
            return {**strategy_row(lab, s),
                    "stages": [(st.layer_start, st.layer_end, st.cluster_idx)
                               for st in s.stages],
                    "c_links": s.c_links,
                    "t_stage": [st.t for st in s.stages],
                    "t_f": [st.t_f for st in s.stages],
                    "t_b": [st.t_b for st in s.stages]}
        r = cached(f"table1_{label}", fn)
        strats[label] = r
        rows.append(r)

    # Table 1's imbalance metric: longest/shortest stage cost ratio
    for r in rows:
        ts = r["t_stage"]
        r["imbalance"] = max(ts) / min(ts)
        r["derived"] = f"imbalance={r['imbalance']:.2f};eta={r['eta']:.3f}"

    speedup = rows[0]["step_time_s"] / rows[1]["step_time_s"]
    rows.append({"label": "fine_vs_coarse_speedup", "step_time_s": 0.0,
                 "derived": f"{(speedup - 1) * 100:.1f}% (paper: ~40.1%)"})

    # Validate the PAPER'S OWN Table-1 arithmetic through our simulator:
    # coarse stage costs {1.65t, t, t} vs fine {1.13t, 1.10t, 1.10t}, B=128,
    # full overlap -> paper reports 40.1% throughput improvement.
    def paper_numbers():
        t = 1.0
        fill = lambda ts: simulate([x * 0.33 for x in ts],
                                   [x * 0.67 for x in ts],
                                   [0.0, 0.0], B, [3, 2, 1]).makespan
        t_coarse = fill([1.65 * t, t, t])
        t_fine = fill([1.13 * t, 1.10 * t, 1.10 * t])
        return {"coarse": t_coarse, "fine": t_fine,
                "improvement_pct": (t_coarse / t_fine - 1) * 100}
    pn = cached("table1_paper_arithmetic", paper_numbers)
    rows.append({"label": "paper_table1_replay", "step_time_s": 0.0,
                 "derived": f"improvement={pn['improvement_pct']:.1f}%"
                            " (paper claims 40.1% from its Table 1 costs)"})

    # Fig 3(c)/(d): schedulers on the fine-grained plan
    fine = strats["fine_L128"]
    tf, tb, c = fine["t_f"], fine["t_b"], fine["c_links"]
    S = len(tf)
    for label, counts in [
            ("fig3_classic_1f1b", classic_1f1b_counts(S, B)),
            ("fig3_eager_1f1b", eager_1f1b_counts(S, B)),
            ("fig3_h1f1b", h1f1b_counts([a + b for a, b in zip(tf, tb)], c, B))]:
        res = simulate(tf, tb, c, B, counts)
        rows.append({"label": label, "step_time_s": res.makespan,
                     "derived": f"overlap={res.overlap_ratio:.2f};"
                                f"counts={counts}"})
    return rows


def main():
    emit_csv(run())


if __name__ == "__main__":
    main()
