"""Paper Fig. 8: stage-wise breakdown (compute / blocking comm / idle) and
the eta load-balance metric + boundary-stage overlap ratio, per system,
on the hc4 configuration (paper uses Fig. 7h's)."""
from __future__ import annotations

from benchmarks.common import (
    CASE_MODEL, GLOBAL_BATCH, N_MICROBATCHES, SEQ_LEN, cached, emit_csv,
    hetero_cluster, plan_hapt,
)
from repro.configs import get_config
from repro.core.baselines import (
    plan_blind_eager, plan_coarse_sync, plan_uniform,
)
from repro.core.h1f1b import h1f1b_counts
from repro.core.pipesim import eta_load_balance, simulate

CASE = "hc3_2x8A+2x8V"
DIMS = (2, 8, 2, 8)
ARCH = CASE_MODEL[CASE]


def _sim(strat, cluster, no_overlap=False):
    res = simulate([s.t_f for s in strat.stages],
                   [s.t_b for s in strat.stages],
                   strat.c_links, strat.n_microbatches, strat.warmup_counts,
                   no_overlap=no_overlap)
    eta = eta_load_balance(
        res.stage_compute,
        [s.n_devices * cluster.subclusters[s.cluster_idx].device.peak_flops
         for s in strat.stages])
    return res, eta


def run():
    cluster = hetero_cluster(*DIMS)
    rows = []

    def bench():
        out = []
        systems = {}
        systems["hapt"] = (plan_hapt(cluster, ARCH), False)
        try:
            systems["uniform-1f1b"] = (
                plan_uniform(cluster, get_config(ARCH), seq_len=SEQ_LEN,
                             global_batch=GLOBAL_BATCH,
                             n_microbatches=N_MICROBATCHES), False)
        except ValueError:
            pass
        systems["blind-eager (Alpa-like)"] = (
            plan_blind_eager(cluster, get_config(ARCH), seq_len=SEQ_LEN,
                             global_batch=GLOBAL_BATCH,
                             n_microbatches=N_MICROBATCHES,
                             min_submesh_devices=2), False)
        systems["coarse-sync"] = (
            plan_coarse_sync(cluster, get_config(ARCH), seq_len=SEQ_LEN,
                             global_batch=GLOBAL_BATCH,
                             n_microbatches=N_MICROBATCHES,
                             min_submesh_devices=2), True)
        for name, (strat, no_ov) in systems.items():
            res, eta = _sim(strat, cluster, no_overlap=no_ov)
            for i in range(len(strat.stages)):
                out.append({
                    "label": f"{name}/stage{i}",
                    "step_time_s": res.makespan,
                    "derived": f"compute={res.stage_compute[i]:.2f}s;"
                               f"blocking_comm={res.stage_comm_blocking[i]:.2f}s;"
                               f"idle={res.stage_idle[i]:.2f}s",
                })
            out.append({
                "label": f"{name}/summary", "step_time_s": res.makespan,
                "derived": f"eta={eta * 100:.1f}%;"
                           f"overlap={res.overlap_ratio * 100:.1f}%",
            })
        return {"rows": out}

    return cached("fig8_breakdown", bench)["rows"]


def main():
    emit_csv(run())


if __name__ == "__main__":
    main()
