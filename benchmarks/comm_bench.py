"""Comm-subsystem trajectory benchmark -> ``BENCH_comm.json`` at repo root.

One entry per run (same append-style as ``BENCH_search.json``), recording
what the comm pricing buys on the fig10 knee case and what the contention
simulator measures:

- **selection**: joint planning on the fig10 fleet at 3 Gbps cross, with the
  auto-selected collective algorithms vs. the forced flat ring — the
  acceptance case (auto picks the two-level hierarchical gradient sync and
  its plan's simulated step beats the ring plan's);
- **compression**: the cross-cluster sync priced plain vs. int8-compressed;
- **contention**: one lowered plan's step simulated with the fair-share
  netsim (shared-WAN occupancy + explicit grad-sync transfers) vs. the
  uncontended scalars, plus the netsim's own wall-clock cost.

``--tiny`` shrinks granularity/microbatches to CI size (seconds).
``--fail-on-regression`` exits 1 when the auto selection fails to pick the
hierarchy or fails to beat the forced ring — CI runs this.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit_csv, hetero_cluster       # noqa: E402

from repro import api                                        # noqa: E402
from repro.comm.selector import CommConfig, CommModel        # noqa: E402
from repro.core.planner import PlannerConfig                 # noqa: E402

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_comm.json")

ARCH = "gpt-30b"
DIMS = (2, 8, 2, 8)
CROSS_GBPS = 3.0         # the fig10 knee


def _harp_cfg(tiny: bool, comm: Optional[CommConfig]) -> api.HarpConfig:
    gran, B, batch = (24, 32, 256) if tiny else (64, 128, 1024)
    return api.HarpConfig(
        seq_len=1024, global_batch=batch,
        planner=PlannerConfig(granularity=gran, n_microbatches=B,
                              intra_op=True, min_submesh_devices=2,
                              comm=comm))


def run(tiny: bool = False, label: Optional[str] = None) -> Dict:
    cluster = hetero_cluster(*DIMS, cross_gbps=CROSS_GBPS)

    t0 = time.perf_counter()
    auto = api.compile(ARCH, cluster, _harp_cfg(tiny, CommConfig()))
    t_auto = time.perf_counter() - t0
    ring = api.compile(ARCH, cluster,
                       _harp_cfg(tiny, CommConfig(algorithms=("ring",))))

    sync_algos = sorted({s.sync_algorithm or "ring*"
                         for s in auto.lowered.stages
                         if s.sync_time_s > 0})
    auto_step = auto.strategy.est_step_time
    ring_step = ring.strategy.est_step_time

    # compression: the cross-cluster sync priced plain vs. int8
    payload = 512e6
    plain = CommModel(cluster).cross_sync(0, DIMS[0], DIMS[1], 2, payload)
    comp = CommModel(cluster, CommConfig(compressed=True)).cross_sync(
        0, DIMS[0], DIMS[1], 2, payload)

    # contention: fair-share netsim with shared physical links vs. the SAME
    # simulation on private links — isolates the sharing cost from the
    # injected sync work — plus the raw uncontended scalars for reference
    t1 = time.perf_counter()
    contended = auto.simulate(contention=True)
    netsim_s = time.perf_counter() - t1
    no_sharing = auto.simulate(contention=True, share_links=False)
    raw = auto.simulate(priced=False)

    case = {
        "cluster": cluster.describe(),
        "arch": ARCH,
        "granularity": auto.config.planner.granularity,
        "n_microbatches": auto.strategy.n_microbatches,
        "auto_step_s": auto_step,
        "ring_step_s": ring_step,
        "auto_vs_ring_speedup": round(ring_step / auto_step, 4),
        "sync_algorithms": sync_algos,
        "hierarchical_selected": "hierarchical" in sync_algos,
        "auto_beats_ring": auto_step < ring_step,
        "plan_seconds": round(t_auto, 3),
        "compress_plain_s": plain.seconds,
        "compress_int8_s": comp.seconds,
        "compress_wire_ratio": round(comp.wire_bytes / payload, 4),
        "contended_step_s": contended.makespan,
        "no_sharing_step_s": no_sharing.makespan,
        "uncontended_step_s": raw.makespan,
        "contention_stretch": round(contended.makespan / no_sharing.makespan,
                                    4),
        "contended_links": auto.lowered.contended_links,
        "netsim_seconds": round(netsim_s, 3),
    }
    return {"label": label or "HEAD",
            "mode": "tiny" if tiny else "full",
            "cases": {"fig10_bw3": case}}


def extend_trajectory(entry: Dict, path: str = BENCH_PATH) -> Dict:
    """Append one run to the comm trajectory (creates the file on first
    use)."""
    doc = {"schema": 1,
           "description": "Comm-subsystem trajectory; one entry per "
                          "benchmarks/comm_bench.py run — see docs/comm.md.",
           "runs": []}
    if os.path.exists(path):
        with open(path) as f:
            doc = json.load(f)
    doc["runs"].append(entry)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    return doc


def rows_from_entry(entry: Dict) -> List[Dict]:
    rows = []
    for name, c in entry["cases"].items():
        rows.append({
            "label": f"{name}.selection",
            "step_time_s": c["auto_step_s"],
            "derived": f"ring={c['ring_step_s']:.3f}s;"
                       f"speedup={c['auto_vs_ring_speedup']}x;"
                       f"algos={'+'.join(c['sync_algorithms'])}"})
        rows.append({
            "label": f"{name}.compression",
            "step_time_s": c["compress_int8_s"],
            "derived": f"plain={c['compress_plain_s']:.3f}s;"
                       f"wire_ratio={c['compress_wire_ratio']}"})
        rows.append({
            "label": f"{name}.contention",
            "step_time_s": c["contended_step_s"],
            "derived": f"no_sharing={c['no_sharing_step_s']:.3f}s;"
                       f"stretch={c['contention_stretch']}x;"
                       f"netsim={c['netsim_seconds']}s"})
    return rows


def main() -> None:
    """benchmarks/run.py contract: full measurement, CSV on stdout, one
    trajectory entry appended to BENCH_comm.json."""
    entry = run(tiny=False)
    extend_trajectory(entry)
    emit_csv(rows_from_entry(entry))


def cli(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI-sized configs (seconds, not minutes)")
    ap.add_argument("--label", default=None,
                    help="trajectory entry label (default HEAD)")
    ap.add_argument("--out", default=BENCH_PATH,
                    help="trajectory JSON path (default repo root)")
    ap.add_argument("--fail-on-regression", action="store_true",
                    help="exit 1 unless the hierarchy is auto-selected AND "
                         "the auto plan beats the forced ring")
    args = ap.parse_args(argv)

    entry = run(tiny=args.tiny, label=args.label)
    extend_trajectory(entry, args.out)
    emit_csv(rows_from_entry(entry))
    print(f"# trajectory entry appended to {os.path.abspath(args.out)}",
          file=sys.stderr)

    bad = [name for name, c in entry["cases"].items()
           if not (c["hierarchical_selected"] and c["auto_beats_ring"])]
    if bad:
        print(f"# comm selection regressed on: {bad}", file=sys.stderr)
        if args.fail_on_regression:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(cli())
