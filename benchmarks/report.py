"""Regenerate the data-driven sections of EXPERIMENTS.md from the dry-run /
hillclimb JSON artifacts.

  PYTHONPATH=src python -m benchmarks.report          # prints the sections
  PYTHONPATH=src python -m benchmarks.report --write  # splices EXPERIMENTS.md
  PYTHONPATH=src python -m benchmarks.report --all    # roll up BENCH_*.json

``--all`` aggregates every ``BENCH_*.json`` trajectory at the repo root
(chaos / comm / kbench / migrate / obs / search / serve) into one summary
table: latest entry per file, its boolean acceptance gates folded to a
single pass/FAIL verdict.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.roofline import CHIPS, analyze, model_flops  # noqa: E402
from repro.configs import get_config, list_archs             # noqa: E402

ROOT = os.path.join(os.path.dirname(__file__), "..")
MARK = "## §Dry-run"


def _load(path: str) -> Optional[Dict]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def dryrun_section() -> str:
    lines = [
        "## §Dry-run — multi-pod lower + compile (deliverable e)",
        "",
        "Every applicable (architecture × shape × mesh) cell is "
        "`jit(...).lower().compile()`d against the production meshes; "
        "`memory_analysis()` proves per-device fit (v5e = 16 GiB; pipeline "
        "train cells carry f32 activations due to an XLA *CPU* compiler "
        "workaround — on TPU they are bf16, halving the activation part).",
        "",
        "| arch | shape | single-pod (16,16) | multi-pod (2,16,16) | "
        "peak GiB/dev (single / multi) |",
        "|---|---|---|---|---|",
    ]
    n_ok = n_total = 0
    for arch in list_archs(assigned_only=True):
        for shape in get_config(arch).shapes():
            row = [arch, shape.name]
            mems = []
            for mk in ("single", "multi"):
                n_total += 1
                rec = _load(os.path.join(
                    ROOT, "results", "dryrun", f"{arch}_{shape.name}_{mk}.json"))
                if rec and rec.get("ok"):
                    n_ok += 1
                    row.append("OK")
                    mems.append(f"{rec['memory']['peak_per_device'] / 2**30:.1f}")
                else:
                    row.append("FAIL" if rec else "—")
                    mems.append("—")
            lines.append("| " + " | ".join(row) + " | " + " / ".join(mems) + " |")
    lines += ["", f"**{n_ok}/{n_total} cells compiled.** Skipped long_500k "
              "cells (pure full-attention archs) are recorded in DESIGN.md "
              "§Arch-applicability; they do not appear above."]
    return "\n".join(lines)


def roofline_section() -> str:
    lines = [
        "## §Roofline (deliverable g) — single-pod (16,16), per step",
        "",
        "Terms: `t_comp = HLO_FLOPs/(chip·197TF)`, `t_mem = HLO_bytes/"
        "(chip·819GB/s)`, `t_coll = collective_bytes/(chip·50GB/s)`; all "
        "per-device from the compiled, fully-unrolled analysis pass (loop "
        "trip counts folded in — XLA cost analysis alone undercounts loops). "
        "`useful` = MODEL_FLOPS/(HLO_FLOPs·chips) with MODEL_FLOPS = 6·N_act·D "
        "(train) / 2·N_act·D (inference). `MFU bound` = useful peak fraction "
        "attainable under the dominant term. NOTE: XLA CPU 'bytes accessed' "
        "counts every HLO op's operands (no TPU-style fusion), so `t_mem` is "
        "a loose upper bound; `t_coll` and `t_comp` are layout-faithful.",
        "",
        "| arch | shape | t_comp ms | t_mem ms | t_coll ms | dominant | "
        "useful | MFU bound | mem GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in list_archs(assigned_only=True):
        for shape in get_config(arch).shapes():
            rec = _load(os.path.join(
                ROOT, "results", "dryrun", f"{arch}_{shape.name}_single.json"))
            if not rec or not rec.get("ok") or "flops_per_device" not in rec:
                lines.append(f"| {arch} | {shape.name} | — | — | — | — | — | — | — |")
                continue
            a = analyze(rec)
            lines.append(
                f"| {arch} | {shape.name} | {a['t_compute_s']*1e3:.0f} "
                f"| {a['t_memory_s']*1e3:.0f} | {a['t_collective_s']*1e3:.0f} "
                f"| {a['dominant']} | {a['useful_flops_ratio']:.2f} "
                f"| {a['mfu_bound']:.3f} | {a['peak_mem_gib']:.1f}"
                f"{'' if a['fits_mem'] else ' (!)'} |")
    return "\n".join(lines)


def perf_rows(tag: str, paths: List[str]) -> List[str]:
    out = []
    for p in paths:
        rec = _load(p)
        if not rec or not rec.get("ok"):
            continue
        a = analyze(rec)
        out.append(
            f"| {tag} | {a['t_compute_s']*1e3:.0f} | {a['t_memory_s']*1e3:.0f} "
            f"| {a['t_collective_s']*1e3:.0f} | {a['dominant']} "
            f"| {a['mfu_bound']:.3f} | {a['peak_mem_gib']:.1f} |")
    return out


def bench_all_section() -> str:
    """One table over every BENCH_*.json trajectory at the repo root: the
    latest entry's cases with their boolean acceptance gates rolled up.
    Returns a note (not an error) when no trajectories exist."""
    paths = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    lines = [
        "## §Benchmarks — trajectory roll-up",
        "",
        "| trajectory | runs | latest | mode | case | gates | verdict |",
        "|---|---|---|---|---|---|---|",
    ]
    n_cases = n_pass = 0
    for path in paths:
        doc = _load(path)
        if not doc or not doc.get("runs"):
            continue
        run = doc["runs"][-1]
        fname = os.path.basename(path)
        for name, case in sorted(run.get("cases", {}).items()):
            gates = {k: v for k, v in case.items() if isinstance(v, bool)}
            failed = sorted(k for k, v in gates.items() if not v)
            verdict = "pass" if not failed else "FAIL: " + ", ".join(failed)
            n_cases += 1
            n_pass += not failed
            lines.append(
                f"| {fname} | {len(doc['runs'])} | {run.get('label', '?')} "
                f"| {run.get('mode', '?')} | {name} "
                f"| {len(gates) - len(failed)}/{len(gates)} | {verdict} |")
    if n_cases == 0:
        return ("## §Benchmarks — trajectory roll-up\n\n"
                "No BENCH_*.json trajectories at the repo root yet "
                "(run benchmarks/*_replay.py or benchmarks/obs_bench.py).")
    lines += ["", f"**{n_pass}/{n_cases} cases pass all gates.**"]
    return "\n".join(lines)


def main() -> None:
    if "--all" in sys.argv:
        print(bench_all_section())
        return
    sections = dryrun_section() + "\n\n" + roofline_section()
    if "--write" in sys.argv:
        path = os.path.join(ROOT, "EXPERIMENTS.md")
        with open(path) as f:
            text = f.read()
        head = text.split(MARK)[0]
        perf = ""
        if "## §Perf" in text:
            perf = "## §Perf" + text.split("## §Perf", 1)[1]
        with open(path, "w") as f:
            f.write(head + sections + "\n\n" + perf)
        print("EXPERIMENTS.md updated")
    else:
        print(sections)


if __name__ == "__main__":
    main()
