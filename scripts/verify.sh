#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md): full test suite on one CPU device.
set -euo pipefail
cd "$(dirname "$0")/.."
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
