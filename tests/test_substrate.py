"""Substrate layers: optimizer, data pipeline, checkpointing, compression,
sharding rules, trainer fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs import get_config, list_archs
from repro.data.pipeline import DataConfig, make_batch
from repro.models import build_model, param_specs
from repro.parallel import sharding as shd
from repro.parallel.compression import (
    compress_tree, decompress_tree, dequantize_int8, init_error_feedback,
    quantize_int8,
)
from repro.train.optimizer import OptimizerConfig, lr_schedule, make_optimizer


# --- optimizer --------------------------------------------------------------


def test_adamw_minimizes_quadratic():
    opt_cfg = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=1000,
                              weight_decay=0.0, grad_clip=0.0)
    init, update = make_optimizer(opt_cfg)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, _ = update(g, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip_caps_norm():
    opt_cfg = OptimizerConfig(lr=1.0, grad_clip=1.0, warmup_steps=0)
    init, update = make_optimizer(opt_cfg)
    params = {"w": jnp.ones((4,))}
    state = init(params)
    _, _, m = update({"w": 100.0 * jnp.ones((4,))}, state, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 * (1 + 1e-5)     # warmup
    assert lrs[-1] == pytest.approx(1e-4, rel=0.05)  # decays to min frac
    assert max(lrs) <= 1e-3 * (1 + 1e-5)


def test_bf16_optimizer_state_dtype():
    init, _ = make_optimizer(OptimizerConfig(state_dtype=jnp.bfloat16))
    state = init({"w": jnp.ones((4,), jnp.float32)})
    assert state.mu["w"].dtype == jnp.bfloat16


# --- data -------------------------------------------------------------------


def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=3)
    b1 = make_batch(cfg, step=17)
    b2 = make_batch(cfg, step=17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(cfg, step=18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_host_slice_matches_global():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8, seed=0)
    full = make_batch(cfg, 5)
    part = make_batch(cfg, 5, host_slice=(1, 4))
    np.testing.assert_array_equal(full["tokens"][2:4], part["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2, kind="markov")
    b = make_batch(cfg, 0)
    # markov chain: mostly next = (31*cur + 17) % V
    pred = (b["tokens"] * 31 + 17) % 100
    agree = np.mean(pred == b["labels"])
    assert agree > 0.7


# --- checkpoint ---------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6).reshape(2, 3).astype(np.float32),
            "b": {"c": np.ones((4,), np.int32)}}
    ckpt_lib.save(str(tmp_path), 3, tree, extra={"x": 1})
    step, restored, extra = ckpt_lib.restore(str(tmp_path), tree)
    assert step == 3 and extra == {"x": 1}
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])


def test_checkpoint_keeps_window(tmp_path):
    tree = {"a": np.zeros(2)}
    for s in [1, 2, 3, 4, 5]:
        ckpt_lib.save(str(tmp_path), s, tree, keep=2)
    assert ckpt_lib.list_steps(str(tmp_path)) == [4, 5]


def test_checkpoint_restores_latest(tmp_path):
    tree = {"a": np.zeros(2)}
    ckpt_lib.save(str(tmp_path), 1, {"a": np.ones(2)})
    ckpt_lib.save(str(tmp_path), 7, {"a": 7 * np.ones(2)})
    step, restored, _ = ckpt_lib.restore(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(restored["a"], 7 * np.ones(2))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt_lib.save(str(tmp_path), 1, {"a": np.zeros((2, 2))})
    with pytest.raises(ValueError):
        ckpt_lib.restore(str(tmp_path), {"a": np.zeros((3, 3))})


def test_reshard_to_devices(tmp_path):
    tree = {"a": np.arange(8).astype(np.float32)}
    ckpt_lib.save(str(tmp_path), 1, tree)
    _, restored, _ = ckpt_lib.restore(str(tmp_path), tree)
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), tree)
    placed = ckpt_lib.reshard(restored, shardings)
    np.testing.assert_array_equal(np.asarray(placed["a"]), tree["a"])


# --- compression ----------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 2000), scale=st.floats(1e-4, 1e3))
def test_quantize_bounded_error(n, scale):
    rng = np.random.default_rng(n)
    g = jnp.asarray(scale * rng.standard_normal(n), jnp.float32)
    q, s = quantize_int8(g)
    deq = dequantize_int8(q, s, g.shape)
    blockmax = float(jnp.max(jnp.abs(g)))
    assert float(jnp.max(jnp.abs(deq - g))) <= blockmax / 127.0 + 1e-6


def test_error_feedback_converges():
    """With error feedback, the *running sum* of dequantized gradients tracks
    the true sum (bias cancels) — the property that preserves SGD."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(512), jnp.float32) * 0.01
    err = init_error_feedback({"g": g_true})
    total_deq = jnp.zeros_like(g_true)
    steps = 50
    for _ in range(steps):
        payload, err = compress_tree({"g": g_true}, err)
        deq = decompress_tree(payload, {"g": g_true})
        total_deq = total_deq + deq["g"]
    drift = float(jnp.max(jnp.abs(total_deq - steps * g_true)))
    assert drift <= float(jnp.max(jnp.abs(g_true))) * 1.1  # residual bounded


def test_compression_ratio():
    g = {"g": jnp.zeros((1024,), jnp.float32)}
    payload, _ = compress_tree(g, init_error_feedback(g))
    q, s = payload["g"]
    assert q.dtype == jnp.int8
    wire = q.size + s.size * 4
    assert wire < 0.3 * g["g"].size * 4


# --- sharding rules ----------------------------------------------------------------


@pytest.mark.parametrize("arch", list_archs(assigned_only=True))
def test_param_rules_cover_all_leaves(arch):
    cfg = get_config(arch)
    specs = param_specs(cfg)
    pspecs = shd.param_pspecs(specs)  # raises KeyError if any leaf unmatched
    for spec, leaf in zip(jax.tree.leaves(pspecs), jax.tree.leaves(specs)):
        assert len(spec) <= len(leaf.shape)


def test_fit_spec_drops_indivisible():
    import jax.sharding as js
    mesh = jax.make_mesh((1,), ("model",))  # single device: everything divides
    from jax.sharding import PartitionSpec as P
    spec = shd.fit_spec(mesh, P("model", None), (7, 4))
    assert spec == P("model", None)

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.zeros((16, 16))
    spec = shd.fit_spec(FakeMesh, P("model", "data"), (50280, 2560))
    assert spec[0] is None          # 50280 % 16 != 0 -> replicated
    assert spec[1] == "data"
