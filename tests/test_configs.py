"""Config registry + parameter accounting vs published sizes."""
import pytest

from repro.configs import get_config, get_shape, list_archs

PUBLISHED_B = {
    "minitron-8b": (7.0, 8.5),
    "deepseek-7b": (6.5, 7.5),
    "gemma-2b": (2.0, 3.0),
    "gemma3-12b": (11.0, 13.0),
    "qwen3-moe-235b-a22b": (225.0, 245.0),
    "granite-moe-1b-a400m": (1.0, 1.6),
    "mamba2-2.7b": (2.4, 3.0),
    "llama-3.2-vision-90b": (85.0, 95.0),
    "whisper-medium": (0.7, 0.9),
    "zamba2-7b": (6.5, 7.6),
}

ACTIVE_B = {
    "qwen3-moe-235b-a22b": (20.0, 24.0),
    "granite-moe-1b-a400m": (0.3, 0.55),
}


def test_registry_has_all_assigned():
    assert len(list_archs(assigned_only=True)) == 10


@pytest.mark.parametrize("arch", list(PUBLISHED_B))
def test_param_counts_match_published(arch):
    lo, hi = PUBLISHED_B[arch]
    n = get_config(arch).param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo}, {hi}]"


@pytest.mark.parametrize("arch", list(ACTIVE_B))
def test_active_params(arch):
    lo, hi = ACTIVE_B[arch]
    n = get_config(arch).active_param_count() / 1e9
    assert lo <= n <= hi


def test_shapes_applicability():
    # long_500k only for sub-quadratic archs
    long_archs = {a for a in list_archs(assigned_only=True)
                  if any(s.name == "long_500k" for s in get_config(a).shapes())}
    assert long_archs == {"mamba2-2.7b", "zamba2-7b", "gemma3-12b"}
    # everyone gets train/prefill/decode
    for a in list_archs(assigned_only=True):
        names = {s.name for s in get_config(a).shapes()}
        assert {"train_4k", "prefill_32k", "decode_32k"} <= names


def test_total_cells():
    n = sum(len(get_config(a).shapes()) for a in list_archs(assigned_only=True))
    assert n == 33  # 10 archs x 3 + 3 long_500k


def test_reduced_configs_small():
    for a in list_archs():
        r = get_config(a).reduced()
        assert r.d_model <= 64 and r.vocab_size <= 512
        assert r.param_count() < 5e6


def test_shape_lookup():
    s = get_shape("train_4k")
    assert s.seq_len == 4096 and s.global_batch == 256 and s.kind == "train"
    assert get_shape("long_500k").seq_len == 524288
    with pytest.raises(KeyError):
        get_shape("nope")
