"""End-to-end system behaviour: plan -> schedule -> simulate on the paper's
clusters; trainer loop with checkpoint-resume; baseline comparisons."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    HAPTPlanner, PlannerConfig, paper_case_study_cluster, simulate,
)
from repro.core.baselines import plan_coarse_sync, plan_uniform
from repro.core.strategy import ParallelStrategy
from repro.data.pipeline import DataConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig

# end-to-end planning + training loops run minutes on CPU — deselected in
# the tier-1 fast job with -m "not slow" (see pytest.ini / CI)
pytestmark = pytest.mark.slow



@pytest.fixture(scope="module")
def hapt_strategy():
    cluster = paper_case_study_cluster()
    cfg = PlannerConfig(granularity=32, n_microbatches=32)
    return HAPTPlanner(cluster, cfg).plan(
        get_config("gpt-2b"), seq_len=1024, global_batch=64)


def test_planner_produces_valid_strategy(hapt_strategy):
    s = hapt_strategy
    assert s.n_stages >= 2
    assert s.est_step_time > 0
    assert 0.5 < s.eta <= 1.0
    # uses both subclusters (heterogeneity-aware)
    assert {st.cluster_idx for st in s.stages} == {0, 1}


def test_strategy_json_roundtrip(hapt_strategy):
    s2 = ParallelStrategy.from_json(hapt_strategy.to_json())
    assert s2.n_stages == hapt_strategy.n_stages
    assert s2.stages == hapt_strategy.stages
    assert s2.warmup_counts == hapt_strategy.warmup_counts


def test_hapt_beats_naive_uniform(hapt_strategy):
    """The paper's headline: HAPT > heterogeneity-blind baselines."""
    cluster = paper_case_study_cluster()
    try:
        base = plan_uniform(cluster, get_config("gpt-2b"), seq_len=1024,
                            global_batch=64, n_microbatches=32)
    except ValueError:
        pytest.skip("uniform planner cannot express this cluster")
    assert hapt_strategy.est_step_time < base.est_step_time


def test_hapt_beats_no_overlap(hapt_strategy):
    cluster = paper_case_study_cluster()
    sync = plan_coarse_sync(cluster, get_config("gpt-2b"), seq_len=1024,
                            global_batch=64, n_microbatches=32)
    assert hapt_strategy.est_step_time <= sync.est_step_time * 1.001


def test_trainer_loss_decreases_and_resumes(tmp_path):
    cfg = get_config("gemma-2b").reduced()
    opt_cfg = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=40)
    train_step, model, opt_init = make_train_step(cfg, opt_cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt_state": opt_init(params)}
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=8, kind="markov")
    tcfg = TrainerConfig(total_steps=20, ckpt_dir=str(tmp_path),
                         ckpt_every=10, log_every=5)
    out = Trainer(tcfg, data_cfg, jax.jit(train_step), state,
                  log_fn=lambda *_: None).run()
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"], "loss did not decrease"
    assert out["final_step"] == 20

    # simulate preemption: a fresh Trainer resumes from the checkpoint
    state2 = {"params": jax.tree.map(jnp.zeros_like, params),
              "opt_state": opt_init(params)}
    tcfg2 = TrainerConfig(total_steps=30, ckpt_dir=str(tmp_path),
                          ckpt_every=10, log_every=5)
    t2 = Trainer(tcfg2, data_cfg, jax.jit(train_step), state2,
                 log_fn=lambda *_: None)
    out2 = t2.run()
    assert out2["final_step"] == 30  # continued from 20, not 0


def test_straggler_hook_fires():
    calls = []
    cfg = get_config("gemma-2b").reduced()
    opt_cfg = OptimizerConfig(lr=1e-3)
    train_step, model, opt_init = make_train_step(cfg, opt_cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt_state": opt_init(params)}
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=4)

    jitted = jax.jit(train_step)

    # deterministic fake clock: steps take 1.0s except step 8 (10.0s) —
    # immune to real wall-clock noise on loaded CI boxes
    ticks = {"t": 0.0, "calls": 0, "step": 0}

    def fake_clock():
        ticks["calls"] += 1
        if ticks["calls"] % 2 == 1:     # step start
            ticks["step"] += 1
        else:                            # step end
            ticks["t"] += 10.0 if ticks["step"] == 8 else 1.0
        return ticks["t"]

    tcfg = TrainerConfig(total_steps=10, ckpt_dir="/tmp/_none_",
                         ckpt_every=10_000, log_every=100,
                         replan_threshold=2.0)
    Trainer(tcfg, data_cfg, jitted, state,
            on_straggler=lambda *a: calls.append(a),
            log_fn=lambda *_: None, clock=fake_clock).run()
    assert calls, "straggler hook did not fire"
