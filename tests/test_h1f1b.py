"""H-1F1B scheduler: the paper's §4 claims validated against the independent
pipeline-DAG simulator.

Key properties (Lemma 1/2, Eq. 9-11):
  - with K = ceil(1 + 2c/(f+b)) + 1 warm-up launches the 2-stage steady phase
    is bubble-free: T ~= B(f+b) + O(1);
  - K-1 launches are NOT sufficient when c is large enough (minimality);
  - the derived counts never schedule worse than classic or Eager-1F1B;
  - Eager-1F1B hides at most (f+b)/2 of comm (the paper's 50% cap).
"""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.h1f1b import (
    classic_1f1b_counts, eager_1f1b_counts, h1f1b_counts, h1f1b_deltas,
    memory_ok,
)
from repro.core.pipesim import eta_load_balance, simulate


def overhead(f, b, c, K, B=64):
    res = simulate([f, f], [b, b], [c], B, [K, 1])
    ideal = B * (f + b)
    return (res.makespan - ideal) / ideal


@settings(max_examples=25, deadline=None)
@given(f=st.floats(0.2, 2.0), b_mult=st.floats(1.0, 3.0),
       c_frac=st.floats(0.05, 0.99))
def test_two_stage_bubble_free_at_derived_K(f, b_mult, c_frac):
    b = f * b_mult
    c = c_frac * (f + b)          # paper requires c <= f+b
    delta = math.ceil(1.0 + 2.0 * c / (f + b))
    K = 1 + delta
    # steady phase bubble-free: only warm-up/cool-down O(1) overhead remains
    assert overhead(f, b, c, K) < 0.10


@settings(max_examples=25, deadline=None)
@given(f=st.floats(0.2, 2.0), b_mult=st.floats(1.0, 3.0),
       c_frac=st.floats(0.30, 0.95))
def test_minimality_K_minus_one_has_bubbles(f, b_mult, c_frac):
    b = f * b_mult
    c = c_frac * (f + b)
    delta = math.ceil(1.0 + 2.0 * c / (f + b))
    K = 1 + delta
    # one fewer launch leaves steady-phase bubbles (Eq. 9: 2(f+b+c)/K' > f+b)
    if 2 * (f + b + c) / (K - 1) > (f + b) * 1.02:
        assert overhead(f, b, c, K - 1) > overhead(f, b, c, K) + 0.02


def test_counts_formulas():
    # paper Fig. 3(d): tailored {5, 2, 1} for fast link 2-3, slow link 1-2
    t = [1.0, 1.0, 1.0]
    c = [0.9, 0.01]               # c1 in (tmax/2, tmax], c2 negligible
    counts = h1f1b_counts(t, c, n_microbatches=64)
    assert counts == [5, 2, 1]
    assert classic_1f1b_counts(3, 64) == [3, 2, 1]
    assert eager_1f1b_counts(3, 64) == [5, 3, 1]


def test_counts_capped_by_microbatches():
    counts = h1f1b_counts([1.0] * 4, [0.9, 0.9, 0.9], n_microbatches=3)
    assert max(counts) <= 3


@settings(max_examples=15, deadline=None)
@given(S=st.integers(2, 5), seed=st.integers(0, 100))
def test_h1f1b_never_worse_than_baselines(S, seed):
    import random
    rnd = random.Random(seed)
    t = [1.0] * S
    c = [rnd.uniform(0.0, 1.9) for _ in range(S - 1)]
    B = 48
    f = [0.4] * S
    b = [0.6] * S
    mk = lambda counts: simulate(f, b, c, B, counts).makespan
    h = mk(h1f1b_counts(t, c, B))
    cl = mk(classic_1f1b_counts(S, B))
    assert h <= cl * 1.001
    eg = mk(eager_1f1b_counts(S, B))
    assert h <= eg * 1.05  # Eager may tie when its fixed +2 happens to match


def test_eager_cap_at_half():
    """Eager-1F1B (K=3 at 2 stages) fully hides c <= (f+b)/2 but not beyond —
    the paper's 50%-of-upper-bound claim."""
    f, b = 0.4, 0.6
    K_eager = 3
    assert overhead(f, b, 0.49, K_eager) < 0.08     # c < (f+b)/2: hidden
    assert overhead(f, b, 0.95, K_eager) > 0.15     # c -> (f+b): not hidden
    K_h = 1 + math.ceil(1 + 2 * 0.95 / (f + b))     # H-1F1B compensates
    assert overhead(f, b, 0.95, K_h) < 0.08


def test_memory_bound():
    assert memory_ok(10.0, 1.0, 4, 14.0)
    assert not memory_ok(10.0, 1.0, 5, 14.0)


def test_eta_metric():
    # perfect balance
    assert eta_load_balance([1.0, 1.0], [100.0, 100.0]) == pytest.approx(1.0)
    # stage 2 idles half the time on equal hardware
    eta = eta_load_balance([1.0, 0.5], [100.0, 100.0])
    assert eta == pytest.approx(0.75)


def test_banded_rule_all_three_bands():
    """Eq. 2: delta = 1 / 2 / 3 for c in (0, eps*tmax] / (eps*tmax, tmax/2]
    / (tmax/2, tmax] — including both boundaries of each band."""
    t = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]
    eps = 0.05
    c = [0.01,    # well inside band 1
         0.05,    # == eps * tmax (band-1 upper boundary, inclusive)
         0.06,    # just past eps * tmax -> band 2
         0.5,     # == tmax / 2 (band-2 upper boundary, inclusive)
         0.51,    # just past tmax / 2 -> band 3
         1.0]     # == tmax (band-3 upper boundary)
    assert h1f1b_deltas(t, c, eps=eps, banded=True) == [1, 1, 2, 2, 3, 3]


def test_banded_vs_exact_agree_on_tiny_comm():
    t = [2.0, 2.0]
    assert h1f1b_deltas(t, [0.05], banded=True) == \
        h1f1b_deltas(t, [0.05], banded=False) == [1]
