"""Vectorized planning hot path (ISSUE 4): the array-program DP engine must
be *bit-identical* to the scalar oracle — same fill costs, same F/N tables,
same chosen ``ParallelStrategy`` JSON — on the paper's clusters and on
randomized small cases; the search must degrade cleanly where fork-based
workers are unavailable; and the pipesim memo must surface hit/miss
counters through the elastic controller's decision log."""
import json
import random

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cluster import (
    A100_40G, GBPS, V100_32G, DeviceProfile, HeteroCluster, SubCluster,
    paper_case_study_cluster, set_node_efficiencies,
)
from repro.core import dp_search
from repro.core.dp_search import (
    SearchConfig, SearchStats, _DPContext, _dp_eval, _dp_eval_batch,
    _dp_eval_vec, instrumented_search, search,
)
from repro.core.layering import build_layers
from repro.core.opgraph import build_op_sequence
from repro.core.pipesim import sim_memo_stats
from repro.core.profiler import ZeroRedundantProfiler
from repro.runtime.controller import ControllerConfig, ElasticController
from repro.runtime.events import BandwidthShift

GB = 1024 ** 3


def tiny_cluster(mem_gb_a=40.0, mem_gb_b=32.0):
    return HeteroCluster(
        subclusters=(
            SubCluster("A", 1, 2, DeviceProfile("fast", 300e12, mem_gb_a * GB,
                                                1.5e12), 300e9, 25e9),
            SubCluster("B", 1, 2, DeviceProfile("slow", 120e12, mem_gb_b * GB,
                                                0.9e12), 150e9, 25e9),
        ),
        cross_bw=0.625e9)


def fig11_mixed_cluster(slow=0.6):
    """Table-1/fig-11 style: case-study fleet with one throttled node."""
    return set_node_efficiencies(paper_case_study_cluster(), "meshA100",
                                 (slow, 1.0))


def make_tables(cluster, arch="gpt-2b", granularity=12, mb_tokens=1024, **kw):
    ops = build_op_sequence(get_config(arch), seq_len=1024)
    layers = build_layers(ops, granularity)
    prof = ZeroRedundantProfiler(cluster, layers, mb_tokens, **kw)
    return layers, prof.profile()


CASES = {
    "tiny": lambda: (tiny_cluster(), {}),
    "table1_case_study": lambda: (paper_case_study_cluster(), {}),
    "fig11_mixed_joint": lambda: (fig11_mixed_cluster(),
                                  dict(intra_op=True,
                                       amortize_microbatches=16)),
    # 12 GB / 10 GB: small enough that the Eq. 18 bound genuinely binds
    # (K thresholds reach 1) while strategies stay feasible
    "memory_bound": lambda: (tiny_cluster(12.0, 10.0),
                             dict(mb_tokens=8192)),
}


@pytest.mark.parametrize("case", sorted(CASES))
@pytest.mark.parametrize("monotone", [False, True])
def test_dp_tables_bit_identical(case, monotone):
    """F and N tables — not just the final fill — must match exactly for
    every t_max, including infeasible ones."""
    cluster, kw = CASES[case]()
    _, tables = make_tables(cluster, **kw)
    cfg = SearchConfig(n_microbatches=16, monotone_clusters=monotone)
    ctx = _DPContext(cluster, tables, cfg)
    vals = np.unique(ctx.t_tab[tables.feasible])
    assert len(vals), "case produced no feasible candidates"
    ts = vals[:: max(1, len(vals) // 10)][:10].astype(float)
    for t in ts:
        fo, Fo, No = _dp_eval(ctx, float(t), want_tables=True)
        fv, Fv, Nv = _dp_eval_vec(ctx, float(t), want_tables=True)
        assert (fo == fv) or (np.isinf(fo) and np.isinf(fv))
        assert np.array_equal(Fo, Fv)
        assert np.array_equal(No, Nv)


@pytest.mark.parametrize("case", sorted(CASES))
def test_batched_eval_matches_singles(case):
    cluster, kw = CASES[case]()
    _, tables = make_tables(cluster, **kw)
    ctx = _DPContext(cluster, tables, SearchConfig(n_microbatches=16))
    vals = np.unique(ctx.t_tab[tables.feasible])
    ts = vals[:: max(1, len(vals) // 12)][:12].astype(float)
    fills = _dp_eval_batch(ctx, ts)
    for t, f in zip(ts, fills):
        fo = _dp_eval(ctx, float(t))[0]
        assert (fo == f) or (np.isinf(fo) and np.isinf(f))


@pytest.mark.parametrize("case", sorted(CASES))
def test_search_strategy_json_bit_identical(case):
    """The acceptance criterion: identical ParallelStrategy JSON from both
    engines (same fill cost, same stages, same warm-up counts, same meta)."""
    cluster, kw = CASES[case]()
    _, tables = make_tables(cluster, granularity=16, **kw)
    try:
        s_oracle = search(cluster, tables, 1024,
                          SearchConfig(n_microbatches=16, engine="oracle"))
    except RuntimeError:
        # infeasible case: both engines must agree on that too
        with pytest.raises(RuntimeError):
            search(cluster, tables, 1024,
                   SearchConfig(n_microbatches=16, engine="vectorized"))
        return
    s_vec, stats = instrumented_search(
        cluster, tables, 1024, SearchConfig(n_microbatches=16))
    assert s_oracle.to_json() == s_vec.to_json()
    assert stats.engine == "vectorized"
    assert stats.oracle_fallbacks == 0
    assert stats.n_evaluated > 0 and stats.best_t_max == s_vec.t_max


@pytest.mark.parametrize("seed", range(6))
def test_randomized_clusters_bit_identical(seed):
    """Randomized small fleets: device speeds, memory, bandwidths, B."""
    rng = random.Random(seed)
    cluster = HeteroCluster(
        subclusters=(
            SubCluster("A", 1, rng.choice([1, 2, 4]),
                       DeviceProfile("a", rng.uniform(100e12, 400e12),
                                     rng.uniform(8, 40) * GB, 1.5e12),
                       300e9, 25e9),
            SubCluster("B", rng.choice([1, 2]), 2,
                       DeviceProfile("b", rng.uniform(80e12, 200e12),
                                     rng.uniform(8, 32) * GB, 0.9e12),
                       150e9, 25e9),
        ),
        cross_bw=rng.uniform(0.3e9, 3e9))
    B = rng.choice([4, 8, 32])
    _, tables = make_tables(cluster, granularity=rng.choice([6, 10]),
                            mb_tokens=rng.choice([1024, 4096]))
    cfg_o = SearchConfig(n_microbatches=B, engine="oracle",
                         require_all_devices=rng.random() < 0.3)
    cfg_v = SearchConfig(n_microbatches=B, engine="vectorized",
                         require_all_devices=cfg_o.require_all_devices)
    try:
        s_o = search(cluster, tables, 1024, cfg_o)
    except RuntimeError:
        with pytest.raises(RuntimeError):
            search(cluster, tables, 1024, cfg_v)
        return
    s_v = search(cluster, tables, 1024, cfg_v)
    assert s_o.to_json() == s_v.to_json()


def test_four_subclusters_vectorized_only():
    """The scale case the scalar DP cannot represent: four sub-clusters.
    The vectorized engine plans it; the oracle refuses loudly."""
    cluster = HeteroCluster(
        subclusters=(
            SubCluster("A100-a", 1, 2, A100_40G, 300e9, 200 * GBPS),
            SubCluster("A100-b", 1, 2, A100_40G, 300e9, 200 * GBPS),
            SubCluster("V100-a", 1, 2, V100_32G, 150e9, 200 * GBPS),
            SubCluster("V100-b", 1, 2, V100_32G, 150e9, 200 * GBPS),
        ),
        cross_bw=5.0 * GBPS)
    layers, tables = make_tables(cluster, granularity=12)
    strat, stats = instrumented_search(
        cluster, tables, 1024, SearchConfig(n_microbatches=16))
    assert stats.engine == "vectorized" and stats.n_subclusters == 4
    # structural invariants on the multi-pool plan
    pos = 0
    for s in strat.stages:
        assert s.layer_start == pos
        pos = s.layer_end
        assert s.t <= strat.t_max * (1 + 1e-9)
    assert pos == len(layers)
    for ci, sub in enumerate(cluster.subclusters):
        used = sum(s.n_devices for s in strat.stages if s.cluster_idx == ci)
        assert used <= sub.n_devices
    with pytest.raises(ValueError, match="at most 2 sub-clusters"):
        instrumented_search(cluster, tables, 1024,
                            SearchConfig(n_microbatches=16, engine="oracle"))


def test_worker_pool_unavailable_falls_back_to_serial(monkeypatch):
    """Non-fork start methods (or sandboxed fork) must degrade to serial
    evaluation, not crash with a None _WORKER_CTX."""
    monkeypatch.setattr(dp_search, "_fork_pool", lambda n: None)
    cluster = paper_case_study_cluster()
    _, tables = make_tables(cluster, granularity=16)
    cfg = SearchConfig(n_microbatches=16, n_workers=4)
    s_par = search(cluster, tables, 1024, cfg)
    s_ser = search(cluster, tables, 1024,
                   SearchConfig(n_microbatches=16, n_workers=0))
    assert s_par.to_json() == s_ser.to_json()


def test_instrumented_search_public_stats():
    """The benchmark-facing hook: stats describe the run without touching
    any private symbol, and serialize cleanly."""
    cluster = tiny_cluster()
    _, tables = make_tables(cluster)
    strat, stats = instrumented_search(cluster, tables, 1024,
                                       SearchConfig(n_microbatches=8))
    assert isinstance(stats, SearchStats)
    assert stats.n_evaluated + stats.n_cache_served > 0
    assert stats.n_tmax_candidates >= stats.n_evaluated
    assert stats.prune_evals > 0
    assert stats.t_S <= stats.best_t_max <= stats.t_E * (1 + 1e-12)
    assert stats.total_seconds > 0
    d = json.loads(json.dumps(stats.asdict()))
    assert d["engine"] == "vectorized"
    # search() returns the same strategy
    assert search(cluster, tables, 1024,
                  SearchConfig(n_microbatches=8)).to_json() == strat.to_json()


def test_controller_decisions_record_sim_memo_counters():
    """Satellite: replay traces must show when a re-plan was cache-served —
    decisions carry the pipesim-memo hit/miss delta."""
    from repro.core.planner import PlannerConfig
    ctrl = ElasticController(
        paper_case_study_cluster(), "gpt-2b",
        planner_cfg=PlannerConfig(granularity=12, n_microbatches=16),
        cfg=ControllerConfig(total_steps=2000, seq_len=512, global_batch=16,
                             amortize=False))
    ctrl.bootstrap()
    d0 = ctrl.decisions[0]
    assert d0.sim_memo_misses + d0.sim_memo_hits > 0, \
        "bootstrap ran simulations but recorded no memo traffic"
    # same-signature replan path: the bandwidth retune re-simulates the
    # same schedule shape; counters must be populated either way
    d1 = ctrl.handle(BandwidthShift(step=10, cross_bw=4.0 * GBPS))
    assert (d1.sim_memo_hits, d1.sim_memo_misses) != (0, 0)
    assert f"sim-cache {d1.sim_memo_hits}h" in d1.describe()
    # an identical second event is served from warm caches: hits, no misses
    d2 = ctrl.handle(BandwidthShift(step=20, cross_bw=4.0 * GBPS))
    assert d2.sim_memo_hits > 0 and d2.sim_memo_misses == 0
