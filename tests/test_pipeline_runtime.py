"""SPMD pipeline runtime equivalence: the shard_map+ppermute pipeline over a
(2,2,2) host-device mesh computes the SAME loss and gradients as the plain
single-device model.

Multi-device host platforms require XLA_FLAGS before jax init, so these run
in a subprocess (tests otherwise see 1 device)."""
import json
import os
import subprocess
import sys

import jax
import pytest

# The pipeline body is *partial-manual* (manual over pod, auto over
# data/model) and reads axis_index inside it.  jax 0.4.x's SPMD partitioner
# rejects the resulting PartitionId op ("meaning is ambiguous"); the program
# is only expressible on jax versions with the first-class jax.shard_map API.
pytestmark = [
    pytest.mark.slow,   # several minutes per arch — tier-1 fast job skips
    pytest.mark.skipif(
        not hasattr(jax, "shard_map"),
        reason="partial-auto shard_map pipeline needs jax.shard_map (new "
               "jax); 0.4.x SPMD partitioning rejects PartitionId in "
               "partial-manual bodies"),
]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import build_model
from repro.parallel.staging import build_staging
from repro.parallel.pipeline import pipeline_loss_fn

arch = sys.argv[1]
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
cfg = get_config(arch).reduced()
model = build_model(cfg, param_dtype=jnp.float32)
params = model.init(k1)
B, T = 8, 32
batch = {"tokens": jax.random.randint(k2, (B, T), 0, cfg.vocab_size),
         "labels": jax.random.randint(k3, (B, T), 0, cfg.vocab_size)}
if cfg.family == "vlm":
    batch["image_embeds"] = 0.1 * jax.random.normal(
        k2, (B, cfg.n_image_tokens, cfg.d_model))
ref_loss, _ = model.loss(params, batch)
ref_g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
ref_gn = float(jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(ref_g))))

st = build_staging(cfg, 2, params, act_dtype=jnp.float32)
loss_fn = pipeline_loss_fn(st, mesh, n_microbatches=4)
from repro.compat import set_mesh
with set_mesh(mesh):
    loss, _ = jax.jit(loss_fn)(st.staged, st.shared, st.consts, batch)
    g = jax.jit(jax.grad(lambda s, sh: loss_fn(s, sh, st.consts, batch)[0],
                         argnums=(0, 1)))(st.staged, st.shared)
    gn = float(jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32)**2)
                            for x in jax.tree.leaves(g))))
print(json.dumps({"ref": float(ref_loss), "pipe": float(loss),
                  "ref_gn": ref_gn, "pipe_gn": gn}))
"""


def _run(arch):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT, arch],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["deepseek-7b", "mamba2-2.7b", "zamba2-7b"])
def test_pipeline_matches_reference(arch):
    r = _run(arch)
    assert abs(r["ref"] - r["pipe"]) < 5e-3, r
    # gradient magnitudes agree (elementwise equality checked in dev runs;
    # the norm catches wiring errors like dropped stages or double-counting)
    assert abs(r["ref_gn"] - r["pipe_gn"]) / r["ref_gn"] < 0.05, r


@pytest.mark.slow
def test_moe_pipeline_close():
    """MoE capacity effects differ per-microbatch; losses are close, not
    equal."""
    r = _run("qwen3-moe-235b-a22b")
    assert abs(r["ref"] - r["pipe"]) < 0.1, r
