"""repro.kbench: measured-kernel cost model (ISSUE 8 acceptance).

Covers the off-state invariant (``kbench=None`` plans bit-identical to the
pre-kbench golden pin), measured pricing + analytic fallback, the latency
table's round-trip / interpolation / merge determinism, kernel numerics
across autotuned block configs (incl. non-multiple shapes), the tuned-block
registry, telemetry anchor seeding, and the config/CLI surface.
"""
import json
import os

import numpy as np
import pytest

from repro import api
from repro.core.cluster import paper_case_study_cluster
from repro.core.planner import PlannerConfig
from repro.kbench import (
    KBenchConfig, KBenchModel, KernelMeasurement, LatencyTable, shape_bucket,
)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "kbench_offstate_strategy.json")


def small_cfg(**planner_kw):
    return api.HarpConfig(
        seq_len=512, global_batch=16,
        planner=PlannerConfig(granularity=16, n_microbatches=16,
                              **planner_kw))


def strip_times(strategy_json: str):
    d = json.loads(strategy_json)
    d["planner_meta"] = {k: v for k, v in d["planner_meta"].items()
                         if not k.startswith("time_")}
    return d


def meas(device="A100-40G", op="flash_attention", shape=(2, 512, 512, 16, 16, 64),
         median_s=0.001, flops=None, blocks=(128, 128), collected_at=1000.0,
         host="h1", trials=5):
    if flops is None:
        flops = 0.45 * median_s * 312e12        # 45% of A100 peak
    return KernelMeasurement(device=device, op=op, shape=tuple(shape),
                             median_s=median_s, trials=trials, flops=flops,
                             blocks=blocks, collected_at=collected_at,
                             host=host)


# ---------------------------------------------------------------------------
# Latency table: round-trip, interpolation, merge determinism
# ---------------------------------------------------------------------------


def test_shape_bucket_rounds_up_to_pow2():
    assert shape_bucket((200, 130)) == (256, 256)
    assert shape_bucket((256, 128)) == (256, 128)
    assert shape_bucket((1, 3)) == (1, 4)


def test_table_json_round_trip_bit_identical(tmp_path):
    t = LatencyTable([meas(), meas(op="rmsnorm", shape=(256, 128),
                                   blocks=(128,), median_s=2e-5)])
    path = str(tmp_path / "t.json")
    t.save(path)
    t2 = LatencyTable.load(path)
    assert t2.to_dict() == t.to_dict()
    assert t2.fingerprint() == t.fingerprint()


def test_table_rejects_newer_schema():
    with pytest.raises(ValueError, match="newer"):
        LatencyTable.from_dict({"schema": 99, "entries": []})


def test_lookup_prefers_exact_then_nearest_bucket():
    near = meas(op="rmsnorm", shape=(256, 128), blocks=None, median_s=1e-5)
    far = meas(op="rmsnorm", shape=(4096, 2048), blocks=None, median_s=9e-4)
    t = LatencyTable([near, far])
    assert t.lookup("A100-40G", "rmsnorm", (256, 128)) == near
    # (300, 160) buckets to (512, 256) — still nearer the small cell
    assert t.lookup("A100-40G", "rmsnorm", (300, 160)) == near
    assert t.lookup("A100-40G", "rmsnorm", (3000, 1500)) == far
    assert t.lookup("A100-40G", "rmsnorm", (256,)) is None      # rank mismatch
    assert t.lookup("V100-32G", "rmsnorm", (256, 128)) is None  # wrong device


def test_estimate_scales_by_flop_ratio():
    e = meas(op="rmsnorm", shape=(256, 128), blocks=None, median_s=1e-5,
             flops=4.0 * 256 * 128)
    t = LatencyTable([e])
    # double the FLOPs -> double the estimate
    got = t.estimate_s("A100-40G", "rmsnorm", (512, 128),
                       flops=2 * 4.0 * 256 * 128)
    assert got == pytest.approx(2e-5)
    assert t.estimate_s("A100-40G", "flash_attention", (1, 1)) is None


def test_merge_newer_stamp_wins_and_is_commutative():
    old = meas(median_s=5e-4, collected_at=100.0)
    new = meas(median_s=1e-3, collected_at=200.0)
    a, b = LatencyTable([old]), LatencyTable([new])
    ab, ba = a.merge(b), b.merge(a)
    assert ab.to_dict() == ba.to_dict()          # deterministic merge
    assert len(ab) == 1
    assert ab.entries[0].median_s == 1e-3        # newer stamp won
    # equal stamps: the lower latency (better-conditioned run) wins
    tie = LatencyTable([meas(median_s=2e-3, collected_at=200.0)])
    assert b.merge(tie).entries[0].median_s == 1e-3
    assert tie.merge(b).entries[0].median_s == 1e-3


def test_merge_distinct_keys_accumulate():
    a = LatencyTable([meas()])
    b = LatencyTable([meas(blocks=(64, 64)),
                      meas(device="V100-32G")])
    assert len(a.merge(b)) == 3


def test_best_blocks_reads_back_the_winner():
    t = LatencyTable([
        meas(op="rmsnorm", shape=(256, 128), blocks=(128,), median_s=3e-5),
        meas(op="rmsnorm", shape=(256, 128), blocks=(256,), median_s=1e-5),
    ])
    assert t.best_blocks("A100-40G", "rmsnorm", (256, 128)) == (256,)
    assert t.best_blocks("A100-40G", "rmsnorm", (250, 100)) == (256,)
    assert t.best_blocks("A100-40G", "rmsnorm", (256,)) is None


def test_fresh_filters_stale_entries():
    t = LatencyTable([meas(collected_at=100.0),
                      meas(blocks=(64, 64), collected_at=1000.0)])
    assert len(t.fresh(0.0)) == 2                # 0 = never stale
    fresh = t.fresh(500.0)                       # "now" = newest stamp (1000)
    assert [e.blocks for e in fresh.entries] == [(64, 64)]


# ---------------------------------------------------------------------------
# Off-state invariant: kbench=None plans bit-identical to the pre-PR pin
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


def test_offstate_inter_plan_matches_golden(golden):
    p = api.plan("gpt-2b", paper_case_study_cluster(), small_cfg())
    assert strip_times(p.strategy.to_json()) == golden["inter"], (
        "kbench=None inter-op plan drifted from tests/golden/"
        "kbench_offstate_strategy.json — the off-state invariant "
        "(kbench=None bit-identical to pre-kbench pricing) is broken.")


def test_offstate_joint_plan_matches_golden(golden):
    p = api.plan("gpt-2b", paper_case_study_cluster(),
                 small_cfg(intra_op=True))
    assert strip_times(p.strategy.to_json()) == golden["joint"]


def test_empty_table_falls_back_to_analytic_exactly(golden):
    """An *enabled* but uncovering kbench prices exactly like analytic —
    only the provenance stamp differs."""
    p = api.plan("gpt-2b", paper_case_study_cluster(),
                 small_cfg(kbench=KBenchConfig()))
    d = strip_times(p.strategy.to_json())
    stamp = d["planner_meta"].pop("kbench")
    assert d == golden["inter"]
    assert stamp["cells"] == 0


def test_missing_table_file_never_errors(golden):
    """Fallback-never-errors invariant: a dangling table_path is an empty
    table, not an exception."""
    cfg = KBenchConfig(table_path="/nonexistent/ktable.json")
    p = api.plan("gpt-2b", paper_case_study_cluster(), small_cfg(kbench=cfg))
    d = strip_times(p.strategy.to_json())
    d["planner_meta"].pop("kbench")
    assert d == golden["inter"]


# ---------------------------------------------------------------------------
# Measured pricing
# ---------------------------------------------------------------------------


def test_measured_table_changes_stage_prices(golden):
    t = LatencyTable([meas()])                   # A100 at 45% achieved MFU
    p = api.plan("gpt-2b", paper_case_study_cluster(),
                 small_cfg(kbench=KBenchConfig(table=t.to_dict())))
    assert p.strategy.est_step_time != golden["inter"]["est_step_time"]
    stamp = p.strategy.planner_meta["kbench"]
    assert stamp["cells"] == 1
    assert "A100-40G" in stamp["covered_devices"]


def test_measured_mfu_is_flop_weighted_and_clamped():
    cl = paper_case_study_cluster()
    a100 = next(s for s in cl.subclusters if s.device.name == "A100-40G")
    v100 = next(s for s in cl.subclusters if s.device.name == "V100-32G")
    t = LatencyTable([meas()])
    m = KBenchModel(KBenchConfig(table=t.to_dict()))
    assert m.measured_mfu(a100) == pytest.approx(0.45, rel=1e-6)
    assert m.measured_mfu(v100) is None          # uncovered -> analytic
    # a corrupt cell claiming >peak throughput clamps to 1.0
    hot = LatencyTable([meas(flops=10 * 0.001 * 312e12)])
    mh = KBenchModel(KBenchConfig(table=hot.to_dict()))
    assert mh.measured_mfu(a100) == 1.0


def test_device_map_routes_profile_names_to_fingerprints():
    cl = paper_case_study_cluster()
    a100 = next(s for s in cl.subclusters if s.device.name == "A100-40G")
    t = LatencyTable([meas(device="gpu:NVIDIA A100-SXM4-40GB")])
    unmapped = KBenchModel(KBenchConfig(table=t.to_dict()))
    assert unmapped.measured_mfu(a100) is None
    mapped = KBenchModel(KBenchConfig(
        table=t.to_dict(),
        device_map={"A100-40G": "gpu:NVIDIA A100-SXM4-40GB"}))
    assert mapped.measured_mfu(a100) == pytest.approx(0.45, rel=1e-6)


def test_kbench_fingerprint_tracks_table_content():
    m1 = KBenchModel(KBenchConfig(table=LatencyTable([meas()]).to_dict()))
    m2 = KBenchModel(KBenchConfig(
        table=LatencyTable([meas(median_s=0.002)]).to_dict()))
    m3 = KBenchModel(KBenchConfig(table=LatencyTable([meas()]).to_dict()))
    assert m1.fingerprint() != m2.fingerprint()  # cost-cache key must split
    assert m1.fingerprint() == m3.fingerprint()
    assert m1.fingerprint().startswith("kbench:")


def test_measure_fn_adapter_prices_with_the_anchor():
    from repro.api.facade import _build_layers
    from repro.core.costmodel import CostModelConfig, Submesh, stage_cost
    from repro.configs import get_config

    cl = paper_case_study_cluster()
    a100 = next(s for s in cl.subclusters if s.device.name == "A100-40G")
    layers = _build_layers(get_config("gpt-2b"), small_cfg())
    mesh = Submesh(0, 1, 2)
    kb = KBenchModel(KBenchConfig(
        table=LatencyTable([meas()]).to_dict()))
    fn = kb.as_measure_fn()
    got = fn(layers[:4], a100, mesh, 512)
    want = stage_cost(layers[:4], a100, mesh, 512, CostModelConfig(),
                      kbench=kb)
    assert got.t == want.t
    analytic = stage_cost(layers[:4], a100, mesh, 512, CostModelConfig())
    assert got.t != analytic.t


# ---------------------------------------------------------------------------
# Kernel numerics across block configs (autotuned blocks stay correct)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("blocks", [(64, 64), (64, 128), (128, 64),
                                    (256, 256)])
@pytest.mark.parametrize("T", [128, 200])        # incl. non-multiple length
def test_flash_attention_correct_for_all_swept_blocks(blocks, T):
    import jax
    from repro.kernels import ops
    from repro.kernels.ref import flash_attention_ref

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, T, 2, 32))
    k = jax.random.normal(ks[1], (1, T, 2, 32))
    v = jax.random.normal(ks[2], (1, T, 2, 32))
    out = ops.flash_attention(q, k, v, causal=True,
                              block_q=blocks[0], block_k=blocks[1])
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("block_rows", [32, 64, 256])
@pytest.mark.parametrize("rows", [256, 200])     # incl. non-multiple rows
def test_rmsnorm_correct_for_all_swept_blocks(block_rows, rows):
    import jax
    from repro.kernels import ops
    from repro.kernels.ref import rmsnorm_ref

    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    x = jax.random.normal(ks[0], (rows, 128))
    w = jax.random.normal(ks[1], (128,))
    out = ops.rmsnorm(x, w, block_rows=block_rows)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rmsnorm_ref(x, w)),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_kernel_pads_non_multiple_shapes():
    """Satellite (a): the fwd kernel itself (not just the ops wrapper)
    accepts lengths that don't divide the block sizes."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention import flash_attention_fwd
    from repro.kernels.ref import flash_attention_ref

    B, T, H, D = 1, 130, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, H, D))
    v = jax.random.normal(ks[2], (B, T, H, D))
    # the kernel layer works in (B, H, T, D) layout (ops.py transposes)
    to_k = lambda x: jnp.swapaxes(x, 1, 2)
    out, _ = flash_attention_fwd(to_k(q), to_k(k), to_k(v), causal=True,
                                 block_q=128, block_k=128, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(to_k(out)), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_tuned_block_registry_round_trip():
    from repro.kernels import ops

    ops.clear_tuned_blocks()
    try:
        ops.set_tuned_blocks("rmsnorm", (256, 128), (256,))
        assert ops.tuned_blocks("rmsnorm", (256, 128)) == (256,)
        # nearest same-rank shape resolves to the tuned entry
        assert ops.tuned_blocks("rmsnorm", (300, 128)) == (256,)
        assert ops.tuned_blocks("rmsnorm", (256,)) is None
        assert ops.tuned_blocks("flash_attention", (256, 128)) is None
        ops.clear_tuned_blocks("rmsnorm")
        assert ops.tuned_blocks("rmsnorm", (256, 128)) is None
    finally:
        ops.clear_tuned_blocks()


def test_autotune_install_pushes_winners_into_ops():
    import jax
    from repro.kbench import autotune, harness
    from repro.kernels import ops
    from repro.kernels.ref import rmsnorm_ref

    ops.clear_tuned_blocks()
    try:
        table, sweeps = autotune.collect_autotuned(
            ["rmsnorm"], trials=1, warmup=1)
        assert all(sw.speedup >= 1.0 for sw in sweeps)
        n = autotune.install(table)
        assert n == 1
        tuned = ops.tuned_blocks("rmsnorm", harness.OPS["rmsnorm"].tiny_shape)
        assert tuned == sweeps[0].best_blocks
        # entry point with default args now uses the tuned blocks — and
        # still matches the oracle
        ks = jax.random.split(jax.random.PRNGKey(3), 2)
        x = jax.random.normal(ks[0], (256, 128))
        w = jax.random.normal(ks[1], (128,))
        np.testing.assert_allclose(np.asarray(ops.rmsnorm(x, w)),
                                   np.asarray(rmsnorm_ref(x, w)),
                                   atol=2e-5, rtol=2e-5)
    finally:
        ops.clear_tuned_blocks()


def test_harness_is_deterministic_in_inputs_and_coverage():
    from repro.kbench import harness

    t = harness.collect(["rmsnorm"], trials=1, warmup=1,
                        collected_at=123.0, host="h")
    assert len(t) == 1
    e = t.entries[0]
    assert e.op == "rmsnorm" and e.collected_at == 123.0 and e.host == "h"
    assert e.flops > 0 and e.median_s > 0
    assert e.device.startswith("cpu:") or ":" in e.device


def test_table_and_bridge_import_without_jax():
    """Layering invariant (DESIGN.md): the planner-side kbench modules must
    be importable on machines with no accelerator stack."""
    import subprocess
    import sys

    code = (
        "import builtins\n"
        "real = builtins.__import__\n"
        "def guard(name, *a, **k):\n"
        "    if name == 'jax' or name.startswith('jax.'):\n"
        "        raise ImportError('jax blocked: ' + name)\n"
        "    return real(name, *a, **k)\n"
        "builtins.__import__ = guard\n"
        "import repro.kbench.table, repro.kbench.bridge\n"
        "import repro.core.planner\n")
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    assert res.returncode == 0, res.stderr


# ---------------------------------------------------------------------------
# Telemetry seeding
# ---------------------------------------------------------------------------


def test_telemetry_seeds_anchor_from_table():
    from repro.runtime.telemetry import TelemetryCalibrator

    cl = paper_case_study_cluster()
    kb = KBenchConfig(table=LatencyTable([meas()]).to_dict())
    cal = TelemetryCalibrator()
    seeded = cal.seed_from_kbench(cl, kb)
    # A100 covered at 0.45 achieved MFU over base_mfu 0.50 -> 0.9 anchor
    assert seeded == {"meshA100": pytest.approx(0.9, rel=1e-6)}
    assert cal.efficiency("meshA100") == pytest.approx(0.9, rel=1e-6)
    assert cal.efficiency("meshV100") == 1.0     # uncovered -> untouched
    # an existing EWMA estimate is never overwritten by a seed
    again = cal.seed_from_kbench(cl, kb)
    assert again == {}


# ---------------------------------------------------------------------------
# Config / facade / CLI surface
# ---------------------------------------------------------------------------


def test_harp_config_kbench_round_trip():
    kb = KBenchConfig(table_path="ktable.json", max_age_s=3600.0,
                      device_map={"A100-40G": "gpu:A100"})
    cfg = api.HarpConfig(kbench=kb)
    assert cfg.planner.kbench == kb              # mirrored into the planner
    d = json.loads(cfg.to_json())
    cfg2 = api.HarpConfig.from_dict(d)
    assert cfg2.kbench == kb
    assert cfg2.planner.kbench == kb


def test_harp_config_rejects_kbench_disagreement():
    kb1 = KBenchConfig(table_path="a.json")
    kb2 = KBenchConfig(table_path="b.json")
    with pytest.raises(ValueError, match="kbench"):
        api.HarpConfig(kbench=kb1,
                       planner=PlannerConfig(kbench=kb2)).validate()


def test_plan_artifact_round_trips_kbench_config():
    t = LatencyTable([meas()])
    p = api.plan("gpt-2b", paper_case_study_cluster(),
                 small_cfg(kbench=KBenchConfig(table=t.to_dict())))
    p2 = api.Plan.from_json(p.to_json())
    assert p2.to_json() == p.to_json()
    exe = api.compile(plan_artifact=p2)
    assert exe.config.planner.kbench.table == t.to_dict()


def test_explain_costs_reports_pricing_source():
    exe = api.compile("gpt-2b", paper_case_study_cluster(), small_cfg())
    off = exe.explain_costs()
    assert "analytic" in off and "kbench: off" in off

    t = LatencyTable([meas()])
    exe2 = api.compile("gpt-2b", paper_case_study_cluster(),
                       small_cfg(kbench=KBenchConfig(table=t.to_dict())))
    on = exe2.explain_costs()
    assert "measured" in on and "kbench table: 1 cells" in on


def test_cli_kbench_collect_merge_show(tmp_path, capsys):
    from repro.api.cli import main

    pa = str(tmp_path / "a.json")
    pb = str(tmp_path / "b.json")
    pm = str(tmp_path / "m.json")
    assert main(["kbench", "collect", "--ops", "rmsnorm", "--trials", "1",
                 "--warmup", "1", "-o", pa]) == 0
    assert main(["kbench", "collect", "--ops", "rmsnorm", "--trials", "1",
                 "--warmup", "1", "--device", "other:dev", "-o", pb]) == 0
    assert main(["kbench", "merge", pa, pb, "-o", pm]) == 0
    assert len(LatencyTable.load(pm)) == 2
    assert main(["kbench", "show", pm]) == 0
    out = capsys.readouterr().out
    assert "rmsnorm" in out and "other:dev" in out
