"""repro.serving: heterogeneity-aware inference planning (ISSUE 6).

Covers the acceptance contract: KV-bound arithmetic vs hand-computed bytes
(and byte-for-byte vs the real ``models.*.init_cache`` shapes),
prefill == step-by-step-decode logit equivalence through the api
``generate`` path's building blocks, ServePlan JSON round-trip with a
golden schema pin, deterministic tiny-trace simulation, admission control
that rejects instead of OOMing, and — on the fig10 mixed fleet with a
seeded Poisson trace — the searched disaggregated placement beating the
colocated-uniform baseline on p99 TTFT at equal offered QPS.
"""
import dataclasses
import json
import math
import os

import pytest

from repro import api
from repro.configs import get_config
from repro.core.cluster import (
    A100_40G, GBPS, HeteroCluster, SubCluster, paper_eval_cluster,
)
from repro.core.planner import PlannerConfig
from repro.serving import kvplan
from repro.serving.batching import simulate_trace
from repro.serving.objective import percentile, score
from repro.serving.placement import (
    PoolSpec, ServePlan, ServingConfig, colocated_plan, search_placement,
)
from repro.serving.workload import (
    Request, ServeTrace, poisson_trace, scripted_trace,
)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "serve_plan_schema.json")


def fig10_cluster() -> HeteroCluster:
    """The fig10 mixed fleet (2x8 A100 + 2x8 V100, 5 Gbps cross)."""
    return paper_eval_cluster(n_a100_nodes=2, n_v100_nodes=2)


def fig10_scfg(**kw) -> ServingConfig:
    """The acceptance workload: a queueing-dominated regime where uniform
    routing saturates the slow pool."""
    kw.setdefault("qps", 1600.0)
    kw.setdefault("duration_s", 1.0)
    kw.setdefault("prompt_mean", 256)
    kw.setdefault("output_mean", 64)
    kw.setdefault("search_sample", 400)
    return ServingConfig(**kw)


@pytest.fixture(scope="module")
def fig10_case():
    """(scfg, searched plan, colocated baseline plan, full trace) — searched
    once per module; every consumer treats the plans as immutable."""
    scfg = fig10_scfg()
    cluster = fig10_cluster()
    arch = get_config("gemma-2b")
    trace = poisson_trace(scfg.qps, scfg.duration_s, seed=scfg.seed,
                          prompt_mean=scfg.prompt_mean,
                          output_mean=scfg.output_mean)
    best = search_placement(arch, cluster, scfg, trace=trace)
    base = colocated_plan(arch, cluster, scfg)
    return scfg, best, base, trace


# ---------------------------------------------------------------------------
# Workload traces
# ---------------------------------------------------------------------------


def test_poisson_trace_deterministic_per_seed():
    a = poisson_trace(100.0, 0.5, seed=7)
    b = poisson_trace(100.0, 0.5, seed=7)
    c = poisson_trace(100.0, 0.5, seed=8)
    assert a.to_dict() == b.to_dict()
    assert a.to_dict() != c.to_dict()
    assert a.n_requests > 10
    arr = [r.arrival_s for r in a.requests]
    assert arr == sorted(arr)


def test_trace_json_round_trip():
    t = poisson_trace(50.0, 0.5, seed=3)
    assert ServeTrace.from_dict(t.to_dict()).to_dict() == t.to_dict()


def test_trace_remapped_rescales_qps_keeping_lengths():
    t = poisson_trace(100.0, 1.0, seed=1)
    fast = t.remapped(200.0)
    assert fast.qps == pytest.approx(200.0)
    assert [(r.prompt_tokens, r.output_tokens) for r in fast.requests] \
        == [(r.prompt_tokens, r.output_tokens) for r in t.requests]


def test_trace_take_prefix():
    t = poisson_trace(100.0, 1.0, seed=0)
    assert t.take(5).n_requests == 5
    assert t.take(5).requests == t.requests[:5]
    assert t.take(10 ** 9) is t


def test_scripted_trace_even_spacing():
    t = scripted_trace(10.0, 5, prompt_tokens=32, output_tokens=8)
    assert [r.arrival_s for r in t.requests] \
        == pytest.approx([0.0, 0.1, 0.2, 0.3, 0.4])
    assert all(r.prompt_tokens == 32 and r.output_tokens == 8
               for r in t.requests)


# ---------------------------------------------------------------------------
# KV-bound arithmetic (Eq. 18 analog)
# ---------------------------------------------------------------------------


def test_kv_bytes_per_token_hand_computed():
    # gemma-2b: 18 layers, 1 KV head x 256 head_dim, K+V at 2 bytes
    cfg = get_config("gemma-2b")
    assert kvplan.kv_bytes_per_token(cfg, 2.0) == 18 * 2 * 1 * 256 * 2.0
    # zamba2: one shared attention application every 6 SSM layers
    hyb = get_config("zamba2-7b")
    n_apps = hyb.n_layers // hyb.shared_attn_every
    assert kvplan.kv_bytes_per_token(hyb, 2.0) \
        == n_apps * 2 * hyb.kv_dim * 2.0
    # pure SSM appends no per-token KV; its state is fixed f32
    ssm = get_config("mamba2-2.7b")
    assert kvplan.kv_bytes_per_token(ssm, 2.0) == 0.0
    per_layer = (ssm.n_ssm_heads * ssm.ssm_head_dim * ssm.ssm_state
                 + (ssm.ssm_conv - 1) * (ssm.d_inner + 2 * ssm.ssm_state))
    assert kvplan.state_bytes_per_seq(ssm) == ssm.n_layers * 4.0 * per_layer


@pytest.mark.parametrize("arch", [
    "gemma-2b", "granite-moe-1b-a400m", "mamba2-2.7b", "zamba2-7b",
    "llama-3.2-vision-90b", "whisper-medium",
])
def test_kv_accounting_matches_model_cache_bytes(arch):
    """The planner's byte formulas equal the real decode-cache footprint
    (f32 cache) for every unwindowed family."""
    import jax
    import jax.numpy as jnp

    from repro.models import build_model

    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    B, S = 2, 8
    cache = model.init_cache(B, S, dtype=jnp.float32)
    nbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
    pred = B * S * kvplan.kv_bytes_per_token(cfg, 4.0) \
        + B * kvplan.state_bytes_per_seq(cfg, 4.0)
    assert pred == pytest.approx(nbytes)


def test_windowed_charge_is_conservative():
    """Sliding-window archs are charged at the full-attention rate: the
    bound may over-reserve, never under-reserve."""
    import jax
    import jax.numpy as jnp

    from repro.models import build_model

    cfg = get_config("gemma3-12b").reduced()
    model = build_model(cfg)
    B, S = 2, 64
    cache = model.init_cache(B, S, dtype=jnp.float32)
    nbytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))
    pred = B * S * kvplan.kv_bytes_per_token(cfg, 4.0) \
        + B * kvplan.state_bytes_per_seq(cfg, 4.0)
    assert pred >= nbytes


def test_blocks_for_seq_rounding():
    cfg = get_config("gemma-2b")
    # 100 tokens in 16-token blocks -> ceil = 7; no fixed state
    assert kvplan.blocks_for_seq(cfg, 100, 16) == 7
    assert kvplan.blocks_for_seq(cfg, 96, 16) == 6
    # pure SSM degenerates to one per-sequence slot
    assert kvplan.blocks_for_seq(get_config("mamba2-2.7b"), 10_000, 16) == 1


def test_decode_capacity_hand_computed():
    cfg = get_config("gemma-2b")
    sub = SubCluster("toy", 1, 2, A100_40G, 300e9, 200 * GBPS)  # 2x40 GB
    weights = 10e9
    bound = kvplan.decode_capacity(cfg, sub, weights_bytes=weights,
                                   block_tokens=16, dtype_bytes=2.0,
                                   mem_headroom=0.9)
    bb = 16 * kvplan.kv_bytes_per_token(cfg, 2.0)
    free = 0.9 * 2 * A100_40G.mem_bytes - weights
    assert bound.block_bytes == bb
    assert bound.blocks_capacity == int(free // bb)
    # weights that don't fit -> zero capacity, never negative
    huge = kvplan.decode_capacity(cfg, sub, weights_bytes=1e15,
                                  block_tokens=16)
    assert huge.blocks_capacity == 0


# ---------------------------------------------------------------------------
# Objective
# ---------------------------------------------------------------------------


def test_percentile_linear_interpolation():
    xs = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs, 50) == pytest.approx(2.5)
    assert percentile(xs, 100) == 4.0
    assert percentile(xs, 0) == 1.0
    assert percentile([], 99) == 0.0
    assert percentile([5.0], 99) == 5.0


def _result(n_completed=10, n_rejected=0, ttft=0.01, tpot=0.001,
            goodput=1000):
    from repro.serving.batching import ServeSimResult
    return ServeSimResult(
        n_completed=n_completed, n_rejected=n_rejected,
        ttft_s=[ttft] * n_completed, tpot_s=[tpot] * n_completed,
        makespan_s=1.0, completed_output_tokens=goodput,
        goodput_output_tokens=goodput, slo_ttft_s=0.2, slo_tpot_s=0.02)


def test_score_tiers_rejections_dominate_slo_dominates_latency():
    ok = score(_result(), "slo", slo_ttft_s=0.2, slo_tpot_s=0.02)
    slow = score(_result(ttft=0.05), "slo", slo_ttft_s=0.2, slo_tpot_s=0.02)
    violating = score(_result(ttft=0.5), "slo",
                      slo_ttft_s=0.2, slo_tpot_s=0.02)
    rejecting = score(_result(n_rejected=5), "slo",
                      slo_ttft_s=0.2, slo_tpot_s=0.02)
    assert ok < slow < violating < rejecting
    with pytest.raises(ValueError):
        score(_result(), "nope", slo_ttft_s=0.2, slo_tpot_s=0.02)


# ---------------------------------------------------------------------------
# Simulator: determinism + admission control
# ---------------------------------------------------------------------------


def _toy_plan(blocks_capacity=40, max_queue=8, routing="uniform"):
    """Hand-built single-pool plan with exactly controllable KV capacity."""
    pool = PoolSpec(
        name="toy", cluster_idx=0, role="mixed", n_devices=1,
        weights_bytes=1e9, block_bytes=16 * 1000.0,
        blocks_capacity=blocks_capacity, prefill_chunk_s=1e-3,
        hbm_bytes_per_s=1e12, decode_flops_per_s=1e12)
    return ServePlan(
        arch="toy", objective="slo", routing=routing, prefill_chunk=256,
        block_tokens=16, kv_bytes_per_token=1000.0, state_bytes_per_seq=0.0,
        flops_per_token=1e9, step_overhead_s=1e-4, max_queue=max_queue,
        slo_ttft_s=0.2, slo_tpot_s=0.02, pools=[pool])


def test_simulator_deterministic():
    plan = _toy_plan()
    trace = poisson_trace(100.0, 0.3, seed=5, prompt_mean=64, output_mean=8)
    a = simulate_trace(plan, trace)
    b = simulate_trace(plan, trace)
    assert a.summary() == b.summary()
    assert a.ttft_s == b.ttft_s and a.tpot_s == b.tpot_s


def test_scripted_trace_completes_at_low_load():
    plan = _toy_plan()
    trace = scripted_trace(5.0, 10, prompt_tokens=64, output_tokens=8)
    res = simulate_trace(plan, trace)
    assert res.n_completed == 10 and res.n_rejected == 0
    assert res.kv_violations == 0
    assert res.n_handoffs == 0          # single pool: KV never ships


def test_admission_control_rejects_never_ooms():
    # capacity = 2 concurrent worst-case sequences (20 blocks each); a burst
    # of 30 must reject the overflow, and the block bound must hold
    plan = _toy_plan(blocks_capacity=40, max_queue=4)
    assert plan.seq_blocks(256 + 64) == 20
    trace = scripted_trace(5000.0, 30, prompt_tokens=256, output_tokens=64)
    res = simulate_trace(plan, trace)
    assert res.n_rejected > 0
    assert res.n_completed + res.n_rejected == 30
    assert res.kv_violations == 0
    for name, peak in res.peak_blocks.items():
        assert peak <= res.blocks_capacity[name]


def test_seq_blocks_matches_kvplan():
    cfg = get_config("gemma-2b")
    plan = _toy_plan()
    plan = dataclasses.replace(
        plan, kv_bytes_per_token=kvplan.kv_bytes_per_token(cfg, 2.0),
        state_bytes_per_seq=kvplan.state_bytes_per_seq(cfg, 2.0))
    for seq in (1, 15, 16, 17, 100, 1000):
        assert plan.seq_blocks(seq) == kvplan.blocks_for_seq(cfg, seq, 16)


# ---------------------------------------------------------------------------
# fig10 acceptance: disaggregated beats colocated-uniform on p99 TTFT
# ---------------------------------------------------------------------------


def test_fig10_searched_beats_colocated_p99_ttft(fig10_case):
    scfg, best, base, trace = fig10_case
    searched = simulate_trace(best, trace)
    colocated = simulate_trace(base, trace)
    # equal offered load, strictly better tail latency
    assert searched.p99_ttft_s < colocated.p99_ttft_s
    # the KV bound is never violated on either plan; peaks stay in budget
    for res in (searched, colocated):
        assert res.kv_violations == 0
        for name, peak in res.peak_blocks.items():
            assert peak <= res.blocks_capacity[name]
    # the search disaggregates: not every pool is left in the mixed role
    assert any(p.role != "mixed" for p in best.pools)
    assert all(p.role == "mixed" for p in base.pools)


def test_fig10_plan_records_predicted_and_baseline(fig10_case):
    _, best, _, _ = fig10_case
    assert best.predicted and best.baseline
    assert best.predicted["p99_ttft_s"] < best.baseline["p99_ttft_s"]
    assert best.predicted["kv_violations"] == 0


def test_serve_plan_json_round_trip(fig10_case):
    _, best, _, _ = fig10_case
    s = json.dumps(best.to_dict(), indent=2)
    back = ServePlan.from_dict(json.loads(s))
    assert json.dumps(back.to_dict(), indent=2) == s


def _schema(obj):
    """Key-tree + JSON-type skeleton (mirrors tests/test_api.py)."""
    if isinstance(obj, dict):
        return {k: _schema(v) for k, v in sorted(obj.items())}
    if isinstance(obj, list):
        return [_schema(obj[0])] if obj else []
    if isinstance(obj, bool):
        return "bool"
    if isinstance(obj, int):
        return "int"
    if isinstance(obj, float):
        return "float"
    if isinstance(obj, str):
        return "str"
    assert obj is None, f"unexpected JSON type {type(obj)}"
    return "null"


def test_serve_plan_schema_matches_golden(fig10_case):
    _, best, _, _ = fig10_case
    with open(GOLDEN) as f:
        golden = json.load(f)
    assert _schema(best.to_dict()) == golden, (
        "ServePlan JSON schema drifted from tests/golden/"
        "serve_plan_schema.json.  If the change is INTENTIONAL, bump "
        "repro.serving.placement.SERVE_SCHEMA_VERSION and regenerate the "
        "golden file; otherwise you broke the serve section of the plan "
        "artifact.")


def test_cost_cache_reused_across_searches():
    """A second search on the same fleet re-uses every stage-cost entry
    (the profiler's key recipe — no re-pricing)."""
    cache = {}
    scfg = fig10_scfg(duration_s=0.1, search_sample=50)
    arch = get_config("gemma-2b")
    search_placement(arch, fig10_cluster(), scfg, cost_cache=cache)
    n = len(cache)
    assert n > 0
    search_placement(arch, fig10_cluster(), scfg, cost_cache=cache)
    assert len(cache) == n


# ---------------------------------------------------------------------------
# Facade + CLI integration (schema v4)
# ---------------------------------------------------------------------------


def small_cfg(**kw):
    return api.HarpConfig(
        seq_len=512, global_batch=16,
        planner=PlannerConfig(granularity=16, n_microbatches=16), **kw)


def serving_small_cfg():
    return small_cfg(serving=fig10_scfg(duration_s=0.2, search_sample=100))


def test_plan_serving_off_state_is_training_identical():
    """The off-state invariant (DESIGN.md §7): attaching a ServingConfig
    changes ONLY the serve section and the config's serving field — the
    strategy, predicted step sim, and cluster provenance are bit-identical."""
    cluster = fig10_cluster()
    off = api.plan("gemma-2b", cluster, small_cfg())
    on = api.plan("gemma-2b", cluster, serving_small_cfg())
    assert off.serve is None and on.serve is not None
    d_off, d_on = off.to_dict(), on.to_dict()
    for d in (d_off, d_on):
        # wall-clock provenance varies between any two runs, serving or not
        for k in list(d["strategy"]["planner_meta"]):
            if k.startswith("time_"):
                d["strategy"]["planner_meta"].pop(k)
    assert d_off["strategy"] == d_on["strategy"]
    assert d_off["predicted"] == d_on["predicted"]
    assert d_off["cluster"] == d_on["cluster"]
    d_on["config"]["serving"] = None
    d_on["serve"] = None
    assert d_off == d_on


def test_pre_v4_artifact_still_loads():
    cluster = fig10_cluster()
    d = api.plan("gemma-2b", cluster, small_cfg()).to_dict()
    # a v3 artifact has neither key
    d.pop("serve")
    d["config"].pop("serving")
    p = api.Plan.from_dict(d)
    assert p.serve is None and p.config.serving is None


def test_plan_with_serving_round_trips_and_simulates():
    cluster = fig10_cluster()
    p = api.plan("gemma-2b", cluster, serving_small_cfg())
    s = p.to_json()
    assert api.Plan.from_json(s).to_json() == s
    exe = api.compile(plan_artifact=p)
    res = exe.serve_simulate()
    assert res.n_completed > 0 and res.kv_violations == 0
    # override load through the facade
    res2 = exe.serve_simulate(qps=100.0, duration_s=0.1)
    assert res2.n_completed + res2.n_rejected <= res.n_completed \
        + res.n_rejected
    # a supplied trace is remapped to the requested qps
    t = scripted_trace(10.0, 20, prompt_tokens=64, output_tokens=8)
    res3 = exe.serve_simulate(t, qps=40.0)
    assert res3.n_completed == 20


def test_serve_simulate_without_serving_raises():
    exe = api.compile("gemma-2b", fig10_cluster(), small_cfg())
    with pytest.raises(ValueError, match="serving"):
        exe.serve_simulate()


def test_serving_config_validation_through_harp_config():
    with pytest.raises(ValueError, match="serving"):
        small_cfg(serving=ServingConfig(qps=-1.0)).validate()
    with pytest.raises(ValueError, match="objective"):
        small_cfg(serving=ServingConfig(objective="nope")).validate()


def test_registry_serve_trace_builders():
    scfg = ServingConfig(qps=10.0, duration_s=0.5, prompt_mean=64,
                         output_mean=8)
    t = api.registry.resolve("serve_trace", "poisson")(scfg)
    assert t.n_requests > 0
    t2 = api.registry.resolve("serve_trace", "poisson")(scfg, qps=20.0,
                                                        duration_s=0.25)
    assert t2.to_dict() != t.to_dict()
    s = api.registry.resolve("serve_trace", "scripted")(scfg, n_requests=7)
    assert s.n_requests == 7
    assert s.requests[0].prompt_tokens == 64


def test_cli_plan_serving_simulate_trace(tmp_path, capsys):
    from repro.api.cli import main
    out = tmp_path / "plan.json"
    rc = main(["plan", "--arch", "gemma-2b", "--cluster", "paper_eval",
               "--cluster-kw", "n_a100_nodes=2",
               "--cluster-kw", "n_v100_nodes=2",
               "--granularity", "16", "--microbatches", "16",
               "--global-batch", "16", "--seq-len", "512",
               "--serving", "--qps", "200", "--serving-duration", "0.2",
               "--prompt-mean", "128", "--output-mean", "16",
               "-o", str(out)])
    assert rc == 0 and out.exists()
    plan = api.Plan.from_json(out.read_text())
    assert plan.serve is not None
    assert plan.to_json() == out.read_text()
    assert "ServePlan" in capsys.readouterr().out
    rc = main(["simulate", "--plan", str(out), "--trace", "poisson",
               "--qps", "100", "--duration", "0.1"])
    assert rc == 0
    assert "completed" in capsys.readouterr().out


def test_cli_simulate_trace_without_serving_plan_errors(tmp_path):
    from repro.api.cli import main
    out = tmp_path / "plan.json"
    rc = main(["plan", "--arch", "gpt-2b", "--cluster", "paper_case_study",
               "--granularity", "16", "--microbatches", "16",
               "--global-batch", "16", "--seq-len", "512", "-o", str(out)])
    assert rc == 0
    with pytest.raises(SystemExit, match="serving"):
        main(["simulate", "--plan", str(out), "--trace", "poisson"])


# ---------------------------------------------------------------------------
# Serve step: greedy honored, sampling threads the PRNG key
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_serve_model():
    import jax

    from repro.configs.base import ShapeSpec
    from repro.models.prefill import prefill as run_prefill
    from repro.serve.step import make_serve_step

    cfg = get_config("gemma-2b").reduced()
    shape = ShapeSpec("test_decode", 24, 2, "decode")
    step_g, model, _ = make_serve_step(cfg, shape=shape, greedy=True)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = {"tokens": jax.random.randint(rng, (2, 8), 0, cfg.vocab_size)}
    last, cache = run_prefill(cfg, params, batch, cache_len=24)
    return cfg, shape, model, params, batch, last, cache


def test_serve_step_greedy_matches_argmax(tiny_serve_model):
    import jax.numpy as jnp

    from repro.serve.step import make_serve_step

    cfg, shape, model, params, batch, last, cache = tiny_serve_model
    step_g, _, _ = make_serve_step(cfg, shape=shape, greedy=True)
    tok = jnp.argmax(last[:, -1:], axis=-1).astype(jnp.int32)
    nxt, _ = step_g(params, cache, tok, jnp.int32(8))
    logits, _ = model.decode_step(params, cache, tok, jnp.int32(8))
    assert bool(jnp.all(nxt == jnp.argmax(logits[:, -1:], axis=-1)))
    assert nxt.shape == (2, 1)


def test_serve_step_sampling_honors_greedy_flag(tiny_serve_model):
    """The regression this pins: ``greedy=False`` used to silently run
    argmax.  Now it samples — deterministic per key, temperature-scaled."""
    import jax
    import jax.numpy as jnp

    from repro.serve.step import make_serve_step

    cfg, shape, model, params, batch, last, cache = tiny_serve_model
    step_s, _, _ = make_serve_step(cfg, shape=shape, greedy=False,
                                   temperature=1.0)
    key = jax.random.PRNGKey(42)
    a, _ = step_s(params, cache, batch["tokens"][:, :1], jnp.int32(8), key)
    b, _ = step_s(params, cache, batch["tokens"][:, :1], jnp.int32(8), key)
    assert bool(jnp.all(a == b))        # same key -> same sample
    assert a.shape == (2, 1) and a.dtype == jnp.int32
    # matches categorical on the same logits with the same key
    logits, _ = model.decode_step(params, cache, batch["tokens"][:, :1],
                                  jnp.int32(8))
    want = jax.random.categorical(
        key, logits[:, -1, :].astype(jnp.float32), axis=-1)[:, None]
    assert bool(jnp.all(a == want))


def test_serve_step_rejects_bad_temperature(tiny_serve_model):
    from repro.serve.step import make_serve_step

    cfg, shape, *_ = tiny_serve_model
    with pytest.raises(ValueError, match="temperature"):
        make_serve_step(cfg, shape=shape, greedy=False, temperature=0.0)


# ---------------------------------------------------------------------------
# Prefill == step-by-step decode (dense + MoE + SSM state), fast tier
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["gemma-2b", "granite-moe-1b-a400m",
                                  "mamba2-2.7b"])
def test_prefill_equals_stepwise_decode_logits(arch):
    """The serving contract api.generate relies on: prefilling t0 tokens
    then decoding one-by-one produces the same logits as the full forward
    (f32 cache for exact accumulation)."""
    import jax
    import jax.numpy as jnp

    from repro.models import build_model
    from repro.models.prefill import prefill as run_prefill

    cfg = get_config(arch).reduced()
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(6)
    params = model.init(rng)
    B, T, t0 = 2, 10, 6
    batch = {"tokens": jax.random.randint(rng, (B, T), 0, cfg.vocab_size)}
    full, _ = model.forward(params, batch)
    last, cache = run_prefill(cfg, params,
                              {"tokens": batch["tokens"][:, :t0]},
                              cache_len=T, cache_dtype=jnp.float32)
    errs = [float(jnp.max(jnp.abs(last[:, 0] - full[:, t0 - 1])))]
    for t in range(t0, T):
        lg, cache = model.decode_step(params, cache,
                                      batch["tokens"][:, t:t + 1],
                                      jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(errs) < 5e-4, f"{arch}: decode diverges {max(errs)}"


def test_generate_greedy_deterministic():
    out = api.generate("gemma-2b", batch=2, prompt_len=8, gen_tokens=4,
                       reduced=True)
    out2 = api.generate("gemma-2b", batch=2, prompt_len=8, gen_tokens=4,
                        reduced=True)
    assert out["tokens"].shape == (2, 4)
    assert (out["tokens"] == out2["tokens"]).all()
