"""repro.obs: unified tracing / metrics / drift accounting (ISSUE 10).

Covers the exactness contract (adapter span sums reproduce the engines'
own totals bit for bit), Chrome-trace byte determinism + golden schema
pinning, migration timeline lanes with flow arrows, drift-ledger math
(the 20% pool-slowdown acceptance case), the v8 ``obs`` config off-state,
metrics shims over pre-existing counters, replay/run-log round trips, and
the ``repro trace`` CLI.
"""
import dataclasses
import json
import os

import pytest

from repro import api
from repro.core import paper_case_study_cluster
from repro.core.pipesim import ascii_timeline, sim_memo_stats
from repro.core.planner import PlannerConfig
from repro.migrate import (
    diff_layouts, layout_from_strategy, lost_devices, price_migration,
)
from repro.obs import (
    DriftLedger, MetricsRegistry, ObsConfig, iter_kind, read_runlog,
    render_ascii, sync_from_sim_memo, trace_from_decisions,
    trace_from_migration, trace_from_serve, trace_from_sim, trace_to_chrome,
)

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "obs_trace_schema.json")


def small_cfg(**kw):
    return api.HarpConfig(
        seq_len=512, global_batch=16,
        planner=PlannerConfig(granularity=16, n_microbatches=16), **kw)


@pytest.fixture(scope="module")
def exe_case():
    """Plain compile on the paper's case-study mixed fleet."""
    return api.compile("gpt-2b", paper_case_study_cluster(), small_cfg())


@pytest.fixture(scope="module")
def chaos_run(tmp_path_factory):
    """One elastic chaos replay with the full obs surface wired: drift
    ledger, JSONL run-log, and a Chrome trace with the decision track."""
    d = tmp_path_factory.mktemp("obs")
    log = d / "run.jsonl"
    trace_path = d / "replay_trace.json"
    cfg = small_cfg(obs=ObsConfig(run_log=str(log)))
    exe = api.compile("gpt-2b", paper_case_study_cluster(), cfg)
    exe.attach_elastic()
    res = exe.replay("chaos", 200, seed=1, trace_out=str(trace_path))
    return exe, res, log, trace_path


# ---------------------------------------------------------------------------
# Adapter exactness (the module's core contract)
# ---------------------------------------------------------------------------


def test_sim_adapter_span_sums_reproduce_engine_totals(exe_case):
    res = exe_case.simulate(priced=False)
    tr = trace_from_sim(res)
    compute = [s for s in tr.spans if s.cat == "compute"]
    for i, expected in enumerate(res.stage_compute):
        got = sum(s.dur for s in compute if s.args["stage"] == i)
        assert got == expected          # exact float equality, not approx
    comm = sum(s.dur for s in tr.spans
               if s.cat == "comm" and s.args.get("kind") in ("CF", "CB"))
    assert comm == res.comm_total
    assert tr.meta["comm_exposed_s"] == res.comm_exposed
    assert tr.makespan() == res.makespan


def test_render_ascii_matches_legacy_pipesim_timeline(exe_case):
    res = exe_case.simulate(priced=False)
    assert render_ascii(trace_from_sim(res), width=100) == \
        ascii_timeline(res, width=100)


def test_describe_timeline_rides_the_span_model(exe_case):
    out = exe_case.describe(timeline=True)
    assert "stage0|" in out


def test_serve_adapter_pool_busy_rollup():
    events = [(0.0, 0.10, 0, "poolA", "prefill", 256),
              (0.10, 0.05, 0, "poolA", "decode", 4),
              (0.05, 0.02, 1, "poolB", "decode", 2)]
    tr = trace_from_serve(events)
    assert len(tr.spans) == 3
    assert tr.meta["pool_busy_s"] == {
        "poolA/decode": 0.05, "poolA/prefill": 0.10, "poolB/decode": 0.02}


# ---------------------------------------------------------------------------
# Chrome export: byte determinism + golden schema
# ---------------------------------------------------------------------------


def test_chrome_export_is_byte_deterministic(exe_case, tmp_path):
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    trace_to_chrome(exe_case.trace(), str(a))
    trace_to_chrome(exe_case.trace(), str(b))
    assert a.read_bytes() == b.read_bytes()
    doc = json.loads(a.read_text())
    assert doc["otherData"]["schema"] == 1
    assert all(ev["dur"] >= 0 for ev in doc["traceEvents"]
               if ev["ph"] == "X")


def _chrome_shape(doc):
    """Structural digest: per-phase event key sets + top-level layout —
    what a Perfetto-compatible consumer depends on."""
    shapes = {}
    for ev in doc["traceEvents"]:
        shapes.setdefault(ev["ph"], sorted(ev.keys()))
    return {"top": sorted(doc.keys()),
            "otherData": sorted(doc["otherData"].keys()),
            "schema": doc["otherData"]["schema"],
            "event_shapes": {k: shapes[k] for k in sorted(shapes)}}


def _golden_trace(exe):
    """Contended sim trace (has a link-busy counter) plus one synthetic
    flow pair, so every event phase the exporter can emit is pinned."""
    tr = exe.trace(contention=True)
    tr.add_span("x", "rel", "release", "drain", 0.0, 1.0,
                flow_id=0, flow_start=True)
    tr.add_span("x", "mig", "flow", "migration", 1.0, 1.0,
                flow_id=0, flow_end=True)
    return tr


def test_chrome_schema_matches_golden(exe_case):
    with open(GOLDEN) as f:
        golden = json.load(f)
    got = _chrome_shape(_golden_trace(exe_case).to_chrome())
    assert got == golden, (
        "Chrome-trace event schema drifted from tests/golden/"
        "obs_trace_schema.json.  If the change is INTENTIONAL, bump "
        "repro.obs.trace.OBS_TRACE_SCHEMA and regenerate the golden file "
        "(json.dump(_chrome_shape(...), indent=2, sort_keys=True)); "
        "otherwise you broke every saved trace consumers already have.")


def test_executable_trace_writes_valid_chrome_json(exe_case, tmp_path):
    out = tmp_path / "trace.json"
    tr = exe_case.trace(out=str(out))
    doc = json.loads(out.read_text())
    n_x = sum(1 for ev in doc["traceEvents"] if ev["ph"] == "X")
    assert n_x == len(tr.spans)
    # counters land on tid 0, metadata names every pid exactly once
    assert all(ev["tid"] == 0 for ev in doc["traceEvents"]
               if ev["ph"] == "C")
    names = [ev for ev in doc["traceEvents"]
             if ev["ph"] == "M" and ev["name"] == "process_name"]
    assert len({ev["pid"] for ev in names}) == len(names)


def test_registry_resolves_trace_adapters(exe_case):
    fn = api.registry.resolve("trace_adapter", "sim")
    tr = fn(exe_case.simulate(priced=False))
    assert tr.spans and tr.makespan() > 0


# ---------------------------------------------------------------------------
# Migration timeline lanes
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def shrink_costs(exe_case):
    """Price the same shrink migration (one meshA100 node leaves) with and
    without the timeline; live flows survive, so the trace has arrows."""
    cl = exe_case.cluster
    sc0 = cl.subclusters[0]
    shrunk = dataclasses.replace(
        cl, subclusters=(dataclasses.replace(sc0, n_nodes=sc0.n_nodes - 1),)
        + cl.subclusters[1:])
    exe2 = api.compile("gpt-2b", shrunk, small_cfg())
    old_lay = layout_from_strategy(exe_case.strategy, cl, exe_case.layers)
    new_lay = layout_from_strategy(exe2.strategy, shrunk, exe_case.layers)
    mplan = diff_layouts(old_lay, new_lay,
                         lost=lost_devices(cl, shrunk))
    kw = dict(old_strategy=exe_case.strategy, old_cluster=cl,
              layers=exe_case.layers)
    with_tl = price_migration(mplan, old_lay, shrunk,
                              collect_timeline=True, **kw)
    without = price_migration(mplan, old_lay, shrunk, **kw)
    return with_tl, without


def test_timeline_collection_never_changes_prices(shrink_costs):
    with_tl, without = shrink_costs
    assert with_tl.serial_s == without.serial_s
    assert with_tl.overlap_extra_s == without.overlap_extra_s
    assert with_tl.drain_s == without.drain_s
    assert without.timeline is None
    assert len(with_tl.timeline["flows"]) == with_tl.n_flows


def test_migration_trace_lanes_and_flow_arrows(shrink_costs):
    with_tl, without = shrink_costs
    tr = trace_from_migration(with_tl)
    tl = with_tl.timeline
    assert len(tr.spans) == len(tl["flows"]) + len(tl["drain"])
    # live flows (a surviving source stage) terminate a flow arrow from
    # that stage's release span
    ends = [s for s in tr.spans if s.flow_end]
    starts = [s for s in tr.spans if s.flow_start]
    assert ends and starts
    assert {s.flow_id for s in ends} <= {s.flow_id for s in starts}
    assert tr.meta["downtime_s"] == with_tl.downtime_s
    with pytest.raises(ValueError, match="collect_timeline"):
        trace_from_migration(without)


# ---------------------------------------------------------------------------
# Drift ledger
# ---------------------------------------------------------------------------


def test_drift_ledger_exact_math_and_window():
    led = DriftLedger(threshold=0.15, window=4)
    led.register_plan({"makespan_s": 1.0, "stage_compute_s": [0.5, 0.25]},
                      stage_pools={0: "A", 1: "B"})
    for step in range(10):                  # window keeps the last 4
        led.observe_step(step, 1.1, stage_times=[0.55, 0.25])
    rep = led.report()
    assert rep.n_samples == 4 and rep.n_observed == 10
    assert rep.rel_error == pytest.approx(0.1)
    assert rep.per_stage[0] == pytest.approx(0.1)
    assert rep.per_stage[1] == 0.0
    assert rep.per_pool == {"A": pytest.approx(0.1), "B": 0.0}
    assert not rep.flagged                  # 10% < 15% threshold
    # a new plan restarts the window: old samples don't indict it
    led.register_plan({"makespan_s": 2.0})
    rep2 = led.report()
    assert rep2.n_samples == 0 and not rep2.flagged
    assert rep2.n_observed == 10


def test_drift_report_flags_injected_pool_slowdown(exe_case):
    """ISSUE 10 acceptance: a 20% slowdown on every stage flags the run
    and attributes it to the hosting pools."""
    res = exe_case.simulate(priced=False)
    led = DriftLedger(threshold=0.15, window=8)
    led.register_plan(
        {"makespan_s": res.makespan,
         "stage_compute_s": list(res.stage_compute)},
        stage_pools=exe_case._stage_pools())
    for step in range(10):
        led.observe_step(step, res.makespan * 1.2,
                         stage_times=[t * 1.2 for t in res.stage_compute])
    rep = led.report()
    assert rep.flagged
    assert rep.rel_error == pytest.approx(0.2)
    assert rep.flagged_pools == sorted(set(exe_case._stage_pools().values()))
    assert "DRIFT" in rep.describe() and "+20.0%" in rep.describe()
    assert json.loads(rep.to_json())["flagged"] is True


def test_drift_report_requires_a_ledger(exe_case):
    with pytest.raises(ValueError, match="obs"):
        exe_case.drift_report()


# ---------------------------------------------------------------------------
# Config plumbing (schema v8)
# ---------------------------------------------------------------------------


def test_obs_config_round_trips_and_off_state_is_null():
    assert api.HarpConfig().to_dict()["obs"] is None
    cfg = small_cfg(obs=ObsConfig(run_log="run.jsonl",
                                  drift_threshold=0.2, drift_window=4))
    back = api.HarpConfig.from_dict(cfg.to_dict())
    assert back.obs == cfg.obs
    assert back.to_json() == cfg.to_json()


def test_pre_v8_config_dict_still_loads():
    d = small_cfg().to_dict()
    d.pop("obs")                            # a v7 artifact has no obs key
    assert api.HarpConfig.from_dict(d).obs is None


# ---------------------------------------------------------------------------
# Metrics registry + shims
# ---------------------------------------------------------------------------


def test_metrics_registry_snapshot_is_deterministic():
    def build():
        r = MetricsRegistry()
        r.inc("req", 2, pool="b")
        r.inc("req", pool="a")
        r.gauge("depth", 3.0)
        r.observe("lat_s", 0.2)
        r.observe("lat_s", 0.4)
        return r.snapshot()
    snap = build()
    assert snap == build()
    assert snap["counters"] == {"req{pool=a}": 1, "req{pool=b}": 2}
    assert snap["histograms"]["lat_s"] == {
        "count": 2, "sum": pytest.approx(0.6), "min": 0.2, "max": 0.4}
    r = MetricsRegistry()
    r.inc("x")
    r.reset()
    assert r.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


def test_sim_memo_shim_mirrors_live_stats(exe_case):
    exe_case.simulate(priced=False)         # ensure the memo has traffic
    reg = sync_from_sim_memo(MetricsRegistry())
    s = sim_memo_stats()
    g = reg.snapshot()["gauges"]
    assert g["sim_memo.hits"] == s.hits
    assert g["sim_memo.misses"] == s.misses


def test_ckpt_saves_count_bytes_on_default_registry(tmp_path):
    from repro.checkpoint import ckpt
    from repro.obs.metrics import DEFAULT_REGISTRY

    def written():
        return DEFAULT_REGISTRY.snapshot()["counters"].get(
            "ckpt.bytes_written", 0)

    before = written()
    path = ckpt.save(str(tmp_path), 1, {"w": [1.0, 2.0, 3.0]})
    assert written() - before == os.path.getsize(path)


# ---------------------------------------------------------------------------
# Replay integration: decision track, metrics roll-up, run-log
# ---------------------------------------------------------------------------


def test_replay_trace_has_every_decision(chaos_run):
    exe, res, _log, trace_path = chaos_run
    assert res.decisions                    # the storm must actually act
    doc = json.loads(trace_path.read_text())
    dec = [ev for ev in doc["traceEvents"]
           if ev["ph"] == "X" and ev.get("cat") == "decision"]
    assert len(dec) == len(res.decisions)
    assert {ev["args"]["step"] for ev in dec} == \
        {d.step for d in res.decisions}


def test_replay_result_carries_metrics_snapshot(chaos_run):
    _exe, res, _log, _trace = chaos_run
    m = res.metrics
    assert m["counters"]["replay.tokens"] == res.tokens_total
    assert m["gauges"]["replay.steps"] == 200
    assert m["gauges"]["replay.wall_s"] == pytest.approx(res.wall_total_s)
    n_dec = sum(v for k, v in m["counters"].items()
                if k.startswith("controller.decisions"))
    assert n_dec == len(res.decisions)


def test_run_log_round_trips_on_the_replay_clock(chaos_run):
    _exe, res, log, _trace = chaos_run
    events = read_runlog(str(log))
    assert all(ev["schema"] == 1 for ev in events)
    steps = list(iter_kind(events, "step"))
    assert len(steps) == 200
    assert [e["step"] for e in steps] == sorted(e["step"] for e in steps)
    assert len(list(iter_kind(events, "decision"))) == len(res.decisions)
    # sim clock only: the log's wall matches the replay's, not time.time()
    assert steps[-1]["t"] == pytest.approx(res.wall_total_s)


def test_run_log_rejects_newer_schema(tmp_path):
    p = tmp_path / "run.jsonl"
    p.write_text('{"schema": 99, "kind": "step", "t": 0.0}\n')
    with pytest.raises(ValueError, match="newer"):
        read_runlog(str(p))


def test_controller_drift_ledger_observes_the_replay(chaos_run):
    exe, _res, _log, _trace = chaos_run
    rep = exe.drift_report()
    assert rep.n_observed > 0
    assert rep.predicted_step_s > 0
    # the decision adapter places spans at replay wall times
    tr = trace_from_decisions(
        exe.controller.decisions,
        wall_times={s.step: s.wall_s for s in _res.samples})
    assert tr.meta["clock"] == "wall"
    assert len(tr.spans) == len(exe.controller.decisions)


# ---------------------------------------------------------------------------
# CLI round trip
# ---------------------------------------------------------------------------


def test_cli_trace_round_trip(tmp_path, capsys):
    from repro.api.cli import main
    plan = tmp_path / "plan.json"
    out = tmp_path / "trace.json"
    rc = main(["plan", "--arch", "gpt-2b", "--cluster", "paper_case_study",
               "--granularity", "16", "--microbatches", "16",
               "--global-batch", "16", "--seq-len", "512", "-o", str(plan)])
    assert rc == 0
    rc = main(["trace", "--plan", str(plan), "-o", str(out), "--timeline"])
    assert rc == 0
    printed = capsys.readouterr().out
    assert "Chrome trace written" in printed and "stage0|" in printed
    doc = json.loads(out.read_text())
    assert doc["otherData"]["schema"] == 1
    assert any(ev["ph"] == "X" for ev in doc["traceEvents"])
