"""repro.comm: topology construction, algorithm cost crossover, selector,
netsim contention, the contention-off bit-equivalence guarantee, and the
ISSUE 5 acceptance (fig10 3 Gbps: auto-selected two-level hierarchical
allreduce beats the forced flat ring end-to-end)."""
import copy
import dataclasses
import json

import numpy as np
import pytest

from repro.comm import netsim
from repro.comm.algorithms import (
    CollectiveAlgorithm, CollectiveCost, get_algorithm, register_collective,
)
from repro.comm.selector import (
    QUANT_BLOCK, CommConfig, CommModel, boundary_link_ids,
    collective_breakdown, compressed_wire_bytes,
)
from repro.comm.topology import (
    CROSS_LINK, CommGroup, Link, build_topology, fingerprint,
)
from repro.core.cluster import (
    A100_40G, GBPS, V100_32G, HeteroCluster, SubCluster,
    paper_case_study_cluster, set_node_efficiencies, with_cross_bw,
)
from repro.core.pipesim import clear_sim_memo, simulate
from repro.core.planner import HAPTPlanner, PlannerConfig
from repro.configs import get_config

FIG10_BWS = [3, 4, 5, 7, 10]          # benchmarks/fig10_bandwidth.py sweep


def fig10_cluster(cross_gbps: float = 3.0) -> HeteroCluster:
    """The fig10 sweep's fleet shape (2x8 A100 + 2x8 V100)."""
    return HeteroCluster(
        subclusters=(
            SubCluster("A100", 2, 8, A100_40G, 300e9, 200 * GBPS),
            SubCluster("V100", 2, 8, V100_32G, 150e9, 200 * GBPS)),
        cross_bw=cross_gbps * GBPS)


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------


def canonical_clusters():
    from repro.api import registry
    return [(name, registry.resolve("cluster", name)())
            for name in registry.available("cluster")]


def test_topology_from_every_canonical_registry_cluster():
    for name, cluster in canonical_clusters():
        topo = build_topology(cluster)
        assert len(topo.links) == 2 * len(cluster.subclusters) + 1, name
        for i, sub in enumerate(cluster.subclusters):
            assert topo.intra_link(i).bandwidth == sub.intra_node_bw
            assert topo.inter_link(i).bandwidth == sub.inter_node_bw
            assert topo.intra_link(i).latency == 0.0
        assert topo.cross_link().bandwidth == cluster.cross_bw
        assert topo.cross_link().latency == cluster.cross_latency
        # fingerprint is a pure function of the cluster value
        assert fingerprint(topo) == fingerprint(build_topology(cluster))


def test_p2p_link_matches_cluster_link_bw():
    for _, cluster in canonical_clusters():
        topo = build_topology(cluster)
        C = len(cluster.subclusters)
        for a in range(C):
            for b in range(C):
                assert topo.p2p_link(a, b).bandwidth == cluster.link_bw(a, b)


def test_fingerprint_tracks_everything_the_comm_model_reads():
    cl = paper_case_study_cluster()
    fp = fingerprint(build_topology(cl))
    assert fingerprint(build_topology(with_cross_bw(cl, 3 * GBPS))) != fp
    mixed = set_node_efficiencies(cl, "meshA100", (1.0, 0.6))
    assert fingerprint(build_topology(mixed)) != fp


def test_comm_model_fingerprint_tracks_config():
    cl = paper_case_study_cluster()
    base = CommModel(cl).fingerprint()
    assert CommModel(cl, CommConfig(algorithms=("ring",))).fingerprint() != base
    assert CommModel(cl, CommConfig(compressed=True)).fingerprint() != base
    assert CommModel(cl).fingerprint() == base


# ---------------------------------------------------------------------------
# Algorithm zoo: closed forms + crossover
# ---------------------------------------------------------------------------


def test_ring_matches_legacy_scalar_on_single_tier():
    """On a flat latency-free tier the ring IS the legacy pricing —
    the same float expression, bit for bit."""
    link = Link("intra:x", "nvlink", 300e9)
    for n in (2, 4, 8):
        for nbytes in (1e6, 512e6):
            got = get_algorithm("ring").cost(
                CommGroup(((n, link),)), nbytes).seconds
            assert got == nbytes * 2.0 * (n - 1) / n / 300e9


def test_selector_prefers_ring_on_uniform_links():
    """Single-tier groups: hierarchical is structurally unsupported; and on
    a two-tier group with equal latency-free bandwidth every bandwidth-
    optimal algorithm degenerates to the same cost, so the tie goes to the
    ring (candidate order)."""
    cl = paper_case_study_cluster()
    m = CommModel(cl)
    assert m.tp_allreduce(0, 2, 64e6).algorithm == "ring"
    assert not get_algorithm("hierarchical").supports(
        CommGroup(((4, Link("l", "ib", 25e9)),)))
    eq = CommGroup(((4, Link("a", "ib", 25e9)), (2, Link("b", "ib", 25e9))))
    sel = m.select(eq, 256e6)
    assert sel.algorithm == "ring"
    hier = get_algorithm("hierarchical").cost(eq, 256e6).seconds
    assert sel.seconds == pytest.approx(hier)     # bandwidth-optimal tie


def test_hierarchical_wins_as_cross_bw_drops_through_fig10_sweep():
    """Cost crossover on the cross-cluster sync group: the hierarchical
    advantage over the flat ring grows monotonically as the WAN slows
    through the fig10 sweep, and the selector picks it everywhere the WAN
    is the bottleneck."""
    payload = 512e6
    margins = []
    for bw in sorted(FIG10_BWS, reverse=True):     # 10 -> 3 Gbps
        m = CommModel(fig10_cluster(bw))
        group = m.topology.cross_group(0, 2, 8, 2)
        ring = get_algorithm("ring").cost(group, payload).seconds
        hier = get_algorithm("hierarchical").cost(group, payload).seconds
        assert hier < ring
        assert m.select(group, payload).algorithm == "hierarchical"
        margins.append(ring - hier)
    assert margins == sorted(margins)              # grows as bw drops


def test_dp_sync_selection_two_tier_beats_ring():
    """A multi-node stage's gradient sync: the hierarchy moves only
    1/per_node of the payload over the inter-node fabric."""
    m = CommModel(fig10_cluster(3))
    sel = m.dp_sync(0, n_nodes=2, per_node=8, nbytes=1e9)
    assert sel.algorithm == "hierarchical"
    ring = CommModel(fig10_cluster(3),
                     CommConfig(algorithms=("ring",))).dp_sync(0, 2, 8, 1e9)
    assert ring.algorithm == "ring"
    assert sel.seconds < ring.seconds
    # single-node stage: flat group, ring (exact legacy expression)
    flat = m.dp_sync(0, n_nodes=1, per_node=8, nbytes=1e9)
    assert flat.algorithm == "ring"
    assert flat.seconds == 1e9 * 2.0 * 7 / 8 / 300e9


def test_rhd_wins_latency_dominated_wan_collectives():
    """Tiny payloads on a flat latency-heavy group: 2*log2(N) startups beat
    the ring's 2*(N-1) (a hierarchy needs >= 2 tiers, so it cannot bid)."""
    m = CommModel(fig10_cluster(3))
    flat_wan = CommGroup(((8, m.topology.cross_link()),))   # 1 ms latency
    sel = m.select(flat_wan, 8.0)                  # one scalar
    assert sel.algorithm == "rhd"
    ring = get_algorithm("ring").cost(flat_wan, 8.0).seconds
    assert sel.seconds < ring
    assert not get_algorithm("rhd").supports(
        CommGroup(((3, Link("l", "ib", 25e9)),)))  # non-power-of-two


def test_third_party_algorithm_registers_through_api_registry():
    from repro.api import registry

    class Free(CollectiveAlgorithm):
        name = "free"

        def supports(self, group):
            return True

        def cost(self, group, nbytes):
            return CollectiveCost(0.0)

    registry.register("collective", "free", Free())
    try:
        assert "free" in registry.available("collective")
        assert get_algorithm("free").cost(None, 1).seconds == 0.0
        sel = CommModel(paper_case_study_cluster(),
                        CommConfig(algorithms=("ring", "free"))
                        ).dp_sync(0, 2, 2, 1e9)
        assert sel.algorithm == "free"
        with pytest.raises(ValueError, match="already registered"):
            registry.register("collective", "free", Free())
    finally:
        from repro.comm.algorithms import ALGORITHMS
        ALGORITHMS.pop("free", None)


# ---------------------------------------------------------------------------
# Netsim: fair-share contention
# ---------------------------------------------------------------------------


def test_netsim_fair_share_two_transfers_double():
    res = netsim.price_transfers(
        [("a", ("L",), 1.0, 0.0), ("b", ("L",), 1.0, 0.0)])
    assert res.end["a"] == pytest.approx(2.0)
    assert res.end["b"] == pytest.approx(2.0)
    assert res.link_busy["L"] == pytest.approx(2.0)


def test_netsim_disjoint_links_full_rate():
    res = netsim.price_transfers(
        [("a", ("L1",), 1.0, 0.0), ("b", ("L2",), 1.0, 0.0)])
    assert res.end["a"] == pytest.approx(1.0)
    assert res.end["b"] == pytest.approx(1.0)


def test_netsim_staggered_release_exact_processor_sharing():
    # a alone for 1s (half done), shares for 1s (quarter each), finishes
    # alone: a ends at 1 + 1 + 0.25? -> solve: a: work 2, release 0;
    # b: work 1, release 1.  t in [0,1]: a does 1.  t in [1,3]: both at 1/2;
    # b drains its 1.0 at t=3; a has 2-1-1=0 left -> also t=3.
    res = netsim.price_transfers(
        [("a", ("L",), 2.0, 0.0), ("b", ("L",), 1.0, 1.0)])
    assert res.start["b"] == pytest.approx(1.0)
    assert res.end["a"] == pytest.approx(3.0)
    assert res.end["b"] == pytest.approx(3.0)


def test_netsim_multilink_transfer_paced_by_most_congested():
    # "ar" holds both directions; "x" congests fwd only -> ar runs at 1/2
    res = netsim.price_transfers(
        [("ar", ("l/fwd", "l/bwd"), 1.0, 0.0), ("x", ("l/fwd",), 1.0, 0.0)])
    assert res.end["ar"] == pytest.approx(2.0)
    assert res.end["x"] == pytest.approx(2.0)


def test_netsim_rejects_cycles_and_unknown_deps():
    with pytest.raises(ValueError, match="cycle"):
        netsim.run([netsim.SimNode("a", 1.0, ("b",)),
                    netsim.SimNode("b", 1.0, ("a",))])
    with pytest.raises(ValueError, match="unknown"):
        netsim.run([netsim.SimNode("a", 1.0, ("ghost",))])


# ---------------------------------------------------------------------------
# Contended pipesim engine
# ---------------------------------------------------------------------------

SCHED = dict(t_f=[1.0, 1.2, 0.9], t_b=[2.0, 2.2, 1.8], c=[0.3, 0.4], B=6,
             counts=[3, 2, 1])


def test_contended_with_distinct_links_reproduces_graph_engine():
    g = simulate(SCHED["t_f"], SCHED["t_b"], SCHED["c"], SCHED["B"],
                 SCHED["counts"], fast=False, cache=False)
    k = simulate(SCHED["t_f"], SCHED["t_b"], SCHED["c"], SCHED["B"],
                 SCHED["counts"], contention=True, cache=False)
    assert k.makespan == pytest.approx(g.makespan, abs=1e-9)
    for node, s in g.start.items():
        assert k.start[node] == pytest.approx(s, abs=1e-9), node
        assert k.dur[node] == pytest.approx(g.dur[node], abs=1e-9), node


def test_contended_shared_wan_is_slower_and_sync_contends():
    base = simulate(SCHED["t_f"], SCHED["t_b"], SCHED["c"], SCHED["B"],
                    SCHED["counts"], contention=True, cache=False)
    shared = simulate(SCHED["t_f"], SCHED["t_b"], SCHED["c"], SCHED["B"],
                      SCHED["counts"], contention=True,
                      link_ids=["wan", "wan"], cache=False)
    assert shared.makespan > base.makespan
    with_sync = simulate(SCHED["t_f"], SCHED["t_b"], SCHED["c"], SCHED["B"],
                         SCHED["counts"], contention=True,
                         link_ids=["wan", "wan"],
                         sync_work=[(0, "wan", 1.5)], cache=False)
    assert with_sync.makespan > shared.makespan
    assert ("SYNC", 0, 0) in with_sync.start
    assert with_sync.link_busy["wan/fwd"] > shared.link_busy["wan/fwd"]


def test_contention_flag_validation():
    with pytest.raises(ValueError, match="no_overlap"):
        simulate([1.0], [1.0], [], 2, [1], contention=True, no_overlap=True)
    with pytest.raises(ValueError, match="fast"):
        simulate([1.0], [1.0], [], 2, [1], contention=True, fast=True)
    with pytest.raises(ValueError, match="link_ids"):
        simulate(SCHED["t_f"], SCHED["t_b"], SCHED["c"], SCHED["B"],
                 SCHED["counts"], contention=True, link_ids=["only-one"])


# ---------------------------------------------------------------------------
# contention=False bit-equivalence (the off-state guarantee)
# ---------------------------------------------------------------------------


def test_contention_off_is_bit_identical_to_legacy_scalar_pricing():
    """SimResult start/dur dicts of the default (contention-less) call are
    the legacy engines' exact output — the comm subsystem must not perturb
    a single bit of the uncontended path."""
    clear_sim_memo()
    for counts in ([3, 2, 1], [1, 1, 1], [5, 3, 1]):
        legacy = simulate(SCHED["t_f"], SCHED["t_b"], SCHED["c"], SCHED["B"],
                          counts, fast=False, cache=False)
        off = simulate(SCHED["t_f"], SCHED["t_b"], SCHED["c"], SCHED["B"],
                       counts, contention=False, cache=False)
        assert off.start == legacy.start          # dict-identical, not approx
        assert off.dur == legacy.dur
        assert off.makespan == legacy.makespan
        assert off.link_busy == {}                # occupancy is contended-only


def _strip_volatile(plan_dict):
    d = copy.deepcopy(plan_dict)
    meta = d["strategy"]["planner_meta"]
    for k in list(meta):
        if k.startswith("time_"):
            meta.pop(k)
    return d


def test_comm_disabled_full_pipeline_reproduces_legacy_json():
    """costmodel -> dp_search -> pipesim -> artifacts with the comm config
    absent vs. present-but-disabled: byte-identical Plan and LoweredPlan
    JSON (modulo wall-clock provenance, which differs between any two
    runs)."""
    from repro import api
    cl = paper_case_study_cluster()
    mk = lambda comm: api.HarpConfig(
        seq_len=512, global_batch=16,
        planner=PlannerConfig(granularity=16, n_microbatches=16, comm=comm))
    legacy = api.compile("gpt-2b", cl, mk(None))
    off = api.compile("gpt-2b", cl, mk(CommConfig(enabled=False)))
    a = _strip_volatile(legacy.plan.to_dict())
    b = _strip_volatile(off.plan.to_dict())
    a["config"]["planner"]["comm"] = b["config"]["planner"]["comm"] = None
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    assert legacy.lowered.to_json() == off.lowered.to_json()


def test_comm_cache_is_sub_scoped_across_fleet_changes():
    """A cross-bandwidth change must not evict any sub-cluster's comm-aware
    cost-cache entries (stage collectives never leave their sub-cluster):
    the second profile is served entirely from the warm cache."""
    from repro.core.layering import build_layers
    from repro.core.opgraph import build_op_sequence
    from repro.core.profiler import ZeroRedundantProfiler
    arch = get_config("gpt-2b")
    layers = build_layers(build_op_sequence(arch, seq_len=512), 12, z=2)
    cache = {}
    cl = paper_case_study_cluster()

    def profile(cluster):
        return ZeroRedundantProfiler(
            cluster, layers, 1024, intra_op=True, amortize_microbatches=16,
            comm=CommModel(cluster, CommConfig()),
            cost_cache=cache).profile().stats

    profile(cl)
    n_entries = len(cache)
    assert n_entries > 0
    stats2 = profile(with_cross_bw(cl, 3 * GBPS))
    assert len(cache) == n_entries
    assert stats2.n_unique_profiled == 0
    # a *sub-local* change does miss (and only adds that sub's entries)
    stats3 = profile(set_node_efficiencies(cl, "meshA100", (1.0, 0.5)))
    assert stats3.n_unique_profiled > 0


# ---------------------------------------------------------------------------
# End-to-end acceptance: fig10 3 Gbps
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fig10_plans():
    cluster = fig10_cluster(3.0)
    arch = get_config("gpt-30b")
    base = PlannerConfig(granularity=24, n_microbatches=32, intra_op=True,
                         min_submesh_devices=2)

    def plan(comm):
        cfg = dataclasses.replace(base, comm=comm)
        return HAPTPlanner(cluster, cfg).plan(arch, seq_len=1024,
                                              global_batch=256)
    return plan(CommConfig()), plan(CommConfig(algorithms=("ring",)))


def test_fig10_3gbps_planner_auto_selects_hierarchical(fig10_plans):
    auto, _ = fig10_plans
    multi_node = [s for s in auto.stages if s.mesh_n > 1 and s.dp > 1]
    assert multi_node, "expected multi-node stages on the fig10 fleet"
    assert all(s.intra_op.sync_algo == "hierarchical" for s in multi_node)


def test_fig10_3gbps_auto_beats_forced_flat_ring(fig10_plans):
    auto, ring = fig10_plans
    assert all(s.intra_op.sync_algo == "ring"
               for s in ring.stages if s.dp > 1)
    assert auto.est_step_time < ring.est_step_time


def test_fig10_comm_meta_and_breakdown(fig10_plans):
    auto, _ = fig10_plans
    assert tuple(auto.planner_meta["comm"]["algorithms"]) == \
        ("ring", "rhd", "hierarchical")
    bd = collective_breakdown(auto, fig10_cluster(3.0), layers=[])
    assert any(e["sync_algorithm"] == "hierarchical" for e in bd["stages"])
    assert all(l in ("wan",) or l.startswith("ib:")
               for l in bd["link_ids"])


# ---------------------------------------------------------------------------
# Compression candidate (satellite): selector accounting == real quantizer
# ---------------------------------------------------------------------------


def test_compressed_candidate_wins_on_slow_wan():
    m = CommModel(fig10_cluster(3.0), CommConfig(compressed=True))
    sel = m.cross_sync(0, 2, 8, 2, nbytes=512e6)
    assert sel.compressed
    assert sel.algorithm == "hierarchical"
    assert sel.wire_bytes < sel.payload_bytes / 3.9
    plain = CommModel(fig10_cluster(3.0)).cross_sync(0, 2, 8, 2, 512e6)
    assert sel.seconds < plain.seconds


def test_compressed_wire_accounting_matches_real_quantizer():
    compression = pytest.importorskip("repro.parallel.compression")
    import jax.numpy as jnp
    assert compression.BLOCK == QUANT_BLOCK
    for n_elems in (256, 1000, 4096, 77777):
        g = jnp.asarray(np.random.RandomState(0).randn(n_elems),
                        dtype=jnp.float32)
        q, scale = compression.quantize_int8(g)
        actual_wire = q.size * q.dtype.itemsize \
            + scale.size * scale.dtype.itemsize
        assert actual_wire == compressed_wire_bytes(n_elems * 4.0)


def test_error_feedback_residual_accounting_round_trip():
    """The priced compressed path is bias-free by construction: the residual
    the selector's cost model assumes is exactly what compress_tree carries
    forward (corrected == dequantized + residual, leaf by leaf)."""
    compression = pytest.importorskip("repro.parallel.compression")
    import jax.numpy as jnp
    rng = np.random.RandomState(1)
    grads = {"w": jnp.asarray(rng.randn(300, 7), jnp.float32),
             "b": jnp.asarray(rng.randn(11), jnp.float32)}
    err = compression.init_error_feedback(grads)
    payload, new_err = compression.compress_tree(grads, err)
    deq = compression.decompress_tree(payload, grads)
    for k in grads:
        corrected = np.asarray(grads[k], np.float32)  # err starts at zero
        np.testing.assert_allclose(
            np.asarray(deq[k]) + np.asarray(new_err[k]), corrected,
            rtol=0, atol=1e-6)
        q, scale = payload[k]
        assert q.size * q.dtype.itemsize + scale.size * scale.dtype.itemsize \
            == compressed_wire_bytes(corrected.size * 4.0)
    # second step: the residual rides into the next quantization
    payload2, err2 = compression.compress_tree(grads, new_err)
    deq2 = compression.decompress_tree(payload2, grads)
    for k in grads:
        corrected2 = np.asarray(grads[k]) + np.asarray(new_err[k])
        np.testing.assert_allclose(
            np.asarray(deq2[k]) + np.asarray(err2[k]), corrected2,
            rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# Telemetry bandwidth recalibration + controller re-selection
# ---------------------------------------------------------------------------


def test_observe_comm_calibrates_cross_bandwidth():
    from repro.runtime.telemetry import CROSS, TelemetryCalibrator
    cl = fig10_cluster(10.0)
    cal = TelemetryCalibrator(alpha=0.5, deadband=0.05)
    for _ in range(8):                  # WAN measured 3x slower than priced
        cal.observe_comm(cl, CROSS, predicted_s=1.0, measured_s=3.0)
    est = cal.bandwidth(CROSS)
    assert est == pytest.approx(cl.cross_bw / 3.0, rel=0.05)
    assert cal.drift(cl) > 0.5
    calibrated = cal.calibrated(cl)
    assert calibrated.cross_bw == pytest.approx(est)
    # inter-node tier calibrates by sub-cluster name
    cal.observe_comm(cl, "A100", predicted_s=1.0, measured_s=2.0)
    assert cal.bandwidth("A100") < cl.subclusters[0].inter_node_bw
    assert cal.calibrated(cl).subclusters[0].inter_node_bw < \
        cl.subclusters[0].inter_node_bw


def test_controller_on_comm_time_replans_on_bandwidth_drift():
    from repro.runtime.controller import ControllerConfig, ElasticController
    from repro.runtime.telemetry import CROSS, TelemetryCalibrator
    cluster = paper_case_study_cluster(cross_gbps=10.0)
    ctrl = ElasticController(
        cluster, "gpt-2b",
        planner_cfg=PlannerConfig(granularity=12, n_microbatches=16,
                                  comm=CommConfig()),
        cfg=ControllerConfig(total_steps=10_000, seq_len=512,
                             global_batch=16, drift_threshold=0.2),
        telemetry=TelemetryCalibrator(alpha=0.6, deadband=0.05))
    ctrl.bootstrap()
    decision = None
    for step in range(2, 10):           # WAN congested 4x
        decision = ctrl.on_comm_time(step, CROSS, predicted_s=0.1,
                                     measured_s=0.4)
        if decision is not None:
            break
    assert decision is not None, "bandwidth drift never triggered the ladder"
    assert decision.action in ("warmup_only", "incremental", "full")
    # the calibrated WAN bandwidth was committed as the fleet's truth: every
    # subsequent re-search builds its CommModel (and re-selects algorithms)
    # from it, and the committed shift reset the tier's EWMA history
    assert ctrl.cluster.cross_bw < 0.75 * cluster.cross_bw
    assert ctrl.telemetry.bandwidth("cross", default=0.0) == 0.0


# ---------------------------------------------------------------------------
# Lowering the hierarchy onto mesh axes
# ---------------------------------------------------------------------------


def test_hierarchical_sync_axes_and_phases():
    from repro.core.strategy import IntraOpPlan
    from repro.parallel.sharding import (
        hierarchical_sync_axes, sync_collective_phases,
    )
    plan = IntraOpPlan(axis="data", tp=2, dp=8,
                       shard_ratios=(0.125,) * 8, comm_bytes=0.0,
                       comm_time_f=0.0, comm_time_b=0.0,
                       sync_algo="hierarchical")
    assert hierarchical_sync_axes(plan, mesh_n=2) == \
        (("node", 2), ("data", 4), ("model", 2))
    assert sync_collective_phases(plan, mesh_n=2) == \
        (("reduce_scatter", "data"), ("all_reduce", "node"),
         ("all_gather", "data"))
    flat = dataclasses.replace(plan, sync_algo="ring")
    assert sync_collective_phases(flat, mesh_n=2) == (("all_reduce", "data"),)
    with pytest.raises(ValueError, match="factor"):
        hierarchical_sync_axes(plan, mesh_n=3)


# ---------------------------------------------------------------------------
# Artifacts / facade surface
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def comm_exe():
    from repro import api
    cfg = api.HarpConfig(
        seq_len=512, global_batch=16,
        planner=PlannerConfig(granularity=16, n_microbatches=16,
                              intra_op=True, comm=CommConfig()))
    return api.compile("gpt-2b", paper_case_study_cluster(), cfg)


def test_lowered_plan_v3_collective_fields(comm_exe):
    from repro import api
    from repro.api.artifacts import SCHEMA_VERSION
    lo = comm_exe.lowered
    # collective fields landed in v3; the artifact family version moves on
    assert lo.version == SCHEMA_VERSION >= 3
    assert len(lo.link_ids) == lo.n_stages - 1
    assert lo.link_occupancy_s
    assert any(s.sync_algorithm for s in lo.stages)
    j = lo.to_json()
    assert api.LoweredPlan.from_json(j).to_json() == j
    # v2 artifacts (no collective plan) still load, with defaults
    d = json.loads(j)
    for k in ("link_ids", "link_occupancy_s", "contended_links"):
        d.pop(k)
    for s in d["stages"]:
        for k in ("ar_algorithm", "sync_algorithm", "sync_compressed",
                  "sync_time_s", "sync_link"):
            s.pop(k)
    old = api.LoweredPlan.from_dict(d)
    assert old.link_ids == [] and old.stages[0].sync_algorithm is None


def test_explain_comm_and_describe(comm_exe):
    txt = comm_exe.explain_comm()
    assert "collective breakdown" in txt
    assert "link occupancy per step" in txt
    assert "sync=ring" in txt or "sync=hierarchical" in txt
    assert comm_exe.describe(comm=True).count("collective breakdown") == 1


def test_executable_contention_simulation(comm_exe):
    res = comm_exe.simulate(contention=True)
    assert res.link_busy, "contended run must report link occupancy"
    priced = comm_exe.simulate(priced=True)
    # same plan, same totals modulo sync scheduling: the two accountings
    # must land in the same ballpark (sanity, not equality)
    assert res.makespan == pytest.approx(priced.makespan, rel=0.15)
    assert boundary_link_ids(comm_exe.strategy, comm_exe.cluster) \
        == comm_exe.lowered.link_ids


def test_cli_accepts_comm_flags():
    from repro.api.cli import build_parser
    args = build_parser().parse_args(
        ["plan", "--arch", "gpt-2b", "--comm", "--comm-compressed",
         "--comm-algorithms", "ring,hierarchical", "--explain-comm"])
    assert args.comm and args.comm_compressed and args.explain_comm
    args = build_parser().parse_args(
        ["simulate", "--plan", "p.json", "--contention"])
    assert args.contention
