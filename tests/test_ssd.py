"""SSD (state-space duality) properties: chunked == naive recurrence for all
chunk sizes, states compose across splits, decode step == one-step scan."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.ssm import ssd_chunked, ssd_naive


def _inputs(seed, B, T, H, P, N):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, N))
    Cm = jax.random.normal(ks[4], (B, T, N))
    return x, dt, A, Bm, Cm


@settings(max_examples=15, deadline=None)
@given(T=st.integers(1, 70), chunk=st.sampled_from([1, 4, 16, 64]),
       seed=st.integers(0, 5))
def test_chunked_equals_naive(T, chunk, seed):
    x, dt, A, Bm, Cm = _inputs(seed, 2, T, 3, 8, 4)
    y1, s1 = ssd_naive(x, dt, A, Bm, Cm)
    y2, s2 = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-4, rtol=1e-3)


def test_state_composes_across_splits():
    """Running [0:t) then [t:T) with the carried state == running [0:T)."""
    x, dt, A, Bm, Cm = _inputs(0, 1, 48, 2, 8, 4)
    y_full, s_full = ssd_naive(x, dt, A, Bm, Cm)
    t = 20
    y1, s1 = ssd_naive(x[:, :t], dt[:, :t], A, Bm[:, :t], Cm[:, :t])
    y2, s2 = ssd_naive(x[:, t:], dt[:, t:], A, Bm[:, t:], Cm[:, t:],
                       init_state=s1)
    np.testing.assert_allclose(np.asarray(y_full[:, t:]), np.asarray(y2),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), atol=1e-5)


def test_chunked_supports_init_state():
    x, dt, A, Bm, Cm = _inputs(1, 1, 32, 2, 8, 4)
    s0 = jnp.ones((1, 2, 8, 4)) * 0.3
    y1, s1 = ssd_naive(x, dt, A, Bm, Cm, init_state=s0)
    y2, s2 = ssd_chunked(x, dt, A, Bm, Cm, chunk=8, init_state=s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4,
                               rtol=1e-3)
