"""Checkpoint subsystem: atomic full saves round-trip bit-identically,
crashes mid-write never corrupt the newest checkpoint, the GC keep-window
honors incremental manifests, restore errors are loud, and the async /
incremental checkpointer writes only deltas while every step stays fully
restorable."""
import os

import numpy as np
import pytest

import repro.checkpoint.ckpt as ckpt


def tree():
    return {
        "params": {
            "dense": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                      "b": np.zeros(4, dtype=np.float32)},
            "scale": np.float32(2.5),
        },
        "opt": [np.ones(5, dtype=np.float32),
                np.full(5, 7, dtype=np.int32)],
    }


def trees_equal(a, b) -> bool:
    la = [np.asarray(x) for x in
          __import__("jax").tree_util.tree_leaves(a)]
    lb = [np.asarray(x) for x in
          __import__("jax").tree_util.tree_leaves(b)]
    return len(la) == len(lb) and all(
        x.shape == y.shape and x.dtype == y.dtype and np.array_equal(x, y)
        for x, y in zip(la, lb))


# --- full save / restore ----------------------------------------------------


def test_save_restore_bit_identity(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), 7, t, extra={"loss": 0.5})
    step, restored, extra = ckpt.restore(str(tmp_path), tree())
    assert step == 7
    assert extra == {"loss": 0.5}
    assert trees_equal(restored, t)


def test_restore_empty_dir_returns_none(tmp_path):
    assert ckpt.restore(str(tmp_path), tree()) is None
    assert ckpt.list_steps(str(tmp_path)) == []


def test_restore_specific_step(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), 1, t, keep=0)
    t2 = tree()
    t2["params"]["scale"] = np.float32(9.0)
    ckpt.save(str(tmp_path), 2, t2, keep=0)
    step, restored, _ = ckpt.restore(str(tmp_path), tree(), step=1)
    assert step == 1
    assert trees_equal(restored, t)
    step, restored, _ = ckpt.restore(str(tmp_path), tree())
    assert step == 2 and float(restored["params"]["scale"]) == 9.0


def test_crash_mid_write_leaves_previous_intact(tmp_path, monkeypatch):
    t = tree()
    ckpt.save(str(tmp_path), 1, t)

    def boom(src, dst):
        raise OSError("simulated crash before rename")

    monkeypatch.setattr(ckpt.os, "replace", boom)
    with pytest.raises(OSError):
        ckpt.save(str(tmp_path), 2, tree())
    monkeypatch.undo()
    # the failed write left no partial checkpoint and no temp litter
    assert ckpt.list_steps(str(tmp_path)) == [1]
    assert [f for f in os.listdir(tmp_path) if f.endswith(".tmp")] == []
    step, restored, _ = ckpt.restore(str(tmp_path), tree())
    assert step == 1 and trees_equal(restored, t)


def test_stray_files_ignored(tmp_path):
    ckpt.save(str(tmp_path), 3, tree())
    (tmp_path / "ckpt_0000000009.npz.tmp").write_bytes(b"garbage")
    (tmp_path / "notes.txt").write_text("hi")
    assert ckpt.list_steps(str(tmp_path)) == [3]
    assert ckpt.restore(str(tmp_path), tree())[0] == 3


def test_gc_keep_window(tmp_path):
    for s in range(5):
        ckpt.save(str(tmp_path), s, tree(), keep=3)
    assert ckpt.list_steps(str(tmp_path)) == [2, 3, 4]


def test_gc_keep_zero_keeps_everything(tmp_path):
    for s in range(5):
        ckpt.save(str(tmp_path), s, tree(), keep=0)
    assert ckpt.list_steps(str(tmp_path)) == [0, 1, 2, 3, 4]


def test_restore_missing_leaf_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": np.zeros(3)})
    with pytest.raises(KeyError, match="missing leaf"):
        ckpt.restore(str(tmp_path), {"a": np.zeros(3), "b": np.zeros(2)})


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": np.zeros(3)})
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path), {"a": np.zeros((2, 2))})


def test_leaf_key_separator_rejected(tmp_path):
    # regression: a "|" inside a pytree key would silently corrupt the
    # flat namespace ("a|b" indistinguishable from nested a -> b)
    with pytest.raises(ValueError, match="separator"):
        ckpt.save(str(tmp_path), 1, {"a|b": np.zeros(2)})
    assert ckpt.list_steps(str(tmp_path)) == []


def test_leaf_key_meta_collision_rejected(tmp_path):
    with pytest.raises(ValueError, match="metadata"):
        ckpt.save(str(tmp_path), 1, {ckpt.META_KEY: np.zeros(2)})


def test_reshard_places_on_new_shardings():
    import jax

    t = tree()
    dev = jax.devices()[0]
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), t)
    placed = ckpt.reshard(t, shardings)
    assert trees_equal(placed, t)
    for leaf in jax.tree_util.tree_leaves(placed):
        assert isinstance(leaf, jax.Array)


# --- async / incremental ----------------------------------------------------


def test_incremental_writes_only_changed_leaves(tmp_path):
    t = {"a": np.arange(4, dtype=np.float32),
         "b": np.ones(3, dtype=np.float32)}
    with ckpt.AsyncCheckpointer(str(tmp_path), keep=0,
                                background=False) as cp:
        cp.save(1, t)
        t2 = {"a": t["a"] + 1, "b": t["b"]}    # only a changes
        cp.save(2, t2)
    with np.load(str(tmp_path / "ckpt_0000000002.npz")) as z:
        assert set(z.files) == {ckpt.META_KEY, "a"}
    step, restored, _ = ckpt.restore(str(tmp_path), t)
    assert step == 2
    assert trees_equal(restored, t2)           # b resolved from step 1's file


def test_background_write_is_durable_after_wait(tmp_path):
    t = tree()
    cp = ckpt.AsyncCheckpointer(str(tmp_path), background=True)
    cp.save(5, t)
    cp.wait()
    step, restored, _ = ckpt.restore(str(tmp_path), tree())
    assert step == 5 and trees_equal(restored, t)
    cp.close()


def test_snapshot_is_the_consistency_point(tmp_path):
    t = {"a": np.arange(4, dtype=np.float32)}
    want = t["a"].copy()
    cp = ckpt.AsyncCheckpointer(str(tmp_path), background=True)
    cp.save(1, t)
    t["a"][:] = -1                 # mutation after save must not leak to disk
    cp.close()
    _, restored, _ = ckpt.restore(str(tmp_path), {"a": np.zeros(4)})
    assert np.array_equal(restored["a"], want)


def test_gc_never_drops_a_referenced_donor(tmp_path):
    a = np.arange(3, dtype=np.float32)
    b = np.ones(2, dtype=np.float32)
    with ckpt.AsyncCheckpointer(str(tmp_path), keep=2,
                                background=False) as cp:
        cp.save(10, {"a": a, "b": b})
        cp.save(20, {"a": a + 1, "b": b})      # b unchanged: owner stays 10
        cp.save(30, {"a": a + 2, "b": b})
        cp.save(40, {"a": a + 3, "b": b})
    # keep=2 leaves {30, 40}; the plain window would also drop 10 and 20,
    # but 10 owns b's newest bytes for both kept manifests — only 20 goes
    assert ckpt.list_steps(str(tmp_path)) == [10, 30, 40]
    for step in (30, 40):
        got = ckpt.restore(str(tmp_path),
                           {"a": np.zeros(3), "b": np.zeros(2)}, step=step)
        assert np.array_equal(got[1]["b"], b)
    assert np.array_equal(
        ckpt.restore(str(tmp_path), {"a": np.zeros(3), "b": np.zeros(2)}
                     )[1]["a"], a + 3)


def test_vanished_leaf_drops_out_of_manifest(tmp_path):
    with ckpt.AsyncCheckpointer(str(tmp_path), keep=0,
                                background=False) as cp:
        cp.save(1, {"a": np.zeros(2), "b": np.ones(2)})
        cp.save(2, {"a": np.full(2, 3.0)})
    meta = ckpt._read_meta(str(tmp_path), 2)
    assert set(meta["leaves"]) == {"a"}
    _, restored, _ = ckpt.restore(str(tmp_path), {"a": np.zeros(2)}, step=2)
    assert np.array_equal(restored["a"], np.full(2, 3.0))


def test_background_error_surfaces_on_close(tmp_path, monkeypatch):
    def boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(ckpt, "_write_atomic", boom)
    cp = ckpt.AsyncCheckpointer(str(tmp_path), background=True)
    cp.save(1, {"a": np.zeros(2)})
    with pytest.raises(RuntimeError, match="background checkpoint"):
        cp.close()


def test_shape_change_rewrites_leaf(tmp_path):
    with ckpt.AsyncCheckpointer(str(tmp_path), keep=0,
                                background=False) as cp:
        cp.save(1, {"a": np.zeros(2, dtype=np.float32)})
        cp.save(2, {"a": np.zeros(3, dtype=np.float32)})
    with np.load(str(tmp_path / "ckpt_0000000002.npz")) as z:
        assert z["a"].shape == (3,)
    _, restored, _ = ckpt.restore(str(tmp_path),
                                  {"a": np.zeros(3)}, step=2)
    assert restored["a"].shape == (3,)
