"""DP search (§5.2) correctness: optimal vs exhaustive brute force on small
instances, constraint satisfaction, pruning soundness."""
import itertools
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.cluster import (
    DeviceProfile, HeteroCluster, SubCluster, paper_case_study_cluster,
)
from repro.core.costmodel import CostModelConfig
from repro.core.dp_search import SearchConfig, _DPContext, _dp_eval, search
from repro.core.layering import build_layers
from repro.core.opgraph import build_op_sequence
from repro.core.profiler import ZeroRedundantProfiler

GB = 1024 ** 3


def tiny_cluster(mem_gb_a=40.0, mem_gb_b=32.0):
    return HeteroCluster(
        subclusters=(
            SubCluster("A", 1, 2, DeviceProfile("fast", 300e12, mem_gb_a * GB,
                                                1.5e12), 300e9, 25e9),
            SubCluster("B", 1, 2, DeviceProfile("slow", 120e12, mem_gb_b * GB,
                                                0.9e12), 150e9, 25e9),
        ),
        cross_bw=0.625e9)  # 5 Gbps


def make_tables(cluster, arch="gpt-15b", granularity=10, mb_tokens=2048):
    ops = build_op_sequence(get_config(arch), seq_len=1024)
    layers = build_layers(ops, granularity)
    prof = ZeroRedundantProfiler(cluster, layers, mb_tokens)
    return layers, prof.profile()


def brute_force(ctx, t_max, B):
    """Exhaustive enumeration of (partition, mesh assignment) under the same
    constraints/objective as the DP (small L only)."""
    tab = ctx.tables
    L = ctx.L
    best = math.inf

    def recurse(k, a, b, fill, n_next_cluster, N_next):
        nonlocal best
        if k == L:
            best = min(best, fill)
            return
        for mid, mesh in enumerate(tab.meshes):
            c = mesh.cluster_idx
            u = ctx.mesh_units[mid]
            avail = a if c == 0 else b
            if u > avail:
                continue
            for j in range(k + 1, L + 1):
                if not tab.feasible[mid, k, j]:
                    continue
                t = ctx.t_tab[mid, k, j]
                if t > t_max:
                    continue
                # comm to the stage AFTER this one: we recurse outward, so
                # enumerate the next stage's cluster choice implicitly by
                # trying both link speeds pessimistically -> replicate DP by
                # carrying next-cluster; here recurse forward:
                recurse_fwd(j, a - u * (c == 0), b - u * (c == 1),
                            fill, c, t, mid, k)

    # forward recursion carrying previous stage info to price the link
    def recurse_fwd(k, a, b, fill, prev_cluster, prev_t, prev_mid, prev_k):
        nonlocal best
        # price the cut between prev stage (ending at k) and what follows
        if k == L:
            best = min(best, fill + prev_t)
            return
        for mid, mesh in enumerate(ctx.tables.meshes):
            c = mesh.cluster_idx
            u = ctx.mesh_units[mid]
            avail = a if c == 0 else b
            if u > avail:
                continue
            c_time = ctx.tables.cut_bytes[k] / ctx.bw(prev_cluster, c)
            if c_time > t_max:
                continue
            for j in range(k + 1, L + 1):
                if not ctx.tables.feasible[mid, k, j]:
                    continue
                t = ctx.t_tab[mid, k, j]
                if t > t_max:
                    continue
                recurse_fwd(j, a - u * (c == 0), b - u * (c == 1),
                            fill + prev_t + 2 * c_time, c, t, mid, k)

    recurse(0, ctx.units_total[0],
            ctx.units_total[1] if ctx.C > 1 else 0, 0.0, None, 0)
    return best


@pytest.mark.parametrize("granularity", [4, 6])
def test_dp_matches_brute_force(granularity):
    cluster = tiny_cluster()
    layers, tables = make_tables(cluster, granularity=granularity)
    cfg = SearchConfig(n_microbatches=8)
    ctx = _DPContext(cluster, tables, cfg)
    vals = ctx.t_tab[tables.feasible]
    t_max = float(np.median(vals))
    dp_fill = _dp_eval(ctx, t_max)[0]
    bf_fill = brute_force(ctx, t_max, 8)
    if math.isinf(bf_fill):
        assert math.isinf(dp_fill)
    else:
        # DP ignores the memory-K coupling only through N table — identical
        # here since the brute force doesn't model Eq.18 either at K>1;
        # allow DP <= brute force (DP explores a superset incl. idle devices)
        assert dp_fill <= bf_fill + 1e-9


def test_search_end_to_end_properties():
    cluster = paper_case_study_cluster()
    layers, tables = make_tables(cluster, arch="gpt-2b", granularity=16,
                                 mb_tokens=1024)
    strat = search(cluster, tables, 1024, SearchConfig(n_microbatches=32))
    # stages tile the layer range
    pos = 0
    for s in strat.stages:
        assert s.layer_start == pos
        pos = s.layer_end
    assert pos == len(layers)
    # per-stage compute under t_max; links under t_max (H-1F1B condition)
    for s in strat.stages:
        assert s.t <= strat.t_max * (1 + 1e-9)
    for c in strat.c_links:
        assert c <= strat.t_max * (1 + 1e-9)
    # warm-up counts are non-increasing and end at 1
    wc = strat.warmup_counts
    assert all(wc[i] >= wc[i + 1] for i in range(len(wc) - 1))
    assert wc[-1] == 1
    # devices never oversubscribed per cluster
    for ci, sub in enumerate(cluster.subclusters):
        used = sum(s.n_devices for s in strat.stages if s.cluster_idx == ci)
        assert used <= sub.n_devices


def test_fine_granularity_improves_balance():
    """The paper's central claim (Table 1): finer layers -> better balance
    -> lower step time on a heterogeneous cluster."""
    cluster = paper_case_study_cluster()
    coarse_l, coarse_t = make_tables(cluster, "gpt-2b", 8, 1024)
    fine_l, fine_t = make_tables(cluster, "gpt-2b", 64, 1024)
    sc = SearchConfig(n_microbatches=64)
    t_coarse = search(cluster, coarse_t, 1024, sc).est_step_time
    t_fine = search(cluster, fine_t, 1024, sc).est_step_time
    assert t_fine <= t_coarse * 1.001


def test_feasibility_monotone_in_tmax():
    cluster = tiny_cluster()
    _, tables = make_tables(cluster, granularity=8)
    ctx = _DPContext(cluster, tables, SearchConfig(n_microbatches=8))
    vals = np.unique(ctx.t_tab[tables.feasible])
    feas = [not math.isinf(_dp_eval(ctx, float(t))[0])
            for t in vals[:: max(1, len(vals) // 8)]]
    # once feasible, stays feasible
    assert feas == sorted(feas)
