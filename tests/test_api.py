"""repro.api: staged plan -> lower -> execute pipeline.

Covers the facade contract (ISSUE 3 acceptance): bit-identical Plan JSON
round-trip, golden-file schema pinning (loud failure on accidental drift),
simulate() parity with the pipesim/replay referees, registry pluggability,
HarpConfig validation, and the CLI plan/simulate artifact round-trip.
"""
import json
import os

import pytest

from repro import api
from repro.core import paper_case_study_cluster
from repro.core.cluster import cluster_fingerprint, set_node_efficiencies
from repro.core.layering import build_layers
from repro.core.opgraph import build_op_sequence
from repro.core.pipesim import simulate as pipesim_simulate
from repro.core.planner import HAPTPlanner, PlannerConfig
from repro.runtime.events import BandwidthShift
from repro.runtime.replay import sync_priced_step

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "api_artifact_schema.json")


def small_cfg(**kw):
    return api.HarpConfig(
        seq_len=512, global_batch=16,
        planner=PlannerConfig(granularity=16, n_microbatches=16, **kw))


@pytest.fixture(scope="module")
def exe_case():
    """Inter-op-only compile on the paper's §2.2.2 case-study cluster."""
    return api.compile("gpt-2b", paper_case_study_cluster(), small_cfg())


@pytest.fixture(scope="module")
def exe_mixed():
    """Joint inter+intra compile on the fig11-style mixed fleet (one A100
    node throttled to 60%)."""
    cluster = set_node_efficiencies(paper_case_study_cluster(), "meshA100",
                                    (1.0, 0.6))
    return api.compile("gpt-2b", cluster, small_cfg(intra_op=True))


# ---------------------------------------------------------------------------
# JSON round trips
# ---------------------------------------------------------------------------


def test_plan_json_round_trip_bit_identical(exe_case):
    j = exe_case.plan.to_json()
    assert api.Plan.from_json(j).to_json() == j


def test_plan_json_round_trip_with_intra_op(exe_mixed):
    j = exe_mixed.plan.to_json()
    assert api.Plan.from_json(j).to_json() == j


def test_lowered_json_round_trip(exe_mixed):
    j = exe_mixed.lowered.to_json()
    assert api.LoweredPlan.from_json(j).to_json() == j


def test_cluster_dict_round_trip(exe_mixed):
    cl = exe_mixed.cluster
    rebuilt = api.cluster_from_dict(api.cluster_to_dict(cl))
    assert cluster_fingerprint(rebuilt) == cluster_fingerprint(cl)
    assert rebuilt == cl


def test_config_json_round_trip():
    cfg = small_cfg(intra_op=True)
    assert api.HarpConfig.from_json(cfg.to_json()).to_json() == cfg.to_json()


def test_config_with_measure_fn_refuses_serialization():
    cfg = api.HarpConfig(planner=PlannerConfig(measure_fn=lambda *a: 0.0))
    with pytest.raises(ValueError, match="measure_fn"):
        cfg.to_json()


# ---------------------------------------------------------------------------
# Golden schema (fails loudly on accidental artifact drift)
# ---------------------------------------------------------------------------


def _schema(obj):
    """Key-tree + JSON-type skeleton of an artifact dict."""
    if isinstance(obj, dict):
        return {k: _schema(v) for k, v in sorted(obj.items())}
    if isinstance(obj, list):
        return [_schema(obj[0])] if obj else []
    if isinstance(obj, bool):
        return "bool"
    if isinstance(obj, int):
        return "int"
    if isinstance(obj, float):
        return "float"
    if isinstance(obj, str):
        return "str"
    assert obj is None, f"unexpected JSON type {type(obj)}"
    return "null"


def test_artifact_schema_matches_golden(exe_case):
    with open(GOLDEN) as f:
        golden = json.load(f)
    got = {"plan": _schema(exe_case.plan.to_dict()),
           "lowered": _schema(exe_case.lowered.to_dict())}
    assert got == golden, (
        "Plan/LoweredPlan JSON schema drifted from tests/golden/"
        "api_artifact_schema.json.  If the change is INTENTIONAL, bump "
        "repro.api.artifacts.SCHEMA_VERSION and regenerate the golden file "
        "(see its header comment); otherwise you broke the cross-machine "
        "plan hand-off contract.")


# ---------------------------------------------------------------------------
# Facade semantics
# ---------------------------------------------------------------------------


def test_simulate_raw_equals_direct_pipesim(exe_case):
    strat = exe_case.strategy
    direct = pipesim_simulate(
        [s.t_f for s in strat.stages], [s.t_b for s in strat.stages],
        strat.c_links, strat.n_microbatches, strat.warmup_counts)
    assert exe_case.simulate(priced=False).makespan == direct.makespan


def test_simulate_priced_equals_referee_on_mixed_fleet(exe_mixed):
    """Acceptance: Executable.simulate() == referee-priced sync_priced_step
    throughput on the mixed fleet (identical accounting for joint plans)."""
    cfg = exe_mixed.config
    ops = build_op_sequence(exe_mixed.arch, seq_len=cfg.seq_len)
    layers = build_layers(ops, cfg.planner.granularity,
                          z=cfg.planner.z_heavy)
    ref = sync_priced_step(exe_mixed.strategy, exe_mixed.cluster, layers)
    res = exe_mixed.simulate()
    assert res.makespan == ref.makespan
    tok = exe_mixed.strategy.tokens_per_step()
    assert exe_mixed.throughput() == tok / ref.makespan


def test_lowered_schedule_matches_strategy_warmups(exe_case):
    # default scheduler is h1f1b — lowering must reproduce the plan's counts
    assert exe_case.lowered.warmup_counts == exe_case.strategy.warmup_counts


def test_lowered_apportionment_sums_to_microbatch(exe_mixed):
    low = exe_mixed.lowered
    for st in low.stages:
        assert sum(st.microbatch_shards) == low.microbatch_samples
        dp = dict(tuple(a) for a in st.mesh_axes)["data"]
        assert len(st.microbatch_shards) == dp


def test_compile_from_plan_artifact_rebuilds_cluster(exe_case):
    plan2 = api.Plan.from_json(exe_case.plan.to_json())
    exe2 = api.compile(plan_artifact=plan2)   # no cluster: rebuilt from JSON
    assert cluster_fingerprint(exe2.cluster) == plan2.cluster_fingerprint
    assert exe2.lowered.to_json() == exe_case.lowered.to_json()


def test_compile_warns_on_fingerprint_mismatch(exe_case):
    other = paper_case_study_cluster(cross_gbps=50.0)
    with pytest.warns(UserWarning, match="fingerprint"):
        api.compile(plan_artifact=exe_case.plan, cluster=other)


def test_attach_elastic_is_seeded_not_researched(exe_case):
    ctrl = exe_case.attach_elastic()
    assert ctrl.strategy is not None
    assert ctrl.decisions[0].reason == "seeded from compiled plan"
    # and it reacts to events without a bootstrap() call
    d = ctrl.handle(BandwidthShift(step=5, cross_bw=exe_case.cluster.cross_bw
                                   * 0.5))
    assert d.action in ("warmup_only", "incremental", "full", "none")
    # seeding must not alias the immutable Plan artifact's strategy
    assert ctrl.strategy is not exe_case.strategy


def test_describe_mentions_every_stage(exe_case):
    text = exe_case.describe()
    for i in range(exe_case.strategy.n_stages):
        assert f"stage{i}" in text


# ---------------------------------------------------------------------------
# HarpConfig validation
# ---------------------------------------------------------------------------


def test_validate_rejects_bad_values():
    with pytest.raises(ValueError, match="seq_len"):
        api.HarpConfig(seq_len=0).validate()
    with pytest.raises(ValueError, match="scheduler"):
        api.HarpConfig(scheduler="nope").validate()
    with pytest.raises(ValueError, match="granularity"):
        api.HarpConfig(planner=PlannerConfig(granularity=-1)).validate()


def test_validate_rejects_disagreeing_data_cfg():
    from repro.data.pipeline import DataConfig
    with pytest.raises(ValueError, match="data.seq_len"):
        api.HarpConfig(
            seq_len=128,
            data=DataConfig(vocab_size=64, seq_len=64,
                            global_batch=4)).validate()


def test_validate_rejects_nondivisible_batch():
    with pytest.raises(ValueError, match="multiple"):
        api.HarpConfig(global_batch=100,
                       planner=PlannerConfig(n_microbatches=32)).validate()


def test_default_microbatches_follow_global_batch():
    # README one-liner ergonomics: an untouched planner config follows the
    # workload instead of failing divisibility against the default B=128
    cfg = api.HarpConfig(global_batch=64)
    assert cfg.planner.n_microbatches == 64
    cfg.validate()


def test_elastic_cfg_backfill_and_mismatch_guard(exe_case):
    from repro.runtime.controller import ControllerConfig
    ctrl = exe_case.attach_elastic(ControllerConfig(drift_threshold=0.1))
    assert ctrl.cfg.seq_len == exe_case.config.seq_len
    assert ctrl.cfg.global_batch == exe_case.config.global_batch
    assert ctrl.cfg.drift_threshold == 0.1
    with pytest.raises(ValueError, match="disagrees"):
        exe_case.attach_elastic(ControllerConfig(seq_len=999))
    with pytest.raises(ValueError, match="elastic.seq_len"):
        api.HarpConfig(seq_len=512,
                       elastic=ControllerConfig(seq_len=999)).validate()


def test_planner_accepts_missing_config():
    # satellite: HAPTPlanner(cfg) is Optional with an explicit default
    p = HAPTPlanner(paper_case_study_cluster())
    assert isinstance(p.cfg, PlannerConfig)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_resolve_and_errors():
    from repro.api import registry
    assert registry.resolve("scheduler", "h1f1b") is not None
    with pytest.raises(KeyError, match="unknown scheduler"):
        registry.resolve("scheduler", "nope")
    with pytest.raises(KeyError, match="registry kind"):
        registry.resolve("fruit", "apple")
    with pytest.raises(ValueError, match="already registered"):
        registry.register("scheduler", "h1f1b", lambda *a: [])


def test_registry_third_party_scheduler_changes_lowering(exe_case):
    from repro.api import registry

    name = "_test_all_ones"
    if name not in registry.available("scheduler"):
        registry.register("scheduler", name,
                          lambda t, c, B: [1] * len(t))
    import dataclasses
    plan2 = dataclasses.replace(exe_case.plan,
                                config=dataclasses.replace(
                                    exe_case.plan.config, scheduler=name))
    lowered = api.lower(plan2)
    assert lowered.warmup_counts == [1] * exe_case.strategy.n_stages


def test_classic_scheduler_selection(exe_case):
    import dataclasses
    plan2 = dataclasses.replace(
        exe_case.plan, config=dataclasses.replace(exe_case.plan.config,
                                                  scheduler="classic_1f1b"))
    S = exe_case.strategy.n_stages
    assert api.lower(plan2).warmup_counts == list(range(S, 0, -1))


# ---------------------------------------------------------------------------
# CLI round trip
# ---------------------------------------------------------------------------


def test_cli_plan_simulate_round_trip(tmp_path, capsys):
    from repro.api.cli import main
    out = tmp_path / "plan.json"
    rc = main(["plan", "--arch", "gpt-2b", "--cluster", "paper_case_study",
               "--granularity", "16", "--microbatches", "16",
               "--global-batch", "16", "--seq-len", "512",
               "-o", str(out)])
    assert rc == 0 and out.exists()
    plan = api.Plan.from_json(out.read_text())
    assert plan.arch == "gpt-2b"
    # the artifact on disk is bit-stable
    assert plan.to_json() == out.read_text()
    rc = main(["simulate", "--plan", str(out)])
    assert rc == 0
    assert "tokens/s" in capsys.readouterr().out
