"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + gradients
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.ref import flash_attention_ref, rmsnorm_ref, ssd_intra_oracle


def _qkv(rng, B, T, S, H, KV, D, dtype):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, T, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D)).astype(dtype)
    return q, k, v


FLASH_CASES = [
    # (B, T, S, H, KV, D, causal, window)
    (1, 128, 128, 2, 2, 64, True, 0),
    (2, 200, 200, 8, 2, 64, True, 0),      # GQA + non-multiple length
    (1, 256, 256, 4, 1, 32, True, 64),     # MQA + sliding window
    (2, 64, 192, 2, 2, 64, False, 0),      # cross-shaped (Tq != Tk)
    (1, 130, 130, 2, 2, 128, True, 0),
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_forward(case, dtype):
    B, T, S, H, KV, D, causal, window = case
    q, k, v = _qkv(jax.random.PRNGKey(0), B, T, S, H, KV, D, dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("case", FLASH_CASES[:3])
def test_flash_attention_grads(case):
    B, T, S, H, KV, D, causal, window = case
    q, k, v = _qkv(jax.random.PRNGKey(1), B, T, S, H, KV, D, jnp.float32)

    def f(impl):
        def inner(q, k, v):
            return jnp.sum(jnp.sin(impl(q, k, v, causal=causal, window=window)))
        return inner

    g1 = jax.grad(f(ops.flash_attention), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f(flash_attention_ref), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    B=st.integers(1, 2), nc=st.integers(1, 3),
    Q=st.sampled_from([16, 32]), H=st.integers(1, 4),
    P=st.sampled_from([8, 16]), N=st.sampled_from([8, 16]),
)
def test_ssd_intra_property(B, nc, Q, H, P, N):
    rng = jax.random.PRNGKey(B * 1000 + nc * 100 + Q + H + P + N)
    ks = jax.random.split(rng, 5)
    xc = jax.random.normal(ks[0], (B, nc, Q, H, P))
    dtc = jax.nn.softplus(jax.random.normal(ks[1], (B, nc, Q, H)))
    a = -jnp.abs(jax.random.normal(ks[2], (B, nc, Q, H))) * 0.1
    cum = jnp.cumsum(a, axis=2)
    Bc = jax.random.normal(ks[3], (B, nc, Q, N))
    Cc = jax.random.normal(ks[4], (B, nc, Q, N))
    out = ops.ssd_intra(xc, dtc, cum, Bc, Cc)
    ref = ssd_intra_oracle(xc, dtc, cum, Bc, Cc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("shape", [(4, 64), (3, 5, 128), (128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    rng = jax.random.PRNGKey(7)
    x = jax.random.normal(rng, shape).astype(dtype)
    w = 1 + 0.1 * jax.random.normal(rng, shape[-1:])
    out = ops.rmsnorm(x, w)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2 if dtype == jnp.bfloat16 else 1e-6)


def test_flash_attention_fully_masked_rows():
    """Window smaller than block: early rows see 1 key; no NaNs."""
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 128, 128, 2, 2, 32, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=4)
    ref = flash_attention_ref(q, k, v, causal=True, window=4)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
