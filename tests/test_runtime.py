"""Elastic runtime subsystem: events fold correctly, telemetry calibrates,
the controller picks the cheapest sufficient response (warm-up retune vs.
incremental re-search vs. full replan) under the amortization rule, plan
caches survive restarts, profiler tables are reused for untouched meshes,
and the replay harness shows elastic > static after a disruption."""
import json

import pytest

from repro.configs import get_config
from repro.core.cluster import (
    GB, GBPS, DeviceProfile, HeteroCluster, SubCluster, cluster_fingerprint,
)
from repro.core.costmodel import CostModelConfig, stage_cost
from repro.core.layering import build_layers
from repro.core.opgraph import build_op_sequence
from repro.core.planner import PlannerConfig
from repro.core.profiler import ZeroRedundantProfiler
from repro.core.strategy import ParallelStrategy
from repro.runtime import (
    BandwidthShift, ControllerConfig, ElasticController, EventTrace,
    NodeFailure, NodeJoin, Preemption, StepObservation, Straggler,
    TelemetryCalibrator, apply_event, paper_trace, random_trace, run_replay,
)


def tiny_cluster(a_nodes=1, b_nodes=2, cross_gbps=10.0):
    return HeteroCluster(
        subclusters=(
            SubCluster("A", a_nodes, 2,
                       DeviceProfile("fast", 300e12, 40 * GB, 1.5e12),
                       300e9, 25e9),
            SubCluster("B", b_nodes, 2,
                       DeviceProfile("slow", 120e12, 32 * GB, 0.9e12),
                       150e9, 25e9),
        ),
        cross_bw=cross_gbps * GBPS)


def tiny_layers(granularity=8, seq_len=256):
    ops = build_op_sequence(get_config("gpt-2b"), seq_len=seq_len)
    return build_layers(ops, granularity)


def make_controller(cluster, total_steps=500, plan_cache_dir=None,
                    amortize=True, require_all=True):
    pcfg = PlannerConfig(granularity=8, n_microbatches=8,
                         min_submesh_devices=2)
    # all devices participate so plans genuinely span the cross link
    pcfg.search.require_all_devices = require_all
    ccfg = ControllerConfig(total_steps=total_steps, seq_len=256,
                            global_batch=32, plan_cache_dir=plan_cache_dir,
                            amortize=amortize)
    return ElasticController(cluster, "gpt-2b", planner_cfg=pcfg, cfg=ccfg)


# --- events -----------------------------------------------------------------


def test_apply_node_failure_and_join():
    cl = tiny_cluster(b_nodes=2)
    cl2 = apply_event(cl, NodeFailure(step=5, subcluster="B"))
    assert cl2.subclusters[1].n_nodes == 1
    assert cl.subclusters[1].n_nodes == 2          # original untouched (frozen)
    cl3 = apply_event(cl2, NodeJoin(step=9, subcluster="B"))
    assert cluster_fingerprint(cl3) == cluster_fingerprint(cl)


def test_apply_failure_drops_empty_subcluster_and_template_rejoins():
    cl = tiny_cluster(a_nodes=1)
    cl2 = apply_event(cl, NodeFailure(step=1, subcluster="A"))
    assert [s.name for s in cl2.subclusters] == ["B"]
    cl3 = apply_event(cl2, NodeJoin(step=2, subcluster="A",
                                    template=cl.subclusters[0]))
    assert {s.name for s in cl3.subclusters} == {"A", "B"}


def test_apply_bandwidth_and_straggler():
    cl = tiny_cluster()
    cl2 = apply_event(cl, BandwidthShift(step=1, cross_bw=2 * GBPS))
    assert cl2.cross_bw == pytest.approx(2 * GBPS)
    cl3 = apply_event(cl2, Straggler(step=2, subcluster="B", efficiency=0.6))
    assert cl3.subclusters[1].device.efficiency == pytest.approx(0.6)
    assert cl3.subclusters[1].device.effective_flops == pytest.approx(
        0.6 * 120e12)


def test_remove_too_many_nodes_raises():
    with pytest.raises(ValueError):
        apply_event(tiny_cluster(), NodeFailure(step=0, subcluster="A",
                                                n_nodes=5))


def test_preemption_expands_to_scheduled_rejoin():
    tr = EventTrace([Preemption(step=10, subcluster="B", n_nodes=1,
                                duration_steps=25)])
    assert len(tr.events) == 2
    joins = [e for e in tr.events if isinstance(e, NodeJoin)]
    assert joins and joins[0].step == 35
    cl = tiny_cluster()
    assert tr.cluster_at(cl, 20).subclusters[1].n_nodes == 1
    assert tr.cluster_at(cl, 40).subclusters[1].n_nodes == 2


def test_random_trace_deterministic_per_seed():
    cl = tiny_cluster(a_nodes=4, b_nodes=4)
    t1 = random_trace(cl, 2000, seed=3)
    t2 = random_trace(cl, 2000, seed=3)
    t3 = random_trace(cl, 2000, seed=4)
    assert [e.describe() for e in t1.events] == [e.describe() for e in t2.events]
    assert t1.events and [e.describe() for e in t1.events] \
        != [e.describe() for e in t3.events]


# --- telemetry --------------------------------------------------------------


def _fake_strategy(stage_ts, cluster_idxs, est):
    from repro.core.strategy import StageAssignment
    stages = [StageAssignment(layer_start=i, layer_end=i + 1, cluster_idx=ci,
                              mesh_n=1, mesh_m=2, tp=1, dp=2,
                              t_f=t / 3, t_b=2 * t / 3, mem_p=0, mem_a=0)
              for i, (t, ci) in enumerate(zip(stage_ts, cluster_idxs))]
    return ParallelStrategy(stages=stages, c_links=[0.0] * (len(stages) - 1),
                            warmup_counts=[1] * len(stages), t_max=max(stage_ts),
                            n_microbatches=4, mb_tokens=128, est_step_time=est)


def test_telemetry_converges_to_true_efficiency():
    cl = tiny_cluster()
    strat = _fake_strategy([1.0, 2.0], [0, 1], est=3.0)
    cal = TelemetryCalibrator(alpha=0.5)
    # sub-cluster B actually runs 2x slow: measured stage time = 2 * predicted
    for step in range(20):
        cal.observe(cl, strat, StepObservation(step, 5.0, [1.0, 4.0]))
    assert cal.efficiency("A") == pytest.approx(1.0, abs=1e-6)
    assert cal.efficiency("B") == pytest.approx(0.5, rel=1e-3)
    assert cal.drift(cl) == pytest.approx(0.5, rel=1e-3)
    calibrated = cal.calibrated(cl)
    assert calibrated.subclusters[1].device.efficiency == pytest.approx(
        0.5, rel=1e-3)
    # A stays inside the deadband -> untouched object semantics
    assert calibrated.subclusters[0].device.efficiency == 1.0


def test_telemetry_deadband_suppresses_noise():
    cl = tiny_cluster()
    strat = _fake_strategy([1.0], [0], est=1.0)
    cal = TelemetryCalibrator(alpha=0.5, deadband=0.10)
    for step in range(10):
        cal.observe(cl, strat, StepObservation(step, 1.03, [1.03]))
    assert cluster_fingerprint(cal.calibrated(cl)) == cluster_fingerprint(cl)


def test_telemetry_step_time_fallback():
    cl = tiny_cluster()
    strat = _fake_strategy([1.0, 1.0], [0, 1], est=2.0)
    cal = TelemetryCalibrator(alpha=0.5)
    for step in range(20):
        cal.observe(cl, strat, StepObservation(step, 4.0))   # 2x slower
    assert cal.efficiency("A") == pytest.approx(0.5, rel=1e-3)
    assert cal.efficiency("B") == pytest.approx(0.5, rel=1e-3)


# --- strategy serialization (plan cache survives restarts) ------------------


def test_strategy_json_roundtrip_with_planner_meta():
    layers = tiny_layers()
    cl = tiny_cluster()
    ctrl = make_controller(cl)
    strat = ctrl.bootstrap()
    assert strat.planner_meta.get("profiler") is not None
    s = strat.to_json()
    back = ParallelStrategy.from_json(s)
    assert back.stages == strat.stages
    assert back.warmup_counts == strat.warmup_counts
    assert [pytest.approx(c) for c in strat.c_links] == back.c_links
    assert back.t_max == pytest.approx(strat.t_max)
    assert back.est_step_time == pytest.approx(strat.est_step_time)
    assert back.planner_meta == json.loads(json.dumps(strat.planner_meta))
    # second round trip is exact
    assert back.to_json() == ParallelStrategy.from_json(back.to_json()).to_json()


# --- profiler table reuse (incremental re-search) ---------------------------


class CountingMeasure:
    """measure_fn that delegates to the analytic model and records which
    (sub-cluster, mesh) pairs were actually profiled."""

    def __init__(self):
        self.calls = []

    def __call__(self, layers, sub, mesh, mb_tokens):
        self.calls.append((sub.name, mesh.n, mesh.m))
        return stage_cost(layers, sub, mesh, mb_tokens, CostModelConfig())


def test_profiler_cache_skips_untouched_meshes_on_node_join():
    from repro.core.cluster import add_nodes
    layers = tiny_layers()
    cache = {}
    cl = tiny_cluster(a_nodes=1, b_nodes=2)
    m1 = CountingMeasure()
    ZeroRedundantProfiler(cl, layers, 1024, measure_fn=m1,
                          cost_cache=cache).profile()
    assert m1.calls
    # B gains a node: only B's NEW mesh shapes may be profiled
    cl2 = add_nodes(cl, "B", 1)
    m2 = CountingMeasure()
    t2 = ZeroRedundantProfiler(cl2, layers, 1024, measure_fn=m2,
                               cost_cache=cache).profile()
    assert all(name == "B" and n == 3 for (name, n, m) in m2.calls), m2.calls
    assert t2.stats.n_cache_hits > 0


def test_profiler_cache_invalidates_only_changed_subcluster():
    from repro.core.cluster import set_efficiency
    layers = tiny_layers()
    cache = {}
    cl = tiny_cluster()
    ZeroRedundantProfiler(cl, layers, 1024, measure_fn=CountingMeasure(),
                          cost_cache=cache).profile()
    # A degrades: A's entries miss (device profile changed), B's all hit
    cl2 = set_efficiency(cl, "A", 0.5)
    m2 = CountingMeasure()
    ZeroRedundantProfiler(cl2, layers, 1024, measure_fn=m2,
                          cost_cache=cache).profile()
    assert m2.calls and all(name == "A" for (name, _, _) in m2.calls)


def test_profiler_cache_full_hit_on_unchanged_cluster():
    layers = tiny_layers()
    cache = {}
    cl = tiny_cluster()
    ZeroRedundantProfiler(cl, layers, 1024, measure_fn=CountingMeasure(),
                          cost_cache=cache).profile()
    m2 = CountingMeasure()
    t2 = ZeroRedundantProfiler(cl, layers, 1024, measure_fn=m2,
                               cost_cache=cache).profile()
    assert m2.calls == []
    assert t2.stats.n_unique_profiled == 0


# --- controller decision ladder ---------------------------------------------


def test_bandwidth_shift_is_warmup_only_and_retunes():
    cl = tiny_cluster(cross_gbps=10.0)
    ctrl = make_controller(cl)
    strat = ctrl.bootstrap()
    counts_before = list(strat.warmup_counts)
    c_before = list(strat.c_links)
    d = ctrl.handle(BandwidthShift(step=10, cross_bw=1 * GBPS))
    assert d.action == "warmup_only"
    assert d.downtime_s == pytest.approx(0.0) or d.search_time_s >= 0
    # comm got 10x more expensive across the cross link
    if any(c > 0 for c in c_before):
        assert max(ctrl.strategy.c_links) > max(c_before)
    assert ctrl.strategy.warmup_counts != counts_before or \
        ctrl.strategy.c_links != c_before
    # fleet state tracked even without adoption
    assert ctrl.cluster.cross_bw == pytest.approx(1 * GBPS)


def test_node_failure_forces_incremental_replan():
    cl = tiny_cluster(b_nodes=2)
    ctrl = make_controller(cl)
    ctrl.bootstrap()
    uses_b = any(ctrl.plan_cluster.subclusters[s.cluster_idx].name == "B"
                 and s.mesh_n == 2 for s in ctrl.strategy.stages)
    d = ctrl.handle(NodeFailure(step=10, subcluster="B"))
    if uses_b:
        assert d.action in ("incremental", "full")
        assert "forced" in d.reason
        assert d.profile_cache_hits > 0 or d.plan_cache_hit  # warm tables
    # whatever the path, the new plan fits the shrunk fleet
    from repro.runtime import feasible_under
    assert feasible_under(ctrl.strategy, ctrl.plan_cluster, ctrl.cluster)
    assert ctrl.cluster.subclusters[-1].n_nodes == 1


def test_amortization_rejects_replan_near_horizon():
    cl = tiny_cluster(b_nodes=1)
    # 2 steps left: nothing amortizes
    ctrl = make_controller(cl, total_steps=2)
    ctrl.bootstrap()
    d = ctrl.handle(NodeJoin(step=1, subcluster="B"), step=1)
    assert d.action == "none"
    assert "not amortized" in d.reason
    # the join is still tracked in the fleet state
    assert [s.n_nodes for s in ctrl.cluster.subclusters
            if s.name == "B"] == [2]


def test_amortization_accepts_replan_with_long_horizon():
    cl = tiny_cluster(b_nodes=1)
    ctrl = make_controller(cl, total_steps=10_000_000)
    ctrl.bootstrap()
    t0 = ctrl.strategy.est_step_time
    d = ctrl.handle(NodeJoin(step=1, subcluster="B", n_nodes=3), step=1)
    assert d.action in ("incremental", "full")
    assert ctrl.strategy.est_step_time < t0


def test_plan_cache_survives_controller_restart(tmp_path):
    cl = tiny_cluster()
    ctrl = make_controller(cl, plan_cache_dir=str(tmp_path))
    s1 = ctrl.bootstrap()
    assert not ctrl.decisions[0].plan_cache_hit
    # "restart": a fresh controller over the same dir loads instead of searching
    ctrl2 = make_controller(cl, plan_cache_dir=str(tmp_path))
    s2 = ctrl2.bootstrap()
    assert ctrl2.decisions[0].plan_cache_hit
    assert ctrl2.decisions[0].search_time_s == 0.0
    assert s2.to_json() == s1.to_json()


def test_straggler_event_shifts_plan_or_is_amortized_away():
    cl = tiny_cluster()
    ctrl = make_controller(cl, total_steps=10_000_000)
    ctrl.bootstrap()
    d = ctrl.handle(Straggler(step=10, subcluster="A", efficiency=0.25))
    assert d.action in ("none", "incremental", "full")
    assert ctrl.cluster.subclusters[0].device.efficiency == pytest.approx(0.25)


# --- replay harness ---------------------------------------------------------


def test_replay_elastic_beats_static_after_disruption():
    cl = tiny_cluster(a_nodes=2, b_nodes=2)
    trace = paper_trace(cl, fail_step=10, bw_step=20, recover_step=35,
                        degraded_gbps=1.0)
    n_steps = 50

    ctrl = make_controller(cl, total_steps=n_steps)
    ctrl.bootstrap()
    elastic = run_replay(trace, n_steps, controller=ctrl)

    ctrl_s = make_controller(cl, total_steps=n_steps)
    static_plan = ctrl_s.bootstrap()
    static = run_replay(trace, n_steps, strategy=static_plan,
                        plan_cluster=cl, layers=ctrl_s.layers)

    assert elastic.tokens_total >= static.tokens_total
    post_e = elastic.throughput_between(10, n_steps)
    post_s = static.throughput_between(10, n_steps)
    assert post_e > post_s
    # static loses the outage; elastic never starves
    assert elastic.stalled_steps == 0
    # replan decisions were logged with their flavor
    actions = {d.action for d in ctrl.decisions}
    assert actions & {"warmup_only", "incremental", "full"}


def test_replay_quiet_trace_is_noop():
    cl = tiny_cluster()
    ctrl = make_controller(cl)
    strat = ctrl.bootstrap()
    res = run_replay(EventTrace([]), 10, controller=ctrl)
    assert res.stalled_steps == 0
    assert res.tokens_total == 10 * strat.tokens_per_step()
    assert len(ctrl.decisions) == 1        # bootstrap only


def test_replay_samples_accounting():
    cl = tiny_cluster()
    ctrl = make_controller(cl)
    ctrl.bootstrap()
    res = run_replay(EventTrace([]), 5, controller=ctrl)
    assert len(res.samples) == 5
    assert res.samples[-1].wall_s == pytest.approx(
        sum(s.step_time_s for s in res.samples))
    assert res.throughput() == pytest.approx(
        res.tokens_total / res.wall_total_s)
