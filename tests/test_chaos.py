"""Chaos-hardened elastic runtime (ISSUE 9).

Covers the storm generators (seeded, JSON-round-tripping, lowering onto the
existing typed events), the three injection seams (planner / migration
transfer / checkpoint write), the controller hardening (debounce +
hysteresis, replan deadline, the degraded-mode ladder, checkpoint-restart
retries, plan-cache quarantine, drained-pool rejoin), the serving
follow-on, and the two off-state pins: the PR-8 decision sequence is
bit-identical with chaos off, and the v7 artifact additions carry exactly
their off values.

Property suite (acceptance): every seeded storm replays through the
hardened controller with zero uncaught exceptions, and after every
decision the committed strategy's mesh footprint fits the live fleet.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.chaos import (
    ChaosConfig, FaultInjector, chaos_storm, correlated_failure,
    event_from_dict, event_to_dict, flapping_node, slow_then_dead,
    trace_from_json, trace_to_json, wan_brownout,
)
from repro.checkpoint import ckpt as ckpt_lib
from repro.core.cluster import (
    GB, GBPS, DeviceProfile, HeteroCluster, SubCluster, cluster_fingerprint,
)
from repro.core.dp_search import SearchTimeout
from repro.core.planner import HAPTPlanner, PlannerConfig
from repro.migrate import (
    MigrationAborted, RetryPolicy, apply_migration, diff_layouts,
    shard_state, states_equal,
)
from repro.migrate.layout import LeafSpec, PlanLayout
from repro.runtime import (
    ControllerConfig, ElasticController, EventTrace, NodeFailure, NodeJoin,
    Preemption, paper_trace, run_replay,
)
from repro.runtime.replay import feasible_under


def tiny_cluster(a_nodes=1, b_nodes=2, cross_gbps=10.0):
    return HeteroCluster(
        subclusters=(
            SubCluster("A", a_nodes, 2,
                       DeviceProfile("fast", 300e12, 40 * GB, 1.5e12),
                       300e9, 25e9),
            SubCluster("B", b_nodes, 2,
                       DeviceProfile("slow", 120e12, 32 * GB, 0.9e12),
                       150e9, 25e9),
        ),
        cross_bw=cross_gbps * GBPS)


def make_controller(cluster, total_steps=500, plan_cache_dir=None,
                    require_all=True, **ccfg_kw):
    pcfg = PlannerConfig(granularity=8, n_microbatches=8,
                         min_submesh_devices=2)
    pcfg.search.require_all_devices = require_all
    ccfg = ControllerConfig(total_steps=total_steps, seq_len=256,
                            global_batch=32, plan_cache_dir=plan_cache_dir,
                            **ccfg_kw)
    return ElasticController(cluster, "gpt-2b", planner_cfg=pcfg, cfg=ccfg)


def committed_ok(ctrl):
    """The never-commit-a-dead-node invariant."""
    return ctrl.strategy is None or feasible_under(
        ctrl.strategy, ctrl.plan_cluster, ctrl.cluster)


# ---------------------------------------------------------------------------
# Storm generators
# ---------------------------------------------------------------------------


def test_storm_deterministic_per_seed():
    cl = tiny_cluster(a_nodes=2, b_nodes=2)
    t1 = chaos_storm(cl, 300, seed=3, intensity=2.0)
    t2 = chaos_storm(cl, 300, seed=3, intensity=2.0)
    t3 = chaos_storm(cl, 300, seed=4, intensity=2.0)
    assert [e.describe() for e in t1.events] \
        == [e.describe() for e in t2.events]
    assert t1.events and [e.describe() for e in t1.events] \
        != [e.describe() for e in t3.events]


def test_storm_trace_json_round_trip():
    cl = tiny_cluster(a_nodes=2, b_nodes=2)
    tr = chaos_storm(cl, 300, seed=5, intensity=2.0)
    tr2 = trace_from_json(trace_to_json(tr))
    assert tr2.events == tr.events
    # materialized traces must NOT re-expand preemptions on load
    assert tr2.materialized
    assert len(tr2.events) == len(tr.events)


def test_event_dict_round_trip_keeps_template():
    sub = tiny_cluster().subclusters[0]
    ev = NodeJoin(step=7, subcluster="A", n_nodes=1, template=sub)
    ev2 = event_from_dict(event_to_dict(ev))
    assert ev2 == ev and ev2.template == sub


def test_storm_never_drains_fleet():
    for seed in range(6):
        cl = tiny_cluster(a_nodes=2, b_nodes=2)
        tr = chaos_storm(cl, 400, seed=seed, intensity=3.0)
        cur = cl
        for ev in tr.events:
            from repro.runtime.events import apply_event
            cur = apply_event(cur, ev)          # must never raise
            assert cur.subclusters, f"seed {seed}: fleet drained at {ev}"


def test_correlated_failure_rack_blast_and_outage():
    cl = tiny_cluster(a_nodes=2, b_nodes=2)
    tr = EventTrace(correlated_failure(cl, step=10, subcluster="B",
                                       n_nodes=2, outage_steps=20),
                    materialized=True)
    mid = tr.cluster_at(cl, 15)
    assert {s.name for s in mid.subclusters} == {"A"}   # rack gone
    back = tr.cluster_at(cl, 40)
    assert cluster_fingerprint(back) == cluster_fingerprint(cl)


def test_slow_then_dead_sequence():
    cl = tiny_cluster(a_nodes=2)
    evs = slow_then_dead(cl, start=5, subcluster="A", efficiency=0.4,
                         degrade_steps=10)
    names = [type(e).__name__ for e in evs]
    assert names == ["Straggler", "NodeFailure", "Straggler"]
    tr = EventTrace(evs, materialized=True)
    assert tr.cluster_at(cl, 7).subclusters[0].device.efficiency \
        == pytest.approx(0.4)
    after = tr.cluster_at(cl, 30)
    assert after.subclusters[0].n_nodes == 1
    assert after.subclusters[0].device.efficiency == pytest.approx(1.0)


def test_wan_brownout_ramps_and_recovers():
    cl = tiny_cluster(cross_gbps=10.0)
    evs = wan_brownout(cl, start=10, depth=0.25, duration=20, ramp=3)
    tr = EventTrace(evs, materialized=True)
    mid = tr.cluster_at(cl, 15)
    assert mid.cross_bw < cl.cross_bw
    assert tr.cluster_at(cl, 14).cross_bw == pytest.approx(2.5 * GBPS)
    assert tr.cluster_at(cl, 50).cross_bw == pytest.approx(cl.cross_bw)
    with pytest.raises(ValueError):
        wan_brownout(cl, start=0, depth=0.5, duration=2, ramp=2)


# ---------------------------------------------------------------------------
# FaultInjector determinism
# ---------------------------------------------------------------------------


def test_injector_streams_are_seeded_and_independent():
    cfg = ChaosConfig(seed=3, p_planner_timeout=0.5, p_transfer_failure=0.5)
    a, b = FaultInjector(cfg), FaultInjector(cfg)
    # same seed -> same per-seam streams
    assert [a.planner_fault() for _ in range(20)] \
        == [b.planner_fault() for _ in range(20)]
    # draws on one seam never perturb another: burn the transfer stream on
    # c, its planner stream must still match a fresh injector's
    c, fresh = FaultInjector(cfg), FaultInjector(cfg)
    for _ in range(50):
        c.transfer_fails()
    assert [c.planner_fault() for _ in range(20)] \
        == [fresh.planner_fault() for _ in range(20)]
    # different seed -> different stream (with 20 draws at p=0.5 a
    # collision would be astronomically unlikely)
    other = FaultInjector(dataclasses.replace(cfg, seed=4))
    d1, d2 = FaultInjector(cfg), other
    assert [d1.planner_fault() for _ in range(20)] \
        != [d2.planner_fault() for _ in range(20)]


def test_injector_zero_probabilities_never_fire():
    inj = FaultInjector(ChaosConfig(seed=0))
    assert all(inj.planner_fault() is None for _ in range(50))
    assert not any(inj.transfer_fails() for _ in range(50))
    assert all(inj.ckpt_write_fault() is None for _ in range(50))
    assert sum(inj.stats().values()) == 0


# ---------------------------------------------------------------------------
# Property suite: seeded storms through the hardened controller
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_hardened_controller_survives_storm(seed):
    """Acceptance: zero uncaught exceptions, zero dead-node commits, for
    every seeded storm."""
    cl = tiny_cluster(a_nodes=2, b_nodes=2)
    ctrl = make_controller(cl, require_all=False, debounce_steps=2,
                           min_steps_between_replans=4,
                           restart_retry_steps=10)
    ctrl.bootstrap()
    ctrl.injector = FaultInjector(ChaosConfig(
        seed=seed, p_planner_timeout=0.25, p_planner_infeasible=0.25))
    trace = chaos_storm(cl, 100, seed=seed, intensity=2.5)
    by_step = {}
    for e in trace.events:
        by_step.setdefault(e.step, []).append(e)
    for step in range(100):
        for ev in by_step.get(step, ()):
            d = ctrl.handle(ev, step=step)      # must never raise
            assert d is not None
            assert committed_ok(ctrl), \
                f"seed {seed} step {step}: committed a dead-node plan"
        d = ctrl.poll(step)
        if d is not None:
            assert committed_ok(ctrl)
    assert any(d.action != "none" for d in ctrl.decisions)


def test_unhardened_controller_raises_on_injected_fault():
    cl = tiny_cluster(a_nodes=2, b_nodes=2)
    ctrl = make_controller(cl, degraded_ladder=False)
    ctrl.bootstrap()
    ctrl.injector = FaultInjector(ChaosConfig(seed=0, p_planner_timeout=1.0))
    with pytest.raises(RuntimeError):
        ctrl.handle(NodeFailure(step=5, subcluster="B"), step=5)


# ---------------------------------------------------------------------------
# Degraded-mode ladder
# ---------------------------------------------------------------------------


def test_injected_fault_falls_down_ladder_not_raises():
    cl = tiny_cluster(a_nodes=2, b_nodes=2)
    ctrl = make_controller(cl)
    ctrl.bootstrap()
    ctrl.injector = FaultInjector(ChaosConfig(seed=0, p_planner_timeout=1.0,
                                              planner_timeout_s=0.5))
    d = ctrl.handle(NodeFailure(step=5, subcluster="B"), step=5)
    assert d.action in ("degraded_cached", "degraded_pool_drop",
                        "degraded_half_batch", "checkpoint_restart")
    assert committed_ok(ctrl)


def test_ladder_exhaustion_reaches_checkpoint_restart_then_recovers():
    cl = tiny_cluster(a_nodes=2, b_nodes=2)
    ctrl = make_controller(cl, restart_retry_steps=5)
    ctrl.bootstrap()
    # every planner call fails -> all search rungs die; the cached bootstrap
    # plan no longer fits the shrunk fleet -> rung 4
    ctrl.injector = FaultInjector(ChaosConfig(seed=0,
                                              p_planner_infeasible=1.0))
    d = ctrl.handle(NodeFailure(step=5, subcluster="B"), step=5)
    assert d.action == "checkpoint_restart"
    assert ctrl.strategy is None
    assert ctrl.poll(6) is None                 # retry window not yet open
    # heal the planner seam; the next retry brings the job back
    ctrl.injector = None
    d2 = ctrl.poll(20)
    assert d2 is not None and d2.action == "restart"
    assert ctrl.strategy is not None and committed_ok(ctrl)
    assert d2.migration_s > 0                   # restore from checkpoint paid


def test_controller_deadline_times_out_search_without_raising():
    cl = tiny_cluster(a_nodes=2, b_nodes=2)
    ctrl = make_controller(cl)
    ctrl.bootstrap()
    # impossible deadline AFTER bootstrap: every re-search times out, the
    # ladder absorbs it (cached plan infeasible on the shrunk fleet)
    ctrl.cfg = dataclasses.replace(ctrl.cfg, replan_deadline_s=1e-9)
    d = ctrl.handle(NodeFailure(step=5, subcluster="B"), step=5)
    assert d.action == "checkpoint_restart"
    assert "timeout" in d.reason


def test_search_deadline_raises_searchtimeout_directly():
    pcfg = PlannerConfig(granularity=8, n_microbatches=8,
                         min_submesh_devices=2)
    pcfg.search = dataclasses.replace(pcfg.search, deadline_s=1e-12)
    from repro.configs import get_config
    with pytest.raises(SearchTimeout):
        HAPTPlanner(tiny_cluster(), pcfg).plan(
            get_config("gpt-2b"), seq_len=256, global_batch=32)


# ---------------------------------------------------------------------------
# Debounce + hysteresis (replan storm control)
# ---------------------------------------------------------------------------


def test_flapping_node_costs_one_replan():
    cl = tiny_cluster(a_nodes=2, b_nodes=2)
    ctrl = make_controller(cl, require_all=False, debounce_steps=3,
                           min_steps_between_replans=8)
    ctrl.bootstrap()
    flap = flapping_node(cl, start=10, subcluster="B", n_flaps=3,
                         down_steps=1, up_steps=2)
    n_researches = 0
    for ev in flap:
        d = ctrl.handle(ev, step=ev.step)
        assert committed_ok(ctrl)
        if d.action in ("full", "incremental"):
            n_researches += 1
    # during the flap itself, at most the first (forced) replan commits —
    # every voluntary follow-up defers into the window
    assert n_researches <= 1
    # flush: walk poll() past the debounce + hysteresis windows; the whole
    # backlog lands as ONE coalesced recovery replan
    last = flap[-1].step
    flushed = []
    for step in range(last + 1, last + 20):
        d = ctrl.poll(step)
        if d is not None:
            flushed.append(d)
            assert committed_ok(ctrl)
    assert len(flushed) == 1
    assert flushed[0].coalesced == len(flap) - n_researches
    # net: 6 flap events cost 2 replans (1 forced + 1 coalesced recovery)
    # where the unhardened controller would pay one per event


def test_deferred_bandwidth_retune_still_applied():
    cl = tiny_cluster(a_nodes=2, b_nodes=2)
    ctrl = make_controller(cl, require_all=False, debounce_steps=5)
    ctrl.bootstrap()
    from repro.runtime.events import BandwidthShift
    d = ctrl.handle(BandwidthShift(step=3, cross_bw=2 * GBPS), step=3)
    assert d.action == "deferred"
    # the true fleet already carries the new bandwidth while the replan waits
    assert ctrl.cluster.cross_bw == pytest.approx(2 * GBPS)


def test_off_state_windows_never_defer():
    cl = tiny_cluster(a_nodes=2, b_nodes=2)
    ctrl = make_controller(cl, require_all=False)   # debounce=0, min_steps=0
    ctrl.bootstrap()
    d = ctrl.handle(NodeJoin(step=3, subcluster="B"), step=3)
    assert d.action != "deferred"
    assert ctrl.poll(4) is None


# ---------------------------------------------------------------------------
# Plan-cache quarantine (satellite 1)
# ---------------------------------------------------------------------------


def test_truncated_plan_cache_is_quarantined_not_fatal(tmp_path):
    cache = str(tmp_path / "plans")
    ctrl = make_controller(tiny_cluster(), plan_cache_dir=cache)
    ctrl.bootstrap()
    files = [f for f in os.listdir(cache) if f.endswith(".json")]
    assert files
    path = os.path.join(cache, files[0])
    with open(path) as f:
        s = f.read()
    with open(path, "w") as f:
        f.write(s[:len(s) // 2])                # torn write
    ctrl2 = make_controller(tiny_cluster(), plan_cache_dir=cache)
    ctrl2.bootstrap()                           # must not raise: cache miss
    assert not ctrl2.decisions[-1].plan_cache_hit
    assert os.path.exists(path + ".bad")        # quarantined for post-mortem
    with open(path + ".bad") as f:
        assert f.read() == s[:len(s) // 2]      # torn bytes preserved
    with open(path) as f:
        json.load(f)                            # re-search rewrote it valid


def test_stale_schema_plan_cache_is_miss(tmp_path):
    cache = str(tmp_path / "plans")
    ctrl = make_controller(tiny_cluster(), plan_cache_dir=cache)
    ctrl.bootstrap()
    fn = [f for f in os.listdir(cache) if f.endswith(".json")][0]
    path = os.path.join(cache, fn)
    with open(path) as f:
        doc = json.load(f)
    doc["schema"] = 99
    with open(path, "w") as f:
        json.dump(doc, f)
    ctrl2 = make_controller(tiny_cluster(), plan_cache_dir=cache)
    ctrl2.bootstrap()
    assert not ctrl2.decisions[-1].plan_cache_hit


def test_plan_cache_v2_round_trips_cluster():
    ctrl = make_controller(tiny_cluster())
    ctrl.bootstrap()
    entries = list(ctrl._cached_candidates())
    assert entries
    strat, cl = entries[0]
    assert cl is not None
    assert cluster_fingerprint(cl) == cluster_fingerprint(ctrl.cluster)


# ---------------------------------------------------------------------------
# Drained-pool preemption return (satellite 2)
# ---------------------------------------------------------------------------


def test_preemption_of_whole_pool_returns_via_template():
    cl = tiny_cluster(a_nodes=1, b_nodes=2)
    tr = EventTrace([Preemption(step=10, subcluster="A", n_nodes=1,
                                duration_steps=15,
                                template=cl.subclusters[0])])
    assert {s.name for s in tr.cluster_at(cl, 12).subclusters} == {"B"}
    back = tr.cluster_at(cl, 30)
    # the returned pool is re-appended, so compare pools order-insensitively
    assert sorted(cluster_fingerprint(back).split("|")) \
        == sorted(cluster_fingerprint(cl).split("|"))


def test_controller_recreates_fully_drained_pool_on_rejoin():
    """Regression: pool A (1 node) is preempted away entirely; the return
    NodeJoin carries no template, but the controller remembers the vanished
    pool's spec and re-creates it."""
    cl = tiny_cluster(a_nodes=1, b_nodes=2)
    ctrl = make_controller(cl, require_all=False)
    ctrl.bootstrap()
    d1 = ctrl.handle(NodeFailure(step=5, subcluster="A"), step=5)
    assert {s.name for s in ctrl.cluster.subclusters} == {"B"}
    assert committed_ok(ctrl)
    d2 = ctrl.handle(NodeJoin(step=20, subcluster="A"), step=20)  # no template
    assert {s.name for s in ctrl.cluster.subclusters} == {"A", "B"}
    restored = next(s for s in ctrl.cluster.subclusters if s.name == "A")
    assert restored == cl.subclusters[0]
    assert committed_ok(ctrl)
    del d1, d2


# ---------------------------------------------------------------------------
# Migration-transfer seam (retry / backoff / fallback / rollback)
# ---------------------------------------------------------------------------


def _one_leaf_case(nbytes=64):
    old = PlanLayout(devices_per_node={"A": 2})
    old.add(LeafSpec("w", nbytes, "param", 0), 0, {("A", 1): [(0, nbytes)]})
    new = PlanLayout(devices_per_node={"A": 2})
    new.add(LeafSpec("w", nbytes, "param", 0), 0, {("A", 0): [(0, nbytes)]})
    full = {"w": np.arange(nbytes, dtype=np.uint8)}
    state = shard_state(old, full)
    mplan = diff_layouts(old, new)
    return state, mplan, new, full


def test_transfer_retries_with_exponential_backoff_then_succeeds():
    state, mplan, new, full = _one_leaf_case()
    fails = {"n": 2}

    def fault(t, attempt):
        if fails["n"] > 0:
            fails["n"] -= 1
            return True
        return False

    out, stats = apply_migration(state, mplan, new, fault_fn=fault,
                                 retry=RetryPolicy(max_retries=3,
                                                   backoff_s=0.1, mult=2.0))
    assert states_equal(out, shard_state(new, full))
    assert stats.retries == 2
    assert stats.backoff_s == pytest.approx(0.1 + 0.2)
    assert stats.ckpt_fallbacks == 0


def test_transfer_budget_exhausted_falls_back_to_checkpoint():
    state, mplan, new, full = _one_leaf_case()
    out, stats = apply_migration(
        state, mplan, new, ckpt_image=full,
        fault_fn=lambda t, a: True,
        retry=RetryPolicy(max_retries=2, backoff_s=0.01))
    assert states_equal(out, shard_state(new, full))
    assert stats.ckpt_fallbacks == 1
    assert stats.ckpt_bytes == 64 and stats.live_bytes == 0
    assert stats.retries == 3                   # initial + 2 retries, all drew


def test_migration_abort_rolls_back_and_carries_stats():
    state, mplan, new, full = _one_leaf_case()
    before = shard_state(state.layout, full)
    with pytest.raises(MigrationAborted) as ei:
        apply_migration(state, mplan, new, fault_fn=lambda t, a: True,
                        retry=RetryPolicy(max_retries=1, backoff_s=0.01))
    assert ei.value.stats.retries == 2
    # rollback contract: the input state is untouched — the caller keeps
    # running the old plan on it
    assert states_equal(state, before)


def test_injector_drives_transfer_seam_deterministically():
    cfg = ChaosConfig(seed=9, p_transfer_failure=0.5)
    f1 = FaultInjector(cfg).transfer_fault_fn()
    f2 = FaultInjector(cfg).transfer_fault_fn()
    assert [f1(None, 0) for _ in range(30)] == [f2(None, 0) for _ in range(30)]


# ---------------------------------------------------------------------------
# Checkpoint-write seam (atomic rename protects readers)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["partial", "fsync"])
def test_ckpt_write_fault_keeps_previous_checkpoint_readable(tmp_path, mode):
    d = str(tmp_path / "ckpts")
    tree = {"w": np.arange(8, dtype=np.float32)}
    ckpt_lib.save(d, 1, tree)
    prev = ckpt_lib.set_write_fault(lambda step: mode)
    try:
        with pytest.raises(IOError):
            ckpt_lib.save(d, 2, {"w": np.ones(8, dtype=np.float32)})
    finally:
        ckpt_lib.set_write_fault(prev)
    # the torn write never reached a ckpt path; step 1 restores intact
    assert ckpt_lib.list_steps(d) == [1]
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]
    step, got, _ = ckpt_lib.restore(d, tree)
    assert step == 1
    np.testing.assert_array_equal(got["w"], tree["w"])
    # and a clean retry after the fault clears lands normally
    ckpt_lib.save(d, 2, {"w": np.ones(8, dtype=np.float32)})
    assert ckpt_lib.list_steps(d) == [1, 2]


# ---------------------------------------------------------------------------
# Serving follow-on
# ---------------------------------------------------------------------------


def test_pool_loss_reruns_serving_placement():
    from repro.serving.placement import ServingConfig
    cl = tiny_cluster(a_nodes=2, b_nodes=2)
    ctrl = make_controller(cl, require_all=False)
    ctrl.serving_cfg = ServingConfig(qps=4.0, duration_s=0.5,
                                     search_sample=32)
    ctrl.bootstrap()
    d = ctrl.handle(NodeFailure(step=5, subcluster="B"), step=5)
    assert ctrl.serve_replans >= 1
    assert ctrl.serve_plan is not None
    assert d.serve_replanned


# ---------------------------------------------------------------------------
# Off-state pins (chaos=None == PR-8, bit for bit)
# ---------------------------------------------------------------------------


def test_off_state_decision_sequence_bit_identical_to_pr8():
    """The hardening knobs at their defaults (chaos=None, debounce=0,
    min_steps=0, deadline=0, ladder armed but never triggered) reproduce
    the pre-chaos controller's decision sequence exactly."""
    cl = tiny_cluster()
    ctrl = make_controller(cl)
    ctrl.bootstrap()
    res = run_replay(paper_trace(cl), 160, controller=ctrl)
    got = [(d.step, d.action, round(d.step_time_after, 9))
           for d in ctrl.decisions]
    assert got == [
        (0, "full", 0.277367989),
        (60, "incremental", 0.364577801),
        (100, "warmup_only", 0.398132233),
        (150, "incremental", 0.304570459),
        (150, "warmup_only", 0.277367989),
    ]
    assert res.tokens_total == 1310720


def test_off_state_artifact_additions_are_pinned():
    """Schema v7 added exactly two knobs to the artifact (v8 adds the null
    ``obs`` key on top); with chaos and obs off they carry exactly their
    off values (the diff vs v6 is pinned)."""
    from repro import api
    from repro.api.artifacts import SCHEMA_VERSION
    assert SCHEMA_VERSION == 8
    cfg = api.HarpConfig(seq_len=256, global_batch=32,
                         planner=PlannerConfig(granularity=8,
                                               n_microbatches=8,
                                               min_submesh_devices=2))
    d = cfg.to_dict()
    assert d["chaos"] is None
    assert d["obs"] is None          # the v8 addition, off by default
    assert d["planner"]["search"]["deadline_s"] == 0.0
    e = d["elastic"]
    assert e is None                 # elastic block unchanged when unset
    # ControllerConfig's new knobs default to the off state
    cc = dataclasses.asdict(ControllerConfig())
    assert cc["debounce_steps"] == 0
    assert cc["min_steps_between_replans"] == 0
    assert cc["replan_deadline_s"] == 0.0
    assert cc["degraded_ladder"] is True    # armed, but a no-op until a
    #                                         failure PR-8 would have raised on


def test_chaos_config_json_round_trip_via_harp_config():
    from repro import api
    cfg = api.HarpConfig(chaos=ChaosConfig(seed=4, p_planner_timeout=0.1,
                                           p_transfer_failure=0.2))
    cfg2 = api.HarpConfig.from_json(cfg.to_json())
    assert cfg2.chaos == cfg.chaos
    # pre-v7 artifacts (no chaos key) still load
    d = cfg.to_dict()
    d.pop("chaos")
    assert api.HarpConfig.from_dict(d).chaos is None


def test_chaos_event_source_registered():
    from repro.api import registry
    assert "chaos" in registry.available("event_source")
    cl = tiny_cluster(a_nodes=2, b_nodes=2)
    tr = registry.resolve("event_source", "chaos")(cl, 200, seed=1,
                                                   intensity=2.0)
    assert isinstance(tr, EventTrace)
