"""Joint inter+intra-operator search (two-level planning) and the
IntraOpPlan -> mesh lowering in parallel/sharding.py."""
import pytest

from repro.configs import get_config
from repro.core import HAPTPlanner, IntraOpPlan, PlannerConfig
from repro.core.cluster import paper_case_study_cluster, set_node_efficiencies
from repro.core.costmodel import Submesh, intra_op_candidates, stage_cost
from repro.core.layering import build_layers
from repro.core.opgraph import build_op_sequence
from repro.core.profiler import ZeroRedundantProfiler
from repro.core.strategy import ParallelStrategy
from repro.parallel.sharding import (
    batch_shard_sizes, intra_op_mesh_axes, mesh_from_intra_op,
    validate_intra_op_plan,
)
from repro.runtime.replay import sync_priced_step

ARCH = "gpt-2b"


def mixed_cluster(slow=0.6):
    return set_node_efficiencies(
        paper_case_study_cluster(), "meshA100", (1.0, slow))


def make_layers(granularity=16, seq_len=512):
    ops = build_op_sequence(get_config(ARCH), seq_len=seq_len)
    return build_layers(ops, granularity)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def test_uneven_shards_beat_even_on_mixed_nodes():
    cluster = mixed_cluster(0.5)
    sub = cluster.subclusters[0]
    layers = make_layers(8)
    mesh = Submesh(0, 2, 2)       # spans both nodes
    even = {c.tp: c for c in intra_op_candidates(
        layers[:4], sub, mesh, 1024, uneven=False)}
    uneven = {c.tp: c for c in intra_op_candidates(
        layers[:4], sub, mesh, 1024, uneven=True)}
    for tp in even:
        # even shards wait for the 0.5-efficiency node; uneven shards
        # (proportional to node efficiency) finish together
        assert uneven[tp].t < even[tp].t
        r = uneven[tp].intra.shard_ratios
        assert abs(sum(r) - 1.0) < 1e-9
        assert max(r) > min(r)    # genuinely uneven
        # ratios ordered with node_scales (slowest node first)
        assert r[0] < r[-1]


def test_homogeneous_uneven_is_even():
    cluster = paper_case_study_cluster()
    sub = cluster.subclusters[0]
    layers = make_layers(8)
    mesh = Submesh(0, 2, 2)
    for cand in intra_op_candidates(layers[:4], sub, mesh, 1024, uneven=True):
        r = cand.intra.shard_ratios
        assert max(r) - min(r) < 1e-12
        assert abs(sum(r) - 1.0) < 1e-9


def test_stage_cost_is_cheapest_candidate():
    cluster = paper_case_study_cluster()
    sub = cluster.subclusters[0]
    layers = make_layers(8)
    mesh = Submesh(0, 1, 2)
    greedy = stage_cost(layers[:4], sub, mesh, 1024)
    cands = intra_op_candidates(layers[:4], sub, mesh, 1024, uneven=False)
    assert greedy.t == min(c.t for c in cands)
    assert greedy.intra is not None and greedy.intra.n_devices == mesh.n_devices


# ---------------------------------------------------------------------------
# profiler: variant rows + degree-keyed cache
# ---------------------------------------------------------------------------


def test_profiler_joint_emits_variant_rows():
    cluster = paper_case_study_cluster()
    layers = make_layers(8)
    inter = ZeroRedundantProfiler(cluster, layers, 1024).profile()
    joint = ZeroRedundantProfiler(cluster, layers, 1024, intra_op=True,
                                  amortize_microbatches=16).profile()
    assert len(joint.meshes) >= len(inter.meshes)
    assert joint.variant_tp is not None
    assert all(tp is not None for tp in joint.variant_tp)
    # every surviving row's stage costs carry the matching intra plan
    for (mid, i, j), sc in joint.stage_costs.items():
        assert sc.intra is not None
        assert sc.intra.tp == joint.variant_tp[mid]
        assert sc.intra.n_devices == joint.meshes[mid].n_devices


def test_profiler_cache_keys_include_degree():
    cluster = paper_case_study_cluster()
    layers = make_layers(8)
    cache = {}
    ZeroRedundantProfiler(cluster, layers, 1024, cost_cache=cache,
                          intra_op=True, amortize_microbatches=16).profile()
    degrees = {k[-1] for k in cache}
    assert len(degrees) > 1           # several tp widths cached separately
    n_joint = len(cache)
    # inter-only entries (degree None) do not collide with joint entries
    ZeroRedundantProfiler(cluster, layers, 1024, cost_cache=cache).profile()
    assert None in {k[-1] for k in cache}
    assert len(cache) > n_joint
    # re-profiling joint on a warm cache adds nothing
    n_all = len(cache)
    t = ZeroRedundantProfiler(cluster, layers, 1024, cost_cache=cache,
                              intra_op=True, amortize_microbatches=16).profile()
    assert len(cache) == n_all
    assert t.stats.n_unique_profiled == 0


def test_intra_op_max_degree_prunes():
    cluster = paper_case_study_cluster()
    layers = make_layers(8)
    capped = ZeroRedundantProfiler(cluster, layers, 1024, intra_op=True,
                                   intra_op_max_degree=1).profile()
    assert all(tp == 1 for tp in capped.variant_tp)


# ---------------------------------------------------------------------------
# joint search end-to-end
# ---------------------------------------------------------------------------


def test_joint_beats_inter_only_on_mixed_cluster():
    """The acceptance property: on a mixed-efficiency sub-cluster, the joint
    search finds a strictly better plan than inter-op-only planning when both
    are referee-priced identically (sync charged to both)."""
    cluster = mixed_cluster(0.6)
    layers = make_layers(16, seq_len=1024)
    pcfg = PlannerConfig(granularity=16, n_microbatches=16)
    planner = HAPTPlanner(cluster, pcfg)
    arch = get_config(ARCH)
    s_inter = planner.plan(arch, seq_len=1024, global_batch=16, layers=layers)
    s_joint = planner.plan(arch, seq_len=1024, global_batch=16, layers=layers,
                           intra_op=True)
    t_inter = sync_priced_step(s_inter, cluster, layers).makespan
    t_joint = sync_priced_step(s_joint, cluster, layers).makespan
    assert t_joint < t_inter
    assert s_joint.planner_meta["intra_op"] is True
    assert any(s.intra_op is not None and s.intra_op.is_uneven
               for s in s_joint.stages)


def test_joint_no_worse_on_homogeneous_cluster():
    cluster = paper_case_study_cluster()
    layers = make_layers(16)
    pcfg = PlannerConfig(granularity=16, n_microbatches=16)
    planner = HAPTPlanner(cluster, pcfg)
    arch = get_config(ARCH)
    s_inter = planner.plan(arch, seq_len=512, global_batch=16, layers=layers)
    s_joint = planner.plan(arch, seq_len=512, global_batch=16, layers=layers,
                           intra_op=True)
    t_inter = sync_priced_step(s_inter, cluster, layers).makespan
    t_joint = sync_priced_step(s_joint, cluster, layers).makespan
    assert t_joint <= t_inter * (1 + 1e-9)


def test_joint_strategy_respects_search_invariants():
    cluster = mixed_cluster()
    layers = make_layers(16)
    strat = HAPTPlanner(cluster, PlannerConfig(
        granularity=16, n_microbatches=16)).plan(
            get_config(ARCH), seq_len=512, global_batch=16, layers=layers,
            intra_op=True)
    pos = 0
    for s in strat.stages:
        assert s.layer_start == pos
        pos = s.layer_end
        assert s.t <= strat.t_max * (1 + 1e-9)
        plan = s.intra_op
        assert plan is not None
        assert plan.tp * plan.dp == s.n_devices
        assert len(plan.shard_ratios) == plan.dp
        assert abs(sum(plan.shard_ratios) - 1.0) < 1e-9
    assert pos == len(layers)
    for ci, sub in enumerate(cluster.subclusters):
        used = sum(s.n_devices for s in strat.stages if s.cluster_idx == ci)
        assert used <= sub.n_devices


def test_strategy_json_round_trip_with_intra_op():
    cluster = mixed_cluster()
    layers = make_layers(16)
    strat = HAPTPlanner(cluster, PlannerConfig(
        granularity=16, n_microbatches=16)).plan(
            get_config(ARCH), seq_len=512, global_batch=16, layers=layers,
            intra_op=True)
    rt = ParallelStrategy.from_json(strat.to_json())
    assert rt.to_json() == strat.to_json()
    for a, b in zip(rt.stages, strat.stages):
        assert a == b                      # frozen dataclasses, deep equality
        assert isinstance(a.intra_op, IntraOpPlan)
        assert isinstance(a.intra_op.shard_ratios, tuple)


# ---------------------------------------------------------------------------
# sharding lowering
# ---------------------------------------------------------------------------


def plan_of(tp=1, dp=1, ratios=None):
    ratios = tuple(ratios) if ratios is not None else (1.0 / dp,) * dp
    return IntraOpPlan(axis="tensor" if tp > 1 else "data", tp=tp, dp=dp,
                       shard_ratios=ratios, comm_bytes=0.0,
                       comm_time_f=0.0, comm_time_b=0.0)


def test_validate_rejects_bad_ratios():
    with pytest.raises(ValueError):
        validate_intra_op_plan(plan_of(dp=2, ratios=(0.5, 0.6)))
    with pytest.raises(ValueError):
        validate_intra_op_plan(plan_of(dp=2, ratios=(1.0,)))
    with pytest.raises(ValueError):
        validate_intra_op_plan(plan_of(dp=2, ratios=(-0.5, 1.5)))


def test_mesh_axes_shape():
    assert intra_op_mesh_axes(plan_of(tp=4, dp=2, ratios=(0.4, 0.6))) == \
        (("data", 2), ("model", 4))


def test_degenerate_degree_one_is_noop():
    plan = plan_of()
    assert plan.degree == 1 and plan.n_devices == 1
    mesh = mesh_from_intra_op(plan)          # single CPU device suffices
    assert dict(zip(mesh.axis_names, mesh.devices.shape)) == \
        {"data": 1, "model": 1}
    assert batch_shard_sizes(plan, 32) == [32]


def test_mesh_requires_enough_devices():
    with pytest.raises(ValueError):
        mesh_from_intra_op(plan_of(tp=2, dp=4, ratios=(0.25,) * 4))


def test_batch_shard_sizes_sum_and_apportion():
    p = plan_of(dp=4, ratios=(0.1, 0.2, 0.3, 0.4))
    for batch in (1, 7, 16, 33, 1024):
        sizes = batch_shard_sizes(p, batch)
        assert sum(sizes) == batch
        assert all(s >= 0 for s in sizes)
        # monotone with the ratios (largest ratio never gets fewer samples)
        assert sorted(sizes) == sizes
    even = plan_of(dp=4)
    assert batch_shard_sizes(even, 32) == [8, 8, 8, 8]


def test_search_ratios_lower_to_exact_batch():
    """End-to-end: every searched stage's ratios apportion a real microbatch
    exactly (uneven shards sum to the batch, nothing lost or invented)."""
    cluster = mixed_cluster()
    layers = make_layers(16)
    strat = HAPTPlanner(cluster, PlannerConfig(
        granularity=16, n_microbatches=16)).plan(
            get_config(ARCH), seq_len=512, global_batch=16, layers=layers,
            intra_op=True)
    for s in strat.stages:
        sizes = batch_shard_sizes(s.intra_op, 64)
        assert sum(sizes) == 64
        assert len(sizes) == s.intra_op.dp
