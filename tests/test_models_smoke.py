"""Per-architecture smoke tests: reduced config, one forward + train step on
CPU, asserting output shapes and finiteness; decode-vs-full equivalence;
prefill->decode continuation."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model
from repro.models.prefill import prefill

# full per-arch forward+train sweeps take minutes on CPU — tier-1 fast job
# deselects these with -m "not slow" (see pytest.ini / CI)
pytestmark = pytest.mark.slow

ARCHS = list_archs(assigned_only=True)


def make_batch(cfg, rng, B=2, T=16, with_labels=True):
    k1, k2, k3 = jax.random.split(rng, 3)
    batch = {"tokens": jax.random.randint(k1, (B, T), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(k2, (B, T), 0, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.1 * jax.random.normal(
            k3, (B, cfg.n_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jax.random.normal(
            k3, (B, cfg.enc_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = make_batch(cfg, rng)
    logits, aux = model.forward(params, batch)
    B, T = batch["tokens"].shape
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))

    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0

    # one SGD step decreases loss on the same batch
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype),
                           params, grads)
    loss2, _ = model.loss(params2, batch)
    assert float(loss2) < float(loss)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(1)
    params = model.init(rng)
    B, T = 2, 8
    batch = make_batch(cfg, rng, B=B, T=T, with_labels=False)
    full, _ = model.forward(params, batch)
    cache = model.init_cache(B, 32, dtype=jnp.float32)
    if cfg.family == "vlm":
        from repro.models import vlm
        cache = vlm.prefill_cross_kv(
            cfg, params, batch["image_embeds"].astype(jnp.float32), cache)
    if cfg.family == "audio":
        from repro.models import encdec
        cache = encdec.prefill_memory(
            cfg, params, batch["frames"].astype(jnp.float32), cache)
    errs = []
    for t in range(T):
        lg, cache = model.decode_step(params, cache,
                                      batch["tokens"][:, t:t + 1],
                                      jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(errs) < 5e-4, f"decode diverges from forward: {max(errs)}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_full(arch):
    cfg = get_config(arch).reduced()
    if cfg.family == "moe":
        # exact match requires no capacity drops
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(2)
    params = model.init(rng)
    B, T, t0 = 2, 12, 8
    batch = make_batch(cfg, rng, B=B, T=T, with_labels=False)
    full, _ = model.forward(params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :t0]
    last, cache = prefill(cfg, params, pre, cache_len=T,
                          cache_dtype=jnp.float32)
    errs = [float(jnp.max(jnp.abs(last[:, 0] - full[:, t0 - 1])))]
    for t in range(t0, T):
        lg, cache = model.decode_step(params, cache,
                                      batch["tokens"][:, t:t + 1],
                                      jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(errs) < 5e-4


def test_moe_routing_drops_tokens_under_capacity():
    cfg = dataclasses.replace(get_config("granite-moe-1b-a400m").reduced(),
                              capacity_factor=0.25)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(3)
    params = model.init(rng)
    batch = make_batch(cfg, rng, B=4, T=32)
    loss, _ = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))  # drops must not produce NaN


def test_moe_aux_loss_positive():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(4)
    params = model.init(rng)
    batch = make_batch(cfg, rng)
    _, aux = model.forward(params, batch)
    assert float(aux) > 0
