"""Pipeline-DAG simulator: ``no_overlap`` mode (HexiScale-like synchronous
sends) and the eta load-balance metric's edge cases — the surfaces the
elastic replay harness builds on."""
import pytest

from repro.core.h1f1b import h1f1b_counts
from repro.core.pipesim import eta_load_balance, simulate


def test_no_overlap_never_faster():
    t_f, t_b, c = [1.0, 1.2], [2.0, 2.4], [0.5]
    counts = h1f1b_counts([3.0, 3.6], c, 8)
    over = simulate(t_f, t_b, c, 8, counts)
    sync = simulate(t_f, t_b, c, 8, counts, no_overlap=True)
    assert sync.makespan >= over.makespan - 1e-12


def test_no_overlap_equals_overlap_without_comm():
    t_f, t_b = [1.0, 1.0, 1.0], [2.0, 2.0, 2.0]
    c = [0.0, 0.0]
    counts = [3, 2, 1]
    over = simulate(t_f, t_b, c, 6, counts)
    sync = simulate(t_f, t_b, c, 6, counts, no_overlap=True)
    assert sync.makespan == pytest.approx(over.makespan)
    assert sync.comm_total == 0.0


def test_no_overlap_two_stage_one_microbatch_exact():
    # F0(1) -> send(0.5) -> F1(1) -> B1(1) -> send back(0.5) -> B0(1)
    res = simulate([1.0, 1.0], [1.0, 1.0], [0.5], 1, [1, 1], no_overlap=True)
    assert res.makespan == pytest.approx(5.0)


def test_no_overlap_comm_charged_to_stages():
    t_f, t_b, c = [1.0, 1.0], [1.0, 1.0], [0.4]
    B = 4
    sync = simulate(t_f, t_b, c, B, [2, 1], no_overlap=True)
    # every CF is charged to stage 0, every CB to stage 1; full duplex both ways
    assert sync.stage_comm_blocking[0] == pytest.approx(B * 0.4)
    assert sync.stage_comm_blocking[1] == pytest.approx(B * 0.4)
    assert sum(sync.stage_comm_blocking) == pytest.approx(sync.comm_total)
    over = simulate(t_f, t_b, c, B, [2, 1])
    assert over.stage_comm_blocking == [0.0, 0.0]


def test_no_overlap_busy_idle_accounting():
    sync = simulate([1.0, 2.0], [1.0, 2.0], [0.3], 5, [2, 1], no_overlap=True)
    for i in range(2):
        total = (sync.stage_compute[i] + sync.stage_comm_blocking[i]
                 + sync.stage_idle[i])
        assert total == pytest.approx(sync.makespan)


def test_eta_zero_compute():
    assert eta_load_balance([0.0, 0.0], [1e12, 1e12]) == 1.0


def test_eta_single_stage():
    assert eta_load_balance([3.0], [5e12]) == pytest.approx(1.0)


def test_eta_perfect_balance():
    assert eta_load_balance([2.0, 2.0], [1e12, 3e12]) == pytest.approx(1.0)


def test_eta_imbalance_weighted_by_peak():
    # idle time on the big sub-cluster hurts more than on the small one
    eta_big_idle = eta_load_balance([1.0, 2.0], [3e12, 1e12])
    eta_small_idle = eta_load_balance([2.0, 1.0], [3e12, 1e12])
    assert eta_big_idle < eta_small_idle < 1.0


def test_eta_one_stage_idle_zero_compute():
    # a stage with zero compute on equal peaks: eta = 1 - 1/2
    assert eta_load_balance([2.0, 0.0], [1e12, 1e12]) == pytest.approx(0.5)
