"""Pipeline-DAG simulator: ``no_overlap`` mode (HexiScale-like synchronous
sends), the eta load-balance metric's edge cases, and the closed-form fast
path's bit-exact equivalence with the reference graph engine — the surfaces
the elastic replay harness builds on."""
import random

import pytest

from repro.core.h1f1b import (
    classic_1f1b_counts, eager_1f1b_counts, h1f1b_counts,
)
from repro.core.pipesim import (
    clear_sim_memo, eta_load_balance, fast_path_eligible, sim_memo_stats,
    simulate,
)


def test_no_overlap_never_faster():
    t_f, t_b, c = [1.0, 1.2], [2.0, 2.4], [0.5]
    counts = h1f1b_counts([3.0, 3.6], c, 8)
    over = simulate(t_f, t_b, c, 8, counts)
    sync = simulate(t_f, t_b, c, 8, counts, no_overlap=True)
    assert sync.makespan >= over.makespan - 1e-12


def test_no_overlap_equals_overlap_without_comm():
    t_f, t_b = [1.0, 1.0, 1.0], [2.0, 2.0, 2.0]
    c = [0.0, 0.0]
    counts = [3, 2, 1]
    over = simulate(t_f, t_b, c, 6, counts)
    sync = simulate(t_f, t_b, c, 6, counts, no_overlap=True)
    assert sync.makespan == pytest.approx(over.makespan)
    assert sync.comm_total == 0.0


def test_no_overlap_two_stage_one_microbatch_exact():
    # F0(1) -> send(0.5) -> F1(1) -> B1(1) -> send back(0.5) -> B0(1)
    res = simulate([1.0, 1.0], [1.0, 1.0], [0.5], 1, [1, 1], no_overlap=True)
    assert res.makespan == pytest.approx(5.0)


def test_no_overlap_comm_charged_to_stages():
    t_f, t_b, c = [1.0, 1.0], [1.0, 1.0], [0.4]
    B = 4
    sync = simulate(t_f, t_b, c, B, [2, 1], no_overlap=True)
    # every CF is charged to stage 0, every CB to stage 1; full duplex both ways
    assert sync.stage_comm_blocking[0] == pytest.approx(B * 0.4)
    assert sync.stage_comm_blocking[1] == pytest.approx(B * 0.4)
    assert sum(sync.stage_comm_blocking) == pytest.approx(sync.comm_total)
    over = simulate(t_f, t_b, c, B, [2, 1])
    assert over.stage_comm_blocking == [0.0, 0.0]


def test_no_overlap_busy_idle_accounting():
    sync = simulate([1.0, 2.0], [1.0, 2.0], [0.3], 5, [2, 1], no_overlap=True)
    for i in range(2):
        total = (sync.stage_compute[i] + sync.stage_comm_blocking[i]
                 + sync.stage_idle[i])
        assert total == pytest.approx(sync.makespan)


# ---------------------------------------------------------------------------
# Closed-form fast path == graph engine (bit-exact)
# ---------------------------------------------------------------------------


def _assert_same(a, b):
    assert a.makespan == b.makespan          # exact, not approx
    assert a.start == b.start and a.dur == b.dur
    assert a.stage_compute == b.stage_compute
    assert a.stage_idle == b.stage_idle
    assert a.comm_total == b.comm_total
    assert a.comm_exposed == b.comm_exposed
    assert a.stage_intra_comm == b.stage_intra_comm
    assert a.warmup_counts == b.warmup_counts


@pytest.mark.parametrize("sched", ["h1f1b", "h1f1b_banded", "classic",
                                   "eager"])
@pytest.mark.parametrize("seed", range(4))
def test_fast_path_matches_graph_all_schedules(sched, seed):
    rng = random.Random(hash((sched, seed)))
    S = rng.randint(1, 6)
    B = rng.randint(1, 16)
    t_f = [rng.uniform(0.1, 2.0) for _ in range(S)]
    t_b = [rng.uniform(0.1, 3.0) for _ in range(S)]
    c = [rng.choice([0.0, rng.uniform(0.0, 1.5)]) for _ in range(S - 1)]
    if sched == "classic":
        counts = classic_1f1b_counts(S, B)
    elif sched == "eager":
        counts = eager_1f1b_counts(S, B)
    else:
        counts = h1f1b_counts([f + b for f, b in zip(t_f, t_b)], c, B,
                              banded=(sched == "h1f1b_banded"))
    assert fast_path_eligible(counts)
    fast = simulate(t_f, t_b, c, B, counts, fast=True, cache=False)
    graph = simulate(t_f, t_b, c, B, counts, fast=False, cache=False)
    _assert_same(fast, graph)


@pytest.mark.parametrize("seed", range(3))
def test_fast_path_matches_graph_with_intra_and_bwd_links(seed):
    rng = random.Random(seed)
    S, B = rng.randint(2, 5), rng.randint(2, 10)
    t_f = [rng.uniform(0.1, 2.0) for _ in range(S)]
    t_b = [rng.uniform(0.1, 3.0) for _ in range(S)]
    c = [rng.uniform(0.0, 1.0) for _ in range(S - 1)]
    cb = [x * rng.uniform(0.5, 1.5) for x in c]
    intra_f = [rng.uniform(0.0, 0.3) for _ in range(S)]
    intra_b = [rng.uniform(0.0, 0.3) for _ in range(S)]
    counts = h1f1b_counts([f + b for f, b in zip(t_f, t_b)], c, B)
    kw = dict(c_links_bwd=cb, intra_f=intra_f, intra_b=intra_b,
              intra_overlap=rng.uniform(0.0, 1.0), cache=False)
    _assert_same(simulate(t_f, t_b, c, B, counts, fast=True, **kw),
                 simulate(t_f, t_b, c, B, counts, fast=False, **kw))


def test_fast_path_ineligible_schedules():
    # growing warm-up counts downstream break the recurrence's issue order
    assert not fast_path_eligible([1, 2, 3])
    assert not fast_path_eligible([2, 0, 1])
    assert not fast_path_eligible([3, 2, 1], no_overlap=True)
    with pytest.raises(ValueError, match="not closed-form eligible"):
        simulate([1.0, 1.0], [1.0, 1.0], [0.1], 4, [1, 2], fast=True)
    # auto mode falls back to the graph engine, which diagnoses the
    # growing-counts schedule as what it is: a deadlocked pipeline
    with pytest.raises(AssertionError, match="cycle"):
        simulate([1.0, 1.0], [1.0, 1.0], [0.1], 4, [1, 2], cache=False)


def test_no_overlap_uses_graph_engine():
    s0 = sim_memo_stats().graph_path
    simulate([1.0, 1.0], [1.0, 1.0], [0.3], 4, [2, 1], no_overlap=True,
             cache=False)
    assert sim_memo_stats().graph_path == s0 + 1


def test_sim_memo_hits_and_misses():
    clear_sim_memo()
    args = ([1.0, 1.5], [2.0, 2.5], [0.25], 8, [2, 1])
    s0 = sim_memo_stats().snapshot()
    r1 = simulate(*args)
    r2 = simulate(*args)
    live = sim_memo_stats()
    assert live.misses - s0.misses == 1
    assert live.hits - s0.hits == 1
    assert r1 is r2                       # served from cache, same object
    # different signature -> miss
    simulate(*args, no_overlap=True)
    assert sim_memo_stats().misses - s0.misses == 2


def test_eta_zero_compute():
    assert eta_load_balance([0.0, 0.0], [1e12, 1e12]) == 1.0


def test_eta_single_stage():
    assert eta_load_balance([3.0], [5e12]) == pytest.approx(1.0)


def test_eta_perfect_balance():
    assert eta_load_balance([2.0, 2.0], [1e12, 3e12]) == pytest.approx(1.0)


def test_eta_imbalance_weighted_by_peak():
    # idle time on the big sub-cluster hurts more than on the small one
    eta_big_idle = eta_load_balance([1.0, 2.0], [3e12, 1e12])
    eta_small_idle = eta_load_balance([2.0, 1.0], [3e12, 1e12])
    assert eta_big_idle < eta_small_idle < 1.0


def test_eta_one_stage_idle_zero_compute():
    # a stage with zero compute on equal peaks: eta = 1 - 1/2
    assert eta_load_balance([2.0, 0.0], [1e12, 1e12]) == pytest.approx(0.5)
