import os
import sys
import types

# tests run on ONE CPU device (the dry-run alone uses 512 — never set here)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# hypothesis shim: property-based tests are optional (requirements-dev.txt);
# without the package, @given tests skip but the rest of each module runs.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import pytest

    def _given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg signature on purpose: pytest must not mistake the
            # wrapped function's strategy parameters for fixtures
            def skipper():
                pytest.skip(
                    "hypothesis not installed (see requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def _settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):
            return lambda *a, **k: None

    _st = _Strategies("hypothesis.strategies")
    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.assume = lambda *a, **k: None
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
