import os
import sys

# tests run on ONE CPU device (the dry-run alone uses 512 — never set here)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
