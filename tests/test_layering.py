"""Structure-preserving layer construction (§5.1)."""
import pytest

from repro.configs import get_config, list_archs
from repro.core.layering import build_layers, mine_modules
from repro.core.opgraph import build_op_sequence, total_flops_per_token


@pytest.mark.parametrize("arch", ["gpt-39b", "minitron-8b", "mamba2-2.7b",
                                  "qwen3-moe-235b-a22b"])
def test_mining_finds_repeated_blocks(arch):
    cfg = get_config(arch)
    ops = build_op_sequence(cfg)
    mods = mine_modules(ops)
    rep = [m for m in mods if m.repeated]
    assert len(rep) >= cfg.n_layers  # at least one repeated module per block


def test_modules_partition_sequence():
    """Modules tile the op sequence exactly (no gaps, no overlaps)."""
    for arch in list_archs(assigned_only=True):
        ops = build_op_sequence(get_config(arch))
        mods = sorted(mine_modules(ops), key=lambda m: m.start)
        pos = 0
        for m in mods:
            assert m.start == pos, f"{arch}: gap/overlap at {pos}"
            pos = m.end
        assert pos == len(ops)


def test_layers_cover_all_ops():
    for arch in ["gpt-39b", "zamba2-7b", "whisper-medium"]:
        ops = build_op_sequence(get_config(arch))
        layers = build_layers(ops, target_layers=64)
        pos = 0
        for l in layers:
            assert l.start == pos
            pos = l.end
        assert pos == len(ops)
        # flops conserved
        assert sum(l.flops_per_token for l in layers) == pytest.approx(
            total_flops_per_token(ops), rel=1e-9)


def test_repeated_instances_share_class_keys():
    """Zero-redundancy: layers at the same position of repeated module
    instances must share their class_key."""
    ops = build_op_sequence(get_config("gpt-39b"))
    layers = build_layers(ops, target_layers=96)
    by_key = {}
    for l in layers:
        by_key.setdefault(l.class_key, []).append(l)
    # a 48-block model with ~2 layers/block must reuse keys ~48x
    reuse_counts = [len(v) for v in by_key.values()]
    assert max(reuse_counts) >= 40
    # same class key -> identical flops (structural identity)
    for key, ls in by_key.items():
        flops = {round(l.flops_per_token) for l in ls}
        assert len(flops) == 1, f"class {key} has differing flops"


def test_granularity_scales():
    ops = build_op_sequence(get_config("gpt-39b"))
    n8 = len(build_layers(ops, target_layers=8))
    n128 = len(build_layers(ops, target_layers=128))
    assert n8 < n128
    # fine granularity reaches ~1e2 layers (the paper's #L=146 regime)
    assert n128 >= 64
