"""Live state migration: interval arithmetic, layout invariants, the
differ's nearest-replica / checkpoint-fallback source selection,
diff -> apply bit-identity against direct initialization (property-based),
exact pricing through the tiered links, the controller's priced decisions,
and the ``migrate_to`` facade + schema-v5 artifact + CLI round trip."""
import dataclasses
import json
import types

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import api
from repro.comm.topology import CROSS_LINK, build_topology
from repro.core.cluster import (
    GB, GBPS, DeviceProfile, HeteroCluster, SubCluster, remove_nodes,
)
from repro.core.planner import PlannerConfig
from repro.core.strategy import IntraOpPlan, ParallelStrategy, StageAssignment
from repro.migrate import (
    DEFAULT_RESTORE_BW, MigrationPlan, Transfer, apply_migration,
    classify_link, diff_layouts, gather_leaf, layout_from_strategy,
    lost_devices, price_migration, shard_state, stage_devices, states_equal,
)
from repro.migrate.layout import (
    LeafSpec, PlanLayout, intersect, length, normalize, subtract,
)
from repro.runtime import (
    ControllerConfig, ElasticController, EventTrace, Preemption, run_replay,
)

# --- fixtures ---------------------------------------------------------------


def duo(n_a=2, n_b=1, dpn=2, cross_gbps=10.0):
    """Two sub-clusters, ``dpn`` devices per node."""
    return HeteroCluster(
        subclusters=(
            SubCluster("A", n_a, dpn,
                       DeviceProfile("fast", 300e12, 40 * GB, 1.5e12),
                       300e9, 25e9),
            SubCluster("B", n_b, dpn,
                       DeviceProfile("slow", 120e12, 32 * GB, 0.9e12),
                       150e9, 25e9),
        ),
        cross_bw=cross_gbps * GBPS)


def fake_layers(*sizes):
    """Layout construction only reads ``param_bytes``."""
    return [types.SimpleNamespace(param_bytes=int(s)) for s in sizes]


def mk_strategy(specs, mb=4):
    """``specs``: (cluster_idx, layer_start, layer_end, tp, dp, ratios)."""
    stages = []
    for ci, ls, le, tp, dp, ratios in specs:
        stages.append(StageAssignment(
            layer_start=ls, layer_end=le, cluster_idx=ci,
            mesh_n=1, mesh_m=tp * dp, tp=tp, dp=dp,
            t_f=0.01, t_b=0.02, mem_p=0, mem_a=0,
            intra_op=IntraOpPlan(axis="data", tp=tp, dp=dp,
                                 shard_ratios=tuple(ratios),
                                 comm_bytes=0.0, comm_time_f=0.0,
                                 comm_time_b=0.0)))
    return ParallelStrategy(
        stages=stages, c_links=[0.001] * (len(stages) - 1),
        warmup_counts=list(range(len(stages), 0, -1)), t_max=0.03,
        n_microbatches=mb, mb_tokens=128, est_step_time=mb * 0.03)


def one_leaf_layout(per_dev, nbytes, dpn=2, name="w"):
    lay = PlanLayout(devices_per_node={"A": dpn, "B": dpn})
    lay.add(LeafSpec(name, nbytes, "param", 0), 0, per_dev)
    return lay


# --- interval arithmetic ----------------------------------------------------


def test_interval_helpers():
    assert normalize([(5, 9), (0, 3), (3, 5), (7, 7)]) == [(0, 9)]
    assert normalize([(0, 2), (4, 6)]) == [(0, 2), (4, 6)]
    assert intersect([(0, 10)], [(3, 5), (8, 12)]) == [(3, 5), (8, 10)]
    assert intersect([(0, 2)], [(2, 4)]) == []
    assert subtract([(0, 10)], [(3, 5), (8, 12)]) == [(0, 3), (5, 8)]
    assert subtract([(0, 4)], []) == [(0, 4)]
    assert subtract([(0, 4)], [(0, 4)]) == []
    assert length([(0, 3), (10, 14)]) == 7


# --- layouts ----------------------------------------------------------------


def test_layout_tiles_every_leaf_and_replicates_params():
    cl = duo(n_a=2, n_b=1)
    strat = mk_strategy([(0, 0, 2, 2, 2, (0.7, 0.3)),
                         (1, 2, 3, 1, 2, (0.5, 0.5))])
    layers = fake_layers(1000, 777, 500)
    lay = layout_from_strategy(strat, cl, layers)
    assert set(lay.leaves) == {f"layer{i:04d}.{k}" for i in range(3)
                               for k in ("param", "opt")}
    for name, spec in lay.leaves.items():
        union = normalize([iv for ivs in lay.holdings[name].values()
                           for iv in ivs])
        assert union == [(0, spec.nbytes)], name   # fully tiled
        held = sum(length(ivs) for ivs in lay.holdings[name].values())
        if spec.kind == "param":                   # replicated across dp
            dp = strat.stages[lay.leaf_stage[name]].dp
            assert held == dp * spec.nbytes
        else:                                      # ZeRO-1: exact partition
            assert held == spec.nbytes
    # optimizer state is opt_bytes_per_param x the params
    assert lay.leaves["layer0000.opt"].nbytes == 2000


def test_stage_devices_pack_consecutively():
    cl = duo(n_a=2, n_b=1)
    strat = mk_strategy([(0, 0, 1, 1, 2, (0.5, 0.5)),
                         (0, 1, 2, 1, 2, (0.5, 0.5)),
                         (1, 2, 3, 1, 2, (0.5, 0.5))])
    devs = stage_devices(strat, cl)
    assert devs[0] == [("A", 0), ("A", 1)]
    assert devs[1] == [("A", 2), ("A", 3)]        # same pool, next range
    assert devs[2] == [("B", 0), ("B", 1)]


def test_lost_devices_are_the_tail_range():
    old = duo(n_a=2, n_b=1)
    assert lost_devices(old, remove_nodes(old, "A", 1)) == \
        {("A", 2), ("A", 3)}
    assert lost_devices(old, remove_nodes(old, "B", 1)) == \
        {("B", 0), ("B", 1)}
    assert lost_devices(old, old) == set()


# --- differ -----------------------------------------------------------------


def test_identity_diff_moves_nothing():
    cl = duo()
    strat = mk_strategy([(0, 0, 2, 1, 4, (0.4, 0.3, 0.2, 0.1))])
    lay = layout_from_strategy(strat, cl, fake_layers(999, 1000))
    mplan = diff_layouts(lay, lay)
    assert mplan.transfers == []
    assert mplan.moved_bytes == mplan.ckpt_bytes == 0
    assert mplan.local_bytes == mplan.total_bytes == lay.total_bytes


def test_differ_prefers_same_node_then_same_subcluster():
    old = one_leaf_layout({("A", 1): [(0, 100)], ("A", 2): [(0, 100)],
                           ("B", 0): [(0, 100)]}, 100)
    new = one_leaf_layout({("A", 0): [(0, 100)]}, 100)
    mplan = diff_layouts(old, new)
    assert [t.src for t in mplan.transfers] == [("A", 1)]   # same node (dpn=2)
    lost_node_mate = diff_layouts(old, new, lost={("A", 1)})
    assert [t.src for t in lost_node_mate.transfers] == [("A", 2)]  # same sub
    lost_sub = diff_layouts(old, new, lost={("A", 1), ("A", 2)})
    assert [t.src for t in lost_sub.transfers] == [("B", 0)]        # cross


def test_differ_falls_back_to_checkpoint_when_no_replica_survives():
    old = one_leaf_layout({("A", 1): [(0, 100)]}, 100)
    new = one_leaf_layout({("A", 0): [(0, 100)]}, 100)
    mplan = diff_layouts(old, new, lost={("A", 1)})
    assert [t.src for t in mplan.transfers] == [None]
    assert mplan.ckpt_bytes == 100 and mplan.moved_bytes == 0


def test_differ_covers_fragments_from_multiple_sources():
    old = one_leaf_layout({("A", 1): [(0, 50)], ("B", 0): [(25, 100)]}, 100)
    new = one_leaf_layout({("A", 0): [(0, 100)]}, 100)
    mplan = diff_layouts(old, new)
    got = sorted((t.start, t.end, t.src) for t in mplan.transfers)
    assert got == [(0, 50, ("A", 1)), (50, 100, ("B", 0))]
    assert mplan.moved_bytes == 100
    assert mplan.moved_bytes + mplan.ckpt_bytes + mplan.local_bytes \
        == mplan.total_bytes


def test_differ_counts_bytes_already_in_place():
    old = one_leaf_layout({("A", 0): [(0, 40)], ("A", 1): [(0, 100)]}, 100)
    new = one_leaf_layout({("A", 0): [(0, 100)]}, 100)
    mplan = diff_layouts(old, new)
    assert mplan.local_bytes == 40 and mplan.moved_bytes == 60
    assert all(t.start >= 40 for t in mplan.transfers)


# --- diff -> apply bit-identity (property) ----------------------------------


_TPDP_A = [(1, 4), (2, 2), (4, 1), (1, 2), (2, 1)]
_TPDP_B = [(1, 2), (2, 1), (1, 1)]


def _random_case(seed: int):
    """Random layer sizes + random old/new strategies over a shrink of the
    duo fleet: old on A(4 devices)+B(2), new on A(2)+B(2)."""
    rng = np.random.default_rng(seed)
    layers = fake_layers(*rng.integers(1, 300, size=3))

    def ratios(dp):
        r = rng.random(dp) + 0.1
        return tuple(float(x) for x in r / r.sum())

    def pick(pool):
        tp, dp = pool[rng.integers(len(pool))]
        return tp, dp, ratios(dp)

    old_cl, new_cl = duo(n_a=2, n_b=1), duo(n_a=1, n_b=1)
    cut = int(rng.integers(1, 3))
    old = mk_strategy([(0, 0, cut) + pick(_TPDP_A),
                       (1, cut, 3) + pick(_TPDP_B)])
    new = mk_strategy([(0, 0, cut) + pick(_TPDP_B),
                       (1, cut, 3) + pick(_TPDP_B)])
    old_lay = layout_from_strategy(old, old_cl, layers)
    new_lay = layout_from_strategy(new, new_cl, layers)
    lost = lost_devices(old_cl, new_cl)
    full = {name: rng.integers(0, 256, size=spec.nbytes).astype(np.uint8)
            for name, spec in old_lay.leaves.items()}
    return old_lay, new_lay, lost, full


def _assert_roundtrip(seed: int):
    old_lay, new_lay, lost, full = _random_case(seed)
    mplan = diff_layouts(old_lay, new_lay, lost=lost)
    assert mplan.moved_bytes + mplan.ckpt_bytes + mplan.local_bytes \
        == mplan.total_bytes
    st_old = shard_state(old_lay, full)
    st_new, stats = apply_migration(st_old, mplan, new_lay, lost=lost,
                                    ckpt_image=full)
    # bit-identity vs initializing directly in the new layout
    assert states_equal(st_new, shard_state(new_lay, full))
    # the executor shipped exactly what the differ priced — no more
    assert stats.live_bytes == mplan.moved_bytes
    assert stats.ckpt_bytes == mplan.ckpt_bytes
    assert stats.n_transfers == mplan.n_transfers
    for name in new_lay.leaves:
        assert np.array_equal(gather_leaf(st_new, name), full[name])


@pytest.mark.parametrize("seed", range(15))
def test_diff_apply_bit_identity_seeded(seed):
    _assert_roundtrip(seed)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=40, deadline=None)
def test_diff_apply_bit_identity_property(seed):
    _assert_roundtrip(seed)


def test_apply_rejects_lost_source():
    old = one_leaf_layout({("A", 1): [(0, 10)]}, 10)
    new = one_leaf_layout({("A", 0): [(0, 10)]}, 10)
    full = {"w": np.zeros(10, dtype=np.uint8)}
    bogus = MigrationPlan(transfers=[Transfer("w", 0, 10, ("A", 0),
                                              src=("A", 1))])
    with pytest.raises(ValueError, match="lost device"):
        apply_migration(shard_state(old, full), bogus, new,
                        lost={("A", 1)}, ckpt_image=full)


# --- pricing ----------------------------------------------------------------


def test_classify_link_tiers():
    lay = one_leaf_layout({}, 10, dpn=2)
    topo = build_topology(duo(n_a=2, n_b=1))
    assert classify_link(lay, ("A", 0), ("A", 1), topo) == "intra:A"
    assert classify_link(lay, ("A", 0), ("A", 2), topo) == "ib:A"
    assert classify_link(lay, ("A", 0), ("B", 0), topo) == CROSS_LINK


def test_price_empty_plan_is_free():
    lay = one_leaf_layout({}, 10)
    cost = price_migration(MigrationPlan(), lay, duo())
    assert cost.serial_s == cost.downtime_s == 0.0 and cost.n_flows == 0


def test_price_checkpoint_restore_rides_restore_path():
    nb = 4_000_000_000
    lay = one_leaf_layout({}, nb)
    mplan = MigrationPlan(transfers=[Transfer("w", 0, nb, ("A", 0),
                                              src=None)], ckpt_bytes=nb,
                          total_bytes=nb)
    cost = price_migration(mplan, lay, duo(), overlap=False)
    assert not cost.overlapped
    assert cost.downtime_s == pytest.approx(nb / DEFAULT_RESTORE_BW)
    assert cost.link_bytes == {"__restore__": nb}
    half = price_migration(mplan, lay, duo(), restore_bw=1e9, overlap=False)
    assert half.downtime_s == pytest.approx(nb / 1e9)


def test_price_live_transfer_matches_link_bandwidth():
    nb = 1_000_000_000
    lay = one_leaf_layout({}, nb)
    topo = build_topology(duo())
    for src, dst in [(("A", 0), ("A", 1)), (("A", 0), ("A", 2)),
                     (("A", 0), ("B", 0))]:
        mplan = MigrationPlan(transfers=[Transfer("w", 0, nb, dst, src=src)],
                              moved_bytes=nb, total_bytes=nb)
        link = classify_link(lay, src, dst, topo)
        l = topo.link(link)
        cost = price_migration(mplan, lay, duo(), overlap=False)
        assert cost.link_bytes == {link: nb}
        assert cost.serial_s == pytest.approx(l.latency + nb / l.bandwidth)


# --- controller + replay acceptance -----------------------------------------


def _controller(cl, pricing, n_steps=30):
    pcfg = PlannerConfig(granularity=8, n_microbatches=8,
                         min_submesh_devices=2)
    pcfg.search.require_all_devices = True
    return ElasticController(
        cl, "gpt-2b", planner_cfg=pcfg,
        cfg=ControllerConfig(total_steps=n_steps, seq_len=256,
                             global_batch=32, migration_pricing=pricing))


def test_replay_charge_matches_priced_migration():
    """Preemption acceptance: the wall clock the replay charges beyond
    productive steps equals the decisions' priced downtime (±5%), the
    differ engaged on every adoption, and the priced and legacy guesses
    genuinely differ."""
    cl = duo(n_a=2, n_b=2, dpn=2)
    trace = EventTrace([Preemption(step=5, subcluster="B", n_nodes=1,
                                   duration_steps=12)])
    ctrl = _controller(cl, "priced")
    ctrl.bootstrap()
    res = run_replay(trace, 30, controller=ctrl)
    adoptions = [d for d in res.decisions if d.migration_s > 0]
    assert adoptions, "forced replan must have adopted a new plan"
    assert all(d.migration_bytes > 0 for d in adoptions)
    charged = res.wall_total_s - sum(s.step_time_s for s in res.samples)
    priced = res.migration_s + res.search_s
    assert charged == pytest.approx(priced, rel=0.05)

    ctrl_l = _controller(cl, "legacy")
    ctrl_l.bootstrap()
    res_l = run_replay(trace, 30, controller=ctrl_l)
    assert res_l.migration_s != pytest.approx(res.migration_s, rel=1e-3)
    assert res_l.migration_bytes == 0.0        # the guess prices no layout


# --- facade / artifact / CLI ------------------------------------------------


@pytest.fixture(scope="module")
def exe_pair():
    cfg = api.HarpConfig(
        seq_len=256, global_batch=32,
        planner=PlannerConfig(granularity=8, n_microbatches=8,
                              min_submesh_devices=2))
    exe = api.compile("gpt-2b", duo(n_a=2, n_b=1), cfg)
    new_exe = exe.migrate_to(remove_nodes(duo(n_a=2, n_b=1), "A", 1))
    return exe, new_exe


def test_migrate_to_prices_and_stamps_v5(exe_pair):
    exe, new_exe = exe_pair
    from repro.api.artifacts import SCHEMA_VERSION

    m = new_exe.plan.migration
    assert m is not None and new_exe.plan.version == SCHEMA_VERSION >= 5
    assert m["from_fingerprint"] == exe.plan.cluster_fingerprint
    assert m["to_fingerprint"] == new_exe.plan.cluster_fingerprint
    assert m["moved_bytes"] + m["ckpt_bytes"] + m["local_bytes"] \
        == m["total_bytes"] > 0
    assert sum(m["link_bytes"].values()) \
        == m["moved_bytes"] + m["ckpt_bytes"]
    assert m["n_transfers"] > 0
    assert 0 <= m["downtime_s"] <= m["serial_s"] + 1e-9
    # live migration undercuts restoring the full state from the store
    assert m["downtime_s"] < m["total_bytes"] / DEFAULT_RESTORE_BW


def test_migration_section_round_trips(exe_pair):
    _, new_exe = exe_pair
    back = api.Plan.from_json(new_exe.plan.to_json())
    assert back.migration == new_exe.plan.migration
    assert "migrated" in back.describe()


def test_pre_v5_artifacts_still_load(exe_pair):
    exe, _ = exe_pair
    d = json.loads(exe.plan.to_json())
    assert "migration" in d
    del d["migration"]                 # a v4 artifact never wrote the key
    d["version"] = 4
    old = api.Plan.from_dict(d)
    assert old.migration is None
    assert api.compile(plan_artifact=old).plan.arch == "gpt-2b"


def test_migrate_to_validates_target(exe_pair):
    exe, _ = exe_pair
    with pytest.raises(TypeError, match="migrate_to"):
        exe.migrate_to(42)
    with pytest.raises(ValueError, match="state onto"):
        exe.migrate_to(dataclasses.replace(exe.plan, arch="llama-7b"))
    bad_cfg = dataclasses.replace(exe.plan.config, seq_len=512)
    with pytest.raises(ValueError, match="seq_len"):
        exe.migrate_to(dataclasses.replace(exe.plan, config=bad_cfg))


def test_cli_migrate_round_trip(exe_pair, tmp_path, capsys):
    from repro.api.cli import main as cli_main

    exe, _ = exe_pair
    plan_path = tmp_path / "plan.json"
    plan_path.write_text(exe.plan.to_json())
    cl_path = tmp_path / "cluster.json"
    cl_path.write_text(json.dumps(
        api.cluster_to_dict(remove_nodes(duo(n_a=2, n_b=1), "A", 1))))
    out = tmp_path / "migrated.json"
    rc = cli_main(["migrate", "--plan", str(plan_path),
                   "--cluster-file", str(cl_path), "-o", str(out)])
    assert rc == 0
    assert "downtime" in capsys.readouterr().out
    migrated = api.Plan.from_json(out.read_text())
    assert migrated.migration is not None
    assert migrated.migration["downtime_s"] >= 0.0
