"""Zero-Redundant Profiler: structural aliasing, pruning soundness."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cluster import paper_case_study_cluster, paper_eval_cluster
from repro.core.costmodel import CostModelConfig, Submesh, stage_cost
from repro.core.layering import build_layers
from repro.core.opgraph import build_op_sequence
from repro.core.profiler import ZeroRedundantProfiler


def _profile(arch="gpt-15b", granularity=96, rho=16.0):
    cluster = paper_case_study_cluster()
    ops = build_op_sequence(get_config(arch), seq_len=1024)
    layers = build_layers(ops, granularity)
    prof = ZeroRedundantProfiler(cluster, layers, 2048, rho=rho)
    return cluster, layers, prof.profile()


def test_aliasing_saves_most_evaluations():
    _, _, tables = _profile()
    st = tables.stats
    assert st.n_aliased > 0
    # repeated-module structure must alias the majority of candidates
    assert st.dedup_ratio > 0.5, f"dedup only {st.dedup_ratio:.0%}"


def test_aliased_entries_are_consistent():
    """Structurally identical stages on the same mesh get identical costs."""
    _, layers, tables = _profile(granularity=96)
    # find two identical single-layer stages from different instances
    from repro.core.layering import layer_class_sequence
    seen = {}
    for i in range(len(layers)):
        key = layer_class_sequence(layers, i, i + 1)
        if key in seen:
            j = seen[key]
            for mid in range(len(tables.meshes)):
                if tables.feasible[mid, i, i + 1] and \
                        tables.feasible[mid, j, j + 1]:
                    assert tables.t_f[mid, i, i + 1] == \
                        tables.t_f[mid, j, j + 1]
            return
        seen[key] = i
    pytest.skip("no repeated single-layer class found")


def test_memory_pruning_sound():
    """Pruned-for-memory candidates truly exceed the device memory."""
    cluster, layers, tables = _profile(granularity=96)
    for mid, mesh in enumerate(tables.meshes):
        sub = cluster.subclusters[mesh.cluster_idx]
        for i in range(0, len(layers), 5):
            for j in range(i + 1, len(layers) + 1, 7):
                if not tables.feasible[mid, i, j] and \
                        np.isfinite(tables.mem_p[mid, i, j]):
                    continue  # pruned without cost recorded: fine
                if tables.feasible[mid, i, j]:
                    cost = stage_cost(layers[i:j], sub, mesh, 2048)
                    assert cost.mem_p + cost.mem_a <= sub.device.mem_bytes


def test_cost_monotone_in_layers():
    """More layers on the same mesh never get cheaper (sparsity-index
    precondition: the DP's feasible-j window is contiguous)."""
    _, layers, tables = _profile(granularity=96)
    t = tables.t
    for mid in range(len(tables.meshes)):
        for i in range(len(layers)):
            row = t[mid, i, :]
            fin = row[np.isfinite(row)]
            assert np.all(np.diff(fin) >= -1e-12)


def test_cost_decreases_with_devices():
    cluster = paper_eval_cluster(2, 2, 8)
    ops = build_op_sequence(get_config("gpt-15b"), seq_len=1024)
    layers = build_layers(ops, 16)
    sub = cluster.subclusters[0]
    small = stage_cost(layers[2:8], sub, Submesh(0, 1, 2), 2048)
    big = stage_cost(layers[2:8], sub, Submesh(0, 1, 8), 2048)
    assert big.t_f < small.t_f


def test_imbalance_pruning_counts():
    _, _, loose = _profile(rho=1e9)
    _, _, tight = _profile(rho=2.0)
    assert tight.stats.n_pruned_imbalance > loose.stats.n_pruned_imbalance
