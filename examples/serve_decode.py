"""Batched serving: prefill a prompt batch, decode greedily with the KV
cache / SSM state — exercises the same prefill/decode paths the dry-run
lowers at 32k/500k scale.

  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-2.7b
  PYTHONPATH=src python examples/serve_decode.py --arch gemma3-12b
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.models.prefill import prefill


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    B, T = args.batch, args.prompt_len
    total = T + args.gen

    batch = {"tokens": jax.random.randint(rng, (B, T), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.02 * jax.random.normal(
            rng, (B, cfg.n_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = 0.02 * jax.random.normal(
            rng, (B, cfg.enc_frames, cfg.d_model))

    pf = jax.jit(lambda p, b: prefill(cfg, p, b, cache_len=total))
    t0 = time.perf_counter()
    last_logits, cache = pf(params, batch)
    jax.block_until_ready(last_logits)
    print(f"[serve] prefill {B}x{T} ({cfg.arch_id}): "
          f"{(time.perf_counter() - t0) * 1e3:.0f} ms")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(last_logits[:, -1:], axis=-1).astype(jnp.int32)
    toks = [np.asarray(tok)]
    t0 = time.perf_counter()
    for t in range(T, total):
        logits, cache = decode(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        toks.append(np.asarray(tok))
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"[serve] {args.gen} tokens x {B} seqs in {dt * 1e3:.0f} ms "
          f"({B * args.gen / dt:.0f} tok/s greedy)")
    print("[serve] sample:", np.concatenate(toks, 1)[0, :16].tolist())


if __name__ == "__main__":
    main()
