"""Batched serving: prefill a prompt batch, decode with the KV cache / SSM
state — exercises the same prefill/decode paths the dry-run lowers at
32k/500k scale, through the one facade entry point
:func:`repro.api.generate`.

  PYTHONPATH=src python examples/serve_decode.py --arch mamba2-2.7b
  PYTHONPATH=src python examples/serve_decode.py --arch gemma3-12b --sample
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import api


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=48)
    ap.add_argument("--sample", action="store_true",
                    help="temperature sampling instead of greedy argmax")
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    out = api.generate(
        args.arch, batch=args.batch, prompt_len=args.prompt_len,
        gen_tokens=args.gen, greedy=not args.sample,
        temperature=args.temperature, reduced=True, log_fn=print)
    print("[serve] sample:", out["tokens"][0, :16].tolist())


if __name__ == "__main__":
    main()
