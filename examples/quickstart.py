"""Quickstart: plan a heterogeneous training strategy with HAPT and inspect
the schedule — runs in ~10 s on a laptop CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core import (
    HAPTPlanner, PlannerConfig, ascii_timeline, paper_case_study_cluster,
    simulate,
)

# 1. describe the cluster: 2x2 A100 + 1x2 V100, 5 Gbps cross-link (the
#    paper's §2.2.2 case study; swap in tpu_multipod_cluster() for pods)
cluster = paper_case_study_cluster(cross_gbps=5.0)
print("cluster:", cluster.describe())

# 2. pick a model and plan
arch = get_config("gpt-2b")
planner = HAPTPlanner(cluster, PlannerConfig(granularity=64,
                                             n_microbatches=32))
strategy = planner.plan(arch, seq_len=1024, global_batch=64)
print("\n=== HAPT strategy ===")
print(strategy.describe())

# 3. inspect the H-1F1B schedule in the pipeline simulator
res = simulate([s.t_f for s in strategy.stages],
               [s.t_b for s in strategy.stages],
               strategy.c_links, strategy.n_microbatches,
               strategy.warmup_counts)
print(f"\nsimulated step: {res.makespan * 1e3:.1f} ms, "
      f"comm overlap {res.overlap_ratio * 100:.0f}%")
print("\ntimeline (f=forward, B=backward):")
print(ascii_timeline(res, width=96))

# 4. strategies serialize for the launcher
path = "/tmp/hapt_strategy.json"
with open(path, "w") as f:
    f.write(strategy.to_json())
print(f"\nstrategy written to {path}")
