"""Quickstart: compile a heterogeneous training strategy through the
`repro.api` facade and inspect every staged artifact — runs in ~10 s on a
laptop CPU.

  PYTHONPATH=src python examples/quickstart.py

Equivalent CLI:  python -m repro plan --arch gpt-2b --cluster paper_case_study
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import api
from repro.core import ascii_timeline, paper_case_study_cluster
from repro.core.planner import PlannerConfig

# 1. describe the cluster: 2x2 A100 + 1x2 V100, 5 Gbps cross-link (the
#    paper's §2.2.2 case study; swap in tpu_multipod_cluster() for pods)
cluster = paper_case_study_cluster(cross_gbps=5.0)
print("cluster:", cluster.describe())

# 2. one facade call: plan (HAPT search) -> lower (meshes + schedule) ->
#    Executable.  HarpConfig unifies the planner/trainer/data knobs.
cfg = api.HarpConfig(
    seq_len=1024, global_batch=64,
    planner=PlannerConfig(granularity=64, n_microbatches=32))
exe = api.compile("gpt-2b", cluster, cfg)
print("\n=== compiled strategy ===")
print(exe.describe())

# 3. referee-priced discrete-event simulation of one training step
res = exe.simulate()
print(f"\nsimulated step: {res.makespan * 1e3:.1f} ms, "
      f"comm overlap {res.overlap_ratio * 100:.0f}%")
print("\ntimeline (f=forward, B=backward):")
print(ascii_timeline(exe.simulate(priced=False), width=96))

# 4. every staged artifact JSON round-trips — plan here, execute elsewhere
path = "/tmp/hapt_plan.json"
with open(path, "w") as f:
    f.write(exe.plan.to_json())
reloaded = api.compile(plan_artifact=api.Plan.from_json(open(path).read()))
assert reloaded.plan.to_json() == exe.plan.to_json()   # bit-identical
print(f"\nplan written to {path} (reload + re-lower verified);")
print("continue with:  python -m repro simulate --plan", path)
