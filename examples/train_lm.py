"""End-to-end training driver: data pipeline -> model -> AdamW -> fault-
tolerant trainer with checkpoints.  Defaults train a ~10M-param gemma-family
model for 200 steps on CPU in a few minutes (loss visibly decreases); crank
--width/--layers/--steps on real hardware (e.g. --arch minitron-8b --full).

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --steps 300 --resume-demo
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import api
from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--full", action="store_true",
                    help="full config (real hardware); default reduced")
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/hapt_train_ckpt")
    ap.add_argument("--resume-demo", action="store_true",
                    help="continue from the last checkpoint (fault-tolerance "
                         "demo: run once, interrupt, run again)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = dataclasses.replace(
            cfg.reduced(), n_layers=args.layers, d_model=args.width,
            head_dim=max(32, args.width // 8),
            n_heads=8, n_kv_heads=1 if cfg.n_kv_heads == 1 else 4,
            d_ff=4 * args.width, vocab_size=8192)

    n = cfg.param_count()
    print(f"[train_lm] {cfg.arch_id}: {n / 1e6:.1f}M params, "
          f"batch {args.batch}x{args.seq}, {args.steps} steps")

    harp_cfg = api.HarpConfig(
        seq_len=args.seq, global_batch=args.batch,
        trainer=TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                              ckpt_every=max(50, args.steps // 4),
                              log_every=10),
        data=DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                        global_batch=args.batch, kind="markov"))
    out = api.fit(cfg, harp_cfg,
                  optimizer=OptimizerConfig(lr=args.lr, warmup_steps=20,
                                            total_steps=args.steps))
    h = out["history"]
    if h:
        print(f"[train_lm] loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}, "
              f"acc {h[-1]['accuracy'] * 100:.1f}% "
              f"(markov data is ~90% predictable)")


if __name__ == "__main__":
    main()
