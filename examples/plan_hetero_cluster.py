"""Heterogeneous-cluster planning tour: the paper's clusters AND the
TPU multi-pod / mixed-generation targets; shows how the plan shifts with
cross-link bandwidth and how replanning handles a degraded pod (straggler /
elastic-scaling story).

  PYTHONPATH=src python examples/plan_hetero_cluster.py
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import api
from repro.core import PlannerConfig
from repro.core.cluster import (
    heterogeneous_tpu_cluster, paper_case_study_cluster, paper_eval_cluster,
    set_node_efficiencies,
)


def plan(cluster, arch="gpt-15b", granularity=64, B=64, min_sub=2,
         intra_op=False):
    pcfg = PlannerConfig(granularity=granularity, n_microbatches=B,
                         min_submesh_devices=min_sub, intra_op=intra_op)
    pcfg.search.n_workers = 4
    cfg = api.HarpConfig(seq_len=1024, global_batch=B, planner=pcfg)
    return api.plan(arch, cluster, cfg).strategy


def show(tag, strat):
    print(f"\n=== {tag} ===")
    print(strat.describe())


# 1. the paper's A100+V100 evaluation cluster at two cross-link speeds
for gbps in (10.0, 3.0):
    cluster = paper_eval_cluster(1, 1, 8, cross_gbps=gbps)
    s = plan(cluster)
    show(f"A100+V100, cross={gbps:.0f} Gbps", s)
    print(f"  -> warm-up counts adapt to the link: {s.warmup_counts}")

# 2. mixed-generation TPU fleet (v5e pod + v4 pod over DCN) — the paper's
#    idea transplanted to TPU hardware profiles
tpu = heterogeneous_tpu_cluster(dcn_gbps=200.0)
s = plan(tpu, arch="gpt-39b", granularity=64, B=128, min_sub=16)
show("TPU v5e-256 + v4-128 over DCN", s)

# 3. straggler adaptation: pod 1 degrades to 70% efficiency -> replan
slow_dev = dataclasses.replace(tpu.subclusters[1].device,
                               peak_flops=tpu.subclusters[1].device.peak_flops
                               * 0.7, name="TPUv4-degraded")
degraded = dataclasses.replace(
    tpu, subclusters=(tpu.subclusters[0],
                      dataclasses.replace(tpu.subclusters[1],
                                          device=slow_dev)))
s2 = plan(degraded, arch="gpt-39b", granularity=64, B=128, min_sub=16)
show("same fleet, v4 pod degraded to 70% (replan)", s2)
moved = [(a.layer_end - a.layer_start, b.layer_end - b.layer_start)
         for a, b in zip(s.stages, s2.stages)]
print(f"  -> layers per stage before/after degradation: {moved}")

# 4. joint inter+intra-op search on a MIXED sub-cluster: one A100 node runs
#    at 60% (thermal throttling).  intra_op=True lets the DP pick uneven,
#    efficiency-proportional data shards instead of waiting on the slow node
mixed = set_node_efficiencies(paper_case_study_cluster(), "meshA100",
                              (1.0, 0.6))
sj = plan(mixed, arch="gpt-2b", granularity=16, B=16, min_sub=1,
          intra_op=True)
show("mixed A100 nodes (1.0/0.6), joint inter+intra search", sj)
for i, st in enumerate(sj.stages):
    if st.intra_op is not None and st.intra_op.is_uneven:
        print(f"  -> stage{i} shards the microbatch unevenly: "
              f"{[round(r, 3) for r in st.intra_op.shard_ratios]}")
