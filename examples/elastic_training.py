"""Elastic runtime tour, driven through the `repro.api` facade.

Part 1 compiles a plan once (`api.compile`), attaches the ElasticController
(`Executable.attach_elastic`), and replays a scripted disruption (node
failure -> cross-link congestion -> recovery), printing the throughput
timeline with every replan decision — warm-up-only retunes vs. incremental
re-searches (warm profiler tables) vs. full replans.

Part 2 replays the same executable under a seeded random fleet.

Part 3 wires the controller's telemetry hooks into the real Trainer loop via
`Executable.fit` (toy step function, synthetic clock): a simulated straggler
period triggers ``on_straggler`` -> EWMA recalibration -> an
amortization-gated replan.

  PYTHONPATH=src python examples/elastic_training.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import api                                                  # noqa: E402
from repro.core import PlannerConfig, paper_case_study_cluster         # noqa: E402
from repro.train.trainer import TrainerConfig                          # noqa: E402

N_STEPS = 120


def compile_executable():
    cluster = paper_case_study_cluster()      # 2x2 A100 + 1x2 V100, 5 Gbps
    cfg = api.HarpConfig(
        seq_len=512, global_batch=64,
        planner=PlannerConfig(granularity=16, n_microbatches=16,
                              min_submesh_devices=2),
        trainer=TrainerConfig(total_steps=N_STEPS, ckpt_every=1000,
                              log_every=1000,
                              ckpt_dir="/tmp/elastic_example_ckpt"))
    return cluster, api.compile("gpt-2b", cluster, cfg)


# --- part 1: scripted trace replay -----------------------------------------

cluster, exe = compile_executable()
print(f"cluster: {cluster.describe()}")

res = exe.replay("paper", N_STEPS, fail_step=30, bw_step=55,
                 recover_step=85, degraded_gbps=2.0)
print("replan decisions:")
for d in exe.controller.decisions:
    print(f"  {d.describe()}")

print("\nthroughput timeline (tokens/s, 10-step buckets):")
for s0 in range(0, N_STEPS, 10):
    tput = res.throughput_between(s0, s0 + 10)
    bar = "#" * int(tput / 2500)
    print(f"  steps {s0:3d}-{s0 + 10:3d}: {tput:9,.0f} {bar}")
print(f"\noverall: {res.throughput():,.0f} tok/s, "
      f"{res.stalled_steps} stalled steps")

# --- part 2: the same compiled plan under a seeded random fleet -------------

cluster, exe2 = compile_executable()
res2 = exe2.replay("random", N_STEPS, seed=7, p_failure=0.01, p_bw_shift=0.02)
print(f"\nelastic under random dynamics (seed=7): "
      f"{res2.throughput():,.0f} tok/s, "
      f"{len([d for d in exe2.controller.decisions if d.action != 'none'])} "
      f"responses")

# --- part 3: Trainer wiring (telemetry -> controller) ----------------------
# A toy jax train loop with a synthetic clock: steps 20-39 run 1.8x slow
# (thermal straggler), which trips the Trainer's EWMA watch; the controller
# hook recalibrates efficiency and decides whether replanning amortizes.

import jax.numpy as jnp                                                # noqa: E402

from repro.data.pipeline import DataConfig                             # noqa: E402

cluster, exe3 = compile_executable()
exe3.config.trainer.total_steps = 60     # part 3 runs a shorter horizon —
ctrl3 = exe3.attach_elastic()            # set BEFORE attaching so the
                                         # amortization window matches

def train_step(w, batch):
    loss = jnp.mean((w - 0.1) ** 2)
    return w - 0.01 * (w - 0.1), {"loss": loss}

NOMINAL = exe3.strategy.est_step_time     # the fleet runs exactly as planned
_t = [0.0]
_step = [0]

def synthetic_clock():
    # the trainer reads the clock once before and once after each step, so
    # advancing one nominal step time per call yields dt == one step time
    slow = 1.8 if 20 <= _step[0] < 40 else 1.0
    _t[0] += NOMINAL * slow
    return _t[0]

def on_step_time(step, dt):
    _step[0] = step
    return ctrl3.on_step_time(step, dt)

exe3.fit(train_step=train_step, state={"w": jnp.zeros(4)},
         data_cfg=DataConfig(vocab_size=64, seq_len=8, global_batch=4),
         log_fn=lambda m: None, clock=synthetic_clock,
         on_step_time=on_step_time, start_step=0)

print("\ntrainer-driven telemetry decisions:")
for d in ctrl3.decisions[1:]:
    print(f"  {d.describe()}")
if len(ctrl3.decisions) == 1:
    print("  (drift stayed inside the deadband — no replan needed)")
