"""Elastic runtime tour: the planner closed into an event-driven loop.

Part 1 replays a scripted disruption (node failure -> cross-link congestion
-> recovery) through the ElasticController and prints the throughput
timeline with every replan decision — warm-up-only retunes vs. incremental
re-searches (warm profiler tables) vs. full replans.

Part 2 wires the controller's telemetry hooks into the real Trainer loop
(toy model, synthetic clock): a simulated straggler period triggers
``on_straggler`` -> EWMA recalibration -> an amortization-gated replan.

  PYTHONPATH=src python examples/elastic_training.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import paper_case_study_cluster                        # noqa: E402
from repro.core.planner import PlannerConfig                           # noqa: E402
from repro.runtime import (                                            # noqa: E402
    ControllerConfig, ElasticController, paper_trace, random_trace,
    run_replay,
)

N_STEPS = 120


def make_controller():
    cluster = paper_case_study_cluster()      # 2x2 A100 + 1x2 V100, 5 Gbps
    pcfg = PlannerConfig(granularity=16, n_microbatches=16,
                         min_submesh_devices=2)
    ccfg = ControllerConfig(total_steps=N_STEPS, seq_len=512, global_batch=64)
    return cluster, ElasticController(cluster, "gpt-2b",
                                      planner_cfg=pcfg, cfg=ccfg)


# --- part 1: scripted trace replay -----------------------------------------

cluster, ctrl = make_controller()
ctrl.bootstrap()
trace = paper_trace(cluster, fail_step=30, bw_step=55, recover_step=85,
                    degraded_gbps=2.0)
print(f"cluster: {cluster.describe()}")
print(f"trace:   {trace.describe()}\n")

res = run_replay(trace, N_STEPS, controller=ctrl)
print("replan decisions:")
for d in ctrl.decisions:
    print(f"  {d.describe()}")

print("\nthroughput timeline (tokens/s, 10-step buckets):")
for s0 in range(0, N_STEPS, 10):
    tput = res.throughput_between(s0, s0 + 10)
    bar = "#" * int(tput / 2500)
    print(f"  steps {s0:3d}-{s0 + 10:3d}: {tput:9,.0f} {bar}")
print(f"\noverall: {res.throughput():,.0f} tok/s, "
      f"{res.stalled_steps} stalled steps")

# --- part 2: the same controller under a seeded random fleet ---------------

cluster, ctrl2 = make_controller()
ctrl2.bootstrap()
rnd = random_trace(cluster, N_STEPS, seed=7, p_failure=0.01, p_bw_shift=0.02)
print(f"\nseeded trace (seed=7): {rnd.describe() or '(quiet fleet)'}")
res2 = run_replay(rnd, N_STEPS, controller=ctrl2)
print(f"elastic under random dynamics: {res2.throughput():,.0f} tok/s, "
      f"{len([d for d in ctrl2.decisions if d.action != 'none'])} responses")

# --- part 3: Trainer wiring (telemetry -> controller) ----------------------
# A toy jax train loop with a synthetic clock: steps 20-39 run 1.8x slow
# (thermal straggler), which trips the Trainer's EWMA watch; the controller
# hook recalibrates efficiency and decides whether replanning amortizes.

import jax                                                             # noqa: E402
import jax.numpy as jnp                                                # noqa: E402

from repro.data.pipeline import DataConfig                             # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig                 # noqa: E402

cluster, ctrl3 = make_controller()
ctrl3.bootstrap()

def train_step(w, batch):
    loss = jnp.mean((w - 0.1) ** 2)
    return w - 0.01 * (w - 0.1), {"loss": loss}

NOMINAL = ctrl3.strategy.est_step_time    # the fleet runs exactly as planned
_t = [0.0]
_step = [0]

def synthetic_clock():
    # the trainer reads the clock once before and once after each step, so
    # advancing one nominal step time per call yields dt == one step time
    slow = 1.8 if 20 <= _step[0] < 40 else 1.0
    _t[0] += NOMINAL * slow
    return _t[0]

class StepCounter:
    def __call__(self, step, dt):
        _step[0] = step
        return ctrl3.on_step_time(step, dt)

trainer = Trainer(
    TrainerConfig(total_steps=60, ckpt_every=1000, log_every=30,
                  ckpt_dir="/tmp/elastic_example_ckpt"),
    DataConfig(vocab_size=64, seq_len=8, global_batch=4),
    train_step, {"w": jnp.zeros(4)},
    log_fn=lambda m: None,
    clock=synthetic_clock,
    on_step_time=StepCounter(),
    **{"on_straggler": ctrl3.on_straggler})

trainer.run(start_step=0)
print("\ntrainer-driven telemetry decisions:")
for d in ctrl3.decisions[1:]:
    print(f"  {d.describe()}")
if len(ctrl3.decisions) == 1:
    print("  (drift stayed inside the deadband — no replan needed)")
