"""Per-collective algorithm auto-selection + the planner-facing comm model.

:class:`CommModel` binds a fleet's :class:`~repro.comm.topology.Topology` to
a :class:`CommConfig` and answers the three questions the planner asks:

- ``tp_allreduce`` / ``dp_sync`` / ``cross_sync`` — select the cheapest
  registered algorithm for a collective (HAP-style: the *search* sees the
  selected algorithm's cost, so plans are chosen under the algorithm that
  will actually run, not an implicit flat ring);
- ``p2p_seconds`` — point-to-point activation pricing with the WAN link's
  per-transfer latency (the legacy scalar drops it);
- ``fingerprint`` — stable identity for every cache keyed on comm pricing
  (profiler cost cache, controller plan cache).

With ``compressed=True``, collectives whose group crosses the WAN also get
int8 block-quantized candidates: the wire payload shrinks to the exact
:mod:`repro.parallel.compression` accounting (int8 + one f32 scale per
256-element block, padded — asserted bit-exact against the real quantizer
in tests) while quantize/dequantize cost is charged at
``quant_bytes_per_s`` on each side.  Error feedback makes the quantization
bias-free, so the selector may choose compression on cost alone.

This module never imports the api package or jax at import time, so the
numpy-only planner stack stays light.

Units: bytes, bytes/s, seconds.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

from repro.comm import topology as topo_lib
from repro.comm.algorithms import get_algorithm
from repro.comm.topology import CommGroup, build_topology

if TYPE_CHECKING:       # typing only: repro.comm must not import repro.core
    from repro.core.cluster import HeteroCluster    # (cycle via planner)

# mirrors repro.parallel.compression.BLOCK (that module imports jax, which
# the planner stack must not pay for; tests pin the two constants equal)
QUANT_BLOCK = 256
_SCALE_BYTES = 4      # one f32 scale per block


@dataclass
class CommConfig:
    """Planner-facing comm knobs (JSON-native: rides on ``PlannerConfig``).

    ``enabled=False`` (or a ``None`` config on the planner) keeps the legacy
    scalar pricing bit-identical.  ``algorithms`` is the candidate set, in
    tie-breaking order, resolved by name from the collective registry.
    ``contention`` asks executors/benchmarks to simulate with the netsim
    fair-share engine (the planner's closed forms are contention-free either
    way).  ``elem_bytes`` is the bytes-per-element of gradients on the wire
    before compression (f32 = 4)."""
    enabled: bool = True
    algorithms: Tuple[str, ...] = ("ring", "rhd", "hierarchical")
    compressed: bool = False
    contention: bool = False
    p2p_latency: bool = True
    quant_bytes_per_s: float = 100e9
    elem_bytes: float = 4.0

    def __post_init__(self):
        self.algorithms = tuple(self.algorithms)
        if not self.algorithms:
            raise ValueError("CommConfig.algorithms must not be empty")


@dataclass(frozen=True)
class Selection:
    """One selected collective: the winning algorithm and its pricing."""
    algorithm: str
    seconds: float                 # wall time of one collective
    payload_bytes: float           # logical payload (pre-compression)
    wire_bytes: float              # what actually crosses the links
    compressed: bool = False
    link_busy: Dict[str, float] = field(default_factory=dict)


def compressed_wire_bytes(nbytes: float, elem_bytes: float = 4.0) -> float:
    """Exact int8 block-quantization wire accounting for a payload of
    ``nbytes`` (``nbytes / elem_bytes`` elements): int8 per element, padded
    to whole :data:`QUANT_BLOCK` blocks, plus one f32 scale per block —
    matches ``repro.parallel.compression.quantize_int8`` byte for byte."""
    elems = nbytes / elem_bytes
    nblocks = math.ceil(elems / QUANT_BLOCK)
    return float(nblocks * (QUANT_BLOCK + _SCALE_BYTES))


class CommModel:
    """Topology + config -> priced, algorithm-selected collectives."""

    def __init__(self, cluster: HeteroCluster,
                 cfg: Optional[CommConfig] = None):
        self.cluster = cluster
        self.cfg = cfg if cfg is not None else CommConfig()
        self.topology = build_topology(cluster)
        # resolve once: unknown names fail at model build, not mid-search
        self._algos = [(name, get_algorithm(name))
                       for name in self.cfg.algorithms]

    # -- identity ------------------------------------------------------------

    def fingerprint(self) -> str:
        c = self.cfg
        return (f"{topo_lib.fingerprint(self.topology)}"
                f"|algos:{','.join(c.algorithms)}"
                f"|comp:{int(c.compressed)}:{c.quant_bytes_per_s:.6g}"
                f":{c.elem_bytes:.6g}|lat:{int(c.p2p_latency)}")

    def sub_fingerprint(self, sub_idx: int) -> str:
        """Identity of what *stage-local* collective pricing reads for one
        sub-cluster: its own links, node scales, and the selection config —
        deliberately NOT the rest of the fleet, so the profiler's cost
        cache keeps serving untouched sub-clusters across fleet changes
        (the elastic runtime's incremental-replan invariant).  TP
        all-reduces and DP syncs never leave the sub-cluster; cut pricing
        (which does read the WAN) lives in the DP, not in this cache."""
        t = self.topology
        intra, inter = t.intra_link(sub_idx), t.inter_link(sub_idx)
        scales = ",".join(f"{x:.6g}" for x in t.node_scales[sub_idx])
        c = self.cfg
        return (f"{intra.tier}:{intra.bandwidth:.6g}:{inter.bandwidth:.6g}"
                f":[{scales}]|algos:{','.join(c.algorithms)}"
                f"|comp:{int(c.compressed)}:{c.quant_bytes_per_s:.6g}"
                f":{c.elem_bytes:.6g}")

    # -- selection -----------------------------------------------------------

    def select(self, group: CommGroup, nbytes: float) -> Selection:
        """Cheapest candidate for one allreduce of ``nbytes`` over
        ``group``.  Candidates are the supported registered algorithms, in
        config order (first strict minimum wins ties — so on uniform links,
        where every bandwidth-optimal algorithm degenerates to the same
        closed form, the flat ring is selected), plus int8-compressed
        variants of each when enabled and the group crosses the WAN."""
        best: Optional[Selection] = None
        for name, algo in self._algos:
            if not algo.supports(group):
                continue
            cost = algo.cost(group, nbytes)
            cand = Selection(name, cost.seconds, nbytes, nbytes,
                             link_busy=cost.link_busy)
            if best is None or cand.seconds < best.seconds:
                best = cand
            if self.cfg.compressed and group.crosses_wan:
                wire = compressed_wire_bytes(nbytes, self.cfg.elem_bytes)
                ccost = algo.cost(group, wire)
                overhead = 2.0 * nbytes / self.cfg.quant_bytes_per_s
                cand = Selection(name, ccost.seconds + overhead, nbytes,
                                 wire, compressed=True,
                                 link_busy=ccost.link_busy)
                if cand.seconds < best.seconds:
                    best = cand
        if best is None:
            raise RuntimeError(
                f"no registered algorithm supports group {group} "
                f"(candidates: {[n for n, _ in self._algos]})")
        return best

    # -- the planner's three collectives ------------------------------------

    def tp_allreduce(self, sub_idx: int, tp: int, nbytes: float) -> Selection:
        """Megatron row-parallel output allreduce, confined to a node."""
        return self.select(self.topology.tp_group(sub_idx, tp), nbytes)

    def dp_sync(self, sub_idx: int, n_nodes: int, per_node: int,
                nbytes: float) -> Selection:
        """Per-step gradient allreduce over a stage's data-parallel shards
        (two-tier when the stage spans nodes — where the hierarchical
        algorithm pays off)."""
        return self.select(
            self.topology.dp_group(sub_idx, n_nodes, per_node), nbytes)

    def cross_sync(self, sub_idx: int, n_nodes: int, per_node: int,
                   n_clusters: int, nbytes: float) -> Selection:
        """Cross-cluster gradient sync (replicated / shared parameters):
        the group's outermost tier is the shared WAN link, so this is where
        hierarchical reduction and int8 compression earn their keep."""
        return self.select(
            self.topology.cross_group(sub_idx, n_nodes, per_node,
                                      n_clusters), nbytes)

    # -- point-to-point ------------------------------------------------------

    def p2p_latency(self, src_idx: int, dst_idx: int) -> float:
        """Additive per-transfer latency for a stage-boundary send (0 unless
        the boundary crosses the WAN and latency pricing is on)."""
        if not self.cfg.p2p_latency or src_idx == dst_idx:
            return 0.0
        return self.topology.cross_link().latency

    def p2p_seconds(self, nbytes: float, src_idx: int, dst_idx: int) -> float:
        link = self.topology.p2p_link(src_idx, dst_idx)
        return nbytes / link.bandwidth + self.p2p_latency(src_idx, dst_idx)


# ---------------------------------------------------------------------------
# Plan-side accounting (no CommModel needed: reads what the planner recorded)
# ---------------------------------------------------------------------------


def boundary_link_ids(strategy, cluster: HeteroCluster) -> List[str]:
    """Physical link id per stage boundary: the source sub-cluster's
    inter-node fabric within a cluster, the shared ``"wan"`` across — equal
    ids mean the transfers contend in the netsim."""
    out = []
    for i in range(len(strategy.stages) - 1):
        a = strategy.stages[i].cluster_idx
        b = strategy.stages[i + 1].cluster_idx
        out.append(topo_lib.CROSS_LINK if a != b
                   else f"ib:{cluster.subclusters[a].name}")
    return out


def stage_sync_seconds(stage, cluster: HeteroCluster, layers: Sequence,
                       n_microbatches: int) -> float:
    """Per-step data-parallel gradient sync of one stage, with the referee's
    accounting (``runtime.replay.sync_priced_step``): the planner's own
    priced value when the joint search recorded one
    (``IntraOpPlan.sync_time`` is amortized per microbatch, so it scales
    back up by B), else the flat-ring closed form over the stage's dp
    link."""
    io = stage.intra_op
    if io is not None and io.sync_time > 0:
        return io.sync_time * n_microbatches
    if stage.dp <= 1:
        return 0.0
    sub = cluster.subclusters[stage.cluster_idx]
    params = sum(layers[li].param_bytes
                 for li in range(stage.layer_start, stage.layer_end))
    bw = sub.inter_node_bw if stage.mesh_n > 1 else sub.intra_node_bw
    return params * 2 * (stage.dp - 1) / stage.dp / bw


def collective_breakdown(strategy, cluster: HeteroCluster,
                         layers: Sequence) -> Dict:
    """Everything ``Executable.describe()``/``--explain-comm`` and the
    ``LoweredPlan`` collective plan need, computed from a priced strategy:

    - ``stages``: per-stage dicts (algorithms, payload bytes, priced times,
      the links each collective occupies);
    - ``link_ids``: physical link per stage boundary;
    - ``link_occupancy_s``: per physical link, total busy seconds over one
      step (activation sends both directions + TP allreduces + gradient
      syncs) — >1 user on a link is a contended link.

    Intra-op collective occupancy is charged to the collective's bottleneck
    link (the full phase-by-phase split lives in the algorithm costs; the
    bottleneck is what contends)."""
    B = strategy.n_microbatches
    link_ids = boundary_link_ids(strategy, cluster)
    occupancy: Dict[str, float] = {}
    users: Dict[str, int] = {}

    def charge(link: str, seconds: float):
        """One traffic-bearing user of a link; zero-cost collectives carry
        no traffic and neither occupy nor contend."""
        if seconds <= 0:
            return
        occupancy[link] = occupancy.get(link, 0.0) + seconds
        users[link] = users.get(link, 0) + 1

    for i, c in enumerate(strategy.c_links):
        # one boundary = one user, occupying both directions
        charge(link_ids[i], 2 * B * c)

    stages = []
    for si, s in enumerate(strategy.stages):
        sub = cluster.subclusters[s.cluster_idx]
        io = s.intra_op
        intra_id = f"intra:{sub.name}"
        sync_id = f"ib:{sub.name}" if s.mesh_n > 1 else intra_id
        ar_mb = 0.0 if io is None else io.comm_time_f + \
            max(0.0, io.comm_time_b - io.sync_time)
        sync_step = stage_sync_seconds(s, cluster, layers, B)
        charge(intra_id, ar_mb * B)
        charge(sync_id, sync_step)
        stages.append({
            "stage": si,
            "subcluster": sub.name,
            "tp": s.tp, "dp": s.dp,
            "ar_algorithm": None if io is None else io.ar_algo,
            "sync_algorithm": None if io is None else io.sync_algo,
            "sync_compressed": bool(io is not None and io.sync_compressed),
            "comm_bytes": 0.0 if io is None else io.comm_bytes,
            "ar_time_s": ar_mb,             # per microbatch
            "sync_time_s": sync_step,       # per step
            "ar_link": intra_id,
            "sync_link": sync_id,
        })
    contended = sorted(l for l, n in users.items() if n > 1)
    return {"stages": stages, "link_ids": link_ids,
            "link_occupancy_s": occupancy, "contended_links": contended}
