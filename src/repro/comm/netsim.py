"""Event-driven link-occupancy simulator with fair-share contention.

The legacy pipeline simulator prices every transfer as an isolated scalar
(``bytes / link_gbps``): two transfers on the same physical link at the same
time each proceed at full rate, which is wrong exactly when it matters —
concurrent activation sends on the shared cross-cluster WAN, or a gradient
sync overlapping the next microbatch's activation traffic.

This module solves the *contended* timing exactly under processor-sharing:

- a **compute node** has a fixed duration and consumes no link;
- a **transfer node** carries ``work`` seconds of service demand *at full
  link rate* and occupies one or more named links (an allreduce occupies
  both directions; a p2p send one).  While ``k`` transfers are active on a
  link, each gets a ``1/k`` share; a multi-link transfer proceeds at its
  most-congested link's share (a deterministic max-min-fairness
  approximation);
- edges are dependencies (``start >= max(dep ends)``) — per-stage issue
  order, per-channel FIFO, and data deps all become edges.

Between events (a compute/transfer completion) the active set is constant,
so rates are piecewise-constant and the simulation is exact: no sampling,
no time stepping.  A transfer that never shares a link finishes in exactly
``work`` seconds — with all-distinct links this degenerates to the legacy
uncontended timing (asserted in tests).

Working in *seconds of service demand* rather than bytes keeps the
simulator composable with the planner's time-valued tables: callers price
``bytes / bw`` once and the netsim only redistributes capacity.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Dict, Hashable, Iterable, List, Sequence, Tuple

_EPS = 1e-12


@dataclass(frozen=True)
class SimNode:
    """One unit of work.  ``links`` empty -> compute (fixed ``work``
    seconds); non-empty -> transfer (``work`` seconds at full rate, shared
    capacity on every named link)."""
    nid: Hashable
    work: float
    deps: Tuple[Hashable, ...] = ()
    links: Tuple[str, ...] = ()

    @property
    def is_transfer(self) -> bool:
        return bool(self.links)


@dataclass
class NetSimResult:
    start: Dict[Hashable, float]
    end: Dict[Hashable, float]
    link_busy: Dict[str, float]    # seconds each link had >= 1 active transfer

    def duration(self, nid: Hashable) -> float:
        return self.end[nid] - self.start[nid]

    @property
    def makespan(self) -> float:
        return max(self.end.values()) if self.end else 0.0


def run(nodes: Sequence[SimNode]) -> NetSimResult:
    """Solve start/end times for a dependency DAG of compute + transfer
    nodes under fair-share link contention (module docstring).  Raises on
    unknown deps or dependency cycles."""
    by_id: Dict[Hashable, SimNode] = {}
    for n in nodes:
        if n.nid in by_id:
            raise ValueError(f"duplicate node id {n.nid!r}")
        if n.work < 0 or not math.isfinite(n.work):
            raise ValueError(f"node {n.nid!r}: bad work {n.work!r}")
        by_id[n.nid] = n
    indeg: Dict[Hashable, int] = {n.nid: 0 for n in nodes}
    succ: Dict[Hashable, List[Hashable]] = {n.nid: [] for n in nodes}
    for n in nodes:
        for d in n.deps:
            if d not in by_id:
                raise ValueError(f"node {n.nid!r} depends on unknown {d!r}")
            succ[d].append(n.nid)
            indeg[n.nid] += 1

    start: Dict[Hashable, float] = {}
    end: Dict[Hashable, float] = {}
    link_busy: Dict[str, float] = {}
    remaining: Dict[Hashable, float] = {}          # active transfers
    active_on: Dict[str, set] = {}                 # link -> active transfer ids
    compute_done: List[Tuple[float, int, Hashable]] = []   # heap
    seq = 0

    def activate(nid: Hashable, t: float):
        nonlocal seq
        node = by_id[nid]
        start[nid] = t
        if node.is_transfer:
            remaining[nid] = node.work
            for l in node.links:
                active_on.setdefault(l, set()).add(nid)
        else:
            seq += 1
            heapq.heappush(compute_done, (t + node.work, seq, nid))

    def rate(nid: Hashable) -> float:
        return min(1.0 / len(active_on[l]) for l in by_id[nid].links)

    t = 0.0
    for nid, d in indeg.items():
        if d == 0:
            activate(nid, 0.0)

    n_done = 0
    while n_done < len(by_id):
        # next event: earliest compute completion or transfer drain
        t_next = compute_done[0][0] if compute_done else math.inf
        for nid, rem in remaining.items():
            t_next = min(t_next, t + rem / rate(nid))
        if not math.isfinite(t_next):
            raise ValueError("dependency cycle in netsim DAG")
        # advance active transfers at their current (constant) rates
        dt = t_next - t
        if dt > 0:
            for l, act in active_on.items():
                if act:
                    link_busy[l] = link_busy.get(l, 0.0) + dt
            for nid in remaining:
                remaining[nid] -= dt * rate(nid)
        t = t_next

        finished: List[Hashable] = []
        while compute_done and compute_done[0][0] <= t + _EPS:
            finished.append(heapq.heappop(compute_done)[2])
        for nid, rem in list(remaining.items()):
            if rem <= _EPS * max(1.0, by_id[nid].work):
                finished.append(nid)
                del remaining[nid]
                for l in by_id[nid].links:
                    active_on[l].discard(nid)
        if not finished:
            raise ValueError("netsim stalled (no event progressed)")
        ready: List[Hashable] = []
        for nid in finished:
            end[nid] = t
            n_done += 1
            for s in succ[nid]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        for nid in ready:
            activate(nid, t)
    return NetSimResult(start, end, link_busy)


def price_transfers(transfers: Iterable[Tuple[Hashable, Sequence[str],
                                              float, float]]
                    ) -> NetSimResult:
    """Standalone front door: price a set of released transfers against each
    other.  Each entry is ``(id, links, work_seconds, release_time)``;
    releases are modeled as zero-link delay nodes so the event loop handles
    them uniformly.  Returns per-transfer (start, end) + link busy time."""
    nodes: List[SimNode] = []
    for tid, links, work, release in transfers:
        deps: Tuple[Hashable, ...] = ()
        if release > 0:
            rel_id = ("__release__", tid)
            nodes.append(SimNode(rel_id, float(release)))
            deps = (rel_id,)
        nodes.append(SimNode(tid, float(work), deps, tuple(links)))
    res = run(nodes)
    res.start = {k: v for k, v in res.start.items()
                 if not (isinstance(k, tuple) and k and k[0] == "__release__")}
    res.end = {k: v for k, v in res.end.items()
               if not (isinstance(k, tuple) and k and k[0] == "__release__")}
    return res
