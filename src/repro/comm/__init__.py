"""``repro.comm`` — heterogeneity-aware collective communication.

Four pieces (see docs/comm.md for the walkthrough):

- :mod:`repro.comm.topology` — the fleet's typed link graph (nvlink / pcie /
  ib / wan tiers) with a cache-keying fingerprint;
- :mod:`repro.comm.algorithms` — the collective algorithm zoo (flat ring,
  recursive halving-doubling, two-level hierarchical) with closed-form costs
  over a topology, extensible by name;
- :mod:`repro.comm.netsim` — the event-driven fair-share link-occupancy
  simulator (concurrent transfers on a shared link slow each other down);
- :mod:`repro.comm.selector` — per-collective algorithm auto-selection
  (:class:`CommModel`) the planner prices stages with, plus the plan-side
  collective breakdown the api facade reports.

Everything here is numpy-or-lighter at import time; jax is only touched
lazily when int8 compression is exercised end-to-end.
"""
from repro.comm.algorithms import (
    ALGORITHMS, CollectiveAlgorithm, CollectiveCost, available_collectives,
    get_algorithm, register_collective,
)
from repro.comm.netsim import NetSimResult, SimNode, price_transfers, run
from repro.comm.selector import (
    CommConfig, CommModel, Selection, boundary_link_ids,
    collective_breakdown, compressed_wire_bytes, stage_sync_seconds,
)
from repro.comm.topology import (
    CommGroup, Link, Topology, build_topology, fingerprint,
)

__all__ = [
    "ALGORITHMS", "CollectiveAlgorithm", "CollectiveCost",
    "available_collectives", "get_algorithm", "register_collective",
    "NetSimResult", "SimNode", "price_transfers", "run",
    "CommConfig", "CommModel", "Selection", "boundary_link_ids",
    "collective_breakdown", "compressed_wire_bytes", "stage_sync_seconds",
    "CommGroup", "Link", "Topology", "build_topology", "fingerprint",
]
