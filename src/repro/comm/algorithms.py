"""Collective algorithm zoo: closed-form allreduce costs over a topology.

Each algorithm prices one allreduce of ``nbytes`` over a
:class:`~repro.comm.topology.CommGroup` and reports both the wall-clock
seconds and the per-link occupancy (the seconds each named link is busy —
what :mod:`repro.comm.netsim` turns into contention and
``LoweredPlan.link_occupancy_s`` records).

The zoo (all bandwidth terms use the classic cost model, latency terms count
link startups on the critical path):

- ``ring`` — flat ring over all ranks.  Bandwidth-optimal
  (``2(N-1)/N * B / bw``) but paced by the *slowest* link in the group with
  the *full* payload, and it pays ``2(N-1)`` latencies.  On a single uniform
  tier this is exactly the legacy scalar pricing
  (``bytes * 2(N-1)/N / bw``, no latency on intra-cluster links).
- ``rhd`` — recursive halving-doubling.  Same bandwidth term, only
  ``2*log2(N)`` latencies; needs a power-of-two rank count.  Wins on small,
  latency-dominated payloads (e.g. scalar syncs across the WAN).
- ``hierarchical`` — the two-level (generally multi-level) reduce:
  reduce-scatter each inner tier on its fast link, allreduce the outermost
  tier on the slow link with the payload already divided by the inner
  domain sizes, then allgather back out.  The slow link carries ``1/prod
  (inner sizes)`` of the payload — this is HETHUB's cross-cluster
  hierarchy, and it wins exactly when the outer link is much slower
  (paper Fig. 10's low cross-bandwidth regime).

Third-party algorithms register by name here (or through
``repro.api.registry``'s ``"collective"`` kind, which delegates to this
table) and become selectable via ``CommConfig.algorithms``.

Units: bytes, bytes/s, seconds.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from repro.comm.topology import CommGroup


@dataclass(frozen=True)
class CollectiveCost:
    """One priced collective: wall seconds + per-link busy seconds."""
    seconds: float
    link_busy: Dict[str, float] = field(default_factory=dict)


class CollectiveAlgorithm:
    """Interface: ``supports`` guards structural requirements (tier count,
    power-of-two ranks); ``cost`` is the closed form.  Subclass + register
    to extend the zoo."""

    name: str = "?"

    def supports(self, group: CommGroup) -> bool:
        raise NotImplementedError

    def cost(self, group: CommGroup, nbytes: float) -> CollectiveCost:
        raise NotImplementedError


def _busy_all(group: CommGroup, seconds: float) -> Dict[str, float]:
    """Flat algorithms keep every participating link occupied for the whole
    collective (the ring/butterfly is pipelined across all of them)."""
    return {l.name: seconds for _, l in group.tiers}


class RingAllReduce(CollectiveAlgorithm):
    name = "ring"

    def supports(self, group: CommGroup) -> bool:
        return True

    def cost(self, group: CommGroup, nbytes: float) -> CollectiveCost:
        g = group.effective()
        n = g.n_ranks
        if n <= 1:
            return CollectiveCost(0.0)
        bw = g.bottleneck.bandwidth
        secs = nbytes * 2.0 * (n - 1) / n / bw + 2.0 * (n - 1) * g.max_latency
        return CollectiveCost(secs, _busy_all(g, secs))


class RecursiveHalvingDoubling(CollectiveAlgorithm):
    name = "rhd"

    def supports(self, group: CommGroup) -> bool:
        n = group.effective().n_ranks
        return n >= 1 and (n & (n - 1)) == 0

    def cost(self, group: CommGroup, nbytes: float) -> CollectiveCost:
        g = group.effective()
        n = g.n_ranks
        if n <= 1:
            return CollectiveCost(0.0)
        bw = g.bottleneck.bandwidth
        log2n = n.bit_length() - 1
        secs = nbytes * 2.0 * (n - 1) / n / bw + 2.0 * log2n * g.max_latency
        return CollectiveCost(secs, _busy_all(g, secs))


class TwoLevelHierarchical(CollectiveAlgorithm):
    """Reduce-scatter inward, allreduce the outermost tier, allgather
    outward — each phase priced as a ring on its own tier's link."""

    name = "hierarchical"

    def supports(self, group: CommGroup) -> bool:
        return len(group.effective().tiers) >= 2

    def cost(self, group: CommGroup, nbytes: float) -> CollectiveCost:
        g = group.effective()
        tiers = g.tiers
        busy: Dict[str, float] = {}
        secs = 0.0
        remaining = float(nbytes)
        # inner tiers: reduce-scatter + (later) allgather, payload shrinking
        for size, link in tiers[:-1]:
            phase = (remaining * (size - 1) / size / link.bandwidth
                     + (size - 1) * link.latency)
            secs += 2.0 * phase                 # rs in, ag out
            busy[link.name] = busy.get(link.name, 0.0) + 2.0 * phase
            remaining /= size
        size, link = tiers[-1]
        ar = (remaining * 2.0 * (size - 1) / size / link.bandwidth
              + 2.0 * (size - 1) * link.latency)
        secs += ar
        busy[link.name] = busy.get(link.name, 0.0) + ar
        return CollectiveCost(secs, busy)


# ---------------------------------------------------------------------------
# Registry (repro.api.registry's "collective" kind delegates here, so core
# code never has to import the api package)
# ---------------------------------------------------------------------------

ALGORITHMS: Dict[str, CollectiveAlgorithm] = {}


def register_collective(name: str, algo: CollectiveAlgorithm, *,
                        overwrite: bool = False) -> CollectiveAlgorithm:
    if name in ALGORITHMS and not overwrite:
        raise ValueError(
            f"collective {name!r} already registered (pass overwrite=True)")
    ALGORITHMS[name] = algo
    return algo


def get_algorithm(name: str) -> CollectiveAlgorithm:
    try:
        return ALGORITHMS[name]
    except KeyError:
        raise KeyError(f"unknown collective {name!r}; available: "
                       f"{available_collectives()}") from None


def available_collectives() -> List[str]:
    return sorted(ALGORITHMS)


register_collective("ring", RingAllReduce())
register_collective("rhd", RecursiveHalvingDoubling())
register_collective("hierarchical", TwoLevelHierarchical())
