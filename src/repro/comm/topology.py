"""Typed link graph of a heterogeneous fleet (the comm subsystem's ground
truth).

A :class:`HeteroCluster` flattens the network into three scalar bandwidths
(intra-node, inter-node, cross-cluster); the :class:`Topology` re-expresses
them as *named, tiered links* so collectives can be priced on the link they
actually traverse and concurrent transfers can be attributed to shared
physical capacity:

- ``nvlink`` / ``pcie``  — intra-node fabric, one link per sub-cluster
  (classified by bandwidth: >= :data:`NVLINK_MIN_BW` is NVLink/ICI-class);
- ``ib``                 — inter-node fabric inside one sub-cluster
  (RDMA / pod interconnect);
- ``wan``                — the single cross-cluster link every
  cluster-crossing transfer shares (this sharing is what
  :mod:`repro.comm.netsim` models as contention).

Latency: intra-cluster links are latency-free in the cost model (matching
the legacy scalar pricing exactly); the WAN link carries
``HeteroCluster.cross_latency`` per transfer.

``node_scales`` (from ``SubCluster.node_efficiencies``) ride on the topology
so its :func:`fingerprint` keys every cache that depends on what the comm
model read — two clusters with equal topology fingerprints price every
collective identically.

Units: bandwidths bytes/s per direction, latency seconds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, List, Tuple

if TYPE_CHECKING:       # typing only: repro.comm must not import repro.core
    from repro.core.cluster import HeteroCluster    # (cycle via planner)

# intra-node fabrics at or above this are NVLink/ICI class; below is PCIe
NVLINK_MIN_BW = 100e9

TIER_NVLINK = "nvlink"
TIER_PCIE = "pcie"
TIER_IB = "ib"
TIER_WAN = "wan"

TIERS = (TIER_NVLINK, TIER_PCIE, TIER_IB, TIER_WAN)

# the id every cross-cluster transfer shares (see module docstring)
CROSS_LINK = "wan"


@dataclass(frozen=True)
class Link:
    """One physical link class: ``name`` is the occupancy key concurrent
    transfers contend on (``netsim``), ``tier`` the semantic class."""
    name: str
    tier: str
    bandwidth: float          # bytes/s per direction
    latency: float = 0.0      # per-transfer startup (s)

    def transfer_seconds(self, nbytes: float) -> float:
        """Point-to-point time for ``nbytes`` at full rate."""
        return nbytes / self.bandwidth + self.latency


@dataclass(frozen=True)
class CommGroup:
    """The participants of one collective, as nested tiers *innermost
    first*: ``tiers[0]`` is the fastest domain (e.g. the ``tp`` ranks inside
    a node), each outer tier multiplies the rank count.  A flat single-tier
    group is the degenerate case every algorithm supports."""
    tiers: Tuple[Tuple[int, Link], ...]   # (domain size, link), innermost first

    def __post_init__(self):
        if not self.tiers:
            raise ValueError("CommGroup needs at least one tier")
        if any(n < 1 for n, _ in self.tiers):
            raise ValueError(f"tier sizes must be >= 1: {self.tiers}")

    @property
    def n_ranks(self) -> int:
        n = 1
        for size, _ in self.tiers:
            n *= size
        return n

    @property
    def bottleneck(self) -> Link:
        """The slowest link in the group (a flat algorithm's pace-setter)."""
        return min((l for _, l in self.tiers), key=lambda l: l.bandwidth)

    @property
    def max_latency(self) -> float:
        return max(l.latency for _, l in self.tiers)

    @property
    def crosses_wan(self) -> bool:
        return any(l.tier == TIER_WAN for _, l in self.tiers)

    def effective(self) -> "CommGroup":
        """The group with degenerate (size-1) tiers dropped — what the
        algorithms actually see.  Fully degenerate groups keep their
        innermost tier (a 1-rank no-op collective)."""
        tiers = tuple((n, l) for n, l in self.tiers if n > 1)
        return CommGroup(tiers or self.tiers[:1])


@dataclass(frozen=True)
class Topology:
    """The fleet's link graph + just enough structure to build groups."""
    subcluster_names: Tuple[str, ...]
    n_nodes: Tuple[int, ...]
    devices_per_node: Tuple[int, ...]
    node_scales: Tuple[Tuple[float, ...], ...]
    links: Tuple[Link, ...]

    def __post_init__(self):
        names = [l.name for l in self.links]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate link names: {names}")

    # -- lookups -------------------------------------------------------------

    def link(self, name: str) -> Link:
        for l in self.links:
            if l.name == name:
                return l
        raise KeyError(f"no link named {name!r}; have "
                       f"{[l.name for l in self.links]}")

    def intra_link(self, sub_idx: int) -> Link:
        return self.link(f"intra:{self.subcluster_names[sub_idx]}")

    def inter_link(self, sub_idx: int) -> Link:
        return self.link(f"ib:{self.subcluster_names[sub_idx]}")

    def cross_link(self) -> Link:
        return self.link(CROSS_LINK)

    def p2p_link(self, src_idx: int, dst_idx: int) -> Link:
        """The link a stage-boundary activation transfer rides: the source
        sub-cluster's inter-node fabric within a cluster, the shared WAN
        across clusters (mirrors ``HeteroCluster.link_bw``)."""
        if src_idx == dst_idx:
            return self.inter_link(src_idx)
        return self.cross_link()

    # -- canonical groups ----------------------------------------------------

    def tp_group(self, sub_idx: int, tp: int) -> CommGroup:
        """Megatron-style tensor-parallel ranks inside one node."""
        return CommGroup(((tp, self.intra_link(sub_idx)),))

    def dp_group(self, sub_idx: int, n_nodes: int, per_node: int) -> CommGroup:
        """A stage's data-parallel shards: ``per_node`` ranks inside each of
        ``n_nodes`` nodes.  Single-node stages collapse to the intra tier."""
        if n_nodes <= 1:
            return CommGroup(((per_node, self.intra_link(sub_idx)),))
        return CommGroup(((per_node, self.intra_link(sub_idx)),
                          (n_nodes, self.inter_link(sub_idx))))

    def cross_group(self, sub_idx: int, n_nodes: int, per_node: int,
                    n_clusters: int) -> CommGroup:
        """A cross-cluster gradient sync (replicated/shared parameters that
        live on stages in ``n_clusters`` different sub-clusters): intra-node
        domain, inter-node domain, then the shared WAN.  Tier links are
        taken from ``sub_idx`` (the hierarchy's local side)."""
        tiers: List[Tuple[int, Link]] = [(per_node, self.intra_link(sub_idx))]
        if n_nodes > 1:
            tiers.append((n_nodes, self.inter_link(sub_idx)))
        tiers.append((n_clusters, self.cross_link()))
        return CommGroup(tuple(tiers))


def build_topology(cluster: "HeteroCluster") -> Topology:
    """The typed link graph of ``cluster``: one intra-node and one
    inter-node link per sub-cluster plus the shared cross-cluster WAN link
    (with the cluster's ``cross_latency``)."""
    links: List[Link] = []
    for sub in cluster.subclusters:
        tier = TIER_NVLINK if sub.intra_node_bw >= NVLINK_MIN_BW else TIER_PCIE
        links.append(Link(f"intra:{sub.name}", tier, sub.intra_node_bw))
        links.append(Link(f"ib:{sub.name}", TIER_IB, sub.inter_node_bw))
    links.append(Link(CROSS_LINK, TIER_WAN, cluster.cross_bw,
                      cluster.cross_latency))
    return Topology(
        subcluster_names=tuple(s.name for s in cluster.subclusters),
        n_nodes=tuple(s.n_nodes for s in cluster.subclusters),
        devices_per_node=tuple(s.devices_per_node
                               for s in cluster.subclusters),
        node_scales=tuple(s.node_scales() for s in cluster.subclusters),
        links=tuple(links))


def fingerprint(topo: Topology) -> str:
    """Stable identity of everything the comm model reads — keys the
    profiler cost cache and the controller's plan cache, alongside
    ``core.cluster.cluster_fingerprint`` (which covers compute)."""
    parts = []
    for i, name in enumerate(topo.subcluster_names):
        scales = ",".join(f"{x:.6g}" for x in topo.node_scales[i])
        parts.append(f"{name}:{topo.n_nodes[i]}x{topo.devices_per_node[i]}"
                     f":[{scales}]")
    for l in topo.links:
        parts.append(f"{l.name}:{l.tier}:{l.bandwidth:.6g}:{l.latency:.6g}")
    return "|".join(parts)
