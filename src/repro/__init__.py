"""HAPT: heterogeneity-aware automated parallel training, in JAX for multi-pod TPU."""

__version__ = "0.1.0"
