"""One home for every jax-version compatibility shim.

The container pins jax 0.4.37; newer APIs the codebase targets are shimmed
here and nowhere else (previously the same shims drifted apart between
``models/common.py`` and ``kernels/compat.py``).  Import from this module;
the old locations re-export for backward compatibility.

Shims:

- :func:`ambient_mesh` — ``jax.sharding.get_abstract_mesh`` vs. the 0.4.x
  thread-resources physical mesh.
- :func:`set_mesh` — ``jax.set_mesh`` vs. the classic ``with mesh:`` context.
- :func:`shard_map` — first-class ``jax.shard_map`` (manual ``axis_names``)
  vs. the experimental API (complement ``auto`` set, ``check_rep=False``).
- :func:`pcast_varying` — ``jax.lax.pcast(..., to="varying")`` vs. identity.
- :func:`compiler_params` — Pallas-TPU ``pltpu.CompilerParams`` vs. the old
  ``pltpu.TPUCompilerParams`` name.
"""
from __future__ import annotations

import jax


def ambient_mesh():
    """Ambient mesh across jax versions: ``jax.sharding.get_abstract_mesh``
    where available, else the thread-resources physical mesh set by a
    ``with Mesh(...)`` context."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        return get()
    try:
        from jax._src import mesh as _mesh_lib
        m = _mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def set_mesh(mesh):
    """``jax.set_mesh`` across versions: the ambient-mesh setter where it
    exists, else the classic ``with mesh:`` context manager (jax 0.4.x)."""
    setter = getattr(jax, "set_mesh", None)
    return setter(mesh) if setter is not None else mesh


def pcast_varying(x, axis_name):
    """``jax.lax.pcast(..., to="varying")`` across versions: marks a
    replicated value as device-varying for the new rep-checker; on 0.4.x
    (where shard_map runs with check_rep=False) it is the identity."""
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is not None:
        return pcast(x, (axis_name,), to="varying")
    return x


def shard_map(f, mesh, in_specs, out_specs, axis_names):
    """``jax.shard_map`` across versions.  ``axis_names`` is the *manual*
    axis set; on 0.4.x it maps to the experimental API's complement
    ``auto`` set (check_rep off — required with auto axes there)."""
    new = getattr(jax, "shard_map", None)
    if new is not None:
        return new(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   axis_names=axis_names)
    from jax.experimental.shard_map import shard_map as _old
    auto = frozenset(mesh.axis_names) - set(axis_names)
    return _old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                auto=auto, check_rep=False)


def compiler_params(**kwargs):
    """Pallas-TPU compiler params across the ``TPUCompilerParams`` ->
    ``CompilerParams`` rename."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
