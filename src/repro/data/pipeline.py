"""Deterministic synthetic LM data pipeline.

Production-shaped: stateless per-step generation keyed by (seed, step) so any
step's batch is reproducible after a restart — the checkpoint stores only the
step counter (the data "cursor"), giving exactly-once sample delivery across
preemptions without data-state files.  Host-sharded feeding: each data-axis
host slice can generate only its shard (``host_slice``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "zipf"        # 'zipf' (skewed, learnable) | 'uniform' | 'markov'


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))


def make_batch(cfg: DataConfig, step: int,
               host_slice: Optional[Tuple[int, int]] = None) -> Dict[str, np.ndarray]:
    """Batch for ``step``; tokens[t+1] is the label for tokens[t].

    ``host_slice=(i, n)`` generates rows [i*B/n, (i+1)*B/n) only."""
    rng = _batch_rng(cfg, step)
    B, T, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    if cfg.kind == "uniform":
        seq = rng.integers(0, V, size=(B, T + 1), dtype=np.int64)
    elif cfg.kind == "markov":
        # deterministic affine chain + noise: next = (a*cur + b) % V, learnable
        seq = np.empty((B, T + 1), dtype=np.int64)
        seq[:, 0] = rng.integers(0, V, size=B)
        noise = rng.random((B, T)) < 0.1
        rand = rng.integers(0, V, size=(B, T))
        for t in range(T):
            nxt = (seq[:, t] * 31 + 17) % V
            seq[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
    else:  # zipf-distributed unigrams (skewed like natural text)
        u = rng.random((B, T + 1))
        seq = np.minimum((u ** -1.25 - 1).astype(np.int64), V - 1)
        seq = (seq * 2654435761) % V
    tokens = seq[:, :-1].astype(np.int32)
    labels = seq[:, 1:].astype(np.int32)
    if host_slice is not None:
        i, n = host_slice
        rows = slice(i * B // n, (i + 1) * B // n)
        tokens, labels = tokens[rows], labels[rows]
    return {"tokens": tokens, "labels": labels}


def data_iterator(cfg: DataConfig, start_step: int = 0,
                  host_slice: Optional[Tuple[int, int]] = None
                  ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield make_batch(cfg, step, host_slice)
        step += 1
