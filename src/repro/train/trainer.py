"""Training loop with production fault-tolerance semantics.

- auto-resume from the newest checkpoint (params + optimizer + data cursor);
- atomic periodic checkpoints (``checkpoint/ckpt.py``);
- straggler watch: per-step wall times feed an EWMA; a sustained skew beyond
  ``replan_threshold`` triggers the ``on_straggler`` hook (on a real cluster:
  update the slow pod's ``DeviceProfile.efficiency`` and re-run the HAPT
  planner — heterogeneity-aware planning doubles as failure adaptation);
- per-step telemetry: every measured step time flows to ``on_step_time`` —
  ``runtime.ElasticController.trainer_hooks()`` provides both hooks, closing
  the loop: telemetry -> EWMA calibration -> amortized replanning;
- preemption-safe: SIGTERM finishes the current step, checkpoints, exits.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt as ckpt_lib
from repro.data.pipeline import DataConfig, make_batch


@dataclass
class TrainerConfig:
    total_steps: int = 200
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 50
    log_every: int = 10
    keep_ckpts: int = 3
    replan_threshold: float = 1.5   # step time vs EWMA ratio
    ewma_alpha: float = 0.1
    async_ckpt: bool = False        # hand writes to a background thread
    incremental_ckpt: bool = False  # write only leaves changed since last save


class Trainer:
    def __init__(self, cfg: TrainerConfig, data_cfg: DataConfig,
                 train_step: Callable, state: Dict[str, Any],
                 on_straggler: Optional[Callable] = None,
                 on_step_time: Optional[Callable] = None,
                 log_fn: Callable = print,
                 clock: Callable[[], float] = time.perf_counter):
        """``state``: dict of pytrees passed through train_step in order;
        train_step(*state_values, batch) -> (*new_state_values, metrics).
        ``on_step_time(step, dt)`` receives every measured step wall time
        (telemetry feed for the elastic controller); ``on_straggler(step, dt,
        ewma)`` fires only on sustained skew."""
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.train_step = train_step
        self.state = state
        # pin positional arg order NOW: jax tree_unflatten (used on resume)
        # canonicalizes dict key order, which must not reorder arguments
        self._keys = list(state.keys())
        self.on_straggler = on_straggler
        self.on_step_time = on_step_time
        self.log = log_fn
        self.clock = clock
        self._stop = False
        self._ewma = None
        self._ckptr: Optional[ckpt_lib.AsyncCheckpointer] = None
        if cfg.async_ckpt or cfg.incremental_ckpt:
            self._ckptr = ckpt_lib.AsyncCheckpointer(
                cfg.ckpt_dir, keep=cfg.keep_ckpts,
                incremental=cfg.incremental_ckpt,
                background=cfg.async_ckpt)

    def _install_sigterm(self):
        def handler(signum, frame):
            self._stop = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not main thread

    def resume(self) -> int:
        restored = ckpt_lib.restore(self.cfg.ckpt_dir, self.state)
        if restored is None:
            return 0
        step, tree, extra = restored
        self.state = tree
        self.log(f"[trainer] resumed from step {step}")
        return step

    def checkpoint(self, step: int):
        host_state = jax.tree.map(np.asarray, self.state)
        extra = {"data_seed": self.data_cfg.seed}
        if self._ckptr is not None:
            self._ckptr.save(step, host_state, extra=extra)
        else:
            ckpt_lib.save(self.cfg.ckpt_dir, step, host_state,
                          extra=extra, keep=self.cfg.keep_ckpts)

    def run(self, start_step: Optional[int] = None) -> Dict[str, Any]:
        self._install_sigterm()
        step = self.resume() if start_step is None else start_step
        history = []
        keys = self._keys
        while step < self.cfg.total_steps and not self._stop:
            batch = make_batch(self.data_cfg, step)
            t0 = self.clock()
            out = self.train_step(*[self.state[k] for k in keys], batch)
            *new_vals, metrics = out
            jax.block_until_ready(new_vals[0])
            dt = self.clock() - t0
            self.state = dict(zip(keys, new_vals))
            step += 1

            if self.on_step_time is not None:
                self.on_step_time(step, dt)

            # straggler watch (EWMA seeded from the 2nd step — the 1st pays
            # jit compilation and would mask every later straggler)
            if self._ewma is None:
                self._ewma = dt
            elif step == 2:
                self._ewma = dt
            else:
                if dt > self.cfg.replan_threshold * self._ewma \
                        and self.on_straggler is not None:
                    self.on_straggler(step, dt, self._ewma)
                a = self.cfg.ewma_alpha
                self._ewma = (1 - a) * self._ewma + a * dt

            if step % self.cfg.log_every == 0 or step == 1:
                m = {k: float(v) for k, v in metrics.items()}
                history.append({"step": step, "time_s": dt, **m})
                self.log(f"[step {step:5d}] "
                         + " ".join(f"{k}={v:.4f}" for k, v in m.items())
                         + f" ({dt*1e3:.0f} ms)")
            if step % self.cfg.ckpt_every == 0:
                self.checkpoint(step)
        if self._stop:
            self.log("[trainer] SIGTERM — checkpointing and exiting")
            self.checkpoint(step)
        if self._ckptr is not None:
            self._ckptr.close()      # all queued writes durable before exit
        return {"final_step": step, "history": history, "state": self.state}
