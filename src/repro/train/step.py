"""Train-step builders for both execution modes.

``make_train_step``          — single-pod: DP(+FSDP) over ``data``, TP over
                               ``model``; grad-accumulated microbatching.
``make_pipeline_train_step`` — multi-pod: the paper's design — pipeline over
                               ``pod`` (slow axis), DP/TP inside each pod.
Both return jit-able pure functions plus the sharding trees the launcher
uses for ``in_shardings`` / dry-run lowering.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import build_model
from repro.models.common import activation_sharding
from repro.parallel import sharding as shd
from repro.parallel.pipeline import pipeline_loss_fn
from repro.parallel.staging import build_staging
from repro.train.optimizer import OptimizerConfig, make_optimizer


def batch_pspecs(batch_tree, batch_axes=("data",)) -> Any:
    """Tokens/labels (B, T) -> shard batch dim; modality stubs likewise."""
    ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    return jax.tree.map(lambda x: P(ax, *([None] * (len(x.shape) - 1))),
                        batch_tree)


# ---------------------------------------------------------------------------
# single-pod
# ---------------------------------------------------------------------------


def make_train_step(cfg: ArchConfig, opt_cfg: OptimizerConfig, *,
                    act_rules: Optional[Dict] = None,
                    param_dtype=jnp.float32,
                    n_microbatches: int = 1,
                    use_pallas: bool = False):
    """Returns (train_step, model, opt_init).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics).
    Microbatching = grad accumulation via lax.scan (keeps activation memory
    at 1/n_mb; the DP gradient reduce happens once, after accumulation)."""
    model = build_model(cfg, param_dtype=param_dtype, use_pallas=use_pallas)
    opt_init, opt_update = make_optimizer(opt_cfg)
    rules = act_rules or shd.train_act_rules()

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        with activation_sharding(rules):
            if n_microbatches == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                mb_batch = jax.tree.map(
                    lambda x: x.reshape(n_microbatches,
                                        x.shape[0] // n_microbatches,
                                        *x.shape[1:]), batch)

                def acc_fn(carry, mb):
                    g_acc, l_acc = carry
                    (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, mb)
                    g_acc = jax.tree.map(jnp.add, g_acc, g)
                    return (g_acc, l_acc + l), m

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)
                from repro.models.common import scan_unroll
                (grads, loss_sum), ms = jax.lax.scan(
                    acc_fn, (g0, jnp.zeros((), jnp.float32)), mb_batch,
                    unroll=scan_unroll())
                grads = jax.tree.map(lambda g: g / n_microbatches, grads)
                loss = loss_sum / n_microbatches
                metrics = jax.tree.map(lambda x: jnp.mean(x), ms)
            params, opt_state, om = opt_update(grads, opt_state, params)
        return params, opt_state, {"total_loss": loss, **metrics, **om}

    return train_step, model, opt_init


def train_shardings(cfg: ArchConfig, mesh, opt_init, model,
                    param_dtype=jnp.float32):
    """(param_shardings, opt_shardings) NamedSharding trees for jit."""
    pspecs = shd.param_pspecs(
        jax.eval_shape(model.init, jax.random.PRNGKey(0)))
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    opt_shape = jax.eval_shape(
        opt_init, jax.eval_shape(model.init, jax.random.PRNGKey(0)))

    def opt_spec(path_leaf):
        return None
    # OptState(step, mu, nu): mu/nu mirror params
    opt_shard = type(opt_shape)(
        NamedSharding(mesh, P()),
        jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
        jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs))
    return pshard, opt_shard


# ---------------------------------------------------------------------------
# multi-pod (pipeline over 'pod')
# ---------------------------------------------------------------------------


def make_pipeline_train_step(cfg: ArchConfig, opt_cfg: OptimizerConfig, *,
                             mesh, n_stages: int, n_microbatches: int,
                             act_rules: Optional[Dict] = None,
                             param_dtype=jnp.float32,
                             act_dtype=jnp.bfloat16,
                             params: Optional[Any] = None,
                             abstract: bool = False):
    """Returns (train_step, staging, opt_init, shardings dict).

    ``abstract=True`` builds the staging from ShapeDtypeStructs (dry-run —
    no allocation)."""
    model = build_model(cfg, param_dtype=param_dtype)
    if params is None:
        if abstract:
            params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        else:
            params = model.init(jax.random.PRNGKey(0))
    # build_staging accepts ShapeDtypeStructs: restructuring runs under
    # eval_shape (no allocation) and the callables only close over cfg
    staging = build_staging(cfg, n_stages, params, act_dtype=act_dtype)

    opt_init, opt_update = make_optimizer(opt_cfg)
    loss_fn = pipeline_loss_fn(staging, mesh, n_microbatches)
    rules = act_rules or shd.train_act_rules(multi_pod=True)

    def train_step(staged, shared, consts, opt_state, batch):
        with activation_sharding(rules):
            (loss, metrics), grads = jax.value_and_grad(
                lambda st, sh: loss_fn(st, sh, consts, batch),
                argnums=(0, 1), has_aux=True)(staged, shared)
            tree = {"staged": staged, "shared": shared}
            gtree = {"staged": grads[0], "shared": grads[1]}
            new_tree, opt_state, om = opt_update(gtree, opt_state, tree)
        return new_tree["staged"], new_tree["shared"], opt_state, \
            {"total_loss": loss, **metrics, **om}

    shardings = pipeline_shardings(staging, mesh)
    return train_step, staging, opt_init, shardings


def pipeline_shardings(staging, mesh) -> Dict[str, Any]:
    staged_specs = shd.staged_param_pspecs(staging.staged)
    shared_specs = shd.param_pspecs(staging.shared)
    consts_specs = jax.tree.map(
        lambda x: P("pod", *([None] * (len(x.shape) - 1))), staging.consts)
    to_ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
    return {
        "staged": to_ns(staged_specs),
        "shared": to_ns(shared_specs),
        "consts": to_ns(consts_specs),
        "staged_specs": staged_specs,
        "shared_specs": shared_specs,
        "consts_specs": consts_specs,
    }
