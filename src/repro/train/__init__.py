from repro.train.optimizer import OptimizerConfig, make_optimizer
from repro.train.step import make_pipeline_train_step, make_train_step
from repro.train.trainer import Trainer, TrainerConfig
