"""Optimizers (AdamW, Adafactor-lite) as pure (init, update) pairs with
dtype-configurable state — no external deps.

States inherit the parameter sharding (FSDP'd over ``data``, TP dims over
``model``) so optimizer memory scales with 1/n_devices — the ZeRO-1 trick
the planner's Eq. 18 memory model assumes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32    # bf16 halves optimizer memory
    master_weights: bool = False      # params bf16 + f32 master in the state
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any
    master: Any = None                # f32 master copy (master_weights mode)


def lr_schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def make_adamw(cfg: OptimizerConfig):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
        master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
                  if cfg.master_weights else None)
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(zeros, params),
                        jax.tree.map(zeros, params), master)

    def update(grads, state: OptState, params):
        step = state.step + 1
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / (gn + 1e-9)) \
            if cfg.grad_clip else jnp.float32(1.0)
        lr = lr_schedule(cfg, state.step)
        b1, b2 = cfg.beta1, cfg.beta2
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p_master):
            g = g.astype(jnp.float32) * scale
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
            mhat = m32 / c1
            vhat = v32 / c2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if p_master.ndim >= 2 and cfg.weight_decay:  # none on norms
                delta = delta + cfg.weight_decay * p_master.astype(jnp.float32)
            new_master = p_master.astype(jnp.float32) - lr * delta
            return (new_master, m32.astype(cfg.state_dtype),
                    v32.astype(cfg.state_dtype))

        source = state.master if cfg.master_weights else params
        out = jax.tree.map(upd, grads, state.mu, state.nu, source)
        first = lambda t: t[0]
        is_t = lambda t: isinstance(t, tuple)
        new_master = jax.tree.map(first, out, is_leaf=is_t)
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=is_t)
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=is_t)
        if cfg.master_weights:
            new_p = jax.tree.map(lambda mstr, p: mstr.astype(p.dtype),
                                 new_master, params)
            return new_p, OptState(step, new_m, new_v, new_master),                 {"grad_norm": gn, "lr": lr}
        new_p = jax.tree.map(lambda mstr, p: mstr.astype(p.dtype),
                             new_master, params)
        return new_p, OptState(step, new_m, new_v), {"grad_norm": gn, "lr": lr}

    return init, update


def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return make_adamw(cfg)
    raise ValueError(f"unknown optimizer {cfg.name!r}")
