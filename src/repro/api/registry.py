"""Named-component registry for the compile pipeline.

Cost models, pipeline schedulers and event sources are selected *by name*
in :class:`~repro.api.config.HarpConfig`, so a plan artifact can say
``"scheduler": "h1f1b"`` instead of embedding a callable — and third-party
code can plug in alternatives without touching the facade:

    from repro.api import registry

    @registry.scheduler("my_sched")
    def my_counts(t_per_stage, c_links, n_microbatches):
        return [1] * len(t_per_stage)

Registered kinds and their contracts (all times seconds):

- ``scheduler``: ``fn(t_per_stage, c_links, n_microbatches) -> List[int]``
  (per-stage warm-up counts, the 1F1B family's only degree of freedom).
- ``cost_model``: ``fn() -> CostModelConfig`` (factory, so each plan gets a
  fresh value).
- ``event_source``: ``fn(cluster, n_steps, **kw) -> EventTrace``.
- ``cluster``: ``fn(**kw) -> HeteroCluster`` (the canonical fleets, for the
  CLI and config files).
- ``collective``: a :class:`repro.comm.algorithms.CollectiveAlgorithm`
  instance.  This kind is *backed by* ``repro.comm.algorithms.ALGORITHMS``
  (the planner resolves algorithms there without importing the api
  package), so registrations through either door are visible to both.
- ``serve_trace``: ``fn(serving_cfg, **kw) -> ServeTrace`` (request-arrival
  generators for the serving simulator; the CLI's ``simulate --trace``
  resolves here).
- ``device``: a :class:`repro.core.cluster.DeviceProfile` instance (the
  canonical fleet archetypes; ``benchmarks/roofline.py`` and the
  ``repro kbench`` CLI resolve devices by name here).
- ``trace_adapter``: ``fn(artifact, **kw) -> repro.obs.Trace`` (lowerings
  of existing timing artifacts into the typed span model; built-ins
  ``sim`` / ``netsim`` / ``migration`` / ``serve`` / ``decisions`` wrap
  the :mod:`repro.obs.trace` adapters).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List

from repro.comm import algorithms as _collectives
from repro.core import cluster as _cluster_lib
from repro.core.costmodel import CostModelConfig
from repro.core.h1f1b import (
    classic_1f1b_counts, eager_1f1b_counts, h1f1b_counts,
)
from repro.runtime.events import EventTrace, paper_trace, random_trace
from repro.serving.workload import poisson_trace, scripted_trace

KINDS = ("scheduler", "cost_model", "event_source", "cluster", "collective",
         "serve_trace", "device", "trace_adapter")

_REGISTRY: Dict[str, Dict[str, Any]] = {k: {} for k in KINDS}


def register(kind: str, name: str, obj: Any, *, overwrite: bool = False) -> Any:
    """Register ``obj`` under (kind, name).  Returns ``obj`` so it can be
    used as a decorator body.  Re-registration requires ``overwrite=True`` —
    silent shadowing of a built-in would be a debugging trap."""
    if kind == "collective":
        return _collectives.register_collective(name, obj,
                                                overwrite=overwrite)
    if kind not in _REGISTRY:
        raise KeyError(f"unknown registry kind {kind!r}; kinds: {KINDS}")
    if name in _REGISTRY[kind] and not overwrite:
        raise ValueError(
            f"{kind} {name!r} already registered (pass overwrite=True)")
    _REGISTRY[kind][name] = obj
    return obj


def resolve(kind: str, name: str) -> Any:
    if kind == "collective":
        return _collectives.get_algorithm(name)
    if kind not in _REGISTRY:
        raise KeyError(f"unknown registry kind {kind!r}; kinds: {KINDS}")
    try:
        return _REGISTRY[kind][name]
    except KeyError:
        raise KeyError(
            f"unknown {kind} {name!r}; available: {available(kind)}") from None


def available(kind: str) -> List[str]:
    if kind == "collective":
        return _collectives.available_collectives()
    return sorted(_REGISTRY[kind])


def scheduler(name: str) -> Callable:
    """Decorator: ``@registry.scheduler("name")`` registers a warm-up-count
    function."""
    return lambda fn: register("scheduler", name, fn)


def event_source(name: str) -> Callable:
    return lambda fn: register("event_source", name, fn)


# ---------------------------------------------------------------------------
# Built-ins
# ---------------------------------------------------------------------------

register("scheduler", "h1f1b", h1f1b_counts)
register("scheduler", "classic_1f1b",
         lambda t, c, B: classic_1f1b_counts(len(t), B))
register("scheduler", "eager_1f1b",
         lambda t, c, B: eager_1f1b_counts(len(t), B))

register("cost_model", "analytic", CostModelConfig)

register("event_source", "paper",
         lambda cluster, n_steps=0, **kw: paper_trace(cluster, **kw))
register("event_source", "random", random_trace)
register("event_source", "none", lambda cluster, n_steps=0, **kw: EventTrace([]))


def _chaos_trace(cluster, n_steps=0, **kw):
    # lazy: keeps the chaos package off the import path of plain planning
    from repro.chaos.faults import chaos_storm
    return chaos_storm(cluster, n_steps, **kw)


register("event_source", "chaos", _chaos_trace)

register("cluster", "paper_case_study", _cluster_lib.paper_case_study_cluster)
register("cluster", "paper_eval", _cluster_lib.paper_eval_cluster)
register("cluster", "homogeneous", _cluster_lib.homogeneous_cluster)
register("cluster", "tpu_multipod", _cluster_lib.tpu_multipod_cluster)
register("cluster", "heterogeneous_tpu", _cluster_lib.heterogeneous_tpu_cluster)


def _poisson_serve_trace(scfg, *, qps=None, duration_s=None, seed=None, **kw):
    return poisson_trace(
        qps if qps is not None else scfg.qps,
        duration_s if duration_s is not None else scfg.duration_s,
        seed=seed if seed is not None else scfg.seed,
        prompt_mean=scfg.prompt_mean, output_mean=scfg.output_mean, **kw)


def _scripted_serve_trace(scfg, *, qps=None, n_requests=None,
                          duration_s=None, seed=None, **kw):
    # seed accepted for interface parity; scripted arrivals are deterministic
    del seed
    q = qps if qps is not None else scfg.qps
    dur = duration_s if duration_s is not None else scfg.duration_s
    n = n_requests if n_requests is not None else max(1, int(q * dur))
    kw.setdefault("prompt_tokens", scfg.prompt_mean)
    kw.setdefault("output_tokens", scfg.output_mean)
    return scripted_trace(q, n, **kw)


register("serve_trace", "poisson", _poisson_serve_trace)
register("serve_trace", "scripted", _scripted_serve_trace)

for _name, _profile in _cluster_lib.DEVICE_PROFILES.items():
    register("device", _name, _profile)


def _lazy_trace_adapter(attr):
    # lazy: keeps the obs package off the import path of plain planning
    def _adapter(artifact, **kw):
        import repro.obs as _obs
        return getattr(_obs, attr)(artifact, **kw)
    _adapter.__name__ = attr
    return _adapter


register("trace_adapter", "sim", _lazy_trace_adapter("trace_from_sim"))
register("trace_adapter", "netsim", _lazy_trace_adapter("trace_from_netsim"))
register("trace_adapter", "migration",
         _lazy_trace_adapter("trace_from_migration"))
register("trace_adapter", "serve", _lazy_trace_adapter("trace_from_serve"))
register("trace_adapter", "decisions",
         _lazy_trace_adapter("trace_from_decisions"))
