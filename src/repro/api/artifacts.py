"""The staged compile artifacts: ``Plan`` -> ``LoweredPlan``.

Both are passive dataclasses with a lossless, *stable* JSON round trip
(``Plan.from_json(p.to_json()).to_json() == p.to_json()`` bit-for-bit — the
CLI subcommands and any cross-machine plan hand-off depend on it; the golden
schema test in ``tests/test_api.py`` pins the field tree).

- :class:`Plan` — the search stage's output: the raw
  :class:`~repro.core.strategy.ParallelStrategy` plus full provenance (arch,
  serialized cluster spec + fingerprint, the :class:`HarpConfig` used, and
  the predicted step simulation) so a plan is auditable and replayable on a
  machine that never saw the planner run.
- :class:`LoweredPlan` — the lowering stage's output: per-stage logical mesh
  axes (what ``parallel.sharding.mesh_from_intra_op`` materializes), integer
  microbatch apportionment across data shards, the scheduler's warm-up
  counts, and the collective plan (per-link activation bytes + per-stage
  intra-op collective traffic).

Units everywhere: times seconds, payloads bytes, batch entries samples.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.core.cluster import (
    DeviceProfile, HeteroCluster, SubCluster, cluster_from_dict,
    cluster_to_dict,
)
from repro.core.pipesim import SimResult
from repro.core.strategy import ParallelStrategy

from repro.api.config import HarpConfig

SCHEMA_VERSION = 8   # v8: obs subsystem — HarpConfig.obs (tracing /
                     # metrics / drift accounting; None = off, artifacts
                     # bit-identical to v7 apart from this null key)
                     # (v7: chaos subsystem — HarpConfig.chaos (fault
                     # injection; None = off, bit-identical to v6) and
                     # SearchConfig.deadline_s (replan wall-clock budget;
                     # 0.0 = unlimited, the v6 behavior)
                     # v6: kbench subsystem — HarpConfig.kbench /
                     # PlannerConfig.kbench (measured-kernel pricing; None on
                     # analytic plans, which stay bit-identical to v5)
                     # v5: migration subsystem — Plan.migration, the priced
                     # differ summary from Executable.migrate_to / the CLI
                     # `repro migrate`; None on directly-planned artifacts;
                     # v4: serving subsystem — HarpConfig.serving, Plan.serve;
                     # v3: comm subsystem — PlannerConfig.comm, per-stage
                     # collective algorithms, LoweredPlan link occupancy;
                     # v2: SearchConfig gained engine/batch_size knobs)

# Cluster (de)serialization lives in repro.core.cluster (the runtime's plan
# cache and chaos traces need it without importing the api layer); the names
# stay importable from here for artifact consumers.


def sim_summary(res: SimResult, tokens_per_step: int) -> Dict[str, Any]:
    """Compact, JSON-stable digest of a :class:`SimResult` (the full per-node
    start/dur maps are simulation internals, not provenance)."""
    return {
        "makespan_s": res.makespan,
        "throughput_tokens_per_s":
            tokens_per_step / res.makespan if res.makespan else 0.0,
        "overlap_ratio": res.overlap_ratio,
        "comm_total_s": res.comm_total,
        "comm_exposed_s": res.comm_exposed,
        "stage_compute_s": list(res.stage_compute),
        "stage_idle_s": list(res.stage_idle),
        "stage_intra_comm_s": list(res.stage_intra_comm),
        "warmup_counts": list(res.warmup_counts),
    }


# ---------------------------------------------------------------------------
# Plan
# ---------------------------------------------------------------------------


@dataclass
class Plan:
    """Search-stage artifact: strategy + provenance.

    Invariants: ``cluster_fingerprint ==
    cluster_fingerprint(cluster_from_dict(cluster))``; ``config`` is the
    exact :class:`HarpConfig` the search ran with (so ``lower()`` on another
    machine reproduces the same layering and schedule)."""
    arch: str
    strategy: ParallelStrategy
    config: HarpConfig
    cluster: Dict[str, Any]
    cluster_fingerprint: str
    predicted: Dict[str, Any] = field(default_factory=dict)
    serve: Optional[Dict[str, Any]] = None    # ServePlan.to_dict() when the
                                              # config carried a ServingConfig
    migration: Optional[Dict[str, Any]] = None  # priced differ summary when
                                                # this plan was produced by
                                                # migrate_to / `repro migrate`
    version: int = SCHEMA_VERSION

    def to_cluster(self) -> HeteroCluster:
        return cluster_from_dict(self.cluster)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "version": self.version,
            "arch": self.arch,
            "cluster_fingerprint": self.cluster_fingerprint,
            "cluster": self.cluster,
            "config": self.config.to_dict(),
            "strategy": json.loads(self.strategy.to_json()),
            "predicted": self.predicted,
            "serve": self.serve,
            "migration": self.migration,
        }

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "Plan":
        return Plan(
            arch=d["arch"],
            strategy=ParallelStrategy.from_json(json.dumps(d["strategy"])),
            config=HarpConfig.from_dict(d["config"]),
            cluster=d["cluster"],
            cluster_fingerprint=d["cluster_fingerprint"],
            predicted=d.get("predicted", {}),
            serve=d.get("serve"),       # absent on pre-v4 artifacts
            migration=d.get("migration"),   # absent on pre-v5 artifacts
            version=d.get("version", SCHEMA_VERSION))

    @staticmethod
    def from_json(s: str) -> "Plan":
        return Plan.from_dict(json.loads(s))

    def describe(self) -> str:
        pred = self.predicted.get("throughput_tokens_per_s", 0.0)
        lines = [f"Plan[{self.arch}] on {self.to_cluster().describe()}",
                 f"  predicted {pred:,.0f} tokens/s "
                 f"(scheduler={self.config.scheduler})",
                 self.strategy.describe()]
        if self.serve is not None:
            from repro.serving.placement import ServePlan
            lines.append(ServePlan.from_dict(self.serve).describe())
        if self.migration is not None:
            m = self.migration
            lines.append(
                f"  migrated from {m.get('from_fingerprint', '?')}: "
                f"{m.get('moved_bytes', 0) / 1e6:.0f}MB moved + "
                f"{m.get('ckpt_bytes', 0) / 1e6:.0f}MB restored in "
                f"{m.get('n_transfers', 0)} transfers, "
                f"{m.get('downtime_s', 0.0):.2f}s downtime")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# LoweredPlan
# ---------------------------------------------------------------------------


@dataclass
class StageLowering:
    """One pipeline stage, made executable: the logical mesh layout that
    ``parallel.sharding.mesh_from_intra_op`` materializes, plus the integer
    microbatch split across its data shards (largest-remainder apportionment
    — uneven in mixed sub-clusters, slowest shard first)."""
    stage: int
    subcluster: str
    layer_start: int
    layer_end: int                      # exclusive
    mesh_axes: List[List[Any]]          # [["data", dp], ["model", tp]]
    n_devices: int
    microbatch_shards: List[int]        # per-dp-shard samples, sums to the
                                        # per-microbatch sample count
    intra_comm_bytes: float             # per-microbatch collective payload
    intra_comm_time_s: float            # priced collective time (f+b)
    ar_algorithm: Optional[str] = None  # selected TP all-reduce algorithm
                                        # (None = legacy implicit flat ring)
    sync_algorithm: Optional[str] = None   # ditto, DP gradient sync
    sync_compressed: bool = False       # sync priced int8-block-quantized
    sync_time_s: float = 0.0            # per-step gradient sync (priced)
    sync_link: str = ""                 # physical link the sync occupies


@dataclass
class LoweredPlan:
    """Lowering-stage artifact: meshes + apportionment + schedule +
    collective plan.  ``len(c_links_s) == len(link_bytes) == n_stages - 1``;
    ``len(warmup_counts) == n_stages`` (from the *named* scheduler, not
    necessarily H-1F1B)."""
    scheduler: str
    n_microbatches: int
    microbatch_samples: int             # batch rows per microbatch
    warmup_counts: List[int]
    c_links_s: List[float]              # per-link activation transfer time
    link_bytes: List[float]             # per-link activation payload
    stages: List[StageLowering]
    est_step_time_s: float
    link_ids: List[str] = field(default_factory=list)
    # physical link per stage boundary ("wan" = the shared cross-cluster
    # link; equal ids contend in the netsim / contention simulation)
    link_occupancy_s: Dict[str, float] = field(default_factory=dict)
    # per physical link: priced busy seconds over one step (activation
    # sends both directions + TP all-reduces + gradient syncs)
    contended_links: List[str] = field(default_factory=list)
    # links with more than one collective/boundary charged to them
    version: int = SCHEMA_VERSION

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        return {"version": d.pop("version"), **d}

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "LoweredPlan":
        d = dict(d)
        d["stages"] = [StageLowering(**s) for s in d["stages"]]
        return LoweredPlan(**d)

    @staticmethod
    def from_json(s: str) -> "LoweredPlan":
        return LoweredPlan.from_dict(json.loads(s))

    def describe(self) -> str:
        lines = [f"LoweredPlan: {self.n_stages} stages, "
                 f"scheduler={self.scheduler}, B={self.n_microbatches}, "
                 f"est step {self.est_step_time_s * 1e3:.1f} ms"]
        for s in self.stages:
            axes = "x".join(f"{n}={sz}" for n, sz in s.mesh_axes)
            algo = ""
            if s.sync_algorithm:
                algo = f" sync={s.sync_algorithm}"
                if s.sync_compressed:
                    algo += "+int8"
            lines.append(
                f"  stage{s.stage}: layers[{s.layer_start}:{s.layer_end}] "
                f"on {s.subcluster} mesh({axes}) shards={s.microbatch_shards} "
                f"N={self.warmup_counts[s.stage]}{algo}")
        if self.contended_links:
            lines.append(f"  contended links: {', '.join(self.contended_links)}")
        return "\n".join(lines)
