"""``python -m repro`` — the command-line face of the compile pipeline.

Subcommands round-trip the :class:`~repro.api.artifacts.Plan` JSON artifact:

    python -m repro plan --arch gpt-2b --cluster paper_case_study \\
        --global-batch 64 --microbatches 32 -o plan.json
    python -m repro plan --arch gemma-2b --cluster paper_eval \\
        --serving --qps 1600 --prompt-mean 256 -o plan.json
    python -m repro simulate --plan plan.json --timeline
    python -m repro simulate --plan plan.json --trace poisson --qps 800
    python -m repro train --plan plan.json --smoke --steps 20
    python -m repro replay --plan plan.json --trace paper --steps 120
    python -m repro migrate --plan plan.json --cluster paper_eval \\
        --cluster-kw n_a100_nodes=3 -o migrated.json
    python -m repro chaos replay --plan plan.json --steps 200 --seed 1 \\
        --debounce 3 --deadline 2.0
    python -m repro kbench collect --autotune -o ktable.json
    python -m repro kbench merge hostA.json hostB.json -o ktable.json
    python -m repro kbench show ktable.json
    python -m repro plan --arch gpt-2b --kbench-table ktable.json \\
        --kbench-device-map A100-40G=gpu:A100 -o plan.json
    python -m repro trace --plan plan.json -o trace.json
    python -m repro trace --plan plan.json --replay chaos --steps 200 \\
        -o replay_trace.json
    python -m repro dryrun --arch minitron-8b --shape train_4k

``plan`` on a planning box, ``simulate``/``train``/``replay`` anywhere —
the artifact carries the cluster spec and config with it.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional


def _parse_kw(pairs: List[str]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for p in pairs:
        if "=" not in p:
            raise SystemExit(f"--cluster-kw expects key=value, got {p!r}")
        k, v = p.split("=", 1)
        try:
            out[k] = json.loads(v)
        except json.JSONDecodeError:
            out[k] = v
    return out


def _load_cluster(args):
    from repro.api import cluster_from_dict, registry
    if args.cluster_file:
        with open(args.cluster_file) as f:
            return cluster_from_dict(json.load(f))
    return registry.resolve("cluster", args.cluster)(
        **_parse_kw(args.cluster_kw))


def _load_plan(path: str):
    from repro.api import Plan
    with open(path) as f:
        return Plan.from_json(f.read())


# ---------------------------------------------------------------------------
# Subcommands
# ---------------------------------------------------------------------------


def cmd_plan(args) -> int:
    import dataclasses

    from repro.api import HarpConfig, plan
    from repro.core.planner import PlannerConfig

    comm_cfg = None
    if args.comm or args.comm_algorithms or args.comm_compressed:
        from repro.comm.selector import CommConfig
        kw: Dict[str, Any] = {"compressed": args.comm_compressed}
        if args.comm_algorithms:
            kw["algorithms"] = tuple(args.comm_algorithms.split(","))
        comm_cfg = CommConfig(**kw)
    kbench_cfg = None
    if args.kbench_table:
        from repro.kbench.bridge import KBenchConfig
        dmap = None
        if args.kbench_device_map:
            dmap = dict(p.split("=", 1) for p in args.kbench_device_map)
        kbench_cfg = KBenchConfig(table_path=args.kbench_table,
                                  device_map=dmap)
    pcfg = PlannerConfig(
        granularity=args.granularity, n_microbatches=args.microbatches,
        min_submesh_devices=args.min_submesh,
        max_submesh_devices=args.max_submesh, intra_op=args.intra_op,
        comm=comm_cfg, kbench=kbench_cfg)
    if args.workers:
        pcfg.search = dataclasses.replace(pcfg.search, n_workers=args.workers)
    serving_cfg = None
    if args.serving:
        from repro.api import ServingConfig
        serving_cfg = ServingConfig(
            qps=args.qps, duration_s=args.serving_duration,
            prompt_mean=args.prompt_mean, output_mean=args.output_mean,
            objective=args.serving_objective,
            slo_ttft_s=args.slo_ttft_ms / 1e3,
            slo_tpot_s=args.slo_tpot_ms / 1e3)
    cfg = HarpConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                     scheduler=args.scheduler, planner=pcfg,
                     serving=serving_cfg)
    cluster = _load_cluster(args)
    artifact = plan(args.arch, cluster, cfg, verbose=args.verbose)
    with open(args.out, "w") as f:
        f.write(artifact.to_json())
    print(artifact.describe())
    if args.explain_comm:
        from repro.api import compile as api_compile
        print()
        print(api_compile(plan_artifact=artifact).explain_comm())
    if args.explain_costs:
        from repro.api import compile as api_compile
        print()
        print(api_compile(plan_artifact=artifact).explain_costs())
    print(f"\nplan written to {args.out}")
    return 0


def cmd_kbench(args) -> int:
    from repro.kbench.table import LatencyTable

    if args.kcmd == "collect":
        from repro.kbench import autotune, harness
        ops_to_run = args.ops.split(",") if args.ops else None
        kw = dict(shapes=args.shapes, trials=args.trials, warmup=args.warmup,
                  interpret=True if args.interpret else None, seed=args.seed,
                  device=args.device)
        if args.autotune:
            table, sweeps = autotune.collect_autotuned(ops_to_run, **kw)
            for sw in sweeps:
                print(f"{sw.op} {sw.shape}: best={sw.best_blocks} "
                      f"({sw.best_s * 1e6:.1f}us), default="
                      f"{sw.default_blocks} ({sw.default_s * 1e6:.1f}us), "
                      f"speedup {sw.speedup:.2f}x")
        else:
            table = harness.collect(ops_to_run, **kw)
        table.save(args.out)
        print(f"{len(table)} cells ({harness.device_fingerprint(True if args.interpret else None)}) "
              f"written to {args.out}")
        return 0

    if args.kcmd == "merge":
        table = LatencyTable()
        for path in args.tables:
            table = table.merge(LatencyTable.load(path))
        table.save(args.out)
        print(f"merged {len(args.tables)} tables -> {len(table)} cells "
              f"in {args.out}")
        return 0

    # show
    table = LatencyTable.load(args.table)
    entries = table.entries if not args.device \
        else table.for_device(args.device)
    print(f"{args.table}: {len(table)} cells, devices: "
          f"{', '.join(table.devices()) or '(none)'}")
    for e in entries:
        blocks = "default" if e.blocks is None else "x".join(map(str, e.blocks))
        tput = f", {e.flops / e.median_s / 1e12:.3f} TFLOP/s" \
            if e.flops > 0 and e.median_s > 0 else ""
        print(f"  [{e.device}] {e.op} {tuple(e.shape)} blocks={blocks}: "
              f"{e.median_s * 1e6:.1f}us (median of {e.trials}{tput}) "
              f"@{e.host or '?'}")
    return 0


def cmd_simulate(args) -> int:
    from repro.api import compile as api_compile, registry

    exe = api_compile(plan_artifact=_load_plan(args.plan))
    if args.trace:
        if exe.plan.serve is None:
            raise SystemExit(
                "simulate --trace needs a plan built with plan --serving")
        kw: Dict[str, Any] = {}
        if args.qps is not None:
            kw["qps"] = args.qps
        if args.duration is not None:
            kw["duration_s"] = args.duration
        if args.trace_seed is not None:
            kw["seed"] = args.trace_seed
        trace = registry.resolve("serve_trace", args.trace)(
            exe.config.serving, **kw)
        res = exe.serve_simulate(trace, trace_out=args.trace_out)
        print(res.describe())
        if args.trace_out:
            print(f"serving Chrome trace written to {args.trace_out}")
        return 0
    res = exe.simulate(priced=not args.raw, no_overlap=args.no_overlap,
                       contention=args.contention,
                       trace_out=args.trace_out)
    tok = exe.strategy.tokens_per_step()
    print(exe.lowered.describe())
    mode = "contended fair-share" if args.contention else \
        ("referee-priced" if not args.raw else "raw schedule")
    print(f"\nsimulated step: {res.makespan * 1e3:.2f} ms ({mode}), "
          f"{tok / res.makespan:,.0f} tokens/s, "
          f"comm overlap {res.overlap_ratio * 100:.0f}%")
    if args.contention and res.link_busy:
        busy = ", ".join(f"{l}={t * 1e3:.1f}ms"
                         for l, t in sorted(res.link_busy.items()))
        print(f"link busy: {busy}")
    if args.timeline:
        from repro.obs import render_ascii, trace_from_sim
        print(render_ascii(trace_from_sim(res), width=96))
    if args.trace_out:
        print(f"Chrome trace written to {args.trace_out}")
    return 0


def cmd_train(args) -> int:
    from repro.api import HarpConfig, compile as api_compile, fit
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig
    from repro.train.trainer import TrainerConfig

    hooks: Dict[str, Any] = {}
    # CLI flags default to None so a Plan's own workload config wins unless
    # explicitly overridden
    seq, batch, steps = args.seq, args.batch, args.steps
    if args.plan:
        exe = api_compile(plan_artifact=_load_plan(args.plan))
        arch_cfg = exe.arch
        seq = seq if seq is not None else exe.config.seq_len
        batch = batch if batch is not None else exe.config.global_batch
        steps = steps if steps is not None \
            else exe.config.trainer.total_steps
        if args.smoke:
            # the reduced stand-in arch runs nothing like the planned model;
            # anchoring the controller's telemetry to the plan's predictions
            # would produce bogus drift/replan decisions
            print("[train] --smoke: elastic controller NOT attached "
                  "(reduced arch is not the planned workload)")
        elif seq == exe.config.seq_len and batch == exe.config.global_batch:
            from repro.runtime.controller import ControllerConfig
            # the amortization horizon must be the steps actually run, not
            # the plan's default training horizon
            ctrl = exe.attach_elastic(ControllerConfig(
                total_steps=steps, seq_len=seq, global_batch=batch))
            hooks = {"on_step_time": ctrl.on_step_time,
                     "on_straggler": ctrl.on_straggler}
        else:
            print("[train] workload overridden vs. the plan: elastic "
                  "controller NOT attached (telemetry would anchor to the "
                  "wrong prediction)")
    else:
        if not args.arch:
            raise SystemExit("train needs --plan or --arch")
        arch_cfg = get_config(args.arch)
    seq = 128 if seq is None else seq
    batch = 8 if batch is None else batch
    steps = 200 if steps is None else steps
    if args.smoke:
        arch_cfg = arch_cfg.reduced()
    cfg = HarpConfig(
        seq_len=seq, global_batch=batch,
        trainer=TrainerConfig(total_steps=steps, ckpt_dir=args.ckpt_dir,
                              ckpt_every=args.ckpt_every),
        data=DataConfig(vocab_size=arch_cfg.vocab_size, seq_len=seq,
                        global_batch=batch, seed=args.seed,
                        kind=args.data_kind))
    out = fit(arch_cfg, cfg, n_microbatches=args.microbatches,
              seed=args.seed, **hooks)
    hist = out["history"]
    if hist:
        print(f"[train] loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
              f"over {out['final_step']} steps")
    return 0


def cmd_replay(args) -> int:
    from repro.api import compile as api_compile

    exe = api_compile(plan_artifact=_load_plan(args.plan))
    kw: Dict[str, Any] = {}
    if args.trace == "random":
        kw["seed"] = args.seed
    res = exe.replay(args.trace, args.steps, elastic=not args.static,
                     trace_out=args.trace_out, **kw)
    if args.trace_out:
        print(f"Chrome trace written to {args.trace_out}")
    if exe.controller is not None:
        print("replan decisions:")
        for d in exe.controller.decisions:
            print(f"  {d.describe()}")
    bucket = max(1, args.steps // 12)
    print("\nthroughput timeline (tokens/s):")
    for s0 in range(0, args.steps, bucket):
        tput = res.throughput_between(s0, s0 + bucket)
        print(f"  steps {s0:4d}-{s0 + bucket:4d}: {tput:12,.0f}")
    print(f"\noverall: {res.throughput():,.0f} tokens/s, "
          f"{res.stalled_steps} stalled steps")
    return 0


def cmd_migrate(args) -> int:
    from repro.api import compile as api_compile

    exe = api_compile(plan_artifact=_load_plan(args.plan))
    if args.to:
        target = api_compile(plan_artifact=_load_plan(args.to))
    else:
        if not (args.cluster or args.cluster_file):
            raise SystemExit("migrate needs --to PLAN.json or a new "
                             "--cluster/--cluster-file to replan onto")
        target = _load_cluster(args)
    new_exe = exe.migrate_to(target, overlap=not args.no_overlap,
                             verbose=args.verbose)
    with open(args.out, "w") as f:
        f.write(new_exe.plan.to_json())
    m = new_exe.plan.migration
    print(new_exe.plan.describe())
    print(f"\nmigration: {m['moved_bytes'] / 1e6:.1f} MB moved + "
          f"{m['ckpt_bytes'] / 1e6:.1f} MB from checkpoint "
          f"({m['local_bytes'] / 1e6:.1f} MB already in place) in "
          f"{m['n_transfers']} transfers")
    per_link = ", ".join(f"{l}={b / 1e6:.1f}MB"
                         for l, b in m["link_bytes"].items())
    print(f"link traffic: {per_link or 'none'}")
    print(f"downtime: {m['downtime_s']:.3f}s "
          f"(serial {m['serial_s']:.3f}s, drain {m['drain_s']:.3f}s, "
          f"{'overlapped' if m['overlapped'] else 'stop-the-world'})")
    print(f"\nmigrated plan written to {args.out}")
    return 0


def cmd_chaos(args) -> int:
    from repro.api import compile as api_compile
    from repro.chaos import (
        ChaosConfig, FaultInjector, chaos_storm, trace_from_json,
        trace_to_json,
    )
    from repro.runtime.controller import ControllerConfig
    from repro.runtime.replay import run_replay

    exe = api_compile(plan_artifact=_load_plan(args.plan))
    if args.trace_file:
        with open(args.trace_file) as f:
            trace = trace_from_json(f.read())
    else:
        trace = chaos_storm(exe.cluster, args.steps, seed=args.seed,
                            intensity=args.intensity)
    if args.save_trace:
        with open(args.save_trace, "w") as f:
            f.write(trace_to_json(trace))
        print(f"storm trace written to {args.save_trace}")
    cfg = exe.config
    ccfg = ControllerConfig(
        total_steps=args.steps, seq_len=cfg.seq_len,
        global_batch=cfg.global_batch,
        debounce_steps=args.debounce,
        min_steps_between_replans=args.min_replan_gap,
        replan_deadline_s=args.deadline,
        degraded_ladder=not args.no_ladder)
    ctrl = exe.attach_elastic(ccfg)
    if args.p_planner_timeout > 0 or args.p_planner_infeasible > 0:
        ctrl.injector = FaultInjector(ChaosConfig(
            seed=args.seed,
            p_planner_timeout=args.p_planner_timeout,
            p_planner_infeasible=args.p_planner_infeasible))
    res = run_replay(trace, args.steps, controller=ctrl)
    print("replan decisions:")
    for d in ctrl.decisions:
        print(f"  {d.describe()}")
    replans = sum(1 for d in ctrl.decisions
                  if d.action not in ("none", "deferred", "ignored"))
    print(f"\noverall: {res.throughput():,.0f} tokens/s, "
          f"{res.stalled_steps} stalled steps, {replans} replans, "
          f"{len(trace.events)} storm events")
    if ctrl.injector is not None:
        print(f"injected faults: {ctrl.injector.stats()}")
    return 0


def cmd_trace(args) -> int:
    from repro.api import compile as api_compile

    exe = api_compile(plan_artifact=_load_plan(args.plan))
    if args.replay:
        kw: Dict[str, Any] = {}
        if args.replay == "random" or args.replay == "chaos":
            kw["seed"] = args.seed
        res = exe.replay(args.replay, args.steps, trace_out=args.out, **kw)
        n_dec = len(res.decisions)
        print(f"replayed {args.steps} steps ({args.replay}): "
              f"{res.throughput():,.0f} tokens/s, {n_dec} controller "
              f"decisions traced")
        print(f"Chrome trace written to {args.out} "
              f"(load in Perfetto / chrome://tracing)")
        return 0
    if args.serve:
        if exe.plan.serve is None:
            raise SystemExit(
                "trace --serve needs a plan built with plan --serving")
        res = exe.serve_simulate(trace_out=args.out)
        print(res.describe())
        print(f"serving Chrome trace written to {args.out} "
              f"(load in Perfetto / chrome://tracing)")
        return 0
    tr = exe.trace(out=args.out, priced=args.priced,
                   contention=args.contention)
    print(f"{len(tr.spans)} spans / {len(tr.counters)} counter samples, "
          f"makespan {tr.makespan() * 1e3:.2f} ms")
    if args.timeline:
        from repro.obs import render_ascii
        print(render_ascii(tr, width=96))
    print(f"Chrome trace written to {args.out} "
          f"(load in Perfetto / chrome://tracing)")
    return 0


def cmd_dryrun(args, extra: List[str]) -> int:
    # delegate to the launcher (it owns the XLA device-count env dance)
    from repro.launch import dryrun
    sys.argv = ["repro.launch.dryrun"] + extra
    dryrun.main()
    return 0


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="HARP compile pipeline: plan / simulate / train / replay")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("plan", help="HAPT search -> Plan JSON artifact")
    p.add_argument("--arch", required=True)
    p.add_argument("--cluster", default="paper_case_study",
                   help="registered cluster name (see repro.api.registry)")
    p.add_argument("--cluster-kw", action="append", default=[],
                   metavar="K=V", help="cluster factory kwarg, repeatable "
                   "(e.g. --cluster-kw cross_gbps=10)")
    p.add_argument("--cluster-file",
                   help="cluster spec JSON (api.cluster_to_dict format)")
    p.add_argument("--seq-len", type=int, default=1024)
    p.add_argument("--global-batch", type=int, default=64)
    p.add_argument("--granularity", type=int, default=64)
    p.add_argument("--microbatches", type=int, default=32)
    p.add_argument("--min-submesh", type=int, default=1)
    p.add_argument("--max-submesh", type=int, default=0)
    p.add_argument("--intra-op", action="store_true",
                   help="joint inter+intra-operator search")
    p.add_argument("--comm", action="store_true",
                   help="heterogeneity-aware collective pricing: the search "
                        "chooses plans under the selected algorithm's cost "
                        "(repro.comm)")
    p.add_argument("--comm-algorithms", default=None, metavar="A,B,...",
                   help="candidate collective set (default "
                        "ring,rhd,hierarchical; implies --comm)")
    p.add_argument("--comm-compressed", action="store_true",
                   help="add int8-compressed candidates for WAN-crossing "
                        "collectives (implies --comm; stage-local TP/DP "
                        "collectives never cross the WAN, so this prices "
                        "the cross-cluster sync surfaces — see docs/comm.md)")
    p.add_argument("--explain-comm", action="store_true",
                   help="print the per-stage collective breakdown "
                        "(algorithm, bytes, priced time, contended links)")
    p.add_argument("--kbench-table", default=None, metavar="TABLE.json",
                   help="measured-kernel latency table (repro kbench "
                        "collect): the DP search prices stages from "
                        "measurements where covered, analytic elsewhere")
    p.add_argument("--kbench-device-map", action="append", default=[],
                   metavar="NAME=FINGERPRINT",
                   help="map a DeviceProfile name to a table device "
                        "fingerprint, repeatable (e.g. "
                        "A100-40G=gpu:NVIDIA_A100)")
    p.add_argument("--explain-costs", action="store_true",
                   help="print the per-stage pricing breakdown (measured vs "
                        "analytic source, MFU anchors)")
    p.add_argument("--scheduler", default="h1f1b")
    p.add_argument("--workers", type=int, default=0)
    p.add_argument("--serving", action="store_true",
                   help="also search a serving placement (disaggregated "
                        "prefill/decode over the same fleet); the plan "
                        "artifact grows a ServePlan section")
    p.add_argument("--qps", type=float, default=32.0,
                   help="offered request rate for the serving search")
    p.add_argument("--serving-duration", type=float, default=2.0,
                   help="seconds of Poisson arrivals the search replays")
    p.add_argument("--serving-objective", default="slo",
                   choices=["slo", "throughput"])
    p.add_argument("--prompt-mean", type=int, default=512)
    p.add_argument("--output-mean", type=int, default=64)
    p.add_argument("--slo-ttft-ms", type=float, default=200.0,
                   help="p99 time-to-first-token target")
    p.add_argument("--slo-tpot-ms", type=float, default=20.0,
                   help="p99 time-per-output-token target")
    p.add_argument("-o", "--out", default="plan.json")
    p.add_argument("--verbose", action="store_true")

    p = sub.add_parser("simulate", help="simulate one step of a Plan")
    p.add_argument("--plan", required=True)
    p.add_argument("--raw", action="store_true",
                   help="raw lowered schedule (default: referee-priced)")
    p.add_argument("--no-overlap", action="store_true")
    p.add_argument("--contention", action="store_true",
                   help="fair-share link-occupancy simulation (comm.netsim):"
                        " shared links and grad syncs contend")
    p.add_argument("--timeline", action="store_true")
    p.add_argument("--trace", default=None,
                   help="serving mode: replay a registered request trace "
                        "(poisson / scripted) through the plan's ServePlan "
                        "section (needs plan --serving)")
    p.add_argument("--qps", type=float, default=None,
                   help="override the trace's request rate")
    p.add_argument("--duration", type=float, default=None,
                   help="override the trace's duration (seconds)")
    p.add_argument("--trace-seed", type=int, default=None)
    p.add_argument("--trace-out", default=None, metavar="TRACE.json",
                   help="also write the simulation as Chrome-trace JSON "
                        "(Perfetto / chrome://tracing)")

    p = sub.add_parser("train", help="training loop (plan-driven or ad hoc)")
    p.add_argument("--plan", help="Plan JSON (wires the elastic controller)")
    p.add_argument("--arch", help="arch id (when no --plan)")
    p.add_argument("--smoke", action="store_true",
                   help="reduced same-family config (CPU)")
    p.add_argument("--steps", type=int, default=None,
                   help="default: plan's total_steps, else 200")
    p.add_argument("--batch", type=int, default=None,
                   help="default: plan's global_batch, else 8")
    p.add_argument("--seq", type=int, default=None,
                   help="default: plan's seq_len, else 128")
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--ckpt-dir", default="checkpoints")
    p.add_argument("--ckpt-every", type=int, default=100)
    p.add_argument("--data-kind", default="markov",
                   choices=["markov", "zipf", "uniform"])
    p.add_argument("--seed", type=int, default=0)

    p = sub.add_parser("replay", help="fleet-dynamics replay of a Plan")
    p.add_argument("--plan", required=True)
    p.add_argument("--trace", default="paper",
                   help="registered event source (paper / random / none)")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--static", action="store_true",
                   help="keep the plan fixed (checkpoint-restart baseline)")
    p.add_argument("--trace-out", default=None, metavar="TRACE.json",
                   help="write the replay (pipeline lanes + controller-"
                        "decision track) as Chrome-trace JSON")

    p = sub.add_parser("trace", help="export a plan's timing as Chrome-"
                       "trace JSON (repro.obs; Perfetto-loadable)")
    p.add_argument("--plan", required=True)
    p.add_argument("-o", "--out", default="trace.json")
    p.add_argument("--priced", action="store_true",
                   help="referee-priced accounting (default: the raw "
                        "lowered schedule — matches describe(timeline))")
    p.add_argument("--contention", action="store_true",
                   help="fair-share link-occupancy engine (adds sync lanes "
                        "+ link-busy counters)")
    p.add_argument("--timeline", action="store_true",
                   help="also print the ASCII rendering of the same spans")
    p.add_argument("--replay", default=None, metavar="SOURCE",
                   help="trace a fleet-dynamics replay instead (event "
                        "source name: paper / random / chaos / none) — "
                        "adds the controller-decision track")
    p.add_argument("--serve", action="store_true",
                   help="trace the serving simulator instead (per-pool "
                        "prefill/decode lanes; needs plan --serving)")
    p.add_argument("--steps", type=int, default=200,
                   help="steps for --replay")
    p.add_argument("--seed", type=int, default=0)

    p = sub.add_parser("migrate", help="price moving live state from one "
                       "plan onto another (repro.migrate differ + netsim)")
    p.add_argument("--plan", required=True,
                   help="the currently-running Plan JSON (state source)")
    p.add_argument("--to", default=None,
                   help="target Plan JSON (else replan on --cluster)")
    p.add_argument("--cluster", default=None,
                   help="registered cluster name to replan onto")
    p.add_argument("--cluster-kw", action="append", default=[], metavar="K=V")
    p.add_argument("--cluster-file",
                   help="cluster spec JSON (api.cluster_to_dict format)")
    p.add_argument("--no-overlap", action="store_true",
                   help="price stop-the-world instead of overlapping the "
                        "old plan's drain")
    p.add_argument("-o", "--out", default="migrated_plan.json")
    p.add_argument("--verbose", action="store_true")

    p = sub.add_parser("kbench", help="measured-kernel latency tables "
                       "(collect / merge / show)")
    ksub = p.add_subparsers(dest="kcmd", required=True)

    k = ksub.add_parser("collect", help="microbenchmark the fused ops on "
                        "this host -> table JSON")
    k.add_argument("--ops", default=None, metavar="A,B,...",
                   help="subset of the op registry (default: all)")
    k.add_argument("--shapes", default="tiny", choices=["tiny", "default"],
                   help="canonical shape set (tiny = CI/interpret-sized)")
    k.add_argument("--autotune", action="store_true",
                   help="sweep each op's block grid and record the winner")
    k.add_argument("--trials", type=int, default=5)
    k.add_argument("--warmup", type=int, default=2)
    k.add_argument("--seed", type=int, default=0)
    k.add_argument("--interpret", action="store_true",
                   help="force Pallas interpret mode (default: auto off-TPU)")
    k.add_argument("--device", default=None,
                   help="override the recorded device fingerprint")
    k.add_argument("-o", "--out", default="ktable.json")

    k = ksub.add_parser("merge", help="deterministic cross-host merge")
    k.add_argument("tables", nargs="+", metavar="TABLE.json")
    k.add_argument("-o", "--out", default="ktable.json")

    k = ksub.add_parser("show", help="dump a table's cells")
    k.add_argument("table", metavar="TABLE.json")
    k.add_argument("--device", default=None,
                   help="only cells for this device fingerprint")

    p = sub.add_parser("chaos", help="chaos-hardening tools (fault-storm "
                       "replay through the hardened controller)")
    csub = p.add_subparsers(dest="chaoscmd", required=True)
    c = csub.add_parser("replay", help="replay a seeded fault storm (or a "
                        "saved trace) against a Plan's elastic controller")
    c.add_argument("--plan", required=True)
    c.add_argument("--steps", type=int, default=200)
    c.add_argument("--seed", type=int, default=0)
    c.add_argument("--intensity", type=float, default=1.0,
                   help="scales every storm hazard rate")
    c.add_argument("--trace-file", default=None,
                   help="replay a saved storm trace JSON instead of "
                        "generating one")
    c.add_argument("--save-trace", default=None, metavar="TRACE.json",
                   help="write the generated storm trace (fixture-ready)")
    c.add_argument("--debounce", type=int, default=3,
                   help="event-coalescing window (steps); 0 disables")
    c.add_argument("--min-replan-gap", type=int, default=5,
                   help="hysteresis: min steps between voluntary replans")
    c.add_argument("--deadline", type=float, default=0.0,
                   help="replan wall-clock deadline (s); 0 = unbounded")
    c.add_argument("--no-ladder", action="store_true",
                   help="disable the degraded-mode ladder (unhardened "
                        "baseline — planning failures raise)")
    c.add_argument("--p-planner-timeout", type=float, default=0.0)
    c.add_argument("--p-planner-infeasible", type=float, default=0.0)

    sub.add_parser("dryrun", add_help=False,
                   help="forward to repro.launch.dryrun (own flags)")
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "dryrun":
        return cmd_dryrun(None, argv[1:])
    args = build_parser().parse_args(argv)
    return {"plan": cmd_plan, "simulate": cmd_simulate,
            "train": cmd_train, "replay": cmd_replay,
            "migrate": cmd_migrate, "kbench": cmd_kbench,
            "chaos": cmd_chaos, "trace": cmd_trace}[args.cmd](args)


if __name__ == "__main__":
    raise SystemExit(main())
