"""``repro.api`` — the staged plan -> lower -> execute compile pipeline.

One facade over the whole system (see docs/api.md for the walkthrough):

    from repro import api
    from repro.core import paper_case_study_cluster

    exe = api.compile("gpt-2b", paper_case_study_cluster(),
                      api.HarpConfig(global_batch=64))
    print(exe.describe())
    res = exe.simulate()              # referee-priced discrete-event step
    exe.attach_elastic()              # elastic controller + telemetry hooks
    exe.fit()                         # fault-tolerant training loop

Every stage artifact (:class:`Plan`, :class:`LoweredPlan`) JSON round-trips
bit-identically, so ``python -m repro plan`` on one machine feeds
``python -m repro train`` on another.  Pluggable components (schedulers,
cost models, event sources, canonical clusters) are selected by name through
:mod:`repro.api.registry`.
"""
from repro.api.artifacts import (
    LoweredPlan, Plan, StageLowering, cluster_from_dict, cluster_to_dict,
    sim_summary,
)
from repro.api.config import HarpConfig
from repro.api.facade import (
    Executable, compile, fit, generate, lower, plan, warn_deprecated,
)
from repro.api import registry
from repro.chaos import ChaosConfig, FaultInjector
from repro.kbench import KBenchConfig, KBenchModel, LatencyTable
from repro.migrate import MigrationCost, MigrationPlan
from repro.obs import (
    DriftLedger, DriftReport, MetricsRegistry, ObsConfig, RunLog, Trace,
)
from repro.serving.batching import ServeSimResult
from repro.serving.placement import ServePlan, ServingConfig
from repro.serving.workload import ServeTrace

__all__ = [
    "HarpConfig", "Plan", "LoweredPlan", "StageLowering", "Executable",
    "compile", "plan", "lower", "fit", "generate",
    "ServingConfig", "ServePlan", "ServeTrace", "ServeSimResult",
    "MigrationPlan", "MigrationCost",
    "KBenchConfig", "KBenchModel", "LatencyTable",
    "ChaosConfig", "FaultInjector",
    "ObsConfig", "Trace", "DriftLedger", "DriftReport", "MetricsRegistry",
    "RunLog",
    "cluster_to_dict", "cluster_from_dict", "sim_summary",
    "registry", "warn_deprecated",
]
