"""HarpConfig: the one config object the compile pipeline reads.

Unifies the per-subsystem configs that callers previously wired by hand —
``PlannerConfig`` (search), ``TrainerConfig`` (execution loop), ``DataConfig``
(input pipeline, optional: derived from the arch when absent) and
``ControllerConfig`` (elastic runtime, optional) — plus the workload shape
(``seq_len``/``global_batch``, token/sample counts) and the *names* of
pluggable components (``scheduler``/``cost_model``, resolved through
:mod:`repro.api.registry`).

``validate()`` is called by the facade before planning; ``to_json`` /
``from_json`` round-trip everything except ``planner.measure_fn`` (a
callable — plans built for on-hardware profiling cannot be shipped as JSON,
so serializing one raises).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from repro.chaos.inject import ChaosConfig
from repro.comm.selector import CommConfig
from repro.core.costmodel import CostModelConfig
from repro.kbench.bridge import KBenchConfig
from repro.obs import ObsConfig
from repro.core.dp_search import SearchConfig
from repro.core.planner import PlannerConfig
from repro.data.pipeline import DataConfig
from repro.runtime.controller import ControllerConfig
from repro.serving.placement import ServingConfig
from repro.train.trainer import TrainerConfig

from repro.api import registry


@dataclass
class HarpConfig:
    """Everything ``api.compile`` reads.  Units: ``seq_len`` is tokens per
    sample, ``global_batch`` is samples per step; all times priced downstream
    are seconds."""
    seq_len: int = 1024
    global_batch: int = 1024
    scheduler: str = "h1f1b"          # registry: warm-up-count policy
    cost_model: str = "analytic"      # registry: CostModelConfig factory
    # (intra-op collective overlap lives in planner.search.intra_overlap —
    # the search's final pipesim validation reads it there)
    planner: PlannerConfig = field(default_factory=PlannerConfig)
    trainer: TrainerConfig = field(default_factory=TrainerConfig)
    data: Optional[DataConfig] = None       # None -> derived from the arch
    elastic: Optional[ControllerConfig] = None  # None -> derived on attach
    serving: Optional[ServingConfig] = None  # None -> training-only plan
    # (the off-state invariant: serving=None leaves every training artifact
    # bit-identical to the pre-serving schema — see DESIGN.md §7)
    kbench: Optional[KBenchConfig] = None  # None -> analytic pricing
    # (convenience alias for planner.kbench; same off-state invariant —
    # kbench=None plans are bit-identical to pre-kbench plans, DESIGN.md §9)
    chaos: Optional[ChaosConfig] = None  # None -> no fault injection
    # (off-state invariant: chaos=None — and all-zero probabilities — leave
    # controller decisions and artifacts bit-identical to schema v6,
    # DESIGN.md §10)
    obs: Optional[ObsConfig] = None  # None -> no tracing/drift accounting
    # (off-state invariant: obs=None leaves every artifact bit-identical to
    # schema v7 apart from the version bump + this null key, DESIGN.md §11;
    # even obs=ObsConfig() never changes planning or runtime decisions —
    # observability only records)

    def __post_init__(self):
        # the top-level kbench knob materializes into the planner config;
        # disagreement between the two is caught by validate()
        if self.kbench is not None and self.planner.kbench is None:
            self.planner = dataclasses.replace(self.planner,
                                               kbench=self.kbench)
        # the named cost model materializes into the planner config unless
        # the caller already customized it away from the default; unknown
        # names are left for validate() to report (uniform ValueError path)
        if self.cost_model != "analytic" \
                and self.cost_model in registry.available("cost_model") \
                and self.planner.cost == CostModelConfig():
            self.planner = dataclasses.replace(
                self.planner, cost=registry.resolve(
                    "cost_model", self.cost_model)())
        # ergonomics: a planner left at the DEFAULT microbatch count follows
        # the workload (`HarpConfig(global_batch=64)` just works — B=64, one
        # sample per microbatch); an explicitly-set count is the caller's
        # contract and validate() enforces divisibility strictly
        if self.planner.n_microbatches == PlannerConfig().n_microbatches \
                and self.global_batch > 0 \
                and self.global_batch % self.planner.n_microbatches != 0:
            self.planner = dataclasses.replace(
                self.planner, n_microbatches=self.global_batch)

    # -- validation ----------------------------------------------------------

    def validate(self) -> "HarpConfig":
        """Raise ``ValueError`` on inconsistent knobs; returns self so the
        facade can chain ``cfg.validate()``."""
        errs = []
        if self.seq_len <= 0:
            errs.append(f"seq_len must be positive, got {self.seq_len}")
        if self.global_batch <= 0:
            errs.append(f"global_batch must be positive, "
                        f"got {self.global_batch}")
        p = self.planner
        if not 0.0 <= p.search.intra_overlap <= 1.0:
            errs.append(f"planner.search.intra_overlap must be in [0, 1], "
                        f"got {p.search.intra_overlap}")
        if self.global_batch % p.n_microbatches != 0:
            errs.append(
                f"global_batch ({self.global_batch}) must be a multiple of "
                f"planner.n_microbatches ({p.n_microbatches}) — otherwise "
                f"the per-microbatch sample apportionment drops samples")
        if p.granularity <= 0:
            errs.append(f"planner.granularity must be positive, "
                        f"got {p.granularity}")
        if p.n_microbatches <= 0:
            errs.append(f"planner.n_microbatches must be positive, "
                        f"got {p.n_microbatches}")
        if p.rho <= 1.0:
            errs.append(f"planner.rho must exceed 1 (imbalance-pruning "
                        f"ratio), got {p.rho}")
        if self.trainer.total_steps <= 0:
            errs.append(f"trainer.total_steps must be positive, "
                        f"got {self.trainer.total_steps}")
        for kind, name in (("scheduler", self.scheduler),
                           ("cost_model", self.cost_model)):
            if name not in registry.available(kind):
                errs.append(f"unknown {kind} {name!r}; available: "
                            f"{registry.available(kind)}")
        if self.kbench is not None and self.planner.kbench is not None \
                and self.kbench != self.planner.kbench:
            errs.append("kbench and planner.kbench disagree — set one "
                        "(the top-level knob materializes into the planner)")
        if self.data is not None and self.data.seq_len != self.seq_len:
            errs.append(f"data.seq_len ({self.data.seq_len}) disagrees with "
                        f"seq_len ({self.seq_len})")
        if self.serving is not None:
            errs.extend(self.serving.validate_errors())
        e = self.elastic
        if e is not None:
            de = ControllerConfig()
            # class-default workload fields count as "unset" (attach_elastic
            # backfills them from this config); explicit disagreement is an
            # error — the controller would replan a different workload
            if e.seq_len not in (de.seq_len, self.seq_len):
                errs.append(f"elastic.seq_len ({e.seq_len}) disagrees with "
                            f"seq_len ({self.seq_len})")
            if e.global_batch not in (de.global_batch, self.global_batch):
                errs.append(f"elastic.global_batch ({e.global_batch}) "
                            f"disagrees with global_batch "
                            f"({self.global_batch})")
        if errs:
            raise ValueError("invalid HarpConfig: " + "; ".join(errs))
        return self

    # -- (de)serialization ---------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        if self.planner.measure_fn is not None:
            raise ValueError(
                "HarpConfig with planner.measure_fn (a callable) cannot be "
                "serialized — on-hardware-profiled plans are machine-bound")
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "HarpConfig":
        d = dict(d)
        pd = dict(d.pop("planner"))
        pd.pop("measure_fn", None)
        comm = pd.pop("comm", None)
        # absent key: a pre-v6 artifact — still loads
        pkb = pd.pop("kbench", None)
        planner = PlannerConfig(
            cost=CostModelConfig(**pd.pop("cost")),
            search=SearchConfig(**pd.pop("search")),
            comm=None if comm is None else CommConfig(**comm),
            kbench=None if pkb is None else KBenchConfig.from_dict(pkb), **pd)
        trainer = TrainerConfig(**d.pop("trainer"))
        data = d.pop("data", None)
        elastic = d.pop("elastic", None)
        # absent key: a pre-v4 (training-only) artifact — still loads
        serving = d.pop("serving", None)
        kbench = d.pop("kbench", None)
        # absent key: a pre-v7 artifact — still loads
        chaos = d.pop("chaos", None)
        # absent key: a pre-v8 artifact — still loads
        obs = d.pop("obs", None)
        return HarpConfig(
            planner=planner, trainer=trainer,
            data=None if data is None else DataConfig(**data),
            elastic=None if elastic is None else ControllerConfig(**elastic),
            serving=None if serving is None else ServingConfig(**serving),
            kbench=None if kbench is None else KBenchConfig.from_dict(kbench),
            chaos=None if chaos is None else ChaosConfig.from_dict(chaos),
            obs=None if obs is None else ObsConfig.from_dict(obs),
            **d)

    @staticmethod
    def from_json(s: str) -> "HarpConfig":
        return HarpConfig.from_dict(json.loads(s))
