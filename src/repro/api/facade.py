"""The one public entry point: ``compile(arch, cluster, config) -> Executable``.

Staged exactly like a compiler — every stage's artifact is inspectable and
JSON-serializable, so planning and execution can run on different machines:

    plan(arch, cluster, cfg)   -> Plan         (HAPT search + provenance)
    lower(plan)                -> LoweredPlan  (meshes, apportionment,
                                                schedule, collective plan)
    compile(arch, cluster, cfg) -> Executable  (both stages + .fit() /
                                                .simulate() / .describe() /
                                                .attach_elastic())

``fit`` is also exposed at module level for cluster-less local training (the
execution half without a planner run); ``Executable.fit`` delegates to it and
wires the elastic controller's telemetry hooks automatically.
"""
from __future__ import annotations

import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

from repro.comm.selector import collective_breakdown
from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.core.cluster import HeteroCluster, cluster_fingerprint
from repro.core.layering import Layer, build_layers
from repro.core.opgraph import build_op_sequence
from repro.core.pipesim import SimResult, simulate
from repro.core.planner import HAPTPlanner
from repro.core.strategy import IntraOpPlan, ParallelStrategy
from repro.data.pipeline import DataConfig
from repro.parallel.sharding import batch_shard_sizes, intra_op_mesh_axes
from repro.runtime.controller import (
    ControllerConfig, ElasticController, ReplanDecision,
)
from repro.runtime.events import EventTrace
from repro.runtime.replay import ReplayResult, run_replay, sync_priced_step
from repro.train.optimizer import OptimizerConfig
from repro.train.step import make_train_step
from repro.train.trainer import Trainer

from repro.api import registry
from repro.api.artifacts import (
    SCHEMA_VERSION, LoweredPlan, Plan, StageLowering, cluster_to_dict,
    sim_summary,
)
from repro.api.config import HarpConfig

_DEPRECATION_WARNED: set = set()


def warn_deprecated(key: str, message: str) -> None:
    """Warn-once deprecation shim used by the legacy call paths."""
    if key in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)


def _resolve_arch(arch: Union[str, ArchConfig]) -> ArchConfig:
    return get_config(arch) if isinstance(arch, str) else arch


def _build_layers(arch: ArchConfig, cfg: HarpConfig) -> List[Layer]:
    ops = build_op_sequence(arch, seq_len=cfg.seq_len)
    return build_layers(ops, cfg.planner.granularity, z=cfg.planner.z_heavy)


# ---------------------------------------------------------------------------
# Stage 1: plan
# ---------------------------------------------------------------------------


def plan(arch: Union[str, ArchConfig], cluster: HeteroCluster,
         config: Optional[HarpConfig] = None, *,
         verbose: bool = False) -> Plan:
    """Run the HAPT search and wrap the result with provenance.

    The returned :class:`Plan` is self-contained: it embeds the serialized
    cluster spec, the exact config, and the predicted step simulation under
    the *named* scheduler, so ``lower()``/``compile(plan=...)`` reproduce the
    same execution on any machine."""
    cfg = (config if config is not None else HarpConfig()).validate()
    arch_cfg = _resolve_arch(arch)
    strategy = HAPTPlanner(cluster, cfg.planner).plan(
        arch_cfg, seq_len=cfg.seq_len, global_batch=cfg.global_batch,
        verbose=verbose)
    sched = registry.resolve("scheduler", cfg.scheduler)
    counts = sched([s.t for s in strategy.stages], strategy.c_links,
                   strategy.n_microbatches)
    res = simulate([s.t_f for s in strategy.stages],
                   [s.t_b for s in strategy.stages],
                   strategy.c_links, strategy.n_microbatches, counts)
    serve = None
    if cfg.serving is not None:
        # the serving placement search reuses the training comm model (same
        # CommConfig knob) so KV handoffs are priced on the same tiered links
        # the planner saw; serving=None skips this branch entirely — the
        # off-state invariant (DESIGN.md §7)
        from repro.comm.selector import CommModel
        from repro.serving.placement import search_placement
        comm = CommModel(cluster, cfg.planner.comm)
        serve = search_placement(arch_cfg, cluster, cfg.serving, comm=comm,
                                 verbose=verbose).to_dict()
    return Plan(
        arch=arch_cfg.arch_id, strategy=strategy, config=cfg,
        cluster=cluster_to_dict(cluster),
        cluster_fingerprint=cluster_fingerprint(cluster),
        predicted=sim_summary(res, strategy.tokens_per_step()),
        serve=serve)


# ---------------------------------------------------------------------------
# Stage 2: lower
# ---------------------------------------------------------------------------


def _stage_intra_plan(s) -> IntraOpPlan:
    """The stage's intra-op plan, or the even degenerate one for strategies
    from the inter-op-only search (tp/dp still factorize the submesh)."""
    if s.intra_op is not None:
        return s.intra_op
    dp = max(1, s.dp)
    return IntraOpPlan(axis="data" if dp >= max(1, s.tp) else "tensor",
                       tp=max(1, s.tp), dp=dp,
                       shard_ratios=(1.0 / dp,) * dp,
                       comm_bytes=0.0, comm_time_f=0.0, comm_time_b=0.0)


def lower(plan_artifact: Plan, *,
          layers: Optional[Sequence[Layer]] = None) -> LoweredPlan:
    """Lower a :class:`Plan` to executable form: per-stage logical meshes
    (via ``parallel.sharding.intra_op_mesh_axes``), integer microbatch
    apportionment, warm-up counts from the config's named scheduler, and the
    collective plan (per-link activation bytes over the plan's layering)."""
    cfg = plan_artifact.config
    strategy = plan_artifact.strategy
    cluster = plan_artifact.to_cluster()
    arch_cfg = _resolve_arch(plan_artifact.arch)
    if layers is None:
        layers = _build_layers(arch_cfg, cfg)
    B = strategy.n_microbatches
    # exact by HarpConfig.validate() (global_batch % n_microbatches == 0)
    mb_samples = cfg.global_batch // B

    sched = registry.resolve("scheduler", cfg.scheduler)
    counts = [int(c) for c in
              sched([s.t for s in strategy.stages], strategy.c_links, B)]
    res = simulate([s.t_f for s in strategy.stages],
                   [s.t_b for s in strategy.stages],
                   strategy.c_links, B, counts)

    breakdown = collective_breakdown(strategy, cluster, layers)
    stages = []
    for i, s in enumerate(strategy.stages):
        io = _stage_intra_plan(s)
        axes = [[name, size] for name, size in intra_op_mesh_axes(io)]
        e = breakdown["stages"][i]
        stages.append(StageLowering(
            stage=i,
            subcluster=cluster.subclusters[s.cluster_idx].name,
            layer_start=s.layer_start, layer_end=s.layer_end,
            mesh_axes=axes, n_devices=s.n_devices,
            microbatch_shards=batch_shard_sizes(io, mb_samples),
            intra_comm_bytes=io.comm_bytes,
            intra_comm_time_s=io.comm_time,
            ar_algorithm=e["ar_algorithm"],
            sync_algorithm=e["sync_algorithm"],
            sync_compressed=e["sync_compressed"],
            sync_time_s=e["sync_time_s"],
            sync_link=e["sync_link"]))

    link_bytes = [
        layers[strategy.stages[i].layer_end - 1].act_out_bytes_per_token
        * strategy.mb_tokens
        for i in range(strategy.n_stages - 1)]

    return LoweredPlan(
        scheduler=cfg.scheduler, n_microbatches=B,
        microbatch_samples=mb_samples, warmup_counts=counts,
        c_links_s=[float(c) for c in strategy.c_links],
        link_bytes=link_bytes, stages=stages,
        est_step_time_s=res.makespan,
        link_ids=breakdown["link_ids"],
        link_occupancy_s=breakdown["link_occupancy_s"],
        contended_links=breakdown["contended_links"])


# ---------------------------------------------------------------------------
# Stage 3: Executable
# ---------------------------------------------------------------------------


class Executable:
    """A compiled (plan, lowering) pair bound to a concrete cluster.

    ``simulate()`` referee-prices the plan exactly like
    ``runtime.replay.sync_priced_step`` (amortized DP gradient sync charged
    identically to joint and inter-only plans), so numbers from the facade
    are comparable across search modes; ``simulate(priced=False)`` is the
    raw pipeline-DAG simulation of the lowered schedule."""

    def __init__(self, plan_artifact: Plan, lowered: LoweredPlan,
                 cluster: HeteroCluster, arch: ArchConfig,
                 layers: Sequence[Layer]):
        self.plan = plan_artifact
        self.lowered = lowered
        self.cluster = cluster
        self.arch = arch
        self.layers = list(layers)
        self.controller: Optional[ElasticController] = None
        self.drift_ledger = None    # obs.DriftLedger when config.obs is set
        #                             (wired by attach_elastic / fit)

    @property
    def strategy(self) -> ParallelStrategy:
        return self.plan.strategy

    @property
    def config(self) -> HarpConfig:
        return self.plan.config

    # -- inspection ----------------------------------------------------------

    def describe(self, *, timeline: bool = False, comm: bool = False) -> str:
        lines = [self.plan.describe(), self.lowered.describe()]
        if comm:
            lines.append(self.explain_comm())
        if timeline:
            # the ASCII timeline renders the same span model the Chrome
            # exporter serializes (obs.trace) — one source for both views
            from repro.obs import render_ascii
            lines.append(render_ascii(self.trace(decisions=False), width=100))
        return "\n".join(lines)

    def trace(self, out: Optional[str] = None, *, priced: bool = False,
              contention: bool = False, decisions: bool = True):
        """Lower this executable's one-step simulation into the typed span
        model (:class:`repro.obs.Trace`) — per-stage compute lanes with
        warmup/steady/cooldown phases, per-boundary comm lanes, link-busy
        counters — plus a controller-decision track when an elastic
        controller with decisions is attached.

        ``out`` additionally writes Chrome-trace JSON (load in Perfetto /
        ``chrome://tracing``).  Pure lowering of already-computed timing
        artifacts: nothing is re-simulated beyond the (memoized)
        ``simulate()`` call itself."""
        from repro.obs import (trace_from_decisions, trace_from_sim,
                               trace_to_chrome)
        res = self.simulate(priced=priced, contention=contention)
        tr = trace_from_sim(
            res, name=f"{self.plan.arch}"
                      f"@{self.plan.cluster_fingerprint[:8]}")
        tr.meta["arch"] = self.plan.arch
        tr.meta["priced"] = priced
        tr.meta["contention"] = contention
        if decisions and self.controller is not None \
                and self.controller.decisions:
            tr.extend(trace_from_decisions(self.controller.decisions))
        if out is not None:
            trace_to_chrome(tr, out)
        return tr

    def explain_comm(self) -> str:
        """Per-stage collective breakdown: selected algorithm, payload
        bytes, priced time, and the physical links each collective occupies
        (``ring*`` marks the legacy implicit flat ring of plans searched
        without a comm model)."""
        bd = collective_breakdown(self.strategy, self.cluster, self.layers)
        lines = ["collective breakdown (per stage):"]
        for e in bd["stages"]:
            ar = e["ar_algorithm"] or ("ring*" if e["ar_time_s"] > 0 else "-")
            sync = e["sync_algorithm"] or \
                ("ring*" if e["sync_time_s"] > 0 else "-")
            if e["sync_compressed"]:
                sync += "+int8"
            lines.append(
                f"  stage{e['stage']} [{e['subcluster']}] tp={e['tp']} "
                f"dp={e['dp']}: ar={ar} {e['ar_time_s'] * 1e3:.2f}ms/mb on "
                f"{e['ar_link']}; sync={sync} "
                f"{e['sync_time_s'] * 1e3:.2f}ms/step on {e['sync_link']}; "
                f"payload {e['comm_bytes'] / 1e6:.2f} MB/mb")
        if bd["link_ids"]:
            lines.append("  boundary links: " + ", ".join(
                f"{i}->{i + 1}:{l}" for i, l in enumerate(bd["link_ids"])))
        occ = ", ".join(f"{l}={t * 1e3:.1f}ms"
                        for l, t in sorted(bd["link_occupancy_s"].items()))
        lines.append(f"  link occupancy per step: {occ or 'none'}")
        lines.append("  contended links: "
                     + (", ".join(bd["contended_links"]) or "none"))
        return "\n".join(lines)

    def explain_costs(self) -> str:
        """Per-stage price provenance: measured (kbench table) vs analytic.

        Re-prices every stage at its chosen tp both ways; stages on devices
        the table covers show the measured anchor MFU next to the spec-sheet
        ``base_mfu`` and the analytic price they displaced.  Without
        ``config.kbench`` (or with an empty/uncovering table) every stage is
        analytic — the fallback never errors."""
        from repro.comm.selector import CommModel
        from repro.core.costmodel import Submesh, intra_op_candidates
        from repro.kbench.bridge import KBenchModel

        pcfg = self.config.planner
        kb = KBenchModel(pcfg.kbench) if pcfg.kbench is not None else None
        comm = CommModel(self.cluster, pcfg.comm) \
            if pcfg.comm is not None and pcfg.comm.enabled else None
        mb = self.strategy.mb_tokens
        lines = ["stage price provenance (per microbatch, f+b):"]
        for i, s in enumerate(self.strategy.stages):
            sub = self.cluster.subclusters[s.cluster_idx]
            mesh = Submesh(s.cluster_idx, s.mesh_n, s.mesh_m)
            joint = s.intra_op is not None
            stage_layers = self.layers[s.layer_start:s.layer_end]
            kw = dict(uneven=joint,
                      amortize_microbatches=pcfg.n_microbatches if joint else 0,
                      comm=comm)
            analytic = next(
                (c for c in intra_op_candidates(stage_layers, sub, mesh, mb,
                                                pcfg.cost, **kw)
                 if c.tp == s.tp), None)
            mfu = kb.measured_mfu(sub) if kb is not None else None
            tag = f"measured (mfu={mfu:.3f} vs base {sub.device.base_mfu:.3f})" \
                if mfu is not None else "analytic"
            line = (f"  stage{i} [{sub.name}] tp={s.tp} dp={s.dp}: "
                    f"t={(s.t_f + s.t_b) * 1e3:.2f}ms  source={tag}")
            if mfu is not None and analytic is not None:
                line += f"  (analytic would be {analytic.t * 1e3:.2f}ms)"
            lines.append(line)
        if kb is not None:
            lines.append("  " + kb.describe().replace("\n", "\n  "))
        else:
            lines.append("  kbench: off (analytic pricing everywhere)")
        return "\n".join(lines)

    # -- simulation ----------------------------------------------------------

    def sim_cache_stats(self) -> Dict[str, int]:
        """Counters of the process-wide pipesim memo (``core.pipesim``):
        repeated ``simulate()`` calls — warm elastic re-plans, repeated
        ``describe()``/``throughput()`` queries — are served from cache
        instead of re-solving the schedule."""
        from repro.core.pipesim import sim_memo_stats
        s = sim_memo_stats()
        return {"hits": s.hits, "misses": s.misses,
                "fast_path": s.fast_path, "graph_path": s.graph_path}

    def simulate(self, *, priced: bool = True,
                 no_overlap: bool = False,
                 contention: bool = False,
                 share_links: bool = True,
                 trace_out: Optional[str] = None) -> SimResult:
        """One-step discrete-event simulation, served from the pipesim memo
        on repeat signatures (treat the result as immutable).
        ``priced=True`` (default) is the referee accounting
        (== ``sync_priced_step``); ``priced=False`` simulates the lowered
        schedule as-is.

        ``contention=True`` runs the fair-share occupancy engine instead:
        stage boundaries are mapped to their *physical* links (every
        cluster-crossing boundary shares ``"wan"``) and each stage's
        per-step gradient sync becomes an explicit transfer released after
        its last backward — so overlapping activation sends and grad syncs
        slow each other down.  The sync is removed from the amortized
        backward time first (no double counting), making this directly
        comparable to ``priced=True``.  ``share_links=False`` keeps the
        explicit syncs but gives every transfer a private link — the
        uncontended baseline that isolates the *sharing* cost from the
        injected sync work.

        ``trace_out`` additionally writes the result as Chrome-trace JSON
        (``obs.trace_from_sim`` — the returned numbers are unchanged)."""
        if contention:
            if no_overlap:
                raise ValueError("contention=True is overlap-mode only")
            strat = self.strategy
            bd = collective_breakdown(strat, self.cluster, self.layers)
            t_b, sync_work = [], []
            for i, s in enumerate(strat.stages):
                amort = s.intra_op.sync_time if s.intra_op is not None else 0.0
                t_b.append(s.t_b - amort)
                e = bd["stages"][i]
                if e["sync_time_s"] > 0:
                    link = e["sync_link"] if share_links \
                        else f"__private_sync{i}"
                    sync_work.append((i, link, e["sync_time_s"]))
            res = simulate(
                [s.t_f for s in strat.stages], t_b, strat.c_links,
                strat.n_microbatches, self.lowered.warmup_counts,
                contention=True,
                link_ids=bd["link_ids"] if share_links else None,
                sync_work=sync_work)
        elif priced:
            res = sync_priced_step(
                self.strategy, self.cluster, self.layers,
                no_overlap=no_overlap,
                counts_fn=registry.resolve("scheduler",
                                           self.config.scheduler))
        else:
            strat = self.strategy
            res = simulate([s.t_f for s in strat.stages],
                           [s.t_b for s in strat.stages],
                           strat.c_links, strat.n_microbatches,
                           self.lowered.warmup_counts, no_overlap=no_overlap)
        if trace_out is not None:
            from repro.obs import trace_from_sim, trace_to_chrome
            trace_to_chrome(trace_from_sim(res, name=self.plan.arch),
                            trace_out)
        return res

    def throughput(self, *, priced: bool = True) -> float:
        res = self.simulate(priced=priced)
        return self.strategy.tokens_per_step() / res.makespan

    def stage_mesh(self, stage: int, devices=None):
        """Materialize stage ``stage``'s logical mesh as a jax ``Mesh``
        (see ``parallel.sharding.mesh_from_intra_op`` for the device-order
        contract on uneven plans)."""
        from repro.parallel.sharding import mesh_from_intra_op
        return mesh_from_intra_op(
            _stage_intra_plan(self.strategy.stages[stage]), devices)

    # -- elastic runtime -----------------------------------------------------

    def attach_elastic(self, controller_cfg: Optional[ControllerConfig] = None,
                       telemetry=None) -> ElasticController:
        """Wire an :class:`ElasticController` around this executable, seeded
        with the compiled plan (no bootstrap re-search).  The controller's
        trainer hooks are then wired automatically by :meth:`fit`.

        Workload fields of a supplied ``ControllerConfig`` that are still at
        their class defaults are backfilled from this executable's config
        (so ``ControllerConfig(drift_threshold=0.1)`` tweaks one knob
        without re-stating the workload); an explicitly different workload
        raises — the controller would replan for the wrong shape."""
        import dataclasses

        cfg = self.config
        ccfg = controller_cfg or cfg.elastic or ControllerConfig(
            total_steps=cfg.trainer.total_steps, seq_len=cfg.seq_len,
            global_batch=cfg.global_batch)
        d = ControllerConfig()
        fill = {}
        for fld, want in (("seq_len", cfg.seq_len),
                          ("global_batch", cfg.global_batch),
                          ("total_steps", cfg.trainer.total_steps)):
            have = getattr(ccfg, fld)
            if have == getattr(d, fld) and have != want:
                fill[fld] = want
            elif fld != "total_steps" and have != want:
                raise ValueError(
                    f"attach_elastic: controller {fld}={have} disagrees "
                    f"with the compiled plan's {fld}={want}")
        if fill:
            ccfg = dataclasses.replace(ccfg, **fill)
        # chaos wiring (schema v7): a FaultInjector when cfg.chaos is set,
        # and the serving config so pool-structure changes re-run the
        # serving placement through the hardened path.  chaos=None and
        # serving=None leave both hooks off — the off-state invariant.
        injector = None
        if cfg.chaos is not None:
            from repro.chaos.inject import FaultInjector
            injector = FaultInjector(cfg.chaos)
        ctrl = ElasticController(self.cluster, self.arch,
                                 planner_cfg=cfg.planner, cfg=ccfg,
                                 telemetry=telemetry, injector=injector,
                                 serving_cfg=cfg.serving)
        if self.plan.serve is not None:
            from repro.serving.placement import ServePlan
            ctrl.serve_plan = ServePlan.from_dict(self.plan.serve)
        # seed with a copy — the controller retunes its strategy in place,
        # which must not mutate the immutable Plan artifact
        ctrl.strategy = ParallelStrategy.from_json(self.strategy.to_json())
        ctrl.plan_cluster = self.cluster
        # seeding from a compiled plan IS a successful bootstrap — the
        # degraded ladder's never-raise guarantee starts here
        ctrl._bootstrapped = True
        ctrl.decisions.append(ReplanDecision(
            step=0, action="none", reason="seeded from compiled plan",
            step_time_after=ctrl.strategy.est_step_time))
        # obs wiring (schema v8): a record-only drift ledger holding the
        # compiled plan's prediction to account.  obs=None leaves the hook
        # off — and even when wired it never alters a controller decision.
        if cfg.obs is not None:
            ledger = cfg.obs.ledger()
            ledger.register_plan(self.plan.predicted,
                                 stage_pools=self._stage_pools())
            ctrl.drift_ledger = ledger
            self.drift_ledger = ledger
        self.controller = ctrl
        return ctrl

    def _stage_pools(self) -> Dict[int, str]:
        """stage index -> sub-cluster (pool) name, for per-pool drift."""
        return {i: self.cluster.subclusters[s.cluster_idx].name
                for i, s in enumerate(self.strategy.stages)}

    def drift_report(self):
        """The attached drift ledger's current :class:`obs.DriftReport`
        (predicted vs observed step times; needs ``config.obs`` and an
        ``attach_elastic()``/``fit()`` that observed steps)."""
        if self.drift_ledger is None:
            raise ValueError(
                "no drift ledger — set HarpConfig.obs and attach_elastic() "
                "or fit() first")
        return self.drift_ledger.report()

    def replay(self, trace: Union[str, EventTrace], n_steps: int, *,
               elastic: bool = True, trace_out: Optional[str] = None,
               **trace_kw) -> ReplayResult:
        """Replay a fleet-dynamics trace against this executable.  ``trace``
        is an :class:`EventTrace` or a registered event-source name
        (``"paper"``, ``"random"``, ...); elastic mode routes events through
        the attached (or newly attached) controller, static mode keeps the
        compiled plan and stalls through infeasible periods.

        With ``config.obs.run_log`` set, every step and controller decision
        is appended to the JSONL run-log on the replay's own wall clock.
        ``trace_out`` writes a Chrome trace: the pipeline lanes of the
        compiled plan plus a controller-decision track with one span per
        :class:`ReplanDecision`, placed at its replay wall time."""
        if isinstance(trace, str):
            trace = registry.resolve("event_source", trace)(
                self.cluster, n_steps, **trace_kw)
        sink = None
        obs_cfg = self.config.obs
        if obs_cfg is not None and obs_cfg.run_log:
            from repro.obs import RunLog
            sink = RunLog(obs_cfg.run_log)
        try:
            if elastic:
                ctrl = self.controller or self.attach_elastic()
                result = run_replay(trace, n_steps, controller=ctrl,
                                    sink=sink)
            else:
                result = run_replay(trace, n_steps, strategy=self.strategy,
                                    plan_cluster=self.cluster,
                                    layers=self.layers, sink=sink)
        finally:
            if sink is not None:
                sink.close()
        if trace_out is not None:
            from repro.obs import (trace_from_decisions, trace_from_sim,
                                   trace_to_chrome)
            tr = trace_from_sim(self.simulate(priced=False),
                                name=f"{self.plan.arch} replay")
            if result.decisions:
                # decision spans on the replay wall clock: each decision at
                # the wall where its step landed (step index when stalled
                # before the first sample)
                wall = {s.step: s.wall_s for s in result.samples}
                tr.extend(trace_from_decisions(result.decisions,
                                               wall_times=wall))
            tr.meta["tokens_total"] = result.tokens_total
            tr.meta["wall_total_s"] = result.wall_total_s
            tr.meta["stalled_steps"] = result.stalled_steps
            trace_to_chrome(tr, trace_out)
        return result

    def migrate_to(self, target: Union["Executable", Plan, HeteroCluster], *,
                   opt_bytes_per_param: float = 2.0,
                   restore_bw: Optional[float] = None,
                   overlap: bool = True,
                   verbose: bool = False) -> "Executable":
        """Plan the live move of this executable's state onto ``target``.

        ``target`` is a new fleet (a fresh HAPT search runs on it), or an
        already-planned :class:`Plan`/:class:`Executable`.  The exact
        per-device byte layouts of both plans are diffed
        (``repro.migrate``): only *moved* bytes ship, each from the nearest
        surviving replica (or the checkpoint when no replica survived a
        shrink), priced through the comm topology's tiered links overlapped
        with this plan's drain.  Returns the target compiled as a new
        :class:`Executable` whose ``plan.migration`` section carries the
        full priced transfer summary (schema v5)."""
        import dataclasses as _dc

        from repro.migrate import (
            DEFAULT_RESTORE_BW, diff_layouts, layout_from_strategy,
            lost_devices, price_migration,
        )

        if isinstance(target, Executable):
            new_plan, new_cluster = target.plan, target.cluster
        elif isinstance(target, Plan):
            new_plan, new_cluster = target, target.to_cluster()
        elif isinstance(target, HeteroCluster):
            new_plan, new_cluster = plan(self.arch, target, self.config,
                                         verbose=verbose), target
        else:
            raise TypeError(
                f"migrate_to() takes an Executable, Plan, or HeteroCluster, "
                f"not {type(target).__name__}")
        if new_plan.arch != self.plan.arch:
            raise ValueError(
                f"migrate_to(): cannot migrate {self.plan.arch} state onto "
                f"a {new_plan.arch} plan")
        for fld in ("seq_len",):
            if getattr(new_plan.config, fld) != getattr(self.config, fld):
                raise ValueError(f"migrate_to(): target plan's {fld} differs "
                                 f"— state layouts would not correspond")
        for fld in ("granularity", "z_heavy"):
            if getattr(new_plan.config.planner, fld) != \
                    getattr(self.config.planner, fld):
                raise ValueError(
                    f"migrate_to(): target plan's layering ({fld}) differs — "
                    f"leaf-to-leaf correspondence needs the same layering")

        old_lay = layout_from_strategy(
            self.strategy, self.cluster, self.layers,
            opt_bytes_per_param=opt_bytes_per_param)
        new_lay = layout_from_strategy(
            new_plan.strategy, new_cluster, self.layers,
            opt_bytes_per_param=opt_bytes_per_param)
        lost = lost_devices(self.cluster, new_cluster)
        mplan = diff_layouts(old_lay, new_lay, lost=lost)
        cost = price_migration(
            mplan, old_lay, new_cluster,
            old_strategy=self.strategy, old_cluster=self.cluster,
            layers=self.layers,
            restore_bw=restore_bw if restore_bw is not None
            else DEFAULT_RESTORE_BW,
            overlap=overlap)
        migration = {
            "from_fingerprint": self.plan.cluster_fingerprint,
            "to_fingerprint": new_plan.cluster_fingerprint,
            "moved_bytes": int(mplan.moved_bytes),
            "ckpt_bytes": int(mplan.ckpt_bytes),
            "local_bytes": int(mplan.local_bytes),
            "total_bytes": int(mplan.total_bytes),
            "n_transfers": int(mplan.n_transfers),
            "link_bytes": {k: int(v) for k, v in
                           sorted(cost.link_bytes.items())},
            "serial_s": float(cost.serial_s),
            "drain_s": float(cost.drain_s),
            "downtime_s": float(cost.downtime_s),
            "overlapped": bool(cost.overlapped),
        }
        stamped = _dc.replace(new_plan, migration=migration,
                              version=SCHEMA_VERSION)
        return compile(cluster=new_cluster, plan_artifact=stamped)

    # -- serving -------------------------------------------------------------

    def serve_simulate(self, trace=None, *, qps: Optional[float] = None,
                       duration_s: Optional[float] = None,
                       seed: Optional[int] = None,
                       trace_out: Optional[str] = None):
        """Replay a request trace through this plan's serving placement
        (the event-driven continuous-batching simulator,
        :func:`repro.serving.batching.simulate_trace`).

        ``trace`` is a :class:`~repro.serving.workload.ServeTrace` (remapped
        to ``qps`` when given); without one, a Poisson trace is drawn from
        the compiled :class:`ServingConfig` with any of ``qps`` /
        ``duration_s`` / ``seed`` overridden.  Requires the plan to have been
        compiled with ``config.serving`` set."""
        if self.plan.serve is None:
            raise ValueError(
                "serve_simulate() needs a serving plan — compile with "
                "HarpConfig(serving=ServingConfig(...)) first")
        from repro.serving.batching import simulate_trace
        from repro.serving.placement import ServePlan
        from repro.serving.workload import poisson_trace
        splan = ServePlan.from_dict(self.plan.serve)
        scfg = self.config.serving
        if trace is None:
            trace = poisson_trace(
                qps if qps is not None else scfg.qps,
                duration_s if duration_s is not None else scfg.duration_s,
                seed=seed if seed is not None else scfg.seed,
                prompt_mean=scfg.prompt_mean, output_mean=scfg.output_mean)
        elif qps is not None:
            trace = trace.remapped(qps)
        if trace_out is None:
            return simulate_trace(splan, trace)
        # record dispatches and lower them to per-pool Chrome-trace lanes
        # on the simulator's event-heap clock (timestamps never wall time)
        from repro.obs import trace_from_serve, trace_to_chrome
        recorder: List = []
        res = simulate_trace(splan, trace, recorder=recorder)
        tr = trace_from_serve(recorder, name=f"{self.plan.arch} serving")
        tr.meta["n_completed"] = res.n_completed
        tr.meta["n_rejected"] = res.n_rejected
        tr.meta["n_handoffs"] = res.n_handoffs
        trace_to_chrome(tr, trace_out)
        return res

    # -- training ------------------------------------------------------------

    def fit(self, **kwargs) -> Dict[str, Any]:
        """Train under this executable's config.  An attached elastic
        controller's telemetry hooks are wired in unless the caller passes
        explicit hooks.

        With ``config.obs`` set, measured step times also feed the drift
        ledger (unless an attached controller already does) and, when
        ``obs.run_log`` names a path, a JSONL run-log on the trainer's own
        clock — record-only, the training loop is unchanged."""
        if self.controller is not None:
            kwargs.setdefault("on_step_time", self.controller.on_step_time)
            kwargs.setdefault("on_straggler", self.controller.on_straggler)
        obs_cfg = self.config.obs
        if obs_cfg is None:
            return fit(self.arch, self.config, **kwargs)
        if self.drift_ledger is None:
            self.drift_ledger = obs_cfg.ledger()
            self.drift_ledger.register_plan(self.plan.predicted,
                                            stage_pools=self._stage_pools())
        ledger = self.drift_ledger
        # an attached controller feeds the ledger from its own hook;
        # feeding it here too would double-count every step
        feed_ledger = self.controller is None \
            or getattr(self.controller, "drift_ledger", None) is not ledger
        sink = None
        if obs_cfg.run_log:
            from repro.obs import RunLog
            sink = RunLog(obs_cfg.run_log)
        inner = kwargs.get("on_step_time")
        t_acc = [0.0]   # trainer-clock seconds, never time.time()

        def on_step_time(step, step_time, *a, **kw):
            t_acc[0] += step_time
            if feed_ledger:
                ledger.observe_step(step, step_time)
            if sink is not None:
                sink.emit("step", t_acc[0], step=step,
                          step_time_s=step_time)
            if inner is not None:
                return inner(step, step_time, *a, **kw)
            return None

        kwargs["on_step_time"] = on_step_time
        try:
            return fit(self.arch, self.config, **kwargs)
        finally:
            if sink is not None:
                sink.close()


# ---------------------------------------------------------------------------
# The facade
# ---------------------------------------------------------------------------


def compile(arch: Union[str, ArchConfig, None] = None,
            cluster: Optional[HeteroCluster] = None,
            config: Optional[HarpConfig] = None, *,
            plan_artifact: Optional[Plan] = None,
            verbose: bool = False) -> Executable:
    """Plan -> lower -> executable, in one call.

    Either pass ``(arch, cluster[, config])`` to search from scratch, or
    ``plan_artifact=Plan.from_json(...)`` to lower a previously-searched plan
    (optionally overriding ``cluster`` with the live fleet; a fingerprint
    mismatch warns — the plan was priced for a different fleet)."""
    if plan_artifact is None:
        if arch is None or cluster is None:
            raise TypeError("compile() needs (arch, cluster) or plan_artifact")
        plan_artifact = plan(arch, cluster, config, verbose=verbose)
    if cluster is None:
        cluster = plan_artifact.to_cluster()
    elif cluster_fingerprint(cluster) != plan_artifact.cluster_fingerprint:
        warnings.warn(
            "compile(): cluster fingerprint differs from the plan's — the "
            "strategy was priced for a different fleet; predicted times are "
            "not transferable (attach_elastic() to replan on drift)",
            stacklevel=2)
    arch_cfg = _resolve_arch(plan_artifact.arch)
    layers = _build_layers(arch_cfg, plan_artifact.config)
    lowered = lower(plan_artifact, layers=layers)
    return Executable(plan_artifact, lowered, cluster, arch_cfg, layers)


def fit(arch: Union[str, ArchConfig],
        config: Optional[HarpConfig] = None, *,
        train_step: Optional[Callable] = None,
        state: Optional[Dict[str, Any]] = None,
        data_cfg: Optional[DataConfig] = None,
        optimizer: Optional[OptimizerConfig] = None,
        n_microbatches: int = 1,
        on_step_time: Optional[Callable] = None,
        on_straggler: Optional[Callable] = None,
        log_fn: Callable = print,
        clock: Optional[Callable[[], float]] = None,
        start_step: Optional[int] = None,
        seed: int = 0,
        jit: bool = True) -> Dict[str, Any]:
    """The execution half of the pipeline: config -> model -> optimizer ->
    fault-tolerant :class:`~repro.train.trainer.Trainer` loop.

    Pass ``train_step`` + ``state`` to run a custom step function (toy
    models, synthetic clocks); otherwise the arch's model and an AdamW
    optimizer are built.  ``config.data`` (or a ``DataConfig`` derived from
    the arch) feeds the deterministic synthetic pipeline."""
    import jax

    cfg = config if config is not None else HarpConfig()
    arch_cfg = _resolve_arch(arch)
    if train_step is None:
        opt_cfg = optimizer or OptimizerConfig(
            warmup_steps=min(20, cfg.trainer.total_steps),
            total_steps=cfg.trainer.total_steps)
        step_fn, model, opt_init = make_train_step(
            arch_cfg, opt_cfg, n_microbatches=n_microbatches)
        params = model.init(jax.random.PRNGKey(seed))
        state = {"params": params, "opt_state": opt_init(params)}
        if jit:
            step_fn = jax.jit(step_fn)
    else:
        if state is None:
            raise TypeError("fit(train_step=...) also needs state=...")
        step_fn = train_step
    data = data_cfg or cfg.data or DataConfig(
        vocab_size=arch_cfg.vocab_size, seq_len=cfg.seq_len,
        global_batch=cfg.global_batch, seed=seed)
    trainer = Trainer(cfg.trainer, data, step_fn, state,
                      on_straggler=on_straggler, on_step_time=on_step_time,
                      log_fn=log_fn,
                      clock=clock if clock is not None else time.perf_counter)
    return trainer.run(start_step)


def generate(arch: Union[str, ArchConfig], *,
             batch: int = 4, prompt_len: int = 32, gen_tokens: int = 32,
             seed: int = 0, greedy: bool = True, temperature: float = 1.0,
             use_pallas: bool = False, reduced: bool = False,
             log_fn: Optional[Callable] = None) -> Dict[str, Any]:
    """The serving half of the pipeline on one host: prefill a synthetic
    prompt batch, then batched decode through
    :func:`repro.serve.step.make_serve_step` (greedy argmax or
    temperature sampling with a threaded PRNG key).

    Returns ``{"tokens": (B, gen_tokens) int array, "prefill_s",
    "decode_s", "decode_tokens_per_s"}``.  The first generated token comes
    from the prefill logits — cache layouts are identical to
    ``decode_step``'s, which is what ``tests/test_serving.py`` pins."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import ShapeSpec
    from repro.models.prefill import prefill
    from repro.serve.step import make_serve_step

    cfg = _resolve_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    total = prompt_len + gen_tokens
    shape = ShapeSpec("generate", total, batch, "decode")
    serve_step, model, _rules = make_serve_step(
        cfg, shape=shape, use_pallas=use_pallas, greedy=greedy,
        temperature=temperature)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    feed = {"tokens": jax.random.randint(
        rng, (batch, prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        feed["image_embeds"] = 0.02 * jax.random.normal(
            rng, (batch, cfg.n_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        feed["frames"] = 0.02 * jax.random.normal(
            rng, (batch, cfg.enc_frames, cfg.d_model))

    t0 = time.perf_counter()
    last_logits, cache = jax.jit(
        lambda p, b: prefill(cfg, p, b, cache_len=total,
                             use_pallas=use_pallas))(params, feed)
    jax.block_until_ready(last_logits)
    prefill_s = time.perf_counter() - t0
    if log_fn:
        log_fn(f"[serve] prefill {batch}x{prompt_len} ({cfg.arch_id}): "
               f"{prefill_s * 1e3:.0f} ms")

    step = jax.jit(serve_step)
    if greedy:
        tok = jnp.argmax(last_logits[:, -1:], axis=-1).astype(jnp.int32)
    else:
        rng, sub = jax.random.split(rng)
        tok = jax.random.categorical(
            sub, last_logits[:, -1, :].astype(jnp.float32) / temperature,
            axis=-1)[:, None].astype(jnp.int32)
    toks = [np.asarray(tok)]
    t0 = time.perf_counter()
    # the prefill logits supplied token 1; decode the remaining gen_tokens-1
    for t in range(prompt_len, prompt_len + gen_tokens - 1):
        if greedy:
            tok, cache = step(params, cache, tok, jnp.int32(t))
        else:
            rng, sub = jax.random.split(rng)
            tok, cache = step(params, cache, tok, jnp.int32(t), sub)
        toks.append(np.asarray(tok))
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    n_decoded = batch * (gen_tokens - 1)
    tps = n_decoded / decode_s if decode_s > 0 else 0.0
    if log_fn:
        log_fn(f"[serve] {gen_tokens} tokens x {batch} seqs in "
               f"{decode_s * 1e3:.0f} ms ({tps:.0f} tok/s "
               f"{'greedy' if greedy else f'T={temperature}'})")
    return {"tokens": np.concatenate(toks, axis=1),
            "prefill_s": prefill_s, "decode_s": decode_s,
            "decode_tokens_per_s": tps}
