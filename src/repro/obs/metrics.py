"""Process-local metrics registry: counters / gauges / histograms with
labels, deterministic snapshots, and shims over the stack's pre-existing
scattered counters.

The registry is intentionally tiny and dependency-free (the planner stays
numpy-only; nothing here imports jax).  Series are keyed
``(name, sorted(label items))`` and snapshots render as
``name{k=v,...}`` in sorted order — two runs that record the same values
produce byte-identical snapshot dicts.

Back-compat shims (the old surfaces keep working; ``obs.metrics`` *reads*
them): :func:`sync_from_sim_memo` mirrors ``pipesim.sim_memo_stats()``
into ``sim_memo.*`` gauges, :func:`sync_from_injector` mirrors a chaos
``FaultInjector.stats()`` into ``chaos.*``, and
:func:`record_decision` folds one ``ReplanDecision`` into
``controller.*`` counters.  ``checkpoint/ckpt.py`` increments
``ckpt.bytes_written`` on the default registry at every save.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

_Key = Tuple[str, Tuple[Tuple[str, Any], ...]]


def _key(name: str, labels: Dict[str, Any]) -> _Key:
    return (name, tuple(sorted(labels.items())))


def _render(key: _Key) -> str:
    name, labels = key
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Counters (monotone), gauges (last value), histograms (count / sum /
    min / max).  ``snapshot()`` is a plain JSON-safe dict with
    deterministically ordered keys; ``reset()`` clears everything."""

    def __init__(self) -> None:
        self._counters: Dict[_Key, float] = {}
        self._gauges: Dict[_Key, float] = {}
        self._hists: Dict[_Key, Dict[str, float]] = {}

    def inc(self, name: str, value: float = 1, **labels: Any) -> None:
        k = _key(name, labels)
        self._counters[k] = self._counters.get(k, 0) + value

    def gauge(self, name: str, value: float, **labels: Any) -> None:
        self._gauges[_key(name, labels)] = value

    def observe(self, name: str, value: float, **labels: Any) -> None:
        k = _key(name, labels)
        h = self._hists.get(k)
        if h is None:
            h = self._hists[k] = {"count": 0, "sum": 0.0,
                                  "min": value, "max": value}
        h["count"] += 1
        h["sum"] += value
        h["min"] = min(h["min"], value)
        h["max"] = max(h["max"], value)

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "counters": {_render(k): self._counters[k]
                         for k in sorted(self._counters)},
            "gauges": {_render(k): self._gauges[k]
                       for k in sorted(self._gauges)},
            "histograms": {_render(k): dict(self._hists[k])
                           for k in sorted(self._hists)},
        }
        return out

    def reset(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()


DEFAULT_REGISTRY = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return DEFAULT_REGISTRY


# ---------------------------------------------------------------------------
# Shims over pre-existing counters
# ---------------------------------------------------------------------------


def sync_from_sim_memo(reg: Optional[MetricsRegistry] = None
                       ) -> MetricsRegistry:
    """Mirror the live ``pipesim.sim_memo_stats()`` counters into
    ``sim_memo.*`` gauges (the memo predates this registry and keeps its
    own counters; this reads, never resets)."""
    from repro.core.pipesim import sim_memo_stats

    reg = reg if reg is not None else DEFAULT_REGISTRY
    s = sim_memo_stats()
    reg.gauge("sim_memo.hits", s.hits)
    reg.gauge("sim_memo.misses", s.misses)
    reg.gauge("sim_memo.fast_path", s.fast_path)
    reg.gauge("sim_memo.graph_path", s.graph_path)
    reg.gauge("sim_memo.contended_path", s.contended_path)
    return reg


def sync_from_injector(injector, reg: Optional[MetricsRegistry] = None
                       ) -> MetricsRegistry:
    """Mirror a chaos ``FaultInjector.stats()`` dict into ``chaos.<seam>``
    gauges."""
    reg = reg if reg is not None else DEFAULT_REGISTRY
    for seam, n in sorted(injector.stats().items()):
        reg.gauge("chaos.draws", n, seam=seam)
    return reg


def record_decision(d, reg: Optional[MetricsRegistry] = None
                    ) -> MetricsRegistry:
    """Fold one ``ReplanDecision`` into ``controller.*``: per-action
    counts, coalesced folds, downtime / search / migration seconds."""
    reg = reg if reg is not None else DEFAULT_REGISTRY
    reg.inc("controller.decisions", action=d.action)
    if d.coalesced:
        reg.inc("controller.coalesced", d.coalesced)
    reg.observe("controller.downtime_s", d.downtime_s)
    if d.search_time_s:
        reg.observe("controller.search_time_s", d.search_time_s)
    if d.migration_s:
        reg.observe("controller.migration_s", d.migration_s)
    if d.migration_bytes:
        reg.inc("controller.migration_bytes", d.migration_bytes)
    return reg


def record_serve_result(res, reg: Optional[MetricsRegistry] = None
                        ) -> MetricsRegistry:
    """Fold a ``ServeSimResult`` into ``serve.*`` (kv_violations — always 0
    by construction — rejections, handoffs, per-pool busy seconds)."""
    reg = reg if reg is not None else DEFAULT_REGISTRY
    reg.inc("serve.kv_violations", res.kv_violations)
    reg.inc("serve.rejected", res.n_rejected)
    reg.inc("serve.completed", res.n_completed)
    reg.inc("serve.handoffs", res.n_handoffs)
    reg.inc("serve.handoff_bytes", res.handoff_bytes)
    for pool, busy in sorted(res.pool_busy_s.items()):
        reg.gauge("serve.busy_s", busy["prefill"], pool=pool, kind="prefill")
        reg.gauge("serve.busy_s", busy["decode"], pool=pool, kind="decode")
    return reg
