"""Structured JSONL run-log: one event per line, schema-versioned,
sim-clock-only timestamps.

Every line is ``{"schema": 1, "kind": ..., "t": <seconds>, ...}`` with
sorted keys.  The invariant that makes run-logs diffable across machines
and regression-testable in CI: ``t`` always comes from the *producing
clock* — the replay harness's accumulated wall, the serving simulator's
event-heap time, the trainer's injected (and in tests synthetic) clock —
never from ``time.time()``.  Identical runs write byte-identical logs.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterator, List, Optional, TextIO, Union

SINK_SCHEMA = 1


class RunLog:
    """Append-only JSONL writer (context manager).  Pass a path or an open
    text file object (the latter is not closed on exit)."""

    def __init__(self, target: Union[str, TextIO]):
        if isinstance(target, str):
            self._f: TextIO = open(target, "w")
            self._owned = True
        else:
            self._f = target
            self._owned = False
        self.n_events = 0

    def emit(self, kind: str, t: float, **fields: Any) -> Dict[str, Any]:
        ev = {"schema": SINK_SCHEMA, "kind": str(kind), "t": float(t)}
        ev.update(fields)
        self._f.write(json.dumps(ev, sort_keys=True) + "\n")
        self.n_events += 1
        return ev

    def close(self) -> None:
        if self._owned and not self._f.closed:
            self._f.close()

    def __enter__(self) -> "RunLog":
        return self

    def __exit__(self, *exc) -> Optional[bool]:
        self.close()
        return None


def read_runlog(path: str) -> List[Dict[str, Any]]:
    """Load a run-log back; raises ValueError on an event from an unknown
    (newer) schema."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            ev = json.loads(line)
            if ev.get("schema", 0) > SINK_SCHEMA:
                raise ValueError(
                    f"run-log event schema {ev.get('schema')} is newer than "
                    f"supported {SINK_SCHEMA}")
            out.append(ev)
    return out


def iter_kind(events: List[Dict[str, Any]], kind: str
              ) -> Iterator[Dict[str, Any]]:
    return (e for e in events if e.get("kind") == kind)
