"""repro.obs — unified tracing, metrics, and predicted-vs-observed drift
accounting across the planner / runtime / serving stack.

Four pieces (see docs/observability.md):

- :mod:`repro.obs.trace` — typed span model + Chrome-trace/Perfetto
  exporter + adapters over every existing timing artifact (pipesim,
  netsim, migration pricing, serving dispatch, controller decisions);
- :mod:`repro.obs.metrics` — process-local labeled metrics registry with
  deterministic snapshots + shims over the stack's scattered counters;
- :mod:`repro.obs.drift` — predicted-vs-observed ledger and
  :class:`DriftReport` (per-step / per-stage / per-pool relative error);
- :mod:`repro.obs.sink` — schema-versioned JSONL run-log on the sim clock.

``HarpConfig.obs = ObsConfig(...)`` wires it through the facade
(``Executable.trace()``, ``trace_out=`` on simulate/replay/serve_simulate,
drift ledger on the elastic controller); ``obs=None`` (the default) is
bit-identical to the pre-obs stack — pinned in tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

from repro.obs.drift import DriftLedger, DriftReport
from repro.obs.metrics import (DEFAULT_REGISTRY, MetricsRegistry,
                               default_registry, record_decision,
                               record_serve_result, sync_from_injector,
                               sync_from_sim_memo)
from repro.obs.sink import SINK_SCHEMA, RunLog, iter_kind, read_runlog
from repro.obs.trace import (OBS_TRACE_SCHEMA, Counter, Span, Trace,
                             render_ascii, trace_from_decisions,
                             trace_from_migration, trace_from_netsim,
                             trace_from_serve, trace_from_sim,
                             trace_to_chrome)


@dataclass
class ObsConfig:
    """Observability knobs.  All output is opt-in per call site
    (``trace_out=`` / ``run_log``); attaching the config alone never writes
    a file and never changes planning or runtime behavior."""
    run_log: Optional[str] = None       # JSONL run-log path (replay/fit)
    drift_threshold: float = 0.15       # |rel error| that flags a report
    drift_window: int = 8               # observed steps per report window

    def to_dict(self) -> Dict[str, Any]:
        return {"run_log": self.run_log,
                "drift_threshold": self.drift_threshold,
                "drift_window": self.drift_window}

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "ObsConfig":
        return ObsConfig(
            run_log=d.get("run_log"),
            drift_threshold=d.get("drift_threshold", 0.15),
            drift_window=d.get("drift_window", 8))

    def ledger(self) -> DriftLedger:
        return DriftLedger(threshold=self.drift_threshold,
                           window=self.drift_window)


__all__ = [
    "ObsConfig",
    "OBS_TRACE_SCHEMA", "Span", "Counter", "Trace", "trace_to_chrome",
    "render_ascii", "trace_from_sim", "trace_from_netsim",
    "trace_from_migration", "trace_from_serve", "trace_from_decisions",
    "MetricsRegistry", "DEFAULT_REGISTRY", "default_registry",
    "sync_from_sim_memo", "sync_from_injector", "record_decision",
    "record_serve_result",
    "DriftLedger", "DriftReport",
    "SINK_SCHEMA", "RunLog", "read_runlog", "iter_kind",
]
