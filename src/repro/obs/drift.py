"""Predicted-vs-observed drift ledger.

Every committed plan carries a predicted ``SimResult`` digest
(``artifacts.sim_summary``); the trainer / replay harness observes actual
step times.  This module holds both sides to account and answers the one
question the EWMA calibrator alone can't: *how wrong was the plan*, per
step, per stage, per pool — the planner-accuracy evidence HAP / Poplar
lean on to validate their cost models.

- :class:`DriftLedger` — ``register_plan`` the prediction, ``observe_step``
  each measured step (optionally with per-stage times), ``report()`` the
  relative errors over a sliding window;
- :class:`DriftReport` — JSON-serializable: overall / per-stage / per-pool
  ``(observed - predicted) / predicted``, flagged when ``|error|`` exceeds
  the threshold.  The controller's drift-replan path keys off the same
  threshold, so a flagged report and a replan trigger agree by
  construction.

Pure arithmetic on caller-supplied samples — no clocks, no simulation:
feeding identical samples yields identical reports.
"""
from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple


def _rel(observed: float, predicted: float) -> float:
    if predicted <= 0:
        return 0.0
    return (observed - predicted) / predicted


@dataclass
class DriftReport:
    """One windowed accounting of prediction error."""
    predicted_step_s: float
    observed_step_s: float          # mean over the window
    rel_error: float                # (observed - predicted) / predicted
    threshold: float
    window: int
    n_samples: int                  # samples in the window
    n_observed: int                 # samples ever observed
    flagged: bool
    per_stage: Dict[int, float] = field(default_factory=dict)
    per_pool: Dict[str, float] = field(default_factory=dict)
    flagged_stages: List[int] = field(default_factory=list)
    flagged_pools: List[str] = field(default_factory=list)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "predicted_step_s": self.predicted_step_s,
            "observed_step_s": self.observed_step_s,
            "rel_error": self.rel_error,
            "threshold": self.threshold,
            "window": self.window,
            "n_samples": self.n_samples,
            "n_observed": self.n_observed,
            "flagged": self.flagged,
            "per_stage": {str(k): v for k, v in sorted(self.per_stage.items())},
            "per_pool": {k: self.per_pool[k] for k in sorted(self.per_pool)},
            "flagged_stages": list(self.flagged_stages),
            "flagged_pools": list(self.flagged_pools),
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def describe(self) -> str:
        flag = "DRIFT" if self.flagged else "ok"
        pools = ", ".join(f"{p}={e:+.1%}"
                          for p, e in sorted(self.per_pool.items()))
        return (f"[{flag}] step {self.observed_step_s:.4f}s vs predicted "
                f"{self.predicted_step_s:.4f}s ({self.rel_error:+.1%}, "
                f"|thr| {self.threshold:.0%}, n={self.n_samples}"
                + (f"; {pools}" if pools else "") + ")")


class DriftLedger:
    """Sliding-window predicted-vs-observed accounting (module docstring).

    ``stage_pools`` (stage index -> pool/sub-cluster name) lets per-stage
    errors aggregate into per-pool errors — a 20% slowdown confined to one
    pool flags that pool, not the fleet.
    """

    def __init__(self, threshold: float = 0.15, window: int = 8):
        if threshold <= 0:
            raise ValueError(f"threshold must be > 0, got {threshold}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.threshold = float(threshold)
        self.window = int(window)
        self.predicted_step_s: float = 0.0
        self.predicted_stage_s: List[float] = []
        self.stage_pools: Dict[int, str] = {}
        self.plan_registrations = 0
        self.n_observed = 0
        self._steps: Deque[Tuple[int, float]] = deque(maxlen=window)
        self._stage: Deque[Sequence[float]] = deque(maxlen=window)

    # -- feeding -------------------------------------------------------------

    def register_plan(self, predicted: Dict[str, Any], *,
                      stage_pools: Optional[Dict[int, str]] = None) -> None:
        """Adopt a committed plan's predicted digest (``sim_summary``-shaped
        dict: ``makespan_s`` required, ``stage_compute_s`` optional) and
        restart the observation window — samples from the old plan don't
        indict the new one."""
        self.predicted_step_s = float(predicted["makespan_s"])
        self.predicted_stage_s = [
            float(x) for x in predicted.get("stage_compute_s", [])]
        self.stage_pools = dict(stage_pools or {})
        self.plan_registrations += 1
        self._steps.clear()
        self._stage.clear()

    def observe_step(self, step: int, step_time_s: float,
                     stage_times: Optional[Sequence[float]] = None) -> None:
        self.n_observed += 1
        self._steps.append((int(step), float(step_time_s)))
        if stage_times is not None:
            self._stage.append([float(x) for x in stage_times])

    # -- reporting -----------------------------------------------------------

    def report(self) -> DriftReport:
        n = len(self._steps)
        observed = (sum(t for _, t in self._steps) / n) if n else 0.0
        rel = _rel(observed, self.predicted_step_s) if n else 0.0
        per_stage: Dict[int, float] = {}
        if self._stage and self.predicted_stage_s:
            k = min(len(self.predicted_stage_s),
                    min(len(row) for row in self._stage))
            for i in range(k):
                mean_i = sum(row[i] for row in self._stage) / len(self._stage)
                per_stage[i] = _rel(mean_i, self.predicted_stage_s[i])
        per_pool: Dict[str, float] = {}
        if per_stage and self.stage_pools:
            acc: Dict[str, List[float]] = {}
            for i, e in per_stage.items():
                pool = self.stage_pools.get(i)
                if pool is not None:
                    acc.setdefault(pool, []).append(e)
            per_pool = {p: sum(v) / len(v) for p, v in acc.items()}
        flagged_stages = [i for i, e in sorted(per_stage.items())
                          if abs(e) > self.threshold]
        flagged_pools = [p for p in sorted(per_pool)
                         if abs(per_pool[p]) > self.threshold]
        flagged = bool(n) and (abs(rel) > self.threshold
                               or bool(flagged_pools))
        return DriftReport(
            predicted_step_s=self.predicted_step_s,
            observed_step_s=observed, rel_error=rel,
            threshold=self.threshold, window=self.window,
            n_samples=n, n_observed=self.n_observed, flagged=flagged,
            per_stage=per_stage, per_pool=per_pool,
            flagged_stages=flagged_stages, flagged_pools=flagged_pools)
