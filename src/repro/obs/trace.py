"""Typed span/event trace model + deterministic Chrome-trace exporter.

The stack already *computes* every interesting timing artifact — the
pipeline referee's per-(microbatch, stage) start/dur grids, the netsim's
per-transfer intervals, the migration pricer's flow schedule, the serving
simulator's per-pool dispatch heap, the controller's decision log.  This
module only *lowers* them into one common span model (no re-simulation):

- :class:`Span` — one slice on a (process, track) lane, seconds on the
  originating sim clock, with optional flow-arrow endpoints;
- :class:`Trace` — an insertion-ordered container with counter samples and
  free-form metadata, exportable to Chrome-trace / Perfetto JSON
  (``chrome://tracing`` or https://ui.perfetto.dev, "Open trace file");
- ``trace_from_*`` adapters for each timing artifact.

Exactness contract (pinned in ``tests/test_obs.py``): adapters iterate
source artifacts in the *same element order* as the producing engine's own
reductions, so summing span durations reproduces the engine's totals bit
for bit — ``trace_from_sim`` emits each stage's compute spans in
``_stage_order`` issue order (the order ``stage_compute`` accumulates in)
and each boundary's comm spans CF/CB-alternating per microbatch (the order
``comm_total`` accumulates in).  ``comm_exposed`` is *not* reconstructible
from spans (it is a clamped sum of dependency-delay contributions), so the
verbatim float rides in ``Trace.meta`` instead.

Determinism: pids/tids are assigned in first-use order, events are emitted
in insertion order, and no wall-clock timestamp ever enters the file —
identical inputs produce byte-identical JSON.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

OBS_TRACE_SCHEMA = 1


@dataclass
class Span:
    """One complete slice.  ``ts``/``dur`` are *seconds* on the source sim
    clock (the exporter converts to Chrome's microseconds).  ``flow_start``
    emits a flow-arrow origin at the span's end, ``flow_end`` a termination
    at its start (both keyed by ``flow_id``)."""
    process: str
    track: str
    name: str
    cat: str
    ts: float
    dur: float
    args: Dict[str, Any] = field(default_factory=dict)
    flow_id: Optional[int] = None
    flow_start: bool = False
    flow_end: bool = False

    @property
    def end(self) -> float:
        return self.ts + self.dur


@dataclass
class Counter:
    """One counter sample (Chrome ``ph:"C"``): a named multi-series value
    at one instant."""
    process: str
    name: str
    ts: float
    values: Dict[str, float] = field(default_factory=dict)


class Trace:
    """Insertion-ordered span/counter container with free-form metadata."""

    def __init__(self, name: str = "trace",
                 meta: Optional[Dict[str, Any]] = None):
        self.name = name
        self.meta: Dict[str, Any] = dict(meta or {})
        self.spans: List[Span] = []
        self.counters: List[Counter] = []

    def add_span(self, process: str, track: str, name: str, cat: str,
                 ts: float, dur: float,
                 args: Optional[Dict[str, Any]] = None,
                 flow_id: Optional[int] = None,
                 flow_start: bool = False, flow_end: bool = False) -> Span:
        s = Span(process, track, name, cat, float(ts), float(dur),
                 dict(args or {}), flow_id, flow_start, flow_end)
        self.spans.append(s)
        return s

    def add_counter(self, process: str, name: str, ts: float,
                    values: Dict[str, float]) -> Counter:
        c = Counter(process, name, float(ts), dict(values))
        self.counters.append(c)
        return c

    def extend(self, other: "Trace") -> "Trace":
        """Merge ``other``'s spans/counters/meta into this trace (insertion
        order preserved; meta keys from ``other`` win on collision)."""
        self.spans.extend(other.spans)
        self.counters.extend(other.counters)
        self.meta.update(other.meta)
        return self

    # -- export --------------------------------------------------------------

    def to_chrome(self) -> Dict[str, Any]:
        """Chrome-trace JSON object format: ``X`` slices, ``C`` counters,
        ``s``/``f`` flow arrows, ``M`` process/thread names.  Deterministic:
        first-use pid/tid assignment, insertion-order events, sorted keys
        at dump time, timestamps in microseconds of *sim* time."""
        pids: Dict[str, int] = {}
        tids: Dict[tuple, int] = {}
        events: List[Dict[str, Any]] = []

        def pid(process: str) -> int:
            if process not in pids:
                pids[process] = len(pids) + 1
                events.append({"ph": "M", "name": "process_name",
                               "pid": pids[process], "tid": 0,
                               "args": {"name": process}})
            return pids[process]

        def tid(process: str, track: str) -> int:
            key = (process, track)
            if key not in tids:
                p = pid(process)
                tids[key] = len(tids) + 1
                events.append({"ph": "M", "name": "thread_name",
                               "pid": p, "tid": tids[key],
                               "args": {"name": track}})
            return tids[key]

        for s in self.spans:
            p, t = pid(s.process), tid(s.process, s.track)
            events.append({"ph": "X", "name": s.name, "cat": s.cat,
                           "pid": p, "tid": t,
                           "ts": s.ts * 1e6, "dur": s.dur * 1e6,
                           "args": s.args})
            if s.flow_id is not None and s.flow_end:
                events.append({"ph": "f", "bp": "e", "id": s.flow_id,
                               "name": "flow", "cat": s.cat,
                               "pid": p, "tid": t, "ts": s.ts * 1e6})
            if s.flow_id is not None and s.flow_start:
                events.append({"ph": "s", "id": s.flow_id,
                               "name": "flow", "cat": s.cat,
                               "pid": p, "tid": t, "ts": s.end * 1e6})
        for c in self.counters:
            events.append({"ph": "C", "name": c.name, "cat": "counter",
                           "pid": pid(c.process), "tid": 0,
                           "ts": c.ts * 1e6, "args": c.values})
        return {"traceEvents": events,
                "otherData": {"schema": OBS_TRACE_SCHEMA,
                              "name": self.name, "meta": self.meta}}

    def makespan(self) -> float:
        if "makespan_s" in self.meta:
            return float(self.meta["makespan_s"])
        return max((s.end for s in self.spans), default=0.0)


def trace_to_chrome(trace: Trace, path: str) -> str:
    """Write ``trace`` as Chrome-trace JSON at ``path`` (byte-deterministic
    for identical traces)."""
    with open(path, "w") as f:
        json.dump(trace.to_chrome(), f, sort_keys=True, indent=1)
        f.write("\n")
    return path


# ---------------------------------------------------------------------------
# Adapter: pipeline referee (core.pipesim.SimResult)
# ---------------------------------------------------------------------------


def trace_from_sim(res, name: str = "pipeline-step") -> Trace:
    """Lower a :class:`repro.core.pipesim.SimResult` into per-stage compute
    tracks + per-boundary comm tracks, phase-tagged (warmup / steady /
    cooldown) — iterating in the engines' own accumulation order so span
    sums reproduce ``stage_compute`` / ``comm_total`` bit for bit (module
    docstring).  ``comm_exposed`` rides in ``meta`` verbatim."""
    from repro.core.pipesim import _stage_order

    S = len(res.stage_compute)
    B = 1 + max((j for (k, j, _i) in res.start if k == "F"), default=-1)
    tr = Trace(name, meta={
        "makespan_s": res.makespan,
        "comm_total_s": res.comm_total,
        "comm_exposed_s": res.comm_exposed,
        "stage_compute_s": list(res.stage_compute),
        "stage_intra_comm_s": list(res.stage_intra_comm),
        "warmup_counts": list(res.warmup_counts),
        "n_microbatches": B,
    })
    for i in range(S):
        n_w = min(res.warmup_counts[i], B)
        for kind, j in _stage_order(i, S, B, res.warmup_counts[i]):
            node = (kind, j, i)
            if node not in res.start:
                continue
            if kind == "F":
                phase = "warmup" if j < n_w else "steady"
            else:
                phase = "cooldown" if j >= B - n_w else "steady"
            tr.add_span("pipeline", f"stage{i}", f"{kind}{j}", "compute",
                        res.start[node], res.dur[node],
                        args={"kind": kind, "mb": j, "stage": i,
                              "phase": phase})
    # comm spans CF/CB-alternating per microbatch: the exact element order
    # both engines accumulate comm_total in (no_overlap elides zero-cost
    # comm nodes — hence the membership guards)
    for i in range(S - 1):
        for j in range(B):
            for kind in ("CF", "CB"):
                node = (kind, j, i)
                if node not in res.start:
                    continue
                tr.add_span("pipeline", f"comm{i}->{i + 1}", f"{kind}{j}",
                            "comm", res.start[node], res.dur[node],
                            args={"kind": kind, "mb": j, "boundary": i})
    for node in res.start:
        if node[0] == "SYNC":
            tr.add_span("pipeline", f"sync{node[2]}", "SYNC", "comm",
                        res.start[node], res.dur[node],
                        args={"kind": "SYNC", "stage": node[2]})
    if res.link_busy:
        tr.add_counter("pipeline", "link_busy_s", 0.0,
                       {k: res.link_busy[k] for k in sorted(res.link_busy)})
    return tr


def render_ascii(trace: Trace, width: int = 100) -> str:
    """Paper Fig. 3-style timeline from a ``trace_from_sim`` trace — the
    single span source behind ``Executable.describe(timeline=True)``.

    Pixel math and paint order replicate ``pipesim.ascii_timeline`` on
    fast-path results exactly (per stage: all forwards ascending mb, then
    all backwards — the engine's dict insertion order), pinned equal in
    tests."""
    compute = [s for s in trace.spans if s.cat == "compute"]
    if not compute:
        return ""
    stages = sorted({s.args["stage"] for s in compute})
    makespan = trace.makespan()
    scale = width / makespan
    rows = []
    for i in stages:
        row = [" "] * (width + 1)
        mine = [s for s in compute if s.args["stage"] == i]
        mine.sort(key=lambda s: (s.args["kind"] != "F", s.args["mb"]))
        for sp in mine:
            s0 = int(sp.ts * scale)
            e0 = max(s0 + 1, int(sp.end * scale))
            ch = "f" if sp.args["kind"] == "F" else "B"
            for x in range(s0, min(e0, width)):
                row[x] = ch
        rows.append(f"stage{i}|" + "".join(row))
    return "\n".join(rows)


# ---------------------------------------------------------------------------
# Adapter: fair-share network simulator (comm.netsim)
# ---------------------------------------------------------------------------


def trace_from_netsim(nodes: Sequence, res,
                      name: str = "netsim") -> Trace:
    """Lower a netsim run into per-link lanes.  ``NetSimResult`` records
    timing but not link membership, so the original ``SimNode`` list rides
    along; a multi-link transfer lands on its first link's lane with the
    full link set in ``args``.  Internal ``("__release__", ...)`` delay
    nodes are skipped."""
    tr = Trace(name, meta={"makespan_s": res.makespan,
                           "link_busy_s": {k: res.link_busy[k]
                                           for k in sorted(res.link_busy)}})
    for n in nodes:
        nid = n.nid
        if isinstance(nid, tuple) and nid and nid[0] == "__release__":
            continue
        if nid not in res.start:
            continue
        track = n.links[0] if n.links else "compute"
        tr.add_span("netsim", track, str(nid),
                    "comm" if n.links else "compute",
                    res.start[nid], res.end[nid] - res.start[nid],
                    args={"links": list(n.links), "work_s": n.work})
    return tr


# ---------------------------------------------------------------------------
# Adapter: migration pricing (migrate.pricing.MigrationCost.timeline)
# ---------------------------------------------------------------------------


def trace_from_migration(cost, name: str = "migration") -> Trace:
    """Lower a priced migration's flow schedule into drain lanes + per-link
    ``mig:`` lanes, flow arrows from each source stage's release span (its
    drain tail / gradient sync) to the migration flows it gates.

    Requires ``price_migration(..., collect_timeline=True)`` — raises
    ``ValueError`` on a cost priced without a timeline."""
    if getattr(cost, "timeline", None) is None:
        raise ValueError(
            "MigrationCost has no timeline; price with "
            "price_migration(..., collect_timeline=True)")
    tl = cost.timeline
    tr = Trace(name, meta={
        "downtime_s": cost.downtime_s, "serial_s": cost.serial_s,
        "drain_s": cost.drain_s, "overlapped": cost.overlapped,
        "n_flows": cost.n_flows,
        "link_bytes": {k: cost.link_bytes[k]
                       for k in sorted(cost.link_bytes)},
    })
    # one flow-arrow id per gating stage, shared by its release span and
    # every flow it releases
    flow_ids = {f["src_stage"] for f in tl["flows"]
                if f["src_stage"] is not None}
    fid_of = {stage: k for k, stage in enumerate(sorted(flow_ids))}
    for d in tl["drain"]:
        stage = d.get("stage")
        track = f"stage{stage}" if stage is not None else str(d["id"])
        fid = fid_of.get(stage) if d.get("is_release") else None
        tr.add_span("migration", track, d["kind"], "drain",
                    d["start_s"], d["end_s"] - d["start_s"],
                    args={"kind": d["kind"], "stage": stage,
                          "link": d.get("link")},
                    flow_id=fid, flow_start=fid is not None)
    for f in tl["flows"]:
        fid = fid_of.get(f["src_stage"])
        tr.add_span("migration", f"mig:{f['link']}", f["id"], "migration",
                    f["start_s"], f["end_s"] - f["start_s"],
                    args={"src": f["src"], "dst": f["dst"],
                          "src_stage": f["src_stage"], "link": f["link"],
                          "work_s": f["work_s"]},
                    flow_id=fid, flow_end=fid is not None)
    return tr


# ---------------------------------------------------------------------------
# Adapter: serving simulator dispatch log (serving.batching recorder)
# ---------------------------------------------------------------------------


def trace_from_serve(events: Sequence, name: str = "serving") -> Trace:
    """Lower a ``simulate_trace(..., recorder=...)`` dispatch log — entries
    ``(t, dur, pool_idx, pool_name, kind, n)`` — into per-pool
    prefill/decode lanes (``n`` = chunk tokens for prefill, batch size for
    decode)."""
    tr = Trace(name)
    busy: Dict[str, float] = {}
    for (t, dur, idx, pool_name, kind, n) in events:
        tr.add_span("serving", pool_name, kind, "serve", t, dur,
                    args={"pool": idx, "kind": kind, "n": n})
        busy[f"{pool_name}/{kind}"] = busy.get(f"{pool_name}/{kind}", 0.0) \
            + dur
    tr.meta["pool_busy_s"] = {k: busy[k] for k in sorted(busy)}
    return tr


# ---------------------------------------------------------------------------
# Adapter: controller decision log (runtime.controller.ReplanDecision)
# ---------------------------------------------------------------------------


def trace_from_decisions(decisions: Sequence,
                         wall_times: Optional[Dict[int, float]] = None,
                         name: str = "controller") -> Trace:
    """Lower a decision log into one controller track: a span per
    :class:`ReplanDecision` (every decision present — pinned in tests), dur
    = its charged downtime.  ``wall_times`` (step -> replay-clock seconds)
    places spans on the sim clock; without it ``ts`` is the step index."""
    tr = Trace(name, meta={"n_decisions": len(decisions),
                           "clock": "wall" if wall_times else "step"})
    for d in decisions:
        ts = wall_times.get(d.step, float(d.step)) if wall_times \
            else float(d.step)
        tr.add_span("controller", "decisions", d.action, "decision",
                    ts, d.downtime_s,
                    args={"step": d.step, "action": d.action,
                          "reason": d.reason,
                          "event": None if d.event is None else str(d.event),
                          "search_time_s": d.search_time_s,
                          "migration_s": d.migration_s,
                          "migration_bytes": d.migration_bytes,
                          "coalesced": d.coalesced,
                          "serve_replanned": d.serve_replanned,
                          "plan_cache_hit": d.plan_cache_hit})
    return tr
