"""``python -m repro`` entry point (see repro/api/cli.py)."""
from repro.api.cli import main

raise SystemExit(main())
