"""Mamba-2 (SSD — state-space duality) block.

Implements the chunked SSD algorithm (intra-chunk quadratic term + inter-chunk
linear state recurrence) in pure jnp; the intra-chunk term is the compute
hot-spot and has a Pallas kernel (``repro.kernels.ssd_scan``) selected via
``use_pallas``.  ``ssd_naive`` is the sequential oracle used by tests.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, linear, rms_norm, shard_act


def ssm_init(rng, d_model: int, d_inner: int, d_state: int, n_heads: int,
             d_conv: int, dtype=jnp.float32, stack: Tuple[int, ...] = ()) -> Dict[str, Any]:
    ks = jax.random.split(rng, 6)
    d_proj = 2 * d_inner + 2 * d_state + n_heads   # z, xBC, dt
    d_xbc = d_inner + 2 * d_state
    return {
        "in_proj": dense_init(ks[0], d_model, d_proj, dtype, stack),
        "conv_w": 0.1 * jax.random.normal(ks[1], (*stack, d_conv, d_xbc), jnp.float32).astype(dtype),
        "conv_b": jnp.zeros((*stack, d_xbc), dtype),
        "A_log": jnp.zeros((*stack, n_heads), jnp.float32),        # A = -exp(0) = -1
        "D": jnp.ones((*stack, n_heads), jnp.float32),
        "dt_bias": jnp.zeros((*stack, n_heads), jnp.float32),
        "norm_w": jnp.ones((*stack, d_inner), dtype),
        "out_proj": dense_init(ks[2], d_inner, d_model, dtype, stack),
    }


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: (B, T, C); w: (K, C); b: (C,)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for k in range(K):  # K is tiny (4): unrolled taps
        out = out + pad[:, k:k + x.shape[1]].astype(jnp.float32) * w[k].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def ssd_naive(x, dt, A, Bm, Cm, init_state=None):
    """Sequential oracle.  x: (B,T,H,P); dt: (B,T,H); A: (H,) (negative);
    Bm, Cm: (B,T,N).  Returns (y: (B,T,H,P), final_state: (B,H,P,N))."""
    Bsz, T, H, Pd = x.shape
    N = Bm.shape[-1]
    if init_state is None:
        # derive zeros from the input so collective-varying axes (vma) inside
        # shard_map pipelines are inherited by the scan carry
        s0 = jnp.zeros((Bsz, H, Pd, N), jnp.float32) + \
            0.0 * x[:, 0, :, :, None].astype(jnp.float32)
    else:
        s0 = init_state

    def step(s, inp):
        x_t, dt_t, B_t, C_t = inp                      # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(A[None] * dt_t)                # (B,H)
        upd = (dt_t[:, :, None] * x_t)[..., None] * B_t[:, None, None, :]
        s = s * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", s, C_t)
        return s, y

    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Bm.astype(jnp.float32), 1, 0),
          jnp.moveaxis(Cm.astype(jnp.float32), 1, 0))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), s_fin


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None, use_pallas: bool = False):
    """Chunked SSD (Mamba-2 alg. 1). Shapes as :func:`ssd_naive`."""
    Bsz, T, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    if T % Q:
        pad = Q - T % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Tp = x.shape[1]
    nc = Tp // Q
    xc = x.reshape(Bsz, nc, Q, H, Pd).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(jnp.float32)

    a = A[None, None, None, :] * dtc                       # (B,nc,Q,H) log-decays (<=0)
    cum = jnp.cumsum(a, axis=2)                            # inclusive cumsum
    total = cum[:, :, -1]                                  # (B,nc,H)

    # ---- chunk input states: S_c = sum_q exp(total - cum_q) dt_q x_q B_q^T
    w_in = jnp.exp(total[:, :, None] - cum) * dtc          # (B,nc,Q,H)
    S_in = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", w_in, xc, Bc)

    # ---- inter-chunk recurrence over chunk axis
    if init_state is None:
        s0 = jnp.zeros((Bsz, H, Pd, N), jnp.float32) + \
            0.0 * xc[:, 0, 0, :, :, None]    # inherit vma (see ssd_naive)
    else:
        s0 = init_state
    dec_tot = jnp.exp(total)                               # (B,nc,H)

    def scan_fn(s, inp):
        d_c, S_c = inp                                     # (B,H), (B,H,P,N)
        s_prev = s
        s = s * d_c[:, :, None, None] + S_c
        return s, s_prev

    s_fin, S_prev = jax.lax.scan(
        scan_fn, s0, (jnp.moveaxis(dec_tot, 1, 0), jnp.moveaxis(S_in, 1, 0)))
    S_prev = jnp.moveaxis(S_prev, 0, 1)                    # (B,nc,H,P,N)

    # ---- inter-chunk output: C_q . (exp(cum_q) * S_prev)
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cc, S_prev) * jnp.exp(cum)[..., None]

    # ---- intra-chunk (quadratic) part — the kernel hot-spot
    if use_pallas:
        from repro.kernels import ops as kops
        y_intra = kops.ssd_intra(xc, dtc, cum, Bc, Cc)
    else:
        y_intra = ssd_intra_ref(xc, dtc, cum, Bc, Cc)

    y = (y_intra + y_inter).reshape(Bsz, Tp, H, Pd)[:, :T]
    return y.astype(x.dtype), s_fin


def ssd_intra_ref(xc, dtc, cum, Bc, Cc):
    """Intra-chunk quadratic term (jnp oracle).

    xc: (B,nc,Q,H,P); dtc: (B,nc,Q,H); cum: (B,nc,Q,H) inclusive log-decay
    cumsum; Bc, Cc: (B,nc,Q,N).  Output (B,nc,Q,H,P)."""
    Q = xc.shape[2]
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)             # (B,nc,Q,Q)
    # decay from step k (exclusive) to q (inclusive): exp(cum_q - cum_k)
    ldec = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,K,H)
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(ldec), 0.0)
    M = CB[..., None] * L * dtc[:, :, None, :, :]          # (B,nc,Q,K,H)
    return jnp.einsum("bcqkh,bckhp->bcqhp", M, xc)


# ---------------------------------------------------------------------------
# Full block
# ---------------------------------------------------------------------------


def ssm_block(p: Dict[str, Any], h: jnp.ndarray, *, d_inner: int, d_state: int,
              n_heads: int, head_dim: int, chunk: int,
              use_pallas: bool = False, norm_eps: float = 1e-6,
              return_state: bool = False):
    """Mamba-2 mixer over a full sequence. h: (B, T, d_model).

    ``return_state`` additionally returns the decode-compatible state
    (final SSD state + conv tail) for prefill."""
    B, T, _ = h.shape
    zxbcdt = linear(h, p["in_proj"])
    z = zxbcdt[..., :d_inner]
    xBC_raw = zxbcdt[..., d_inner:2 * d_inner + 2 * d_state]
    dt = zxbcdt[..., -n_heads:].astype(jnp.float32)
    xBC = jax.nn.silu(causal_conv1d(xBC_raw, p["conv_w"], p["conv_b"]))
    x = xBC[..., :d_inner]
    Bm = xBC[..., d_inner:d_inner + d_state]
    Cm = xBC[..., d_inner + d_state:]
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = x.reshape(B, T, n_heads, head_dim)
    xh = shard_act(xh, ("batch", "seq", "heads", None))
    y, s_fin = ssd_chunked(xh, dt, A, Bm, Cm, chunk, use_pallas=use_pallas)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, T, d_inner).astype(h.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype),
                 p["norm_w"], norm_eps)
    out = linear(y, p["out_proj"])
    if return_state:
        K = p["conv_w"].shape[0]
        tail = xBC_raw[:, max(T - (K - 1), 0):].astype(jnp.float32)
        if T < K - 1:
            tail = jnp.pad(tail, ((0, 0), (K - 1 - T, 0), (0, 0)))
        return out, {"s": s_fin, "conv": tail}
    return out


def ssm_init_state(batch: int, d_inner: int, d_state: int, n_heads: int,
                   head_dim: int, d_conv: int) -> Dict[str, jnp.ndarray]:
    return {
        "s": jnp.zeros((batch, n_heads, head_dim, d_state), jnp.float32),
        "conv": jnp.zeros((batch, d_conv - 1, d_inner + 2 * d_state), jnp.float32),
    }


def ssm_decode_step(p: Dict[str, Any], h: jnp.ndarray, state: Dict[str, jnp.ndarray], *,
                    d_inner: int, d_state: int, n_heads: int, head_dim: int,
                    norm_eps: float = 1e-6):
    """One-token SSM step. h: (B, 1, d_model). Returns (out, new_state)."""
    B = h.shape[0]
    zxbcdt = linear(h[:, 0], p["in_proj"])                  # (B, d_proj)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:2 * d_inner + 2 * d_state]
    dt = zxbcdt[..., -n_heads:].astype(jnp.float32)

    # conv ring: state['conv'] holds the previous K-1 inputs
    K = p["conv_w"].shape[0]
    win = jnp.concatenate([state["conv"], xBC[:, None, :].astype(jnp.float32)], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", win, p["conv_w"].astype(jnp.float32))
    xBC = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    new_conv = win[:, 1:]

    x = xBC[..., :d_inner]
    Bm = xBC[..., d_inner:d_inner + d_state]
    Cm = xBC[..., d_inner + d_state:]
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32))     # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = x.reshape(B, n_heads, head_dim).astype(jnp.float32)

    decay = jnp.exp(A[None] * dt)                                    # (B,H)
    upd = (dt[:, :, None] * xh)[..., None] * Bm[:, None, None, :]
    s = state["s"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", s, Cm)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, d_inner).astype(h.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(h.dtype),
                 p["norm_w"], norm_eps)
    out = linear(y, p["out_proj"])[:, None, :]
    return out, {"s": s, "conv": new_conv}
