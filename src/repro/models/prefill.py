"""Prefill: full-sequence forward that RETURNS the serving state.

``prefill(cfg, params, batch, cache_len=None)`` -> (last_logits (B,1,V),
cache) where the cache is decode-compatible (same layouts as each family's
``init_cache`` / ``decode_step``).  This is the real inference-prefill
compute pattern: hidden states for every position, per-layer KV / SSM state
materialized, only the final position's logits produced.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import encdec, hybrid_lm, mamba_lm, moe_lm, transformer, vlm
from repro.models import mlp as mlp_mod
from repro.models.common import linear, rms_norm, scan_unroll, shard_act
from repro.models.moe import moe_block
from repro.models.ssm import ssm_block

Params = Dict[str, Any]


def _attn_collect(cfg, p, h, *, window=0, use_pallas=False):
    a, (k, v) = attn.self_attention(
        p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps),
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, causal=True, window=window,
        use_pallas=use_pallas, return_kv=True)
    return h + a, k, v


def _pad_cache(k: jnp.ndarray, cache_len: int) -> jnp.ndarray:
    """(..., T, KV, D) -> (..., cache_len, KV, D), right-padded."""
    T = k.shape[-3]
    if cache_len == T:
        return k
    assert cache_len > T
    pad = [(0, 0)] * k.ndim
    pad[-3] = (0, cache_len - T)
    return jnp.pad(k, pad)


def _ring_slice(k: jnp.ndarray, loc_len: int, T: int) -> jnp.ndarray:
    """Last ``loc_len`` positions laid out in decode's ring order
    (slot = position % loc_len, matching the decode ring buffer size)."""
    w = min(loc_len, T)
    tail = k[:, T - w:]
    slots = (jnp.arange(T - w, T)) % loc_len
    ring = jnp.zeros((k.shape[0], loc_len, *k.shape[2:]), k.dtype)
    return ring.at[:, slots].set(tail)


def _mlp_res(cfg, p, h):
    return h + mlp_mod.mlp(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps),
                           cfg.activation)


# ---------------------------------------------------------------------------


def _prefill_dense(cfg, params, batch, cache_len, dtype, use_pallas):
    h = transformer.embed_tokens(cfg, params, batch["tokens"])
    T = h.shape[1]
    ratio = cfg.local_global_ratio

    if not ratio:
        def body(hh, p):
            hh, k, v = _attn_collect(cfg, p, hh, window=cfg.sliding_window,
                                     use_pallas=use_pallas)
            hh = _mlp_res(cfg, p, hh)
            return shard_act(hh, ("batch", "seq", "embed")), (k, v)
        h, (ks, vs) = jax.lax.scan(body, h, params["blocks"], unroll=scan_unroll())
        ks = shard_act(_pad_cache(ks.astype(dtype), cache_len),
                       (None, "batch", "kv_seq", None, None))
        vs = shard_act(_pad_cache(vs.astype(dtype), cache_len),
                       (None, "batch", "kv_seq", None, None))
        cache = {"k": ks, "v": vs}
    else:
        gsz = ratio + 1
        G = cfg.n_layers // gsz
        grouped = jax.tree.map(
            lambda x: x.reshape(G, gsz, *x.shape[1:]), params["blocks"])

        loc_len = min(cache_len, cfg.sliding_window)

        def gbody(hh, pg):
            loc_k, loc_v = [], []
            for i in range(ratio):
                p = jax.tree.map(lambda x: x[i], pg)
                hh, k, v = _attn_collect(cfg, p, hh, window=cfg.sliding_window,
                                         use_pallas=use_pallas)
                hh = _mlp_res(cfg, p, hh)
                loc_k.append(_ring_slice(k, loc_len, T))
                loc_v.append(_ring_slice(v, loc_len, T))
            pglob = jax.tree.map(lambda x: x[ratio], pg)
            hh, gk, gv = _attn_collect(cfg, pglob, hh, window=0,
                                       use_pallas=use_pallas)
            hh = _mlp_res(cfg, pglob, hh)
            return hh, (jnp.stack(loc_k), jnp.stack(loc_v), gk, gv)

        h, (lk, lv, gk, gv) = jax.lax.scan(gbody, h, grouped, unroll=scan_unroll())
        cache = {
            "k_loc": lk.astype(dtype), "v_loc": lv.astype(dtype),
            "k_glb": shard_act(_pad_cache(gk.astype(dtype), cache_len),
                               (None, "batch", "kv_seq", None, None)),
            "v_glb": shard_act(_pad_cache(gv.astype(dtype), cache_len),
                               (None, "batch", "kv_seq", None, None)),
        }
    return transformer.lm_head(cfg, params, h[:, -1:]), cache


def _prefill_moe(cfg, params, batch, cache_len, dtype, use_pallas):
    h = transformer.embed_tokens(cfg, params, batch["tokens"])

    def body(hh, p):
        hh, k, v = _attn_collect(cfg, p, hh, use_pallas=use_pallas)
        m, _ = moe_block(p["moe"], rms_norm(hh, p["ln2"], cfg.norm_eps),
                         top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                         activation=cfg.activation, router_aux_coef=0.0)
        return hh + m, (k, v)
    h, (ks, vs) = jax.lax.scan(body, h, params["blocks"], unroll=scan_unroll())
    kv_spec = (None, "batch", "kv_seq", None, None)
    cache = {"k": shard_act(_pad_cache(ks.astype(dtype), cache_len), kv_spec),
             "v": shard_act(_pad_cache(vs.astype(dtype), cache_len), kv_spec)}
    return transformer.lm_head(cfg, params, h[:, -1:]), cache


def _ssm_block_state(cfg, p, h, use_pallas):
    out, st = ssm_block(
        p["ssm"], rms_norm(h, p["ln"], cfg.norm_eps),
        d_inner=cfg.d_inner, d_state=cfg.ssm_state, n_heads=cfg.n_ssm_heads,
        head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk,
        use_pallas=use_pallas, norm_eps=cfg.norm_eps, return_state=True)
    return h + out, st


def _prefill_ssm(cfg, params, batch, cache_len, dtype, use_pallas):
    h = transformer.embed_tokens(cfg, params, batch["tokens"])

    def body(hh, p):
        hh, st = _ssm_block_state(cfg, p, hh, use_pallas)
        return hh, st
    h, states = jax.lax.scan(body, h, params["blocks"], unroll=scan_unroll())
    return transformer.lm_head(cfg, params, h[:, -1:]), states


def _prefill_hybrid(cfg, params, batch, cache_len, dtype, use_pallas):
    h = transformer.embed_tokens(cfg, params, batch["tokens"])
    shared = params["shared"]
    T = h.shape[1]

    def gbody(hh, xs):
        pg, a_in, a_out = xs

        def inner(c, p):
            return _ssm_block_state(cfg, p, c, use_pallas)
        hh, st = jax.lax.scan(inner, hh, pg, unroll=scan_unroll())
        x = linear(hh, a_in)
        y, (k, v) = attn.self_attention(
            shared["attn"], rms_norm(x, shared["ln1"], cfg.norm_eps),
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta, causal=True,
            use_pallas=use_pallas, return_kv=True)
        x = x + y
        x = x + mlp_mod.mlp(shared["mlp"], rms_norm(x, shared["ln2"],
                                                    cfg.norm_eps),
                            cfg.activation)
        hh = hh + linear(x, a_out)
        return hh, (st, k, v)

    h, (st_g, ks, vs) = jax.lax.scan(
        gbody, h, (params["groups"], params["adapt_in"], params["adapt_out"]),
        unroll=scan_unroll())

    def tbody(c, p):
        return _ssm_block_state(cfg, p, c, use_pallas)
    h, st_t = jax.lax.scan(tbody, h, params["tail"], unroll=scan_unroll())

    kv_spec = (None, "batch", "kv_seq", None, None)
    cache = {"ssm_groups": st_g, "ssm_tail": st_t,
             "k": shard_act(_pad_cache(ks.astype(dtype), cache_len), kv_spec),
             "v": shard_act(_pad_cache(vs.astype(dtype), cache_len), kv_spec)}
    return transformer.lm_head(cfg, params, h[:, -1:]), cache


def _prefill_vlm(cfg, params, batch, cache_len, dtype, use_pallas):
    h = transformer.embed_tokens(cfg, params, batch["tokens"])
    memory = batch["image_embeds"].astype(h.dtype)

    def gbody(hh, xs):
        pg_self, pg_cross = xs
        nk, nv = [], []
        n_self = jax.tree.leaves(pg_self)[0].shape[0]
        for i in range(n_self):
            p = jax.tree.map(lambda x: x[i], pg_self)
            hh, k, v = _attn_collect(cfg, p, hh, use_pallas=use_pallas)
            hh = _mlp_res(cfg, p, hh)
            nk.append(k)
            nv.append(v)
        hh, (mk, mv) = vlm._cross_apply(cfg, pg_cross, hh, memory,
                                        use_pallas=use_pallas, return_kv=True)
        return hh, (jnp.stack(nk), jnp.stack(nv), mk, mv)

    h, (ks, vs, mks, mvs) = jax.lax.scan(
        gbody, h, (params["self_blocks"], params["cross_blocks"]),
        unroll=scan_unroll())
    kv_spec = (None, None, "batch", "kv_seq", None, None)
    cache = {"k": shard_act(_pad_cache(ks.astype(dtype), cache_len), kv_spec),
             "v": shard_act(_pad_cache(vs.astype(dtype), cache_len), kv_spec),
             "mem_k": mks.astype(dtype), "mem_v": mvs.astype(dtype)}
    return transformer.lm_head(cfg, params, h[:, -1:]), cache


def _prefill_audio(cfg, params, batch, cache_len, dtype, use_pallas):
    memory = encdec.encode(cfg, params,
                           batch["frames"].astype(params["embed"].dtype),
                           use_pallas=use_pallas)
    tokens = batch["tokens"]
    T = tokens.shape[1]
    h = params["embed"][tokens]
    h = h + params["pos_embed"][jnp.arange(T) % encdec.MAX_DEC_POS][None]

    def body(hh, p):
        a, (k, v) = attn.self_attention(
            p["attn"], rms_norm(hh, p["ln1"], cfg.norm_eps),
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=0.0, causal=True,
            use_pallas=use_pallas, return_kv=True)
        hh = hh + a
        x, (mk, mv) = attn.cross_attention(
            p["xattn"], rms_norm(hh, p["ln_x"], cfg.norm_eps), memory,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, use_pallas=use_pallas, return_kv=True)
        hh = hh + x
        hh = hh + mlp_mod.mlp(p["mlp"], rms_norm(hh, p["ln2"], cfg.norm_eps),
                              cfg.activation)
        return hh, (k, v, mk, mv)

    h, (ks, vs, mks, mvs) = jax.lax.scan(body, h, params["dec_blocks"], unroll=scan_unroll())
    kv_spec = (None, "batch", "kv_seq", None, None)
    cache = {"k": shard_act(_pad_cache(ks.astype(dtype), cache_len), kv_spec),
             "v": shard_act(_pad_cache(vs.astype(dtype), cache_len), kv_spec),
             "mem_k": mks.astype(dtype), "mem_v": mvs.astype(dtype)}
    return transformer.lm_head(cfg, params, h[:, -1:]), cache


_FAMILY = {
    "dense": _prefill_dense,
    "moe": _prefill_moe,
    "ssm": _prefill_ssm,
    "hybrid": _prefill_hybrid,
    "vlm": _prefill_vlm,
    "audio": _prefill_audio,
}


def prefill(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray], *,
            cache_len: Optional[int] = None, cache_dtype=jnp.bfloat16,
            use_pallas: bool = False) -> Tuple[jnp.ndarray, Any]:
    T = batch["tokens"].shape[1]
    cache_len = cache_len or T
    return _FAMILY[cfg.family](cfg, params, batch, cache_len, cache_dtype,
                               use_pallas)
