"""Mixture-of-experts decoder LMs (qwen3-moe-235b-a22b, granite-moe-1b-a400m)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import transformer as tf
from repro.models.common import dense_init, embed_init, rms_norm, scan_unroll
from repro.models.moe import moe_block, moe_init

Params = Dict[str, Any]


def block_init(cfg: ArchConfig, rng, dtype) -> Params:
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "moe": moe_init(k2, cfg.d_model, cfg.d_ff, cfg.n_experts,
                        cfg.activation, dtype),
    }


def init(cfg: ArchConfig, rng, dtype=jnp.float32) -> Params:
    k_emb, k_blocks, k_head = jax.random.split(rng, 3)
    p: Params = {
        "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "blocks": jax.vmap(lambda k: block_init(cfg, k, dtype))(
            jax.random.split(k_blocks, cfg.n_layers)),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
    return p


def _block_apply(cfg: ArchConfig, p: Params, h: jnp.ndarray, *,
                 use_pallas: bool):
    a = attn.self_attention(
        p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps),
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, causal=True, use_pallas=use_pallas)
    h = h + a
    m, aux = moe_block(p["moe"], rms_norm(h, p["ln2"], cfg.norm_eps),
                       top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                       activation=cfg.activation,
                       router_aux_coef=cfg.router_aux_coef)
    return h + m, aux


def forward(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray], *,
            use_pallas: bool = False, remat: bool = True):
    h = tf.embed_tokens(cfg, params, batch["tokens"])

    def body(carry, p):
        hh, aux_total = carry
        hh, aux = _block_apply(cfg, p, hh, use_pallas=use_pallas)
        return (hh, aux_total + aux), None

    body = jax.checkpoint(body) if remat else body
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                               params["blocks"], unroll=scan_unroll())
    return tf.lm_head(cfg, params, h), aux


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.bfloat16) -> Params:
    shape = (cfg.n_layers, batch, seq_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray):
    h = tf.embed_tokens(cfg, params, tokens)

    def body(carry, inp):
        p, ck, cv = inp
        a, (ck, cv) = attn.decode_self_attention(
            p["attn"], rms_norm(carry, p["ln1"], cfg.norm_eps), ck, cv, pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta)
        hh = carry + a
        m, _ = moe_block(p["moe"], rms_norm(hh, p["ln2"], cfg.norm_eps),
                         top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                         activation=cfg.activation, router_aux_coef=0.0)
        return hh + m, (ck, cv)

    h, (nk, nv) = jax.lax.scan(body, h, (params["blocks"], cache["k"], cache["v"]),
                               unroll=scan_unroll())
    return tf.lm_head(cfg, params, h), {"k": nk, "v": nv}
