"""Dense decoder-only transformer LMs.

Covers: minitron-8b, deepseek-7b, gemma-2b (MQA), gemma3-12b (5:1
local:global sliding-window pattern), and the paper's GPT-15/30/39B.

Blocks are parameter-stacked along a leading layer axis; the forward pass is a
(remat'd) ``lax.scan``.  Pattern archs (gemma3) scan over *groups* of
``ratio`` local layers + 1 global layer so window masks stay static.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import (
    dense_init, embed_init, linear, rms_norm, scan_unroll, shard_act,
    softmax_cross_entropy,
)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def block_init(cfg: ArchConfig, rng, dtype) -> Params:
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": mlp_mod.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
    }


def init(cfg: ArchConfig, rng, dtype=jnp.float32) -> Params:
    k_emb, k_blocks, k_head = jax.random.split(rng, 3)
    p: Params = {
        "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "blocks": jax.vmap(lambda k: block_init(cfg, k, dtype))(
            jax.random.split(k_blocks, cfg.n_layers)),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _block_apply(cfg: ArchConfig, p: Params, h: jnp.ndarray, *,
                 window: int, use_pallas: bool) -> jnp.ndarray:
    a = attn.self_attention(
        p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps),
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, causal=True, window=window,
        use_pallas=use_pallas)
    h = h + a
    m = mlp_mod.mlp(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps), cfg.activation)
    h = h + m
    return shard_act(h, ("batch", "seq", "embed"))


def _scan_blocks(cfg: ArchConfig, blocks: Params, h: jnp.ndarray, *,
                 use_pallas: bool, remat: bool = True) -> jnp.ndarray:
    ratio = cfg.local_global_ratio

    if not ratio:
        def body(carry, p):
            return _block_apply(cfg, p, carry, window=cfg.sliding_window,
                                use_pallas=use_pallas), None
        body = jax.checkpoint(body) if remat else body
        h, _ = jax.lax.scan(body, h, blocks, unroll=scan_unroll())
        return h

    # pattern: [ratio local layers, 1 global layer] per group
    gsz = ratio + 1
    n_groups = cfg.n_layers // gsz
    grouped = jax.tree.map(lambda x: x.reshape(n_groups, gsz, *x.shape[1:]), blocks)

    def group_body(carry, pg):
        local = jax.tree.map(lambda x: x[:ratio], pg)
        glob = jax.tree.map(lambda x: x[ratio], pg)

        def local_body(c, p):
            return _block_apply(cfg, p, c, window=cfg.sliding_window,
                                use_pallas=use_pallas), None
        carry, _ = jax.lax.scan(local_body, carry, local)
        carry = _block_apply(cfg, glob, carry, window=0, use_pallas=use_pallas)
        return carry, None

    group_body = jax.checkpoint(group_body) if remat else group_body
    h, _ = jax.lax.scan(group_body, h, grouped, unroll=scan_unroll())
    return h


def embed_tokens(cfg: ArchConfig, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    from repro.models.common import act_dtype_cast
    h = act_dtype_cast(params["embed"][tokens])
    if cfg.scale_embed:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return shard_act(h, ("batch", "seq", "embed"))


def lm_head(cfg: ArchConfig, params: Params, h: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = linear(h, w)
    return shard_act(logits, ("batch_head", "seq", "vocab"))


def forward(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray], *,
            use_pallas: bool = False, remat: bool = True):
    """-> (logits (B,T,V), aux_loss scalar)."""
    h = embed_tokens(cfg, params, batch["tokens"])
    h = _scan_blocks(cfg, params["blocks"], h, use_pallas=use_pallas, remat=remat)
    return lm_head(cfg, params, h), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.bfloat16) -> Params:
    ratio = cfg.local_global_ratio
    if not ratio:
        S = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len
        shape = (cfg.n_layers, batch, S, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    gsz = ratio + 1
    n_groups = cfg.n_layers // gsz
    w = cfg.sliding_window
    loc = (n_groups, ratio, batch, min(seq_len, w), cfg.n_kv_heads, cfg.head_dim)
    glb = (n_groups, batch, seq_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k_loc": jnp.zeros(loc, dtype), "v_loc": jnp.zeros(loc, dtype),
            "k_glb": jnp.zeros(glb, dtype), "v_glb": jnp.zeros(glb, dtype)}


def _decode_block(cfg: ArchConfig, p: Params, h, ck, cv, pos, window):
    a, (ck, cv) = attn.decode_self_attention(
        p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps), ck, cv, pos,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, window=window)
    h = h + a
    h = h + mlp_mod.mlp(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps), cfg.activation)
    return h, ck, cv


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray):
    """tokens: (B, 1) int32; pos: scalar int32 (next position index).

    Returns (logits (B, 1, V), new_cache)."""
    h = embed_tokens(cfg, params, tokens)
    ratio = cfg.local_global_ratio

    if not ratio:
        def body(carry, inp):
            p, ck, cv = inp
            hh, ck, cv = _decode_block(cfg, p, carry, ck, cv, pos, cfg.sliding_window)
            return hh, (ck, cv)
        h, (nk, nv) = jax.lax.scan(body, h, (params["blocks"], cache["k"], cache["v"]),
                                   unroll=scan_unroll())
        new_cache = {"k": nk, "v": nv}
    else:
        gsz = ratio + 1
        n_groups = cfg.n_layers // gsz
        grouped = jax.tree.map(
            lambda x: x.reshape(n_groups, gsz, *x.shape[1:]), params["blocks"])

        def body(carry, inp):
            pg, klo, vlo, kgl, vgl = inp
            nk_l, nv_l = [], []
            for i in range(ratio):
                pl = jax.tree.map(lambda x: x[i], pg)
                carry, ck, cv = _decode_block(cfg, pl, carry, klo[i], vlo[i],
                                              pos, cfg.sliding_window)
                nk_l.append(ck)
                nv_l.append(cv)
            pglob = jax.tree.map(lambda x: x[ratio], pg)
            carry, kgl, vgl = _decode_block(cfg, pglob, carry, kgl, vgl, pos, 0)
            return carry, (jnp.stack(nk_l), jnp.stack(nv_l), kgl, vgl)

        h, (klo, vlo, kgl, vgl) = jax.lax.scan(
            body, h, (grouped, cache["k_loc"], cache["v_loc"],
                      cache["k_glb"], cache["v_glb"]), unroll=scan_unroll())
        new_cache = {"k_loc": klo, "v_loc": vlo, "k_glb": kgl, "v_glb": vgl}

    return lm_head(cfg, params, h), new_cache
