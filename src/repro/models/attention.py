"""Attention: GQA/MQA/MHA self-attention (full / sliding-window / causal /
bidirectional), cross-attention, and single-token decode against a KV cache.

The jnp path here is the reference implementation; perf-critical paths
dispatch to the Pallas flash kernel (``repro.kernels.ops``) when enabled.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, linear, shard_act

NEG_INF = -2.0 ** 30


def attn_init(rng, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
              dtype=jnp.float32, stack: Tuple[int, ...] = ()) -> Dict[str, Any]:
    ks = jax.random.split(rng, 4)
    q_dim, kv_dim = n_heads * head_dim, n_kv_heads * head_dim
    return {
        "wq": dense_init(ks[0], d_model, q_dim, dtype, stack),
        "wk": dense_init(ks[1], d_model, kv_dim, dtype, stack),
        "wv": dense_init(ks[2], d_model, kv_dim, dtype, stack),
        "wo": dense_init(ks[3], q_dim, d_model, dtype, stack),
    }


def _split_heads(x: jnp.ndarray, n_heads: int, head_dim: int) -> jnp.ndarray:
    return x.reshape(*x.shape[:-1], n_heads, head_dim)


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool, window: int = 0,
                  q_offset: Any = 0,
                  kv_valid_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Pure-jnp attention oracle.

    q: (B, Tq, H, D); k, v: (B, Tk, KV, D). ``q_offset`` positions queries
    within the kv axis (decode: Tq=1, q_offset=pos). ``kv_valid_len`` masks
    cache slots >= length. ``window`` > 0 limits lookback (sliding window).
    """
    B, Tq, H, D = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    k = _repeat_kv(k, H // KV)
    v = _repeat_kv(v, H // KV)
    scale = D ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Tq)[:, None] + q_offset            # (Tq, 1)
    k_pos = jnp.arange(Tk)[None, :]                        # (1, Tk)
    valid = jnp.broadcast_to(jnp.ones((), bool), (Tq, Tk))
    if causal:
        valid = valid & (k_pos <= q_pos)
    if window:
        valid = valid & (k_pos > q_pos - window)
    if kv_valid_len is not None:
        # (B,) valid lengths -> (B, 1, 1, Tk)
        lv = jnp.arange(Tk)[None, :] < kv_valid_len[:, None]
        scores = jnp.where(lv[:, None, None, :], scores, NEG_INF)
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


CHUNK_THRESHOLD = 8192  # q-chunk the jnp path beyond this (memory: O(T*chunk))


def attention_chunked(q, k, v, *, causal, window, chunk: int = 1024):
    """Memory-efficient jnp attention: scores materialized per q-chunk only
    (the XLA-path analogue of flash tiling; the Pallas kernel is the TPU
    fast path)."""
    B, T, H, D = q.shape
    pad = (-T) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nch = q.shape[1] // chunk
    qs = jnp.moveaxis(q.reshape(B, nch, chunk, H, D), 1, 0)
    offs = jnp.arange(nch) * chunk

    def one(args):
        qc, off = args
        return attention_ref(qc, k, v, causal=causal, window=window,
                             q_offset=off)

    from repro.models.common import scan_unroll
    _, outs = jax.lax.scan(lambda c, x: (c, one(x)), None, (qs, offs),
                           unroll=scan_unroll())
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nch * chunk, H, D)
    return out[:, :T]


def _attention(q, k, v, *, causal, window, use_pallas):
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window)
    if q.shape[1] >= CHUNK_THRESHOLD and q.shape[1] == k.shape[1]:
        return attention_chunked(q, k, v, causal=causal, window=window)
    return attention_ref(q, k, v, causal=causal, window=window)


def self_attention(p: Dict[str, Any], h: jnp.ndarray, *,
                   n_heads: int, n_kv_heads: int, head_dim: int,
                   rope_theta: float, causal: bool = True, window: int = 0,
                   positions: Optional[jnp.ndarray] = None,
                   use_pallas: bool = False,
                   return_kv: bool = False):
    """Full-sequence self attention (train / prefill)."""
    B, T, _ = h.shape
    q = _split_heads(linear(h, p["wq"]), n_heads, head_dim)
    k = _split_heads(linear(h, p["wk"]), n_kv_heads, head_dim)
    v = _split_heads(linear(h, p["wv"]), n_kv_heads, head_dim)
    if rope_theta:
        pos = jnp.arange(T) if positions is None else positions
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    q = shard_act(q, ("batch", "seq", "heads", None))
    k = shard_act(k, ("batch", "seq", "kv_heads", None))
    v = shard_act(v, ("batch", "seq", "kv_heads", None))
    out = _attention(q, k, v, causal=causal, window=window, use_pallas=use_pallas)
    out = linear(out.reshape(B, T, -1), p["wo"])
    if return_kv:
        return out, (k, v)
    return out


def cross_attention(p: Dict[str, Any], h: jnp.ndarray, memory: jnp.ndarray, *,
                    n_heads: int, n_kv_heads: int, head_dim: int,
                    use_pallas: bool = False,
                    memory_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    return_kv: bool = False):
    """Cross attention over an encoder/image memory (non-causal)."""
    B, T, _ = h.shape
    q = _split_heads(linear(h, p["wq"]), n_heads, head_dim)
    if memory_kv is None:
        k = _split_heads(linear(memory, p["wk"]), n_kv_heads, head_dim)
        v = _split_heads(linear(memory, p["wv"]), n_kv_heads, head_dim)
    else:
        k, v = memory_kv
    out = _attention(q, k, v, causal=False, window=0, use_pallas=use_pallas)
    out = linear(out.reshape(B, T, -1), p["wo"])
    if return_kv:
        return out, (k, v)
    return out


# ---------------------------------------------------------------------------
# Decode (single new token against a KV cache)
# ---------------------------------------------------------------------------


def decode_self_attention(p: Dict[str, Any], h: jnp.ndarray,
                          cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                          pos: jnp.ndarray, *,
                          n_heads: int, n_kv_heads: int, head_dim: int,
                          rope_theta: float, window: int = 0):
    """h: (B, 1, d); cache_k/v: (B, S, KV, D); pos: scalar int32 — the index
    of the new token. Returns (out, (cache_k, cache_v)) with the new KV
    written at ``pos`` (ring-buffered modulo S for sliding windows)."""
    B = h.shape[0]
    S = cache_k.shape[1]
    q = _split_heads(linear(h, p["wq"]), n_heads, head_dim)
    k_new = _split_heads(linear(h, p["wk"]), n_kv_heads, head_dim)
    v_new = _split_heads(linear(h, p["wv"]), n_kv_heads, head_dim)
    if rope_theta:
        pvec = jnp.full((1,), 0, jnp.int32) + pos
        q = apply_rope(q, pvec, rope_theta)
        k_new = apply_rope(k_new, pvec, rope_theta)
    slot = jnp.mod(pos, S) if window else jnp.minimum(pos, S - 1)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(cache_v.dtype), slot, axis=1)
    cache_k = shard_act(cache_k, ("batch", "kv_seq", None, None))
    cache_v = shard_act(cache_v, ("batch", "kv_seq", None, None))
    k = _repeat_kv(cache_k, n_heads // n_kv_heads)
    v = _repeat_kv(cache_v, n_heads // n_kv_heads)
    scale = head_dim ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale      # (B, H, 1, S)
    k_idx = jnp.arange(S)
    if window:
        # ring buffer: valid slots are the last min(pos+1, window) writes
        age = jnp.mod(pos - k_idx, S)                        # steps since write
        valid = jnp.where(pos >= S, age < window, (k_idx <= pos) & (age < window))
    else:
        valid = k_idx <= jnp.minimum(pos, S - 1)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32)).astype(h.dtype)
    out = linear(out.reshape(B, 1, -1), p["wo"])
    return out, (cache_k, cache_v)


def decode_cross_attention(p: Dict[str, Any], h: jnp.ndarray,
                           mem_k: jnp.ndarray, mem_v: jnp.ndarray, *,
                           n_heads: int, n_kv_heads: int, head_dim: int):
    """Decode-time cross attention over a precomputed memory KV."""
    B = h.shape[0]
    q = _split_heads(linear(h, p["wq"]), n_heads, head_dim)
    out = attention_ref(q, mem_k, mem_v, causal=False)
    return linear(out.reshape(B, 1, -1), p["wo"])


def init_kv_cache(batch: int, seq_len: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16, window: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sliding-window layers only need ``window`` slots (ring buffer)."""
    S = min(seq_len, window) if window else seq_len
    shape = (batch, S, n_kv_heads, head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)
