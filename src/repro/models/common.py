"""Shared functional building blocks: norms, linears, embeddings, RoPE,
dtype policy and logical-axis activation sharding.

All models are pure functions over explicit parameter pytrees (nested dicts of
``jnp.ndarray``).  Repeated blocks store parameters *stacked* along a leading
layer axis so the forward pass is a ``lax.scan`` — this keeps the HLO compact
enough to SPMD-partition for 512 devices and is the idiomatic TPU pattern.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Logical-axis activation sharding context
# ---------------------------------------------------------------------------

_CTX = threading.local()


@contextlib.contextmanager
def activation_sharding(rules: Dict[str, Optional[object]]):
    """Enable ``shard_act`` constraints inside the context.

    ``rules`` maps logical axis names (e.g. ``'batch'``, ``'embed'``,
    ``'heads'``, ``'ff'``, ``'vocab'``, ``'seq'``, ``'kv_seq'``, ``'expert'``)
    to physical mesh axis names — a string, a tuple of axis names, or None
    for replicated.  Requires an ambient mesh (``jax.set_mesh``); constraints
    use bare PartitionSpecs so they also work inside partial-manual
    ``shard_map`` bodies (pipeline stages).
    """
    prev = getattr(_CTX, "val", None)
    _CTX.val = dict(rules)
    try:
        yield
    finally:
        _CTX.val = prev


# jax-version shims live in repro.compat; re-exported here because model and
# launch code historically imported them from this module
from repro.compat import (  # noqa: F401  (re-export)
    ambient_mesh as _ambient_mesh, pcast_varying, set_mesh, shard_map,
)


def shard_act(x: jnp.ndarray, names: Sequence[Optional[str]]) -> jnp.ndarray:
    """Apply a with_sharding_constraint from logical axis names (no-op outside
    an :func:`activation_sharding` context)."""
    rules = getattr(_CTX, "val", None)
    if rules is None:
        return x
    mesh = _ambient_mesh()
    if mesh is None or mesh.empty:
        return x  # no ambient mesh (single-device tests): no-op
    spec = P(*[rules.get(n) if n is not None else None for n in names])
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def _normal(rng, shape, scale, dtype):
    return (scale * jax.random.normal(rng, shape, dtype=jnp.float32)).astype(dtype)


def dense_init(rng, d_in: int, d_out: int, dtype=jnp.float32,
               stack: Tuple[int, ...] = ()) -> jnp.ndarray:
    """Fan-in scaled normal init; optional leading stack dims."""
    scale = d_in ** -0.5
    return _normal(rng, (*stack, d_in, d_out), scale, dtype)


def embed_init(rng, vocab: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    return _normal(rng, (vocab, d), 0.02, dtype)


def ones_init(shape, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.ones(shape, dtype)


def zeros_init(shape, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.zeros(shape, dtype)


# ---------------------------------------------------------------------------
# Core ops
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def linear(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x: (..., d_in) @ w: (d_in, d_out) in the compute dtype of x."""
    return jnp.einsum("...i,io->...o", x, w.astype(x.dtype))


def activate(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu" or kind == "silu":
        return jax.nn.silu(x)
    if kind == "geglu" or kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind!r}")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                    # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                        # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          z_loss: float = 0.0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token-level CE with f32 accumulation but NO materialized f32 copy of
    the logits: the upcast happens inside the reductions (XLA fuses
    cast+sub+exp into the reduce), which matters at 256k vocab where an f32
    logits copy is 2x the bf16 activation itself.

    logits: (..., V); labels: (...,) int. Returns (loss, correct@1)."""
    m = jnp.max(logits.astype(jnp.float32), axis=-1)          # fused reduce
    shifted_sum = jnp.sum(
        jnp.exp(logits.astype(jnp.float32) - m[..., None]), axis=-1)
    lse = m + jnp.log(shifted_sum)
    ll = jnp.take_along_axis(logits, labels[..., None],
                             axis=-1)[..., 0].astype(jnp.float32)
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    acc = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    return loss, acc


# ---------------------------------------------------------------------------
# Analysis (unroll) mode — the dry-run's cost-analysis pass
# ---------------------------------------------------------------------------
# XLA's cost_analysis counts a while-loop body ONCE, so scan-based models
# under-report FLOPs/collective bytes by the trip count.  The dry-run lowers
# a second "analysis" variant with every scan unrolled (exact costs); the
# production scanned variant provides memory analysis + the compile proof.

_UNROLL = False


def set_unroll(v: bool) -> None:
    global _UNROLL
    _UNROLL = bool(v)


def scan_unroll() -> bool:
    """Pass as ``unroll=`` to every structural lax.scan."""
    return _UNROLL


# ---------------------------------------------------------------------------
# Activation compute dtype policy
# ---------------------------------------------------------------------------
# Parameters may be stored f32 (optimizer master copies) while compute runs
# bf16 (the TPU-native policy): the cast happens once at the embedding;
# ``linear`` already casts weights to the activation dtype per use.

_ACT_DTYPE = None


def set_act_dtype(dt) -> None:
    global _ACT_DTYPE
    _ACT_DTYPE = dt


def act_dtype_cast(x: jnp.ndarray) -> jnp.ndarray:
    if _ACT_DTYPE is not None and x.dtype != _ACT_DTYPE:
        return x.astype(_ACT_DTYPE)
    return x
