"""Llama-3.2-Vision-style VLM backbone: decoder with cross-attention image
layers every ``cross_attn_every`` layers.  The vision tower is a STUB —
``input_specs`` supplies precomputed patch embeddings (B, n_image_tokens, d).

100 layers = 20 groups of (4 self-attn blocks + 1 cross-attn block).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import transformer as tf
from repro.models.common import dense_init, embed_init, rms_norm, scan_unroll

Params = Dict[str, Any]


def _group_dims(cfg: ArchConfig):
    gsz = cfg.cross_attn_every
    n_groups = cfg.n_layers // gsz
    return n_groups, gsz - 1  # (groups, self layers per group)


def cross_block_init(cfg: ArchConfig, rng, dtype) -> Params:
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "xattn": attn.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, dtype),
        "gate_a": jnp.zeros((), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": mlp_mod.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
        "gate_m": jnp.zeros((), dtype),
    }


def init(cfg: ArchConfig, rng, dtype=jnp.float32) -> Params:
    ks = jax.random.split(rng, 4)
    n_groups, n_self = _group_dims(cfg)
    self_blocks = jax.vmap(lambda r: tf.block_init(cfg, r, dtype))(
        jax.random.split(ks[1], n_groups * n_self))
    cross_blocks = jax.vmap(lambda r: cross_block_init(cfg, r, dtype))(
        jax.random.split(ks[2], n_groups))
    return {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "self_blocks": jax.tree.map(
            lambda x: x.reshape(n_groups, n_self, *x.shape[1:]), self_blocks),
        "cross_blocks": cross_blocks,
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(ks[3], cfg.d_model, cfg.vocab_size, dtype),
    }


def _cross_apply(cfg: ArchConfig, p: Params, h, memory, *, use_pallas,
                 memory_kv=None, return_kv=False):
    res = attn.cross_attention(
        p["xattn"], rms_norm(h, p["ln1"], cfg.norm_eps), memory,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        use_pallas=use_pallas, memory_kv=memory_kv, return_kv=return_kv)
    if return_kv:
        a, kv = res
    else:
        a, kv = res, None
    h = h + jnp.tanh(p["gate_a"].astype(jnp.float32)).astype(h.dtype) * a
    m = mlp_mod.mlp(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps), cfg.activation)
    h = h + jnp.tanh(p["gate_m"].astype(jnp.float32)).astype(h.dtype) * m
    return (h, kv) if return_kv else h


def forward(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray], *,
            use_pallas: bool = False, remat: bool = True):
    h = tf.embed_tokens(cfg, params, batch["tokens"])
    memory = batch["image_embeds"].astype(h.dtype)

    def group_body(carry, inp):
        pg_self, pg_cross = inp

        def self_body(c, p):
            return tf._block_apply(cfg, p, c, window=0, use_pallas=use_pallas), None
        carry, _ = jax.lax.scan(self_body, carry, pg_self)
        carry = _cross_apply(cfg, pg_cross, carry, memory, use_pallas=use_pallas)
        return carry, None

    group_body = jax.checkpoint(group_body) if remat else group_body
    h, _ = jax.lax.scan(group_body, h,
                        (params["self_blocks"], params["cross_blocks"]),
                        unroll=scan_unroll())
    return tf.lm_head(cfg, params, h), jnp.zeros((), jnp.float32)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.bfloat16) -> Params:
    n_groups, n_self = _group_dims(cfg)
    kv = (n_groups, n_self, batch, seq_len, cfg.n_kv_heads, cfg.head_dim)
    mem = (n_groups, batch, cfg.n_image_tokens, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
            "mem_k": jnp.zeros(mem, dtype), "mem_v": jnp.zeros(mem, dtype)}


def prefill_cross_kv(cfg: ArchConfig, params: Params, image_embeds, cache: Params):
    """Precompute per-group cross-attention KV from image memory."""
    def one(p):
        k = attn._split_heads(
            jnp.einsum("bmd,dk->bmk", image_embeds, p["xattn"]["wk"]),
            cfg.n_kv_heads, cfg.head_dim)
        v = attn._split_heads(
            jnp.einsum("bmd,dk->bmk", image_embeds, p["xattn"]["wv"]),
            cfg.n_kv_heads, cfg.head_dim)
        return k, v
    k, v = jax.vmap(one)(params["cross_blocks"])
    return {**cache, "mem_k": k.astype(cache["mem_k"].dtype),
            "mem_v": v.astype(cache["mem_v"].dtype)}


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray):
    h = tf.embed_tokens(cfg, params, tokens)

    def group_body(carry, inp):
        pg_self, pg_cross, ck, cv, mk, mv = inp
        nk, nv = [], []
        n_self = ck.shape[0]
        for i in range(n_self):
            p = jax.tree.map(lambda x: x[i], pg_self)
            carry, cki, cvi = tf._decode_block(cfg, p, carry, ck[i], cv[i], pos, 0)
            nk.append(cki)
            nv.append(cvi)
        a = attn.decode_cross_attention(
            pg_cross["xattn"], rms_norm(carry, pg_cross["ln1"], cfg.norm_eps),
            mk, mv, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim)
        carry = carry + jnp.tanh(pg_cross["gate_a"].astype(jnp.float32)).astype(carry.dtype) * a
        m = mlp_mod.mlp(pg_cross["mlp"], rms_norm(carry, pg_cross["ln2"], cfg.norm_eps),
                        cfg.activation)
        carry = carry + jnp.tanh(pg_cross["gate_m"].astype(jnp.float32)).astype(carry.dtype) * m
        return carry, (jnp.stack(nk), jnp.stack(nv))

    h, (nk, nv) = jax.lax.scan(
        group_body, h,
        (params["self_blocks"], params["cross_blocks"],
         cache["k"], cache["v"], cache["mem_k"], cache["mem_v"]),
        unroll=scan_unroll())
    new_cache = {**cache, "k": nk, "v": nv}
    return tf.lm_head(cfg, params, h), new_cache
