"""Feed-forward blocks: gated (swiglu/geglu) and plain (gelu/relu^2)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import activate, dense_init, linear, shard_act

GATED = ("swiglu", "geglu")


def mlp_init(rng, d_model: int, d_ff: int, activation: str,
             dtype=jnp.float32, stack: Tuple[int, ...] = ()) -> Dict[str, Any]:
    ks = jax.random.split(rng, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype, stack),
         "w_down": dense_init(ks[1], d_ff, d_model, dtype, stack)}
    if activation in GATED:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype, stack)
    return p


def mlp(p: Dict[str, Any], h: jnp.ndarray, activation: str) -> jnp.ndarray:
    up = linear(h, p["w_up"])
    if activation in GATED:
        up = activate(linear(h, p["w_gate"]), activation) * up
    else:
        up = activate(up, activation)
    up = shard_act(up, ("batch", "seq", "ff"))
    return linear(up, p["w_down"])
