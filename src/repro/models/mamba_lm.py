"""Mamba-2 (SSD) language model — attention-free (mamba2-2.7b)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf
from repro.models.common import dense_init, embed_init, rms_norm, scan_unroll
from repro.models.ssm import (
    ssm_block, ssm_decode_step, ssm_init, ssm_init_state,
)

Params = Dict[str, Any]


def block_init(cfg: ArchConfig, rng, dtype) -> Params:
    return {
        "ln": jnp.ones((cfg.d_model,), dtype),
        "ssm": ssm_init(rng, cfg.d_model, cfg.d_inner, cfg.ssm_state,
                        cfg.n_ssm_heads, cfg.ssm_conv, dtype),
    }


def init(cfg: ArchConfig, rng, dtype=jnp.float32) -> Params:
    k_emb, k_blocks, k_head = jax.random.split(rng, 3)
    p: Params = {
        "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, dtype),
        "blocks": jax.vmap(lambda k: block_init(cfg, k, dtype))(
            jax.random.split(k_blocks, cfg.n_layers)),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
    return p


def _block_apply(cfg: ArchConfig, p: Params, h: jnp.ndarray, *, use_pallas: bool):
    return h + ssm_block(
        p["ssm"], rms_norm(h, p["ln"], cfg.norm_eps),
        d_inner=cfg.d_inner, d_state=cfg.ssm_state, n_heads=cfg.n_ssm_heads,
        head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk, use_pallas=use_pallas,
        norm_eps=cfg.norm_eps)


def forward(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray], *,
            use_pallas: bool = False, remat: bool = True):
    h = tf.embed_tokens(cfg, params, batch["tokens"])

    def body(carry, p):
        return _block_apply(cfg, p, carry, use_pallas=use_pallas), None

    body = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body, h, params["blocks"], unroll=scan_unroll())
    return tf.lm_head(cfg, params, h), jnp.zeros((), jnp.float32)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.bfloat16) -> Params:
    del seq_len, dtype  # SSM state is O(1) in sequence length
    single = ssm_init_state(batch, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads,
                            cfg.ssm_head_dim, cfg.ssm_conv)
    return jax.tree.map(
        lambda x: jnp.zeros((cfg.n_layers, *x.shape), x.dtype), single)


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray):
    del pos  # SSM decode is position-free
    h = tf.embed_tokens(cfg, params, tokens)

    def body(carry, inp):
        p, st = inp
        out, st = ssm_decode_step(
            p["ssm"], rms_norm(carry, p["ln"], cfg.norm_eps), st,
            d_inner=cfg.d_inner, d_state=cfg.ssm_state, n_heads=cfg.n_ssm_heads,
            head_dim=cfg.ssm_head_dim, norm_eps=cfg.norm_eps)
        return carry + out, st

    h, new_cache = jax.lax.scan(body, h, (params["blocks"], cache),
                                unroll=scan_unroll())
    return tf.lm_head(cfg, params, h), new_cache
