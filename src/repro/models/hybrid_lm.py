"""Zamba2-style hybrid LM: Mamba-2 backbone + one *shared* transformer block
applied every ``shared_attn_every`` layers through per-application adapters.

zamba2-7b: 81 SSD layers, shared block at layers 6,12,...,78 (13 applications)
plus a 3-layer tail.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mamba_lm
from repro.models import mlp as mlp_mod
from repro.models import transformer as tf
from repro.models.common import (
    dense_init, embed_init, linear, rms_norm, scan_unroll,
)
from repro.models.ssm import ssm_decode_step, ssm_init_state

Params = Dict[str, Any]


def _n_apps(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every


def _n_tail(cfg: ArchConfig) -> int:
    return cfg.n_layers - _n_apps(cfg) * cfg.shared_attn_every


def init(cfg: ArchConfig, rng, dtype=jnp.float32) -> Params:
    ks = jax.random.split(rng, 8)
    n_apps, k = _n_apps(cfg), cfg.shared_attn_every
    blocks = jax.vmap(lambda r: mamba_lm.block_init(cfg, r, dtype))(
        jax.random.split(ks[1], cfg.n_layers))
    grouped = jax.tree.map(
        lambda x: x[:n_apps * k].reshape(n_apps, k, *x.shape[1:]), blocks)
    tail = jax.tree.map(lambda x: x[n_apps * k:], blocks)
    p: Params = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "groups": grouped,
        "tail": tail,
        "shared": {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": attn.attn_init(ks[2], cfg.d_model, cfg.n_heads,
                                   cfg.n_kv_heads, cfg.head_dim, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mlp": mlp_mod.mlp_init(ks[3], cfg.d_model, cfg.d_ff,
                                    cfg.activation, dtype),
        },
        "adapt_in": dense_init(ks[4], cfg.d_model, cfg.d_model, dtype, (n_apps,)),
        "adapt_out": dense_init(ks[5], cfg.d_model, cfg.d_model, dtype, (n_apps,)),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[6], cfg.d_model, cfg.vocab_size, dtype)
    return p


def _shared_apply(cfg: ArchConfig, shared: Params, a_in, a_out, h, *, use_pallas):
    x = linear(h, a_in)
    y = attn.self_attention(
        shared["attn"], rms_norm(x, shared["ln1"], cfg.norm_eps),
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta, causal=True, use_pallas=use_pallas)
    x = x + y
    x = x + mlp_mod.mlp(shared["mlp"], rms_norm(x, shared["ln2"], cfg.norm_eps),
                        cfg.activation)
    return h + linear(x, a_out)


def forward(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray], *,
            use_pallas: bool = False, remat: bool = True):
    h = tf.embed_tokens(cfg, params, batch["tokens"])
    shared = params["shared"]

    def group_body(carry, inp):
        pg, a_in, a_out = inp

        def ssm_body(c, p):
            return mamba_lm._block_apply(cfg, p, c, use_pallas=use_pallas), None
        carry, _ = jax.lax.scan(ssm_body, carry, pg)
        carry = _shared_apply(cfg, shared, a_in, a_out, carry,
                              use_pallas=use_pallas)
        return carry, None

    group_body = jax.checkpoint(group_body) if remat else group_body
    h, _ = jax.lax.scan(group_body, h,
                        (params["groups"], params["adapt_in"], params["adapt_out"]),
                        unroll=scan_unroll())

    def tail_body(c, p):
        return mamba_lm._block_apply(cfg, p, c, use_pallas=use_pallas), None
    tail_body = jax.checkpoint(tail_body) if remat else tail_body
    h, _ = jax.lax.scan(tail_body, h, params["tail"], unroll=scan_unroll())
    return tf.lm_head(cfg, params, h), jnp.zeros((), jnp.float32)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.bfloat16) -> Params:
    n_apps, k = _n_apps(cfg), cfg.shared_attn_every
    ssm_single = ssm_init_state(batch, cfg.d_inner, cfg.ssm_state,
                                cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv)
    kv_shape = (n_apps, batch, seq_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "ssm_groups": jax.tree.map(
            lambda x: jnp.zeros((n_apps, k, *x.shape), x.dtype), ssm_single),
        "ssm_tail": jax.tree.map(
            lambda x: jnp.zeros((_n_tail(cfg), *x.shape), x.dtype), ssm_single),
        "k": jnp.zeros(kv_shape, dtype), "v": jnp.zeros(kv_shape, dtype),
    }


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray):
    h = tf.embed_tokens(cfg, params, tokens)
    shared = params["shared"]
    k_every = cfg.shared_attn_every

    def ssm_step(c, p, st):
        out, st = ssm_decode_step(
            p["ssm"], rms_norm(c, p["ln"], cfg.norm_eps), st,
            d_inner=cfg.d_inner, d_state=cfg.ssm_state, n_heads=cfg.n_ssm_heads,
            head_dim=cfg.ssm_head_dim, norm_eps=cfg.norm_eps)
        return c + out, st

    def group_body(carry, inp):
        pg, a_in, a_out, st_g, ck, cv = inp

        def inner(c, xs):
            p, st = xs
            c, st = ssm_step(c, p, st)
            return c, st
        carry, st_g = jax.lax.scan(inner, carry, (pg, st_g))
        # shared attention block (decode)
        x = linear(carry, a_in)
        y, (ck, cv) = attn.decode_self_attention(
            shared["attn"], rms_norm(x, shared["ln1"], cfg.norm_eps), ck, cv, pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
            rope_theta=cfg.rope_theta)
        x = x + y
        x = x + mlp_mod.mlp(shared["mlp"], rms_norm(x, shared["ln2"], cfg.norm_eps),
                            cfg.activation)
        carry = carry + linear(x, a_out)
        return carry, (st_g, ck, cv)

    h, (st_groups, nk, nv) = jax.lax.scan(
        group_body, h,
        (params["groups"], params["adapt_in"], params["adapt_out"],
         cache["ssm_groups"], cache["k"], cache["v"]), unroll=scan_unroll())

    def tail_body(c, xs):
        p, st = xs
        c, st = ssm_step(c, p, st)
        return c, st
    h, st_tail = jax.lax.scan(tail_body, h, (params["tail"], cache["ssm_tail"]),
                              unroll=scan_unroll())

    new_cache = {"ssm_groups": st_groups, "ssm_tail": st_tail, "k": nk, "v": nv}
    return tf.lm_head(cfg, params, h), new_cache
