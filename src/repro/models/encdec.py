"""Whisper-style encoder-decoder backbone.

The conv frontend is a STUB: ``input_specs`` supplies precomputed frame
embeddings (B, enc_frames, d).  Encoder: bidirectional self-attention blocks.
Decoder: causal self-attention + cross-attention blocks.  Absolute learned
position embeddings (rope disabled per config).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models import transformer as tf
from repro.models.common import (
    dense_init, embed_init, rms_norm, scan_unroll, shard_act,
)

Params = Dict[str, Any]

MAX_DEC_POS = 4096  # decoder learned positions (backbone setting)


def dec_block_init(cfg: ArchConfig, rng, dtype) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.attn_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, dtype),
        "ln_x": jnp.ones((cfg.d_model,), dtype),
        "xattn": attn.attn_init(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                cfg.head_dim, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": mlp_mod.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.activation, dtype),
    }


def init(cfg: ArchConfig, rng, dtype=jnp.float32) -> Params:
    ks = jax.random.split(rng, 6)
    return {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "pos_embed": 0.02 * jax.random.normal(
            ks[1], (MAX_DEC_POS, cfg.d_model), jnp.float32).astype(dtype),
        "enc_blocks": jax.vmap(lambda r: tf.block_init(cfg, r, dtype))(
            jax.random.split(ks[2], cfg.enc_layers)),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "dec_blocks": jax.vmap(lambda r: dec_block_init(cfg, r, dtype))(
            jax.random.split(ks[3], cfg.n_layers)),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": dense_init(ks[4], cfg.d_model, cfg.vocab_size, dtype),
    }


def encode(cfg: ArchConfig, params: Params, frames: jnp.ndarray, *,
           use_pallas: bool = False, remat: bool = True) -> jnp.ndarray:
    h = frames

    def body(carry, p):
        a = attn.self_attention(
            p["attn"], rms_norm(carry, p["ln1"], cfg.norm_eps),
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=0.0, causal=False,
            use_pallas=use_pallas)
        carry = carry + a
        carry = carry + mlp_mod.mlp(
            p["mlp"], rms_norm(carry, p["ln2"], cfg.norm_eps), cfg.activation)
        return carry, None

    body = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body, h, params["enc_blocks"], unroll=scan_unroll())
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _dec_block(cfg, p, h, memory, *, use_pallas):
    a = attn.self_attention(
        p["attn"], rms_norm(h, p["ln1"], cfg.norm_eps),
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        rope_theta=0.0, causal=True, use_pallas=use_pallas)
    h = h + a
    x = attn.cross_attention(
        p["xattn"], rms_norm(h, p["ln_x"], cfg.norm_eps), memory,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim,
        use_pallas=use_pallas)
    h = h + x
    h = h + mlp_mod.mlp(p["mlp"], rms_norm(h, p["ln2"], cfg.norm_eps),
                        cfg.activation)
    return shard_act(h, ("batch", "seq", "embed"))


def forward(cfg: ArchConfig, params: Params, batch: Dict[str, jnp.ndarray], *,
            use_pallas: bool = False, remat: bool = True):
    memory = encode(cfg, params, batch["frames"].astype(params["embed"].dtype),
                    use_pallas=use_pallas, remat=remat)
    tokens = batch["tokens"]
    T = tokens.shape[1]
    h = params["embed"][tokens]
    pos = jnp.arange(T) % MAX_DEC_POS
    h = h + params["pos_embed"][pos][None]

    def body(carry, p):
        return _dec_block(cfg, p, carry, memory, use_pallas=use_pallas), None

    body = jax.checkpoint(body) if remat else body
    h, _ = jax.lax.scan(body, h, params["dec_blocks"], unroll=scan_unroll())
    return tf.lm_head(cfg, params, h), jnp.zeros((), jnp.float32)


def init_cache(cfg: ArchConfig, batch: int, seq_len: int, dtype=jnp.bfloat16) -> Params:
    kv = (cfg.n_layers, batch, seq_len, cfg.n_kv_heads, cfg.head_dim)
    mem = (cfg.n_layers, batch, cfg.enc_frames, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
            "mem_k": jnp.zeros(mem, dtype), "mem_v": jnp.zeros(mem, dtype)}


def prefill_memory(cfg: ArchConfig, params: Params, frames, cache: Params):
    """Encode frames and precompute per-layer cross-attention KV."""
    memory = encode(cfg, params, frames.astype(params["embed"].dtype))

    def one(p):
        k = attn._split_heads(
            jnp.einsum("bmd,dk->bmk", memory, p["xattn"]["wk"]),
            cfg.n_kv_heads, cfg.head_dim)
        v = attn._split_heads(
            jnp.einsum("bmd,dk->bmk", memory, p["xattn"]["wv"]),
            cfg.n_kv_heads, cfg.head_dim)
        return k, v
    k, v = jax.vmap(one)(params["dec_blocks"])
    return {**cache, "mem_k": k.astype(cache["mem_k"].dtype),
            "mem_v": v.astype(cache["mem_v"].dtype)}


def decode_step(cfg: ArchConfig, params: Params, cache: Params,
                tokens: jnp.ndarray, pos: jnp.ndarray):
    h = params["embed"][tokens]
    h = h + params["pos_embed"][jnp.mod(pos, MAX_DEC_POS)][None, None]

    def body(carry, inp):
        p, ck, cv, mk, mv = inp
        a, (ck, cv) = attn.decode_self_attention(
            p["attn"], rms_norm(carry, p["ln1"], cfg.norm_eps), ck, cv, pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, rope_theta=0.0)
        carry = carry + a
        x = attn.decode_cross_attention(
            p["xattn"], rms_norm(carry, p["ln_x"], cfg.norm_eps), mk, mv,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim)
        carry = carry + x
        carry = carry + mlp_mod.mlp(
            p["mlp"], rms_norm(carry, p["ln2"], cfg.norm_eps), cfg.activation)
        return carry, (ck, cv)

    h, (nk, nv) = jax.lax.scan(
        body, h, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["mem_k"], cache["mem_v"]), unroll=scan_unroll())
    new_cache = {**cache, "k": nk, "v": nv}
    return tf.lm_head(cfg, params, h), new_cache
