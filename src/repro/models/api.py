"""Unified model API.

``build_model(cfg)`` returns a :class:`Model` with pure functions
``init / forward / loss / init_cache / decode_step`` dispatching on
``cfg.family``.  ``input_specs(cfg, shape)`` returns ShapeDtypeStruct
stand-ins for every model input of a given shape cell (no allocation) —
the same structs feed ``jit(...).lower()`` in the multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import encdec, hybrid_lm, mamba_lm, moe_lm, transformer, vlm
from repro.models.common import softmax_cross_entropy

_FAMILY = {
    "dense": transformer,
    "moe": moe_lm,
    "ssm": mamba_lm,
    "hybrid": hybrid_lm,
    "vlm": vlm,
    "audio": encdec,
}


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[..., Any]
    forward: Callable[..., Any]          # (params, batch) -> (logits, aux)
    loss: Callable[..., Any]             # (params, batch) -> (scalar, metrics)
    init_cache: Callable[..., Any]       # (batch, seq_len) -> cache
    decode_step: Callable[..., Any]      # (params, cache, tokens, pos) -> (logits, cache)


def build_model(cfg: ArchConfig, *, use_pallas: bool = False,
                remat: bool = True, param_dtype=jnp.float32) -> Model:
    mod = _FAMILY[cfg.family]

    def init_fn(rng):
        return mod.init(cfg, rng, dtype=param_dtype)

    def forward_fn(params, batch):
        return mod.forward(cfg, params, batch, use_pallas=use_pallas, remat=remat)

    def loss_fn(params, batch):
        logits, aux = forward_fn(params, batch)
        per_tok, acc = softmax_cross_entropy(logits, batch["labels"])
        mask = batch.get("loss_mask")
        if mask is None:
            loss = jnp.mean(per_tok)
            accuracy = jnp.mean(acc)
        else:
            denom = jnp.maximum(jnp.sum(mask), 1.0)
            loss = jnp.sum(per_tok * mask) / denom
            accuracy = jnp.sum(acc * mask) / denom
        total = loss + aux
        return total, {"loss": loss, "aux_loss": aux, "accuracy": accuracy}

    def init_cache_fn(batch, seq_len, dtype=jnp.bfloat16):
        return mod.init_cache(cfg, batch, seq_len, dtype)

    def decode_fn(params, cache, tokens, pos):
        return mod.decode_step(cfg, params, cache, tokens, pos)

    return Model(cfg=cfg, init=init_fn, forward=forward_fn, loss=loss_fn,
                 init_cache=init_cache_fn, decode_step=decode_fn)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins — weak-type-correct, no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                act_dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Batch input structs for (arch, shape).

    train/prefill: token batch (+ modality stubs). decode: single-token batch
    (+ position); the KV cache/SSM state is built by ``cache_specs``."""
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        specs: Dict[str, jax.ShapeDtypeStruct] = {
            "tokens": jax.ShapeDtypeStruct((B, T), i32),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, T), i32)
        if cfg.family == "vlm":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_image_tokens, cfg.d_model), act_dtype)
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_frames, cfg.d_model), act_dtype)
        return specs
    # decode: one new token against a cache of length T
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def cache_specs(cfg: ArchConfig, shape: ShapeSpec,
                dtype=jnp.bfloat16) -> Any:
    """ShapeDtypeStructs of the KV cache / SSM state for a decode cell."""
    model = build_model(cfg)
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len, dtype))


def param_specs(cfg: ArchConfig, param_dtype=jnp.float32) -> Any:
    """ShapeDtypeStructs of the parameter pytree (no allocation)."""
    model = build_model(cfg, param_dtype=param_dtype)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))
