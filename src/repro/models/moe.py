"""Top-k routed mixture-of-experts with capacity-bounded scatter dispatch.

Dispatch is the GShard cumsum algorithm without the dense (T, E, C) one-hot:
per-assignment positions inside each expert come from a cumulative sum of the
assignment one-hot, then tokens are scattered into an (E, C, d) buffer
(out-of-capacity assignments dropped), experts run as one batched einsum, and
outputs are gathered back weighted by the router gate.  Under GSPMD the
scatter/gather lower to all-to-all-style exchanges when experts are sharded.

Expert weights are sharded expert-dim over the ``data`` axis (EP=DP, confined
to a pod — paper rule 1) and ff-dim over ``model``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import activate, dense_init, linear, shard_act
from repro.models.mlp import GATED


def moe_init(rng, d_model: int, d_ff: int, n_experts: int, activation: str,
             dtype=jnp.float32, stack: Tuple[int, ...] = ()) -> Dict[str, Any]:
    ks = jax.random.split(rng, 4)
    p = {
        "router": dense_init(ks[0], d_model, n_experts, jnp.float32, stack),
        "w_up": dense_init(ks[1], d_model, d_ff, dtype, (*stack, n_experts)),
        "w_down": dense_init(ks[2], d_ff, d_model, dtype, (*stack, n_experts)),
    }
    if activation in GATED:
        p["w_gate"] = dense_init(ks[3], d_model, d_ff, dtype, (*stack, n_experts))
    return p


def _capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(n_tokens * top_k * factor / n_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_block(p: Dict[str, Any], h: jnp.ndarray, *, top_k: int,
              capacity_factor: float, activation: str,
              router_aux_coef: float = 0.0):
    """h: (B, T, d) -> (out: (B, T, d), aux_loss: scalar f32)."""
    B, T, d = h.shape
    E = p["w_up"].shape[0]
    n_tok = B * T
    C = _capacity(n_tok, E, top_k, capacity_factor)
    x = h.reshape(n_tok, d)

    # --- routing (f32) -----------------------------------------------------
    logits = linear(x.astype(jnp.float32), p["router"])           # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)            # (N, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- load-balance auxiliary loss (Switch/GShard form) -------------------
    frac_prob = jnp.mean(probs, axis=0)                            # (E,)
    top1 = expert_ids[:, 0]
    frac_tok = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0)
    aux = router_aux_coef * E * jnp.sum(frac_prob * frac_tok)

    # --- positions within experts (priority = routing order, then token id) --
    flat_e = expert_ids.T.reshape(-1)                              # (k*N,) k-major
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)            # (k*N, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot                 # exclusive
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                      # (k*N,)
    keep = pos < C
    pos_c = jnp.where(keep, pos, C)                                # OOB -> dropped

    # --- dispatch: scatter tokens into (E, C, d) -----------------------------
    x_rep = jnp.broadcast_to(x[None], (top_k, n_tok, d)).reshape(-1, d)
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[flat_e, pos_c].set(x_rep.astype(x.dtype), mode="drop")
    buf = shard_act(buf, ("expert", None, "embed"))

    # --- expert computation (batched over experts) ----------------------------
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(buf.dtype))
    if activation in GATED:
        gt = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(buf.dtype))
        up = activate(gt, activation) * up
    else:
        up = activate(up, activation)
    up = shard_act(up, ("expert", None, "ff"))
    out_buf = jnp.einsum("ecf,efd->ecd", up, p["w_down"].astype(buf.dtype))

    # --- combine: gather + gate-weighted sum over k ----------------------------
    gathered = out_buf.at[flat_e, pos_c].get(mode="fill", fill_value=0)  # (k*N, d)
    w = (gate_vals.T.reshape(-1) * keep).astype(jnp.float32)
    y = jnp.sum((gathered.astype(jnp.float32) * w[:, None]).reshape(top_k, n_tok, d), axis=0)
    return y.reshape(B, T, d).astype(h.dtype), aux
