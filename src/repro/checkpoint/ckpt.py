"""Fault-tolerant checkpointing: atomic, resumable, reshardable,
incremental.

- ``save``: flatten the pytree to path-keyed arrays, write ``.npz`` to a temp
  file, fsync, atomic rename -> a crash mid-write never corrupts the latest
  checkpoint.  A rolling window of checkpoints is kept.
- ``restore``: load the newest (or a specific) step; missing -> None.
  Incremental checkpoints are resolved transparently: each file's manifest
  maps every leaf to the step whose file owns its newest bytes.
- ``reshard``: place restored host arrays onto a *different* mesh/sharding —
  the elastic-scaling path (node failure -> replan on the surviving cluster
  -> reshard the last checkpoint onto the new layout, see ``repro.migrate``).
- :class:`AsyncCheckpointer`: delta-since-last-save (unchanged leaves are
  *referenced*, not rewritten) with the write handed to a background thread
  — the training step only pays for the host snapshot.  The manifest rides
  inside the atomically-renamed file, so a preemption mid-write (or
  mid-migration) always falls back to the newest *consistent* state.

Leaf keys are joined with ``SEP``; a key containing the separator, or named
like the metadata entry, would silently corrupt the flat namespace — both
are rejected at save time (regression-tested in ``tests/test_checkpoint.py``).
"""
from __future__ import annotations

import json
import os
import re
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "|"
META_KEY = "__meta__"


def _key_str(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    for p in parts:
        if SEP in p:
            raise ValueError(
                f"checkpoint leaf key {p!r} contains the path separator "
                f"{SEP!r} — it would corrupt the flat key namespace; "
                f"rename the pytree key")
    key = SEP.join(parts)
    if key == META_KEY:
        raise ValueError(
            f"checkpoint leaf key {META_KEY!r} collides with the metadata "
            f"entry; rename the pytree key")
    return key


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_key_str(kp)] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, tmpl in leaves_kp:
        key = _key_str(kp)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {tmpl.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"ckpt_{step:010d}.npz")


# chaos seam: fn(step) -> None | "partial" | "fsync".  "partial" dies
# mid-stream (half the payload written), "fsync" dies after the payload but
# before the atomic rename.  Either way the destination path is never
# touched — the previous checkpoint stays readable, which is what the
# atomic-rename protocol promises and the chaos tests verify.
_WRITE_FAULT = None


def set_write_fault(fn):
    """Install (or clear, with None) the checkpoint write-fault hook.
    Returns the previous hook so tests can restore it."""
    global _WRITE_FAULT
    prev = _WRITE_FAULT
    _WRITE_FAULT = fn
    return prev


def _write_atomic(ckpt_dir: str, step: int, meta: Dict,
                  flat: Dict[str, np.ndarray]) -> str:
    path = _path(ckpt_dir, step)
    fault = _WRITE_FAULT(step) if _WRITE_FAULT is not None else None
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            if fault == "partial":
                # serialize to memory, write only half, then die — the torn
                # tmp file must never reach ``path``
                import io
                buf = io.BytesIO()
                np.savez(buf, **{META_KEY: json.dumps(meta)}, **flat)
                payload = buf.getvalue()
                f.write(payload[:len(payload) // 2])
                f.flush()
                raise IOError(f"injected partial write at step {step}")
            np.savez(f, **{META_KEY: json.dumps(meta)}, **flat)
            f.flush()
            os.fsync(f.fileno())
            if fault == "fsync":
                raise IOError(f"injected fsync failure at step {step}")
        os.replace(tmp, path)  # atomic
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    # observability: every durable save counts its bytes on the default
    # obs registry (record-only; the write path above is unchanged)
    from repro.obs.metrics import DEFAULT_REGISTRY
    DEFAULT_REGISTRY.inc("ckpt.saves")
    DEFAULT_REGISTRY.inc("ckpt.bytes_written", os.path.getsize(path))
    return path


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Full (self-contained) checkpoint of ``tree`` at ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    meta = {"step": step, "extra": extra or {}}
    path = _write_atomic(ckpt_dir, step, meta, flat)
    _gc(ckpt_dir, keep)
    return path


def _read_meta(ckpt_dir: str, step: int) -> Dict:
    with np.load(_path(ckpt_dir, step), allow_pickle=False) as z:
        return json.loads(str(z[META_KEY]))


def _gc(ckpt_dir: str, keep: int):
    """Drop all but the newest ``keep`` steps (``keep=0``/falsy keeps
    everything) — but never a step an incremental manifest in the kept
    window still references as a leaf owner."""
    ckpts = sorted(list_steps(ckpt_dir))
    if not keep:
        return
    kept, drop = ckpts[-keep:], ckpts[:-keep]
    if not drop:
        return
    referenced = set()
    for step in kept:
        meta = _read_meta(ckpt_dir, step)
        leaves = meta.get("leaves")
        if leaves:
            referenced.update(int(s) for s in leaves.values())
    for step in drop:
        if step not in referenced:
            os.unlink(_path(ckpt_dir, step))


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for fn in os.listdir(ckpt_dir):
        m = re.fullmatch(r"ckpt_(\d{10})\.npz", fn)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def restore(ckpt_dir: str, template, step: Optional[int] = None
            ) -> Optional[Tuple[int, Any, Dict]]:
    """Load the newest (or a specific) step into ``template``'s structure.
    Incremental checkpoints resolve each leaf from the step that owns its
    newest bytes (the file's ``leaves`` manifest)."""
    steps = list_steps(ckpt_dir)
    if not steps:
        return None
    step = steps[-1] if step is None else step
    with np.load(_path(ckpt_dir, step), allow_pickle=False) as z:
        meta = json.loads(str(z[META_KEY]))
        flat = {k: z[k] for k in z.files if k != META_KEY}
    leaves = meta.get("leaves")
    if leaves:
        by_owner: Dict[int, List[str]] = {}
        for key, owner in leaves.items():
            if key not in flat:
                by_owner.setdefault(int(owner), []).append(key)
        for owner, keys in sorted(by_owner.items()):
            with np.load(_path(ckpt_dir, owner), allow_pickle=False) as z:
                for k in keys:
                    if k not in z.files:
                        raise KeyError(
                            f"incremental checkpoint {step} references leaf "
                            f"{k} in step {owner}, which lacks it")
                    flat[k] = z[k]
    tree = _unflatten_into(template, flat)
    return meta["step"], tree, meta.get("extra", {})


def reshard(tree, shardings):
    """Place (host or differently-sharded) arrays onto new shardings —
    elastic scaling after a replan."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), tree, shardings)


# ---------------------------------------------------------------------------
# Async + incremental
# ---------------------------------------------------------------------------


class AsyncCheckpointer:
    """Delta checkpoints with the write off the training thread.

    ``save`` snapshots the pytree to host *synchronously* (the consistency
    point), diffs it against the last saved snapshot, and hands the write
    of only the *changed* leaves to a single background worker.  The file's
    manifest (inside the same atomic rename) maps every leaf to the step
    whose file owns its newest bytes, so ``restore`` — and therefore a
    preemption at any instant — always resolves a complete, consistent
    tree: either this step's (rename landed) or the previous one's.

    ``wait()`` blocks until all queued writes are durable (call before a
    migration cutover or on SIGTERM); errors in the worker re-raise there
    and on the next ``save``.  Not thread-safe across concurrent ``save``
    callers (one trainer loop is the intended writer).
    """

    def __init__(self, ckpt_dir: str, *, keep: int = 3,
                 incremental: bool = True, background: bool = True):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.incremental = incremental
        self.background = background
        self._last_flat: Dict[str, np.ndarray] = {}
        self._owner: Dict[str, int] = {}
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- internals -----------------------------------------------------------

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               changed: Dict[str, np.ndarray], extra: Optional[Dict]):
        try:
            meta: Dict[str, Any] = {"step": step, "extra": extra or {}}
            if self.incremental:
                meta["leaves"] = {k: self._owner[k] for k in flat}
            _write_atomic(self.ckpt_dir, step, meta,
                          changed if self.incremental else flat)
            _gc(self.ckpt_dir, self.keep)
        except BaseException as e:          # surfaced on wait()/next save()
            self._error = e

    def _raise_pending(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError("background checkpoint write failed") from e

    # -- api -----------------------------------------------------------------

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> None:
        """Snapshot now, write (possibly) later.  The snapshot is the
        consistency point: mutating ``tree`` after ``save`` returns never
        affects the bytes on disk."""
        self.wait()
        self._raise_pending()
        os.makedirs(self.ckpt_dir, exist_ok=True)
        flat = _flatten(tree)
        changed: Dict[str, np.ndarray] = {}
        for k, v in flat.items():
            prev = self._last_flat.get(k)
            if prev is None or prev.shape != v.shape or \
                    prev.dtype != v.dtype or not np.array_equal(prev, v):
                changed[k] = np.array(v, copy=True)
                self._owner[k] = step
        # leaves that vanished from the tree drop out of the manifest
        gone = set(self._last_flat) - set(flat)
        for k in gone:
            self._owner.pop(k, None)
            self._last_flat.pop(k, None)
        self._last_flat.update(changed)
        snap = {k: self._last_flat[k] for k in flat}
        if self.background:
            self._thread = threading.Thread(
                target=self._write, args=(step, snap, changed, extra),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, snap, changed, extra)
            self._raise_pending()

    def wait(self) -> None:
        """Block until the in-flight write (if any) is durable."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def close(self) -> None:
        self.wait()
        self._raise_pending()

    def __enter__(self) -> "AsyncCheckpointer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
