"""Fault-tolerant checkpointing: atomic, resumable, reshardable.

- ``save``: flatten the pytree to path-keyed arrays, write ``.npz`` to a temp
  file, fsync, atomic rename -> a crash mid-write never corrupts the latest
  checkpoint.  A rolling window of checkpoints is kept.
- ``restore``: load the newest (or a specific) step; missing -> None.
- ``reshard``: place restored host arrays onto a *different* mesh/sharding —
  the elastic-scaling path (node failure -> replan on the surviving cluster
  -> reshard the last checkpoint onto the new layout).
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "|"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}

    def key_str(kp):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        return SEP.join(parts)

    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[key_str(kp)] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    def key_str(kp):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        return SEP.join(parts)

    leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, tmpl in leaves_kp:
        key = key_str(kp)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {tmpl.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    meta = {"step": step, "extra": extra or {}}
    path = os.path.join(ckpt_dir, f"ckpt_{step:010d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta), **flat)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    _gc(ckpt_dir, keep)
    return path


def _gc(ckpt_dir: str, keep: int):
    ckpts = sorted(list_steps(ckpt_dir))
    for step in ckpts[:-keep] if keep else []:
        os.unlink(os.path.join(ckpt_dir, f"ckpt_{step:010d}.npz"))


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for fn in os.listdir(ckpt_dir):
        m = re.fullmatch(r"ckpt_(\d{10})\.npz", fn)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def restore(ckpt_dir: str, template, step: Optional[int] = None
            ) -> Optional[Tuple[int, Any, Dict]]:
    steps = list_steps(ckpt_dir)
    if not steps:
        return None
    step = steps[-1] if step is None else step
    path = os.path.join(ckpt_dir, f"ckpt_{step:010d}.npz")
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))
        flat = {k: z[k] for k in z.files if k != "__meta__"}
    tree = _unflatten_into(template, flat)
    return meta["step"], tree, meta.get("extra", {})


def reshard(tree, shardings):
    """Place (host or differently-sharded) arrays onto new shardings —
    elastic scaling after a replan."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), tree, shardings)
