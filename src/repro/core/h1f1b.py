"""H-1F1B: heterogeneity-aware 1F1B warm-up schedule (paper §4).

Stage i launches ``N_i = 1 + sum_{k>=i} delta_k`` forward microbatches during
warm-up.  ``delta_i`` compensates the inter-stage communication cost c_i:

  exact rule (Eq. 10/11):  delta_i = ceil(1 + 2*c_i / (f+b))
  banded rule  (Eq. 2):    1 / 2 / 3 for c_i in (0, eps*tmax] /
                           (eps*tmax, tmax/2] / (tmax/2, tmax]

Baselines: classic 1F1B launches ``S - i + 1``; Eager-1F1B launches
``2*(S - i) + 1``.  All counts are capped by the number of microbatches.
"""
from __future__ import annotations

import math
from typing import List, Sequence


def h1f1b_deltas(t_per_stage: Sequence[float], c_links: Sequence[float],
                 eps: float = 0.05, banded: bool = False) -> List[int]:
    """delta_i for i = 1..S-1 (list of length S-1).

    ``t_per_stage``: per-microbatch f+b compute cost per stage;
    ``c_links[i]``: communication cost between stage i and i+1."""
    S = len(t_per_stage)
    assert len(c_links) == S - 1
    t_max = max(t_per_stage)
    out: List[int] = []
    for c in c_links:
        if c <= eps * t_max:
            # negligible comm: one extra launch suffices (Eq. 2 first band);
            # the strict Eq. 10 ceiling would waste a buffer here
            out.append(1)
        elif banded:
            out.append(2 if c <= t_max / 2 else 3)
        else:
            out.append(max(1, math.ceil(1.0 + 2.0 * c / t_max)))
    return out


def h1f1b_counts(t_per_stage: Sequence[float], c_links: Sequence[float],
                 n_microbatches: int, eps: float = 0.05,
                 banded: bool = False) -> List[int]:
    """Warm-up launch counts N_i (Eq. 1), capped at the microbatch count."""
    S = len(t_per_stage)
    deltas = h1f1b_deltas(t_per_stage, c_links, eps=eps, banded=banded)
    counts = [1] * S
    for i in range(S - 2, -1, -1):
        counts[i] = counts[i + 1] + deltas[i]
    return [min(c, n_microbatches) for c in counts]


def classic_1f1b_counts(S: int, n_microbatches: int) -> List[int]:
    return [min(S - i, n_microbatches) for i in range(S)]


def eager_1f1b_counts(S: int, n_microbatches: int) -> List[int]:
    return [min(2 * (S - 1 - i) + 1, n_microbatches) for i in range(S)]


def memory_ok(mem_p: float, mem_a: float, warmup_k: int, cap: float) -> bool:
    """Eq. 18."""
    return mem_p + warmup_k * mem_a <= cap


def steady_latency_2stage(f: float, b: float, c: float, K: int) -> float:
    """Closed-form K-block duration (Eq. 8): Lambda_K / K per microbatch."""
    return max(K * (f + b), 2 * (f + b + c)) / K
