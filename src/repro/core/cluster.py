"""Heterogeneous cluster description.

The paper's abstraction: a heterogeneous cluster = several *homogeneous
sub-clusters* (``DeviceMesh(N, M)`` each), fast links inside a sub-cluster,
slow links across.  TPU mapping: sub-cluster = pod; fast link = ICI; slow
link = DCN.  All bandwidths in bytes/s, compute in FLOP/s, memory in bytes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

GBPS = 1e9 / 8          # 1 Gbps in bytes/s
GB = 1024 ** 3


@dataclass(frozen=True)
class DeviceProfile:
    name: str
    peak_flops: float            # per device, half precision
    mem_bytes: float
    hbm_bw: float                # bytes/s
    base_mfu: float = 0.5        # achievable model-flop utilization at TP=1
    efficiency: float = 1.0      # runtime calibration scale (1.0 = as-specced;
                                 # <1 = straggling/thermal-throttled hardware)

    @property
    def effective_flops(self) -> float:
        return self.peak_flops * self.efficiency


# Published specs (paper Table 2 + TPU targets)
A100_40G = DeviceProfile("A100-40G", 312e12, 40 * GB, 1555e9, base_mfu=0.50)
V100_32G = DeviceProfile("V100-32G", 125e12, 32 * GB, 900e9, base_mfu=0.45)
TPU_V5E = DeviceProfile("TPUv5e", 197e12, 16 * GB, 819e9, base_mfu=0.55)
TPU_V4 = DeviceProfile("TPUv4", 275e12, 32 * GB, 1228e9, base_mfu=0.55)

# Named registry of the canonical profiles (also exposed through
# repro.api.registry under kind "device") — benchmarks/roofline.py and the
# kbench CLI resolve fleet devices by name here instead of hardcoding specs.
DEVICE_PROFILES: Dict[str, DeviceProfile] = {
    p.name: p for p in (A100_40G, V100_32G, TPU_V5E, TPU_V4)
}

# Typical per-device interconnect bandwidth (bytes/s per direction) for
# roofline-style comm bounds: NVLink-gen for the GPUs, ICI for the TPUs.
DEVICE_LINK_BW: Dict[str, float] = {
    "A100-40G": 300e9,
    "V100-32G": 150e9,
    "TPUv5e": 4 * 50e9,
    "TPUv4": 4 * 50e9,
}


@dataclass(frozen=True)
class SubCluster:
    """One DeviceMesh(N, M): N nodes x M devices sharing one DeviceProfile.

    ``node_efficiencies`` (optional, len == ``n_nodes``) makes the sub-cluster
    *mixed*: entry ``i`` is a per-node multiplier on ``device.efficiency``
    (1.0 = as-specced; 0.7 = a node running at 70% of its siblings).  The
    joint planner exploits the mix with uneven intra-op shard ratios; the
    inter-op-only planner is bottlenecked by the slowest node (``min``).
    All bandwidths are bytes/s per direction.
    """
    name: str
    n_nodes: int
    devices_per_node: int
    device: DeviceProfile
    intra_node_bw: float          # NVLink / intra-host ICI (bytes/s, per dir)
    inter_node_bw: float          # RDMA / pod fabric (bytes/s)
    node_efficiencies: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        ne = self.node_efficiencies
        if ne is not None:
            if len(ne) != self.n_nodes:
                raise ValueError(
                    f"{self.name}: node_efficiencies has {len(ne)} entries "
                    f"for {self.n_nodes} nodes")
            if any(e <= 0 for e in ne):
                raise ValueError("node efficiencies must be positive")

    @property
    def n_devices(self) -> int:
        return self.n_nodes * self.devices_per_node

    def node_scales(self, n_nodes: Optional[int] = None) -> Tuple[float, ...]:
        """Per-node efficiency multipliers for a submesh of ``n_nodes`` nodes
        (all nodes when None).  Homogeneous -> all 1.0.  A partial submesh is
        priced on the *slowest* nodes: the scheduler cannot promise the fast
        ones, so plans must be robust to worst-case placement — recovering a
        mixed fleet's capacity is the uneven intra-op sharding's job, not an
        optimistic placement assumption's."""
        n = self.n_nodes if n_nodes is None else n_nodes
        if self.node_efficiencies is None:
            return (1.0,) * n
        return tuple(sorted(self.node_efficiencies)[:n])

    @property
    def peak_flops(self) -> float:
        return self.n_devices * self.device.peak_flops

    def submeshes(self) -> List[Tuple[int, int]]:
        """Alpa's submesh shapes: (1,1),(1,2),...,(1,M), (2,M),...,(N,M)."""
        out = []
        m = 1
        while m <= self.devices_per_node:
            out.append((1, m))
            m *= 2
        if self.devices_per_node not in [s[1] for s in out]:
            out.append((1, self.devices_per_node))
        for n in range(2, self.n_nodes + 1):
            out.append((n, self.devices_per_node))
        return out


@dataclass(frozen=True)
class HeteroCluster:
    subclusters: Tuple[SubCluster, ...]
    cross_bw: float               # slow cross-cluster link (bytes/s)
    cross_latency: float = 1e-3   # per-transfer latency (s)

    @property
    def n_devices(self) -> int:
        return sum(s.n_devices for s in self.subclusters)

    @property
    def peak_flops(self) -> float:
        return sum(s.peak_flops for s in self.subclusters)

    def link_bw(self, src: int, dst: int) -> float:
        """Bandwidth between stages on subclusters ``src`` and ``dst``."""
        if src == dst:
            return self.subclusters[src].inter_node_bw
        return self.cross_bw

    def describe(self) -> str:
        parts = [
            f"{s.name}: {s.n_nodes}x{s.devices_per_node} {s.device.name} "
            f"({s.peak_flops/1e12:.0f} TF)" for s in self.subclusters]
        return " + ".join(parts) + f" | cross {self.cross_bw*8/1e9:.0f} Gbps"


# ---------------------------------------------------------------------------
# Canonical clusters
# ---------------------------------------------------------------------------


def paper_case_study_cluster(cross_gbps: float = 5.0) -> HeteroCluster:
    """§2.2.2: DeviceMesh_A100(2,2) + DeviceMesh_V100(1,2), 5 Gbps cross."""
    return HeteroCluster(
        subclusters=(
            SubCluster("meshA100", 2, 2, A100_40G, 300e9, 200 * GBPS),
            SubCluster("meshV100", 1, 2, V100_32G, 150e9, 200 * GBPS),
        ),
        cross_bw=cross_gbps * GBPS)


def paper_eval_cluster(n_a100_nodes: int = 4, n_v100_nodes: int = 4,
                       gpus_per_node: int = 8,
                       cross_gbps: float = 5.0) -> HeteroCluster:
    """§6: up to 4 nodes x 8 A100 + 4 nodes x 8 V100 (ShanHe)."""
    return HeteroCluster(
        subclusters=(
            SubCluster("A100", n_a100_nodes, gpus_per_node, A100_40G,
                       300e9, 200 * GBPS),
            SubCluster("V100", n_v100_nodes, gpus_per_node, V100_32G,
                       150e9, 200 * GBPS),
        ),
        cross_bw=cross_gbps * GBPS)


def homogeneous_cluster(n_nodes: int = 8, gpus_per_node: int = 8,
                        device: DeviceProfile = A100_40G) -> HeteroCluster:
    """§6.2 baseline: fully-connected homogeneous cluster (200 Gbps RDMA)."""
    return HeteroCluster(
        subclusters=(SubCluster("homo", n_nodes, gpus_per_node, device,
                                300e9, 200 * GBPS),),
        cross_bw=200 * GBPS)


def tpu_multipod_cluster(n_pods: int = 2, pod_side: Tuple[int, int] = (16, 16),
                         device: DeviceProfile = TPU_V5E,
                         dcn_gbps: float = 100.0) -> HeteroCluster:
    """The production target: v5e pods joined by DCN. One sub-cluster per
    pod; intra-pod "node" = one ICI-connected row (model axis)."""
    n, m = pod_side
    subs = tuple(
        SubCluster(f"pod{i}", n, m, device, 4 * 50e9, 3 * 50e9)
        for i in range(n_pods))
    return HeteroCluster(subclusters=subs, cross_bw=dcn_gbps * GBPS)


# ---------------------------------------------------------------------------
# Mutation helpers (elastic runtime): HeteroCluster is frozen, so every fleet
# change produces a new value via dataclasses.replace.  All helpers address
# sub-clusters by *name* — indices shift when a sub-cluster disappears.
# ---------------------------------------------------------------------------


def subcluster_index(cluster: HeteroCluster, name: str) -> int:
    for i, s in enumerate(cluster.subclusters):
        if s.name == name:
            return i
    raise KeyError(f"no sub-cluster named {name!r} in {cluster.describe()}")


def _replace_subcluster(cluster: HeteroCluster, name: str,
                        new: SubCluster | None) -> HeteroCluster:
    idx = subcluster_index(cluster, name)
    subs = list(cluster.subclusters)
    if new is None:
        del subs[idx]
    else:
        subs[idx] = new
    if not subs:
        raise ValueError("cluster would have no sub-clusters left")
    return dataclasses.replace(cluster, subclusters=tuple(subs))


def remove_nodes(cluster: HeteroCluster, name: str, n: int = 1) -> HeteroCluster:
    """Node failure / preemption: ``name`` loses ``n`` nodes (the whole
    sub-cluster is dropped when none remain)."""
    idx = subcluster_index(cluster, name)
    sub = cluster.subclusters[idx]
    if n > sub.n_nodes:
        raise ValueError(
            f"{name} has {sub.n_nodes} nodes, cannot remove {n}")
    if n == sub.n_nodes:
        return _replace_subcluster(cluster, name, None)
    ne = sub.node_efficiencies
    return _replace_subcluster(
        cluster, name, dataclasses.replace(
            sub, n_nodes=sub.n_nodes - n,
            node_efficiencies=None if ne is None else ne[:sub.n_nodes - n]))


def add_nodes(cluster: HeteroCluster, name: str, n: int = 1) -> HeteroCluster:
    """Node (re)join: ``name`` gains ``n`` nodes of its existing profile
    (joining nodes start at nominal per-node efficiency 1.0)."""
    idx = subcluster_index(cluster, name)
    sub = cluster.subclusters[idx]
    ne = sub.node_efficiencies
    return _replace_subcluster(
        cluster, name, dataclasses.replace(
            sub, n_nodes=sub.n_nodes + n,
            node_efficiencies=None if ne is None else ne + (1.0,) * n))


def with_cross_bw(cluster: HeteroCluster, cross_bw: float) -> HeteroCluster:
    """Cross-cluster bandwidth shift (bytes/s)."""
    if cross_bw <= 0:
        raise ValueError("cross_bw must be positive")
    return dataclasses.replace(cluster, cross_bw=cross_bw)


def set_efficiency(cluster: HeteroCluster, name: str,
                   efficiency: float) -> HeteroCluster:
    """Absolute runtime-calibration efficiency for one sub-cluster's device."""
    if efficiency <= 0:
        raise ValueError("efficiency must be positive")
    idx = subcluster_index(cluster, name)
    sub = cluster.subclusters[idx]
    dev = dataclasses.replace(sub.device, efficiency=efficiency)
    return _replace_subcluster(
        cluster, name, dataclasses.replace(sub, device=dev))


def set_node_efficiencies(cluster: HeteroCluster, name: str,
                          efficiencies: Optional[Sequence[float]]
                          ) -> HeteroCluster:
    """Per-node efficiency multipliers for one sub-cluster (length must equal
    its node count; None restores homogeneity).  This is how a *mixed*
    sub-cluster — some nodes throttled, some nominal — enters the planner."""
    idx = subcluster_index(cluster, name)
    sub = cluster.subclusters[idx]
    ne = None if efficiencies is None else tuple(float(e) for e in efficiencies)
    return _replace_subcluster(
        cluster, name, dataclasses.replace(sub, node_efficiencies=ne))


def set_inter_node_bw(cluster: HeteroCluster, name: str,
                      inter_node_bw: float) -> HeteroCluster:
    """Recalibrated inter-node fabric bandwidth for one sub-cluster
    (bytes/s) — the comm telemetry's per-tier analogue of
    :func:`with_cross_bw`."""
    if inter_node_bw <= 0:
        raise ValueError("inter_node_bw must be positive")
    idx = subcluster_index(cluster, name)
    sub = cluster.subclusters[idx]
    return _replace_subcluster(
        cluster, name, dataclasses.replace(sub, inter_node_bw=inter_node_bw))


def cluster_fingerprint(cluster: HeteroCluster) -> str:
    """Stable identity of everything the planner's cost model reads — used to
    key plan caches (two clusters with equal fingerprints plan identically)."""
    parts = []
    for s in cluster.subclusters:
        d = s.device
        ne = "" if s.node_efficiencies is None else \
            ":" + ",".join(f"{e:.6g}" for e in s.node_efficiencies)
        parts.append(f"{s.name}:{s.n_nodes}x{s.devices_per_node}"
                     f":{d.name}:{d.peak_flops:.6g}:{d.mem_bytes:.6g}"
                     f":{d.base_mfu:.6g}:{d.efficiency:.6g}"
                     f":{s.intra_node_bw:.6g}:{s.inter_node_bw:.6g}{ne}")
    parts.append(f"cross:{cluster.cross_bw:.6g}:{cluster.cross_latency:.6g}")
    return "|".join(parts)


# ---------------------------------------------------------------------------
# (De)serialization — plain JSON-native dicts.  Lives here (not in repro.api)
# so the runtime's plan cache and the chaos trace format can round-trip fleet
# specs without importing the api layer; ``repro.api.artifacts`` re-exports.
# ---------------------------------------------------------------------------


def subcluster_to_dict(sub: SubCluster) -> Dict:
    """One sub-cluster spec as JSON-native data (tuples become lists)."""
    import json as _json
    return _json.loads(_json.dumps(dataclasses.asdict(sub)))


def subcluster_from_dict(d: Dict) -> SubCluster:
    d = dict(d)
    dev = DeviceProfile(**d.pop("device"))
    ne = d.pop("node_efficiencies", None)
    return SubCluster(device=dev,
                      node_efficiencies=None if ne is None else tuple(ne), **d)


def cluster_to_dict(cluster: HeteroCluster) -> Dict:
    """Full fleet spec as plain JSON-native data (everything the cost model
    reads; tuples normalized to lists so artifact dicts are pure JSON)."""
    import json as _json
    return _json.loads(_json.dumps(dataclasses.asdict(cluster)))


def cluster_from_dict(d: Dict) -> HeteroCluster:
    subs = tuple(subcluster_from_dict(sd) for sd in d["subclusters"])
    return HeteroCluster(subclusters=subs, cross_bw=d["cross_bw"],
                         cross_latency=d.get("cross_latency", 1e-3))


def heterogeneous_tpu_cluster(dcn_gbps: float = 100.0) -> HeteroCluster:
    """A mixed-generation TPU fleet (v5e pod + v4 pod) — the TPU analogue of
    the paper's A100+V100 setting."""
    return HeteroCluster(
        subclusters=(
            SubCluster("v5e-pod", 16, 16, TPU_V5E, 4 * 50e9, 3 * 50e9),
            SubCluster("v4-pod", 8, 16, TPU_V4, 4 * 50e9, 3 * 50e9),
        ),
        cross_bw=dcn_gbps * GBPS)
