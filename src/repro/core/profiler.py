"""Zero-Redundant Profiler (paper §5.1), extended with the joint
inter+intra-operator candidate space.

Enumerates candidate (stage = contiguous layer range) x (submesh) pairs and
collects execution profiles, with the paper's two prunings:

1. *Feasibility pruning*: drop candidates that OOM outright (Eq. 18 with
   K=1) or whose workload share is severely imbalanced w.r.t. the submesh's
   compute-capacity share (ratio outside [1/rho, rho]).
2. *Structural aliasing* ("zero redundancy"): candidates whose layer
   class-key sequences match (ranges spanning identical instances of repeated
   modules) share one profile entry — the profile function is evaluated once
   per unique key.  With an expensive ``measure_fn`` (real hardware) this is
   the paper's >10x profiling saving; the stats are reported either way.

Profiles are materialized as dense numpy tables indexed (mesh_id, i, j) for
the DP search.

**Joint mode** (``intra_op=True``): instead of collapsing each (stage,
submesh) cell to the greedy-cheapest intra-op factorization, the profiler
emits one table *row per (submesh, tensor-parallel width)* — the DP then
chooses the intra-op degree jointly with the stage slicing, trading compute
speed against intra-op collective time and the Eq. 18 activation bound.  Two
extra prunings keep the joint table small:

- ``intra_op_max_degree`` caps the enumerated tp widths;
- *dominated-variant elimination*: a variant row that is nowhere faster,
  nowhere leaner (mem_p, mem_a), and nowhere uniquely feasible than a
  sibling row of the same physical submesh is dropped before the DP.

Cost-cache keys include the sharding degree (``tp``; ``None`` = greedy
inter-only entry), the per-node efficiency mix, and the microbatch
amortization — everything :func:`repro.core.costmodel.intra_op_candidates`
reads.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import HeteroCluster
from repro.core.costmodel import (
    CostModelConfig, StageCost, Submesh, intra_op_candidates, stage_cost,
)
from repro.core.layering import Layer, layer_class_sequence


@dataclass
class ProfilerStats:
    n_candidates: int = 0
    n_pruned_memory: int = 0
    n_pruned_imbalance: int = 0
    n_unique_profiled: int = 0
    n_aliased: int = 0
    n_cache_hits: int = 0     # hits on a warm cross-invocation cost_cache
    n_variants_dominated: int = 0   # joint rows dropped as dominated

    @property
    def dedup_ratio(self) -> float:
        evaluated = self.n_unique_profiled + self.n_aliased + self.n_cache_hits
        return (self.n_aliased + self.n_cache_hits) / evaluated \
            if evaluated else 0.0


@dataclass
class ProfileTables:
    """Dense DP inputs. meshes[mid] describes column mid of each array.

    In joint mode several rows share one physical submesh;
    ``variant_tp[mid]`` is that row's tensor-parallel width (``None`` for the
    greedy inter-only row)."""
    meshes: List[Submesh]
    t_f: np.ndarray          # (n_mesh, L+1, L+1); [mid, i, j] = stage layers[i:j]
    t_b: np.ndarray
    mem_p: np.ndarray
    mem_a: np.ndarray
    feasible: np.ndarray     # bool, post-pruning
    cut_bytes: np.ndarray    # (L+1,) activation bytes crossing cut at j
    stats: ProfilerStats
    stage_costs: Dict[Tuple[int, int, int], StageCost] = field(default_factory=dict)
    variant_tp: Optional[List[Optional[int]]] = None
    _t_cache: Optional[np.ndarray] = field(default=None, init=False,
                                           repr=False, compare=False)

    @property
    def t(self) -> np.ndarray:
        """Per-stage f+b time, computed once (the planner's hot-path input —
        ``_DPContext`` reads it for every candidate row)."""
        if self._t_cache is None:
            self._t_cache = self.t_f + self.t_b
        return self._t_cache


class ZeroRedundantProfiler:
    def __init__(self, cluster: HeteroCluster, layers: Sequence[Layer],
                 mb_tokens: int, *,
                 cost_cfg: CostModelConfig = CostModelConfig(),
                 rho: float = 16.0,
                 min_submesh_devices: int = 1,
                 max_submesh_devices: int = 0,
                 max_stage_layers: Optional[int] = None,
                 measure_fn: Optional[Callable] = None,
                 cost_cache: Optional[Dict] = None,
                 intra_op: bool = False,
                 intra_op_max_degree: int = 0,
                 amortize_microbatches: int = 0,
                 comm=None, kbench=None):
        """``cost_cache``: a caller-owned stage-cost cache shared ACROSS
        profiler invocations (the elastic runtime's table-reuse API).  Keys
        fingerprint everything the cost model reads — layer-class sequence,
        device profile (incl. calibrated efficiency), per-node efficiency
        mix, link bandwidths, mesh shape, microbatch tokens, cost config,
        and the intra-op sharding degree — so after a fleet change only
        the affected sub-cluster's entries miss; untouched meshes are never
        re-profiled (asserted in tests/test_runtime.py).

        ``intra_op``: emit one table row per (submesh, tp) variant for the
        joint two-level search (see module docstring).
        ``intra_op_max_degree``: cap on enumerated tp widths (0 = all).
        ``amortize_microbatches``: B used to amortize the per-step gradient
        sync into the per-microbatch data-axis cost (0 = don't price it).
        ``comm``: optional :class:`repro.comm.selector.CommModel` — price
        collectives under the selected algorithm (cache keys carry its
        fingerprint so comm-aware and legacy entries never collide).
        ``kbench``: optional :class:`repro.kbench.bridge.KBenchModel` —
        anchor compute MFU at measured kernel throughput (cache keys carry
        its fingerprint too; analytic fallback for uncovered devices)."""
        self.cluster = cluster
        self.layers = list(layers)
        self.mb_tokens = mb_tokens
        self.cost_cfg = cost_cfg
        self.rho = rho
        self.min_submesh = min_submesh_devices
        self.max_submesh = max_submesh_devices
        self.max_stage_layers = max_stage_layers or len(self.layers)
        self.measure_fn = measure_fn
        self.cost_cache = cost_cache if cost_cache is not None else {}
        self.intra_op = intra_op
        self.intra_op_max_degree = intra_op_max_degree
        self.amortize_microbatches = amortize_microbatches
        self.comm = comm
        self.kbench = kbench

    def meshes(self) -> List[Submesh]:
        out = []
        for ci, sub in enumerate(self.cluster.subclusters):
            for (n, m) in sub.submeshes():
                if n * m < self.min_submesh:
                    continue
                if self.max_submesh and n * m > self.max_submesh:
                    continue
                out.append(Submesh(ci, n, m))
        return out

    def _variant_tps(self, mesh: Submesh) -> List[Optional[int]]:
        """Row variants for one physical submesh: tp widths in joint mode,
        the single greedy entry (None) otherwise."""
        if not self.intra_op:
            return [None]
        tps: List[Optional[int]] = []
        tp = 1
        while tp <= mesh.m:
            if mesh.m % tp == 0 and not (self.intra_op_max_degree
                                         and tp > self.intra_op_max_degree):
                tps.append(tp)
            tp *= 2
        return tps or [1]

    def _cell_costs(self, mesh: Submesh, i: int, j: int,
                    tps: Sequence[Optional[int]], stats: ProfilerStats
                    ) -> Dict[Optional[int], StageCost]:
        """Per-variant costs for stage layers[i:j] on ``mesh``, through the
        aliasing / cross-invocation cache."""
        sub = self.cluster.subclusters[mesh.cluster_idx]
        cache = self.cost_cache
        warm = self._warm_keys
        base_key = (layer_class_sequence(self.layers, i, j),
                    sub.device, sub.node_efficiencies,
                    sub.intra_node_bw, sub.inter_node_bw,
                    mesh.n, mesh.m, self.mb_tokens, self.cost_cfg,
                    self.amortize_microbatches if self.intra_op else 0,
                    # sub-scoped comm identity: a fleet change elsewhere must
                    # not evict this sub-cluster's comm-aware entries
                    None if self.comm is None
                    else self.comm.sub_fingerprint(mesh.cluster_idx),
                    # measured-pricing identity: entries priced off a kbench
                    # table must never collide with analytic ones
                    None if self.kbench is None else self.kbench.fingerprint())
        out: Dict[Optional[int], StageCost] = {}
        missing = [tp for tp in tps if (*base_key, tp) not in cache]
        for tp in tps:
            key = (*base_key, tp)
            if key in cache:
                stats.n_cache_hits += 1 if key in warm else 0
                stats.n_aliased += 0 if key in warm else 1
                out[tp] = cache[key]
        if not missing:
            return out
        if self.intra_op:
            cands = {c.tp: c for c in intra_op_candidates(
                self.layers[i:j], sub, mesh, self.mb_tokens, self.cost_cfg,
                uneven=True, amortize_microbatches=self.amortize_microbatches,
                max_degree=self.intra_op_max_degree, comm=self.comm,
                kbench=self.kbench)}
            for tp in missing:
                if tp not in cands:
                    continue
                cache[(*base_key, tp)] = cands[tp]
                out[tp] = cands[tp]
                stats.n_unique_profiled += 1
        else:
            cost = stage_cost(self.layers[i:j], sub, mesh, self.mb_tokens,
                              self.cost_cfg, self.measure_fn, comm=self.comm,
                              kbench=self.kbench)
            cache[(*base_key, None)] = cost
            out[None] = cost
            stats.n_unique_profiled += 1
        return out

    def profile(self) -> ProfileTables:
        L = len(self.layers)
        phys = self.meshes()
        rows: List[Tuple[Submesh, Optional[int]]] = []
        for mesh in phys:
            for tp in self._variant_tps(mesh):
                rows.append((mesh, tp))
        nm = len(rows)
        shape = (nm, L + 1, L + 1)
        t_f = np.full(shape, np.inf)
        t_b = np.full(shape, np.inf)
        mem_p = np.full(shape, np.inf)
        mem_a = np.full(shape, np.inf)
        feas = np.zeros(shape, dtype=bool)
        stats = ProfilerStats()
        self._warm_keys = frozenset(self.cost_cache)  # cross-invocation
        stage_costs: Dict[Tuple[int, int, int], StageCost] = {}

        total_flops = sum(l.flops_per_token for l in self.layers) or 1.0
        total_peak = sum(s.n_devices * s.device.effective_flops
                         for s in self.cluster.subclusters)

        # prefix sums for fast share computation
        pre_flops = np.zeros(L + 1)
        for i, l in enumerate(self.layers):
            pre_flops[i + 1] = pre_flops[i] + l.flops_per_token

        # row ids of each physical mesh (for cell-cost sharing + domination)
        groups: Dict[int, List[int]] = {}
        for mid, (mesh, tp) in enumerate(rows):
            groups.setdefault(phys.index(mesh), []).append(mid)

        for pid, mesh in enumerate(phys):
            sub = self.cluster.subclusters[mesh.cluster_idx]
            mids = groups[pid]
            tps = [rows[mid][1] for mid in mids]
            cap_share = mesh.n_devices * sub.device.effective_flops / total_peak
            for i in range(L):
                jmax = min(L, i + self.max_stage_layers)
                for j in range(i + 1, jmax + 1):
                    stats.n_candidates += 1
                    work_share = (pre_flops[j] - pre_flops[i]) / total_flops
                    if work_share > self.rho * cap_share:
                        stats.n_pruned_imbalance += 1
                        continue
                    costs = self._cell_costs(mesh, i, j, tps, stats)
                    for mid, tp in zip(mids, tps):
                        cost = costs.get(tp)
                        if cost is None:
                            continue
                        # memory pruning at the loosest warm-up (K=1)
                        if cost.mem_p + cost.mem_a > sub.device.mem_bytes:
                            stats.n_pruned_memory += 1
                            continue
                        t_f[mid, i, j] = cost.t_f
                        t_b[mid, i, j] = cost.t_b
                        mem_p[mid, i, j] = cost.mem_p
                        mem_a[mid, i, j] = cost.mem_a
                        feas[mid, i, j] = True
                        stage_costs[(mid, i, j)] = cost

        if self.intra_op:
            keep = self._prune_dominated(groups, t_f, t_b, mem_p, mem_a,
                                         feas, stats)
            remap = {old: new for new, old in enumerate(keep)}
            rows = [rows[mid] for mid in keep]
            t_f, t_b = t_f[keep], t_b[keep]
            mem_p, mem_a = mem_p[keep], mem_a[keep]
            feas = feas[keep]
            stage_costs = {(remap[mid], i, j): c
                           for (mid, i, j), c in stage_costs.items()
                           if mid in remap}

        cut_bytes = np.zeros(L + 1)
        for j in range(1, L):
            cut_bytes[j] = self.layers[j - 1].act_out_bytes_per_token * self.mb_tokens

        return ProfileTables([mesh for mesh, _ in rows],
                             t_f, t_b, mem_p, mem_a, feas, cut_bytes,
                             stats, stage_costs,
                             variant_tp=[tp for _, tp in rows])

    @staticmethod
    def _prune_dominated(groups: Dict[int, List[int]], t_f, t_b, mem_p,
                         mem_a, feas, stats: ProfilerStats) -> List[int]:
        """Joint-mode row pruning: within one physical submesh, drop variant
        r2 when a sibling r1 is feasible everywhere r2 is, and there no
        slower / no more memory-hungry (r1 dominates r2)."""
        t = t_f + t_b
        keep: List[int] = []
        for mids in groups.values():
            dropped = set()
            for r2 in mids:
                f2 = feas[r2]
                if not f2.any():
                    dropped.add(r2)      # nowhere feasible: dead row
                    continue
                for r1 in mids:
                    if r1 == r2 or r1 in dropped:
                        continue
                    if not np.all(feas[r1][f2]):
                        continue
                    if (np.all(t[r1][f2] <= t[r2][f2] + 1e-15)
                            and np.all(mem_a[r1][f2] <= mem_a[r2][f2] + 1e-9)
                            and np.all(mem_p[r1][f2] <= mem_p[r2][f2] + 1e-9)):
                        dropped.add(r2)
                        stats.n_variants_dominated += 1
                        break
            keep.extend(mid for mid in mids if mid not in dropped)
        return sorted(keep)
