"""Zero-Redundant Profiler (paper §5.1).

Enumerates candidate (stage = contiguous layer range) x (submesh) pairs and
collects execution profiles, with the paper's two prunings:

1. *Feasibility pruning*: drop candidates that OOM outright (Eq. 18 with
   K=1) or whose workload share is severely imbalanced w.r.t. the submesh's
   compute-capacity share (ratio outside [1/rho, rho]).
2. *Structural aliasing* ("zero redundancy"): candidates whose layer
   class-key sequences match (ranges spanning identical instances of repeated
   modules) share one profile entry — the profile function is evaluated once
   per unique key.  With an expensive ``measure_fn`` (real hardware) this is
   the paper's >10x profiling saving; the stats are reported either way.

Profiles are materialized as dense numpy tables indexed (mesh_id, i, j) for
the DP search.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import HeteroCluster
from repro.core.costmodel import CostModelConfig, StageCost, Submesh, stage_cost
from repro.core.layering import Layer, layer_class_sequence


@dataclass
class ProfilerStats:
    n_candidates: int = 0
    n_pruned_memory: int = 0
    n_pruned_imbalance: int = 0
    n_unique_profiled: int = 0
    n_aliased: int = 0
    n_cache_hits: int = 0     # hits on a warm cross-invocation cost_cache

    @property
    def dedup_ratio(self) -> float:
        evaluated = self.n_unique_profiled + self.n_aliased + self.n_cache_hits
        return (self.n_aliased + self.n_cache_hits) / evaluated \
            if evaluated else 0.0


@dataclass
class ProfileTables:
    """Dense DP inputs. meshes[mid] describes column mid of each array."""
    meshes: List[Submesh]
    t_f: np.ndarray          # (n_mesh, L+1, L+1); [mid, i, j] = stage layers[i:j]
    t_b: np.ndarray
    mem_p: np.ndarray
    mem_a: np.ndarray
    feasible: np.ndarray     # bool, post-pruning
    cut_bytes: np.ndarray    # (L+1,) activation bytes crossing cut at j
    stats: ProfilerStats
    stage_costs: Dict[Tuple[int, int, int], StageCost] = field(default_factory=dict)

    @property
    def t(self) -> np.ndarray:
        return self.t_f + self.t_b


class ZeroRedundantProfiler:
    def __init__(self, cluster: HeteroCluster, layers: Sequence[Layer],
                 mb_tokens: int, *,
                 cost_cfg: CostModelConfig = CostModelConfig(),
                 rho: float = 16.0,
                 min_submesh_devices: int = 1,
                 max_submesh_devices: int = 0,
                 max_stage_layers: Optional[int] = None,
                 measure_fn: Optional[Callable] = None,
                 cost_cache: Optional[Dict] = None):
        """``cost_cache``: a caller-owned stage-cost cache shared ACROSS
        profiler invocations (the elastic runtime's table-reuse API).  Keys
        fingerprint everything ``stage_cost`` reads — layer-class sequence,
        device profile (incl. calibrated efficiency), link bandwidths, mesh
        shape, microbatch tokens, cost config — so after a fleet change only
        the affected sub-cluster's entries miss; untouched meshes are never
        re-profiled (asserted in tests/test_runtime.py)."""
        self.cluster = cluster
        self.layers = list(layers)
        self.mb_tokens = mb_tokens
        self.cost_cfg = cost_cfg
        self.rho = rho
        self.min_submesh = min_submesh_devices
        self.max_submesh = max_submesh_devices
        self.max_stage_layers = max_stage_layers or len(self.layers)
        self.measure_fn = measure_fn
        self.cost_cache = cost_cache if cost_cache is not None else {}

    def meshes(self) -> List[Submesh]:
        out = []
        for ci, sub in enumerate(self.cluster.subclusters):
            for (n, m) in sub.submeshes():
                if n * m < self.min_submesh:
                    continue
                if self.max_submesh and n * m > self.max_submesh:
                    continue
                out.append(Submesh(ci, n, m))
        return out

    def profile(self) -> ProfileTables:
        L = len(self.layers)
        meshes = self.meshes()
        nm = len(meshes)
        shape = (nm, L + 1, L + 1)
        t_f = np.full(shape, np.inf)
        t_b = np.full(shape, np.inf)
        mem_p = np.full(shape, np.inf)
        mem_a = np.full(shape, np.inf)
        feas = np.zeros(shape, dtype=bool)
        stats = ProfilerStats()
        cache = self.cost_cache
        warm_keys = frozenset(cache)        # pre-existing (cross-invocation)
        stage_costs: Dict[Tuple[int, int, int], StageCost] = {}

        total_flops = sum(l.flops_per_token for l in self.layers) or 1.0
        total_peak = sum(s.n_devices * s.device.effective_flops
                         for s in self.cluster.subclusters)

        # prefix sums for fast share computation
        pre_flops = np.zeros(L + 1)
        for i, l in enumerate(self.layers):
            pre_flops[i + 1] = pre_flops[i] + l.flops_per_token

        for mid, mesh in enumerate(meshes):
            sub = self.cluster.subclusters[mesh.cluster_idx]
            cap_share = mesh.n_devices * sub.device.effective_flops / total_peak
            for i in range(L):
                jmax = min(L, i + self.max_stage_layers)
                for j in range(i + 1, jmax + 1):
                    stats.n_candidates += 1
                    work_share = (pre_flops[j] - pre_flops[i]) / total_flops
                    if work_share > self.rho * cap_share:
                        stats.n_pruned_imbalance += 1
                        continue
                    key = (layer_class_sequence(self.layers, i, j),
                           sub.device, sub.intra_node_bw, sub.inter_node_bw,
                           mesh.n, mesh.m, self.mb_tokens, self.cost_cfg)
                    if key in cache:
                        if key in warm_keys:
                            stats.n_cache_hits += 1
                        else:
                            stats.n_aliased += 1
                        cost = cache[key]
                    else:
                        cost = stage_cost(self.layers[i:j], sub, mesh,
                                          self.mb_tokens, self.cost_cfg,
                                          self.measure_fn)
                        cache[key] = cost
                        stats.n_unique_profiled += 1
                    # memory pruning at the loosest warm-up (K=1)
                    if cost.mem_p + cost.mem_a > sub.device.mem_bytes:
                        stats.n_pruned_memory += 1
                        continue
                    t_f[mid, i, j] = cost.t_f
                    t_b[mid, i, j] = cost.t_b
                    mem_p[mid, i, j] = cost.mem_p
                    mem_a[mid, i, j] = cost.mem_a
                    feas[mid, i, j] = True
                    stage_costs[(mid, i, j)] = cost

        cut_bytes = np.zeros(L + 1)
        for j in range(1, L):
            cut_bytes[j] = self.layers[j - 1].act_out_bytes_per_token * self.mb_tokens

        return ProfileTables(meshes, t_f, t_b, mem_p, mem_a, feas, cut_bytes,
                             stats, stage_costs)
