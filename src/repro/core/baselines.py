"""Baseline planners/schedulers the paper compares against (§6).

- ``plan_uniform``   ("Megatron-like"): equal layer split over fixed
  node-granularity meshes, heterogeneity-blind, classic 1F1B.
- ``plan_coarse``    ("Alpa-like"): HAPT search at coarse granularity
  (#L=8), Eager-1F1B schedule.
- ``plan_coarse_sync`` ("HexiScale-like"): capacity-aware coarse planning
  (#L=48), synchronous sends (no overlap) — simulated with ``no_overlap``.

All reuse the same cost model and simulator so comparisons isolate the
planning/scheduling differences, exactly like the paper's ablations.
"""
from __future__ import annotations

from dataclasses import replace
from typing import List

from repro.configs.base import ArchConfig
from repro.core.cluster import HeteroCluster
from repro.core.costmodel import CostModelConfig, Submesh, stage_cost
from repro.core.h1f1b import classic_1f1b_counts, eager_1f1b_counts, h1f1b_counts
from repro.core.layering import build_layers
from repro.core.opgraph import build_op_sequence
from repro.core.pipesim import eta_load_balance, simulate
from repro.core.planner import HAPTPlanner, PlannerConfig
from repro.core.strategy import ParallelStrategy, StageAssignment


def plan_uniform(cluster: HeteroCluster, arch: ArchConfig, *, seq_len: int,
                 global_batch: int, n_microbatches: int,
                 cost_cfg: CostModelConfig = CostModelConfig()) -> ParallelStrategy:
    """Megatron-like: one pipeline stage per node, equal layer counts,
    ignoring device heterogeneity.  Fails (raises) when the cluster is not
    expressible as equal-sized node groups — mirroring the paper's Fig. 7(a)
    'unsupported configuration' cases."""
    mb_tokens = (global_batch * seq_len) // n_microbatches
    ops = build_op_sequence(arch, seq_len=seq_len)
    nodes: List[tuple] = []
    for ci, sub in enumerate(cluster.subclusters):
        for _ in range(sub.n_nodes):
            nodes.append((ci, sub.devices_per_node))
    if len({m for _, m in nodes}) != 1:
        raise ValueError("Megatron-like planner requires identical GPUs/node")
    S = len(nodes)
    layers = build_layers(ops, target_layers=S * 4)
    L = len(layers)
    # equal split by layer count
    bounds = [round(i * L / S) for i in range(S + 1)]
    stages, c_links = [], []
    for si in range(S):
        ci, m = nodes[si]
        sub = cluster.subclusters[ci]
        mesh = Submesh(ci, 1, m)
        sl = layers[bounds[si]:bounds[si + 1]]
        sc = stage_cost(sl, sub, mesh, mb_tokens, cost_cfg)
        stages.append(StageAssignment(bounds[si], bounds[si + 1], ci, 1, m,
                                      sc.tp, sc.dp, sc.t_f, sc.t_b,
                                      sc.mem_p, sc.mem_a))
    for si in range(S - 1):
        cut = layers[stages[si].layer_end - 1].act_out_bytes_per_token * mb_tokens
        bw = cluster.link_bw(stages[si].cluster_idx, stages[si + 1].cluster_idx)
        c_links.append(cut / bw)
    counts = classic_1f1b_counts(S, n_microbatches)
    res = simulate([s.t_f for s in stages], [s.t_b for s in stages], c_links,
                   n_microbatches, counts)
    eta = eta_load_balance(
        res.stage_compute,
        [s.n_devices * cluster.subclusters[s.cluster_idx].device.peak_flops
         for s in stages])
    return ParallelStrategy(stages, c_links, counts,
                            max(s.t for s in stages), n_microbatches,
                            mb_tokens, res.makespan, eta,
                            {"baseline": "uniform-1f1b"})


def _planned(cluster, arch, *, seq_len, global_batch, n_microbatches,
             granularity, schedule: str, cost_cfg=CostModelConfig(),
             min_submesh_devices: int = 1) -> ParallelStrategy:
    pcfg = PlannerConfig(granularity=granularity,
                         n_microbatches=n_microbatches, cost=cost_cfg,
                         min_submesh_devices=min_submesh_devices)
    pcfg.search.require_all_devices = True
    try:
        strat = HAPTPlanner(cluster, pcfg).plan(
            arch, seq_len=seq_len, global_batch=global_batch)
    except (RuntimeError, AssertionError):
        pcfg.search.require_all_devices = False
        strat = HAPTPlanner(cluster, pcfg).plan(
            arch, seq_len=seq_len, global_batch=global_batch)
    S = strat.n_stages
    if schedule == "eager":
        counts = eager_1f1b_counts(S, n_microbatches)
    elif schedule == "classic":
        counts = classic_1f1b_counts(S, n_microbatches)
    else:
        counts = strat.warmup_counts
    res = simulate([s.t_f for s in strat.stages], [s.t_b for s in strat.stages],
                   strat.c_links, n_microbatches, counts,
                   no_overlap=(schedule == "sync"))
    strat = replace(strat) if False else strat
    strat.warmup_counts = counts
    strat.est_step_time = res.makespan
    strat.planner_meta["schedule"] = schedule
    return strat


def plan_blind_eager(cluster: HeteroCluster, arch: ArchConfig, *, seq_len: int,
                     global_batch: int, n_microbatches: int,
                     granularity: int = 8,
                     cost_cfg: CostModelConfig = CostModelConfig(),
                     min_submesh_devices: int = 1) -> ParallelStrategy:
    """Alpa-like: heterogeneity-BLIND planning — the planner believes every
    device is the fastest one (Alpa's homogeneous-cluster assumption), then
    the strategy executes on the real mixed hardware.  Reproduces the paper's
    Fig. 8(b): stages landing on slow devices run long (eta ~45%)."""
    import dataclasses as _dc
    fast = max((s.device for s in cluster.subclusters),
               key=lambda d: d.peak_flops)
    blind_cluster = _dc.replace(cluster, subclusters=tuple(
        _dc.replace(s, device=_dc.replace(
            fast, mem_bytes=s.device.mem_bytes))
        for s in cluster.subclusters))
    pcfg = PlannerConfig(granularity=granularity,
                         n_microbatches=n_microbatches, cost=cost_cfg,
                         min_submesh_devices=min_submesh_devices)
    pcfg.search.require_all_devices = True
    try:
        strat = HAPTPlanner(blind_cluster, pcfg).plan(
            arch, seq_len=seq_len, global_batch=global_batch)
    except (RuntimeError, AssertionError):
        pcfg.search.require_all_devices = False
        strat = HAPTPlanner(blind_cluster, pcfg).plan(
            arch, seq_len=seq_len, global_batch=global_batch)
    # re-cost the chosen stages on the REAL devices
    mb_tokens = (global_batch * seq_len) // n_microbatches
    from repro.core.layering import build_layers
    from repro.core.opgraph import build_op_sequence
    layers = build_layers(build_op_sequence(arch, seq_len=seq_len),
                          granularity)
    real_stages = []
    for st in strat.stages:
        sub = cluster.subclusters[st.cluster_idx]
        sc = stage_cost(layers[st.layer_start:st.layer_end], sub,
                        Submesh(st.cluster_idx, st.mesh_n, st.mesh_m),
                        mb_tokens, cost_cfg)
        real_stages.append(StageAssignment(
            st.layer_start, st.layer_end, st.cluster_idx, st.mesh_n,
            st.mesh_m, sc.tp, sc.dp, sc.t_f, sc.t_b, sc.mem_p, sc.mem_a))
    S = len(real_stages)
    counts = eager_1f1b_counts(S, n_microbatches)
    res = simulate([s.t_f for s in real_stages],
                   [s.t_b for s in real_stages], strat.c_links,
                   n_microbatches, counts)
    eta = eta_load_balance(
        res.stage_compute,
        [s.n_devices * cluster.subclusters[s.cluster_idx].device.peak_flops
         for s in real_stages])
    return ParallelStrategy(real_stages, strat.c_links, counts,
                            max(s.t for s in real_stages), n_microbatches,
                            mb_tokens, res.makespan, eta,
                            {"baseline": "blind-eager (Alpa-like)"})


def plan_coarse(cluster, arch, *, seq_len, global_batch, n_microbatches,
                granularity: int = 8, **kw) -> ParallelStrategy:
    """Alpa-like: coarse layers + Eager-1F1B."""
    s = _planned(cluster, arch, seq_len=seq_len, global_batch=global_batch,
                 n_microbatches=n_microbatches, granularity=granularity,
                 schedule="eager", **kw)
    s.planner_meta["baseline"] = "coarse-eager (Alpa-like)"
    return s


def plan_coarse_sync(cluster, arch, *, seq_len, global_batch, n_microbatches,
                     granularity: int = 48, **kw) -> ParallelStrategy:
    """HexiScale-like: capacity-aware coarse planning, no comm overlap."""
    s = _planned(cluster, arch, seq_len=seq_len, global_batch=global_batch,
                 n_microbatches=n_microbatches, granularity=granularity,
                 schedule="sync", **kw)
    s.planner_meta["baseline"] = "coarse-sync (HexiScale-like)"
    return s
