"""Heterogeneous inter-op parallel strategy search (paper §5.2, Alg. 1).

DP over ``F[k, a, b, nc]`` = min pipeline fill cost of partitioning layers
``k..L`` into stages, with ``a``/``b`` device *units* of each sub-cluster
remaining and the suffix's first stage placed on cluster ``nc`` (index C =
"end of pipeline").  Objective (Eq. 13):

    T*(t_max) = min F + (B - 1) * t_max,   F = sum_i (t_i + 2 c_i)

subject to t_i <= t_max, c_i <= t_max, and the H-1F1B memory bound (Eq. 18)
with K from Eq. 17.  The warm-up-count table ``N`` is carried through the DP
exactly as the paper's ``N(s, k, d_A, d_B; t_max)``.

Deviation (superset of the paper, flag ``monotone_clusters`` restores the
exact formulation): the paper's Eqs. 14/15 allocate cluster A fully before
cluster B along the pipeline; tracking the next stage's cluster in the state
removes that restriction at 2x state cost and can only find better strategies.

**Joint inter+intra search** (profiler ``intra_op=True``): each table row is
a (submesh, tensor-parallel width) *variant*, so the same DP jointly chooses
the stage slicing, the placement, and the intra-op sharding degree — a
variant's intra-op collective time raises its ``t`` while its leaner
activation footprint relaxes the Eq. 18 bound, and the uneven
efficiency-proportional shard ratios of a mixed sub-cluster lower its
compute time.  The chosen :class:`~repro.core.strategy.IntraOpPlan` rides on
each ``StageAssignment``.

The paper's three search optimizations are implemented:
  - *sparsity index*: per (mesh, k), the feasible j-window under t_max is
    located by binary search over the monotone stage-cost row (precomputed
    cumulative structure from the Zero-Redundant Profiler);
  - *bidirectional pruning*: binary-search the smallest feasible t_S; bound
    t_E = T(t_S)/B and drop all candidates outside [t_S, t_E];
  - *batched parallel evaluation*: remaining candidates are evaluated in
    worker processes (Ray-actor analogue), batched round-robin by activated
    candidate count for balance.
"""
from __future__ import annotations

import dataclasses
import math
import os
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import HeteroCluster
from repro.core.h1f1b import h1f1b_counts
from repro.core.pipesim import eta_load_balance, simulate
from repro.core.profiler import ProfileTables
from repro.core.strategy import ParallelStrategy, StageAssignment

INF = np.inf


@dataclass
class SearchConfig:
    n_microbatches: int = 128
    monotone_clusters: bool = False   # True = paper's exact Eq. 14/15 ordering
    require_all_devices: bool = False
    n_workers: int = 0                # 0 -> serial
    tmax_round_digits: int = 4        # dedupe candidates to this many sig digits
    max_candidates: int = 512
    intra_overlap: float = 0.0        # fraction of intra-op collective time
                                      # hidden under compute in the final
                                      # pipesim validation (0 = fully exposed)


class _DPContext:
    """Immutable tables shared by all t_max evaluations (fork-inherited)."""

    def __init__(self, cluster: HeteroCluster, tables: ProfileTables,
                 cfg: SearchConfig):
        self.cluster = cluster
        self.tables = tables
        self.cfg = cfg
        self.C = len(cluster.subclusters)
        self.L = tables.t_f.shape[1] - 1
        # device units per cluster = smallest submesh size present
        self.unit = []
        for ci in range(self.C):
            sizes = [m.n_devices for m in tables.meshes if m.cluster_idx == ci]
            self.unit.append(min(sizes) if sizes else 1)
        self.units_total = [
            (cluster.subclusters[ci].n_devices // self.unit[ci])
            for ci in range(self.C)]
        self.mesh_units = [m.n_devices // self.unit[m.cluster_idx]
                           for m in tables.meshes]
        self.caps = [s.device.mem_bytes for s in cluster.subclusters]
        self.t_tab = tables.t_f + tables.t_b

    def bw(self, src: int, dst: int) -> float:
        return self.cluster.link_bw(src, dst)


def _shift(plane: np.ndarray, u: int, axis: int, fill=INF) -> np.ndarray:
    """out[a] = plane[a - u] along axis (device-consumption shift)."""
    out = np.full_like(plane, fill)
    if axis == 0:
        out[u:, :] = plane[:plane.shape[0] - u, :]
    else:
        out[:, u:] = plane[:, :plane.shape[1] - u]
    return out


def _dp_eval(ctx: _DPContext, t_max: float,
             want_tables: bool = False):
    """Run the DP under a fixed t_max.  Returns (fill_cost, F, N) where
    fill_cost = min over nc of F[0, UA, UB, nc] (inf if infeasible)."""
    C, L = ctx.C, ctx.L
    UA = ctx.units_total[0]
    UB = ctx.units_total[1] if C > 1 else 0
    tab = ctx.tables
    B = ctx.cfg.n_microbatches

    F = np.full((L + 1, UA + 1, UB + 1, C + 1), INF)
    N = np.zeros((L + 1, UA + 1, UB + 1, C + 1), dtype=np.int64)
    F[L, :, :, C] = 0.0

    for k in range(L - 1, -1, -1):
        for c in range(C):
            axis = 0 if c == 0 else 1
            best = np.full((UA + 1, UB + 1), INF)
            bestK = np.zeros((UA + 1, UB + 1), dtype=np.int64)
            for mid, mesh in enumerate(tab.meshes):
                if mesh.cluster_idx != c:
                    continue
                u = ctx.mesh_units[mid]
                row_t = ctx.t_tab[mid, k]           # (L+1,)
                row_ok = tab.feasible[mid, k]
                # sparsity index: t is monotone in j -> contiguous window
                js = np.nonzero(row_ok & (row_t <= t_max))[0]
                for j in js:
                    t_stage = row_t[j]
                    mp, ma = tab.mem_p[mid, k, j], tab.mem_a[mid, k, j]
                    ncs = (C,) if j == L else tuple(range(C))
                    for nc in ncs:
                        if ctx.cfg.monotone_clusters and j < L and nc < c:
                            continue  # paper: clusters in fixed pipeline order
                        if j == L:
                            c_time = 0.0
                        else:
                            c_time = tab.cut_bytes[j] / ctx.bw(c, nc)
                        if c_time > t_max:
                            continue
                        Fn = F[j, :, :, nc]
                        Nn = N[j, :, :, nc]
                        K = math.ceil(2.0 * c_time / t_max) + 1 + Nn
                        val = Fn + t_stage + 2.0 * c_time
                        val = np.where(mp + K * ma <= ctx.caps[c], val, INF)
                        val = _shift(val, u, axis)
                        Ksh = _shift(K.astype(np.float64), u, axis, fill=0)
                        upd = val < best
                        best = np.where(upd, val, best)
                        bestK = np.where(upd, Ksh.astype(np.int64), bestK)
            F[k, :, :, c] = best
            N[k, :, :, c] = bestK

    if not ctx.cfg.require_all_devices:
        # idle devices allowed: availability is monotone, take running min
        F_full = np.minimum.accumulate(np.minimum.accumulate(F, axis=1), axis=2)
        fill = float(np.min(F_full[0, UA, UB, :C]))
    else:
        fill = float(np.min(F[0, UA, UB, :C]))
    if want_tables:
        return fill, F, N
    return fill, None, None


def _backtrack(ctx: _DPContext, t_max: float, F: np.ndarray, N: np.ndarray
               ) -> List[Tuple[int, int, int, int]]:
    """Extract the argmin stage list [(mid, k, j, K), ...] by re-finding the
    achieving transition at each state along the optimal path."""
    C, L = ctx.C, ctx.L
    tab = ctx.tables
    UA = ctx.units_total[0]
    UB = ctx.units_total[1] if C > 1 else 0

    # find start state (allowing idle devices: scan all (a, b) <= (UA, UB);
    # with require_all_devices, only the full-allocation state qualifies)
    best = (INF, None)
    for c in range(C):
        if ctx.cfg.require_all_devices:
            v = F[0, UA, UB, c]
            if v < best[0] - 1e-15:
                best = (v, (0, UA, UB, c))
            continue
        for a in range(UA + 1):
            for b in range(UB + 1):
                v = F[0, a, b, c]
                if v < best[0] - 1e-15:
                    best = (v, (0, a, b, c))
    assert best[1] is not None, "infeasible strategy"
    k, a, b, c = best[1]
    out = []
    while k < L:
        found = None
        target = F[k, a, b, c]
        for mid, mesh in enumerate(tab.meshes):
            if mesh.cluster_idx != c:
                continue
            u = ctx.mesh_units[mid]
            avail = a if c == 0 else b
            if u > avail:
                continue
            a2 = a - u if c == 0 else a
            b2 = b - u if c == 1 else b
            row_t = ctx.t_tab[mid, k]
            row_ok = tab.feasible[mid, k]
            for j in range(k + 1, L + 1):
                if not row_ok[j] or row_t[j] > t_max:
                    continue
                ncs = (C,) if j == L else tuple(range(C))
                for nc in ncs:
                    if ctx.cfg.monotone_clusters and j < L and nc < c:
                        continue
                    c_time = 0.0 if j == L else tab.cut_bytes[j] / ctx.bw(c, nc)
                    if c_time > t_max:
                        continue
                    K = math.ceil(2.0 * c_time / t_max) + 1 + N[j, a2, b2, nc]
                    mp, ma = tab.mem_p[mid, k, j], tab.mem_a[mid, k, j]
                    if mp + K * ma > ctx.caps[c]:
                        continue
                    val = F[j, a2, b2, nc] + row_t[j] + 2.0 * c_time
                    if abs(val - target) <= 1e-9 * max(1.0, abs(target)):
                        found = (mid, k, j, int(K), a2, b2, nc)
                        break
                if found:
                    break
            if found:
                break
        assert found is not None, "backtrack failed"
        mid, _, j, K, a2, b2, nc = found
        out.append((mid, k, j, K))
        k, a, b, c = j, a2, b2, nc
    return out


# --- module-level worker state for fork-based parallel evaluation -----------
_WORKER_CTX: Optional[_DPContext] = None


def _worker_eval(args):
    t_max_batch = args
    return [(t, _dp_eval(_WORKER_CTX, t)[0]) for t in t_max_batch]


def search(cluster: HeteroCluster, tables: ProfileTables, mb_tokens: int,
           cfg: SearchConfig = SearchConfig(),
           verbose: bool = False) -> ParallelStrategy:
    """Full HAPT search: candidate t_max generation, bidirectional pruning,
    (parallel) batched evaluation, backtracking, H-1F1B scheduling."""
    global _WORKER_CTX
    ctx = _DPContext(cluster, tables, cfg)
    B = cfg.n_microbatches

    # ---- candidate t_max values (sorted, dedup'd — Alg. 1 line 2) ----------
    vals = ctx.t_tab[tables.feasible]
    sig = cfg.tmax_round_digits
    cands = np.unique(np.array(
        [float(f"%.{sig}g" % v) for v in vals if np.isfinite(v)]))
    if len(cands) == 0:
        raise RuntimeError("no feasible stage-mesh candidates")

    # ---- bidirectional pruning ---------------------------------------------
    lo, hi = 0, len(cands) - 1
    if _dp_eval(ctx, float(cands[hi]))[0] == INF:
        raise RuntimeError("infeasible even at largest t_max")
    while lo < hi:  # smallest feasible t_S (monotone feasibility)
        mid = (lo + hi) // 2
        if _dp_eval(ctx, float(cands[mid]))[0] < INF:
            hi = mid
        else:
            lo = mid + 1
    t_S = float(cands[lo])
    fill_S = _dp_eval(ctx, t_S)[0]
    T_S = fill_S + (B - 1) * t_S
    t_E = T_S / max(B - 1, 1)
    keep = cands[(cands >= t_S) & (cands <= t_E)]
    if len(keep) > cfg.max_candidates:
        idx = np.linspace(0, len(keep) - 1, cfg.max_candidates).astype(int)
        keep = keep[np.unique(idx)]
    if verbose:
        print(f"[search] {len(cands)} candidates -> t_S={t_S:.4g}, "
              f"t_E={t_E:.4g}, evaluating {len(keep)}")

    # ---- batched (parallel) evaluation --------------------------------------
    results: List[Tuple[float, float]] = []
    if cfg.n_workers and len(keep) > 8:
        _WORKER_CTX = ctx
        nb = min(cfg.n_workers * 4, len(keep))
        batches = [list(map(float, keep[i::nb])) for i in range(nb)]
        with ProcessPoolExecutor(max_workers=cfg.n_workers) as ex:
            for out in ex.map(_worker_eval, batches):
                results.extend(out)
        _WORKER_CTX = None
    else:
        for t in keep:
            results.append((float(t), _dp_eval(ctx, float(t))[0]))

    best_t, best_T = None, INF
    for t, fill in results:
        if fill == INF:
            continue
        T = fill + (B - 1) * t
        if T < best_T:
            best_T, best_t = T, t
    assert best_t is not None

    # ---- extract strategy ----------------------------------------------------
    _, F, N = _dp_eval(ctx, best_t, want_tables=True)
    picks = _backtrack(ctx, best_t, F, N)
    stages, c_links = [], []
    for si, (mid, k, j, K) in enumerate(picks):
        mesh = tables.meshes[mid]
        sc = tables.stage_costs[(mid, k, j)]
        stages.append(StageAssignment(
            layer_start=k, layer_end=j, cluster_idx=mesh.cluster_idx,
            mesh_n=mesh.n, mesh_m=mesh.m, tp=sc.tp, dp=sc.dp,
            t_f=sc.t_f, t_b=sc.t_b, mem_p=sc.mem_p, mem_a=sc.mem_a,
            intra_op=sc.intra))
        if si < len(picks) - 1:
            nxt_cluster = tables.meshes[picks[si + 1][0]].cluster_idx
            c_links.append(
                tables.cut_bytes[j] / ctx.bw(mesh.cluster_idx, nxt_cluster))

    t_per_stage = [s.t for s in stages]
    counts = h1f1b_counts(t_per_stage, c_links, B)
    if cfg.intra_overlap > 0 and all(s.intra_op is not None for s in stages):
        # validate with the intra-op collectives threaded separately through
        # the simulator so a fraction can overlap with compute (the DP itself
        # prices them fully exposed — a conservative upper bound)
        res = simulate(
            [s.t_f - s.intra_op.comm_time_f for s in stages],
            [s.t_b - s.intra_op.comm_time_b for s in stages],
            c_links, B, counts,
            intra_f=[s.intra_op.comm_time_f for s in stages],
            intra_b=[s.intra_op.comm_time_b for s in stages],
            intra_overlap=cfg.intra_overlap)
    else:
        res = simulate([s.t_f for s in stages], [s.t_b for s in stages],
                       c_links, B, counts)
    eta = eta_load_balance(
        res.stage_compute,
        [s.n_devices * cluster.subclusters[s.cluster_idx].device.peak_flops
         for s in stages])
    return ParallelStrategy(
        stages=stages, c_links=c_links, warmup_counts=counts,
        t_max=float(best_t), n_microbatches=B, mb_tokens=mb_tokens,
        est_step_time=res.makespan, eta=eta,
        planner_meta={
            "fill_cost": best_T - (B - 1) * best_t,
            "predicted_T": best_T,
            "n_tmax_evaluated": len(results),
            "profiler": dataclasses.asdict(tables.stats),
        })
