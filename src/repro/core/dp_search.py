"""Heterogeneous inter-op parallel strategy search (paper §5.2, Alg. 1).

DP over ``F[k, a_0..a_{C-1}, nc]`` = min pipeline fill cost of partitioning
layers ``k..L`` into stages, with ``a_c`` device *units* of each sub-cluster
remaining and the suffix's first stage placed on cluster ``nc`` (index C =
"end of pipeline").  Objective (Eq. 13):

    T*(t_max) = min F + (B - 1) * t_max,   F = sum_i (t_i + 2 c_i)

subject to t_i <= t_max, c_i <= t_max, and the H-1F1B memory bound (Eq. 18)
with K from Eq. 17.  The warm-up-count table ``N`` is carried through the DP
exactly as the paper's ``N(s, k, d_A, d_B; t_max)``.

Deviation (superset of the paper, flag ``monotone_clusters`` restores the
exact formulation): the paper's Eqs. 14/15 allocate cluster A fully before
cluster B along the pipeline; tracking the next stage's cluster in the state
removes that restriction at 2x state cost and can only find better strategies.

**Joint inter+intra search** (profiler ``intra_op=True``): each table row is
a (submesh, tensor-parallel width) *variant*, so the same DP jointly chooses
the stage slicing, the placement, and the intra-op sharding degree — a
variant's intra-op collective time raises its ``t`` while its leaner
activation footprint relaxes the Eq. 18 bound, and the uneven
efficiency-proportional shard ratios of a mixed sub-cluster lower its
compute time.  The chosen :class:`~repro.core.strategy.IntraOpPlan` rides on
each ``StageAssignment``.

**Two engines** (``SearchConfig.engine``), bit-identical on every shared
input:

- ``"vectorized"`` (the ``"auto"`` default): per ``(k, mesh)`` the whole
  ``(j, nc)`` transition fan-in is evaluated as one stacked masked reduction
  over precomputed per-(mesh, k) candidate rows, and the surviving ``t_max``
  batch is evaluated as a single extra array axis
  (:func:`_dp_eval_batch`) — interpreter cost per candidate vanishes.
  Supports any number of sub-clusters (the device-unit axes generalize).
- ``"oracle"``: the original scalar nested-loop DP, kept as the reference
  the vectorized engine is tested bit-exact against (2 sub-clusters max).

The paper's three search optimizations are implemented:
  - *sparsity index*: per (mesh, k), the feasible j-window under t_max is
    located over the monotone stage-cost row (precomputed cumulative
    structure from the Zero-Redundant Profiler);
  - *bidirectional pruning*: binary-search the smallest feasible t_S; bound
    t_E = T(t_S)/B and drop all candidates outside [t_S, t_E];
  - *batched parallel evaluation*: surviving candidates are evaluated as
    stacked array batches; ``n_workers`` distributes whole batches across
    fork-inherited worker processes (Ray-actor analogue) and falls back to
    serial evaluation cleanly where fork is unavailable.

:func:`instrumented_search` is the public benchmarking/observability hook:
identical result to :func:`search`, plus a :class:`SearchStats` record
(candidate counts, pruning window, engine, per-phase wall clock).
"""
from __future__ import annotations

import dataclasses
import math
import time
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cluster import HeteroCluster
from repro.core.h1f1b import h1f1b_counts
from repro.core.pipesim import eta_load_balance, simulate
from repro.core.profiler import ProfileTables
from repro.core.strategy import ParallelStrategy, StageAssignment

INF = np.inf


class SearchTimeout(RuntimeError):
    """The search exceeded ``SearchConfig.deadline_s`` of wall clock.  A
    RuntimeError subclass so every caller that treats planner failure as
    "no feasible strategy" (e.g. the elastic controller's degraded ladder)
    handles timeouts through the same path."""


@dataclass
class SearchConfig:
    n_microbatches: int = 128
    monotone_clusters: bool = False   # True = paper's exact Eq. 14/15 ordering
    require_all_devices: bool = False
    n_workers: int = 0                # 0 -> serial
    tmax_round_digits: int = 4        # dedupe candidates to this many sig digits
    max_candidates: int = 512
    intra_overlap: float = 0.0        # fraction of intra-op collective time
                                      # hidden under compute in the final
                                      # pipesim validation (0 = fully exposed)
    engine: str = "auto"              # auto | vectorized | oracle (plans are
                                      # bit-identical across engines)
    batch_size: int = 8               # t_max candidates per stacked evaluation
                                      # (vectorized engine; clamped by memory.
                                      # Chunks ascend, so small batches keep
                                      # the low-t_max sparsity window tight)
    deadline_s: float = 0.0           # wall-clock budget for one search;
                                      # exceeded -> SearchTimeout (0 = none).
                                      # Checked between DP solves, so overrun
                                      # is bounded by one candidate evaluation


@dataclass
class SearchStats:
    """Observability record returned by :func:`instrumented_search`.

    Times are seconds of wall clock; counts are t_max candidate evaluations
    (each one full DP solve).  ``engine`` is what actually ran;
    ``oracle_fallbacks`` > 0 means the vectorized engine raised and the
    scalar reference re-ran the search (bit-identical result, none of the
    speedup — CI treats it as a regression on the canonical clusters)."""
    engine: str = "vectorized"
    requested_engine: str = "auto"
    n_subclusters: int = 0
    n_mesh_rows: int = 0
    n_layers: int = 0
    n_tmax_candidates: int = 0        # distinct rounded stage times
    n_pruned: int = 0                 # dropped by the bidirectional window
    n_evaluated: int = 0              # fresh DP solves in the surviving batch
    n_cache_served: int = 0           # surviving candidates whose fill was
                                      # reused from the pruning probes
    prune_evals: int = 0              # DP solves spent on the binary search
    t_S: float = 0.0
    t_E: float = 0.0
    best_t_max: float = 0.0
    fill_cost: float = 0.0
    predicted_T: float = 0.0
    workers_used: int = 0
    oracle_fallbacks: int = 0
    eval_seconds: float = 0.0         # surviving-batch evaluation wall clock
    total_seconds: float = 0.0

    def asdict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclass
class _EdgeGroup:
    """Per (start layer, source cluster) stacked transition fan-in: the
    ``(j, nc)`` candidate axis the vectorized engine reduces over, plus the
    per-mesh rows aligned to it.  ``meshes`` entries are
    ``(mid, units, t_stage, t_stage + 2c, K_threshold)``."""
    jj: np.ndarray          # (n,) next start layer per candidate
    nn: np.ndarray          # (n,) next cluster per candidate (C = pipe end)
    ct: np.ndarray          # (n,) inter-stage comm seconds
    tmin: np.ndarray        # (n,) fastest mesh's stage time (window pruning)
    meshes: List[Tuple[int, int, np.ndarray, np.ndarray, np.ndarray, int]]


_KT_HUGE = np.int64(1) << 40   # "memory never binds": far above any real K


def _k_threshold(mp: np.ndarray, ma: np.ndarray, cap: float) -> np.ndarray:
    """Largest integer K with ``mp + K * ma <= cap`` — evaluated with the
    oracle's exact float expression, which is monotone in K (ma >= 0), so
    the Eq. 18 memory mask collapses to one integer compare per candidate.
    -1 where nothing fits (e.g. infeasible rows carrying inf), ``_KT_HUGE``
    where the bound never binds for any realizable warm-up count."""
    finite = np.isfinite(mp) & np.isfinite(ma)
    pos = finite & (ma > 0.0)
    with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
        guess = np.floor((cap - mp) / np.where(pos, ma, 1.0))
        g = np.where(pos, np.clip(guess, -1.0, float(_KT_HUGE)), -1.0) \
            .astype(np.int64)
        # correct the float-division guess against the exact expression
        for _ in range(64):
            bad = pos & (g >= 0) & (mp + g * ma > cap)
            if not bad.any():
                break
            g = np.where(bad, g - 1, g)
        for _ in range(64):
            up = pos & (g < _KT_HUGE) & (mp + (g + 1) * ma <= cap)
            if not up.any():
                break
            g = np.where(up, g + 1, g)
        # ma == 0: feasibility is K-independent
        g = np.where(finite & (ma <= 0.0) & (mp <= cap), _KT_HUGE, g)
    return g


class _DPContext:
    """Immutable tables shared by all t_max evaluations (fork-inherited).

    Besides the original scalar-oracle fields, precomputes the vectorized
    engine's per-(mesh, k) candidate rows: the stacked ``(j, nc)`` transition
    fan-in as flat numpy arrays (stage time, doubled comm time, memory
    operands), built once and reused by every ``t_max`` evaluation.
    """

    def __init__(self, cluster: HeteroCluster, tables: ProfileTables,
                 cfg: SearchConfig, comm=None):
        self.cluster = cluster
        self.tables = tables
        self.cfg = cfg
        self.comm = comm
        self.C = len(cluster.subclusters)
        self.L = tables.t_f.shape[1] - 1
        # device units per cluster = smallest submesh size present
        self.unit = []
        for ci in range(self.C):
            sizes = [m.n_devices for m in tables.meshes if m.cluster_idx == ci]
            self.unit.append(min(sizes) if sizes else 1)
        self.units_total = [
            (cluster.subclusters[ci].n_devices // self.unit[ci])
            for ci in range(self.C)]
        self.mesh_units = [m.n_devices // self.unit[m.cluster_idx]
                           for m in tables.meshes]
        self.caps = [s.device.mem_bytes for s in cluster.subclusters]
        self.t_tab = tables.t          # cached f+b table (ProfileTables.t)
        # --- vectorized-engine precomputation ------------------------------
        self.unit_shape = tuple(u + 1 for u in self.units_total)
        self.full_idx = tuple(self.units_total)
        self.mesh_ids_of_cluster: List[List[int]] = [[] for _ in range(self.C)]
        for mid, mesh in enumerate(tables.meshes):
            self.mesh_ids_of_cluster[mesh.cluster_idx].append(mid)
        # ctime[j, c, nc]: cut-at-j transfer seconds from cluster c to nc
        bw = np.array([[cluster.link_bw(c, nc) for nc in range(self.C)]
                       for c in range(self.C)], dtype=np.float64)
        self.ctime = tables.cut_bytes[:, None, None] / bw[None, :, :]
        if comm is not None:
            # comm-aware cut pricing: the WAN link's per-transfer latency is
            # real cost the scalar model drops; both engines read ctime, so
            # they stay bit-identical to each other either way
            lat = np.array(
                [[comm.p2p_latency(c, nc) for nc in range(self.C)]
                 for c in range(self.C)], dtype=np.float64)
            self.ctime = self.ctime + lat[None, :, :]
        self._groups: Dict[Tuple[int, int], Optional[_EdgeGroup]] = {}

    def bw(self, src: int, dst: int) -> float:
        return self.cluster.link_bw(src, dst)

    def p2p(self, j: int, src: int, dst: int) -> float:
        """Cut-at-``j`` transfer seconds from cluster ``src`` to ``dst`` —
        the one expression every scalar path shares with the vectorized
        engine's precomputed ``ctime`` (bit-identical by construction)."""
        return float(self.ctime[j, src, dst])

    def group(self, k: int, c: int) -> Optional["_EdgeGroup"]:
        """Stacked ``(j, nc)`` transition fan-in for (start layer k, source
        cluster c): the union of every cluster-c mesh row's feasible stages,
        as flat arrays in the scalar engine's iteration order (j ascending,
        nc ascending), with per-mesh cost/memory rows aligned to the union
        (infinite where that mesh is infeasible — the masks exclude them
        exactly like the oracle's ``continue``).  t_max-independent; built
        once, shared by every evaluation."""
        key = (k, c)
        hit = self._groups.get(key, False)
        if hit is not False:
            return hit
        tab = self.tables
        mids = [mid for mid in self.mesh_ids_of_cluster[c]
                if tab.feasible[mid, k].any()]
        if not mids:
            self._groups[key] = None
            return None
        any_ok = np.zeros(self.L + 1, dtype=bool)
        for mid in mids:
            any_ok |= tab.feasible[mid, k]
        C, L = self.C, self.L
        mono = self.cfg.monotone_clusters
        jj: List[int] = []
        nn: List[int] = []
        ct: List[float] = []
        for j in np.nonzero(any_ok)[0]:
            if j == L:
                jj.append(j)
                nn.append(C)
                ct.append(0.0)
                continue
            for nc in range(C):
                if mono and nc < c:
                    continue
                jj.append(int(j))
                nn.append(nc)
                ct.append(float(self.ctime[j, c, nc]))
        jj_a = np.asarray(jj, dtype=np.intp)
        nn_a = np.asarray(nn, dtype=np.intp)
        ct_a = np.asarray(ct, dtype=np.float64)
        twoc = 2.0 * ct_a
        cap = self.caps[c]
        meshes = []
        tmin = np.full(len(jj_a), INF)
        for mid in mids:
            t_m = self.t_tab[mid, k, jj_a]
            s_m = t_m + twoc         # the oracle's (t_stage + 2.0 * c_time)
            kt_m = _k_threshold(tab.mem_p[mid, k, jj_a],
                                tab.mem_a[mid, k, jj_a], cap)
            finite = np.isfinite(t_m)
            kt_min = int(kt_m[finite].min()) if finite.any() else -1
            meshes.append((mid, self.mesh_units[mid], t_m, s_m, kt_m, kt_min))
            tmin = np.minimum(tmin, t_m)
        out = _EdgeGroup(jj_a, nn_a, ct_a, tmin, meshes)
        self._groups[key] = out
        return out

    def batch_chunk(self, requested: int) -> int:
        """Clamp the t_max batch so stacked temporaries stay ~<256 MB."""
        cells = int(np.prod(self.unit_shape))
        per_t = (self.L + 2) * cells * (self.C + 1) * 16 \
            + (self.L + 1) * max(1, self.C) * cells * 8 * 8
        return max(1, min(requested, int(2.56e8 // max(per_t, 1))))


def _shift(plane: np.ndarray, u: int, axis: int, fill=INF) -> np.ndarray:
    """out[a] = plane[a - u] along axis (device-consumption shift)."""
    out = np.full_like(plane, fill)
    src = [slice(None)] * plane.ndim
    dst = [slice(None)] * plane.ndim
    dst[axis] = slice(u, None)
    src[axis] = slice(0, plane.shape[axis] - u)
    out[tuple(dst)] = plane[tuple(src)]
    return out


# ---------------------------------------------------------------------------
# Scalar reference engine (the pre-vectorization code, kept as the oracle)
# ---------------------------------------------------------------------------


def _dp_eval(ctx: _DPContext, t_max: float,
             want_tables: bool = False):
    """Scalar-oracle DP under a fixed t_max (2 sub-clusters max).  Returns
    (fill_cost, F, N) where fill_cost = min over nc of F[0, UA, UB, nc]
    (inf if infeasible)."""
    C, L = ctx.C, ctx.L
    assert C <= 2, "oracle engine supports at most 2 sub-clusters"
    UA = ctx.units_total[0]
    UB = ctx.units_total[1] if C > 1 else 0
    tab = ctx.tables

    F = np.full((L + 1, UA + 1, UB + 1, C + 1), INF)
    N = np.zeros((L + 1, UA + 1, UB + 1, C + 1), dtype=np.int64)
    F[L, :, :, C] = 0.0

    for k in range(L - 1, -1, -1):
        for c in range(C):
            axis = 0 if c == 0 else 1
            best = np.full((UA + 1, UB + 1), INF)
            bestK = np.zeros((UA + 1, UB + 1), dtype=np.int64)
            for mid, mesh in enumerate(tab.meshes):
                if mesh.cluster_idx != c:
                    continue
                u = ctx.mesh_units[mid]
                row_t = ctx.t_tab[mid, k]           # (L+1,)
                row_ok = tab.feasible[mid, k]
                # sparsity index: t is monotone in j -> contiguous window
                js = np.nonzero(row_ok & (row_t <= t_max))[0]
                for j in js:
                    t_stage = row_t[j]
                    mp, ma = tab.mem_p[mid, k, j], tab.mem_a[mid, k, j]
                    ncs = (C,) if j == L else tuple(range(C))
                    for nc in ncs:
                        if ctx.cfg.monotone_clusters and j < L and nc < c:
                            continue  # paper: clusters in fixed pipeline order
                        if j == L:
                            c_time = 0.0
                        else:
                            c_time = ctx.p2p(j, c, nc)
                        if c_time > t_max:
                            continue
                        Fn = F[j, :, :, nc]
                        Nn = N[j, :, :, nc]
                        K = math.ceil(2.0 * c_time / t_max) + 1 + Nn
                        val = Fn + (t_stage + 2.0 * c_time)
                        val = np.where(mp + K * ma <= ctx.caps[c], val, INF)
                        val = _shift(val, u, axis)
                        Ksh = _shift(K.astype(np.float64), u, axis, fill=0)
                        upd = val < best
                        best = np.where(upd, val, best)
                        bestK = np.where(upd, Ksh.astype(np.int64), bestK)
            F[k, :, :, c] = best
            N[k, :, :, c] = bestK

    if not ctx.cfg.require_all_devices:
        # idle devices allowed: availability is monotone, take running min
        F_full = np.minimum.accumulate(np.minimum.accumulate(F, axis=1), axis=2)
        fill = float(np.min(F_full[0, UA, UB, :C]))
    else:
        fill = float(np.min(F[0, UA, UB, :C]))
    if want_tables:
        if C == 1:
            # drop the degenerate second unit axis -> the generalized
            # (L+1, *unit_shape, C+1) layout shared with the vectorized engine
            return fill, F[:, :, 0, :], N[:, :, 0, :]
        return fill, F, N
    return fill, None, None


# ---------------------------------------------------------------------------
# Vectorized engine
# ---------------------------------------------------------------------------


def _dp_eval_batch(ctx: _DPContext, ts: np.ndarray,
                   want_tables: bool = False):
    """Run the DP for a whole batch of t_max candidates as one stacked array
    program.  ``ts``: (T,) float64.  Returns fills (T,), or
    (fills, F, N) with F/N shaped ``(T, L+1, *unit_shape, C+1)``.

    Per (k, cluster, mesh row) the whole ``(j, nc)`` fan-in collapses into a
    masked reduction; per-candidate feasibility under each t_max, the Eq. 18
    memory bound, and the warm-up table N are all evaluated elementwise with
    the exact float expressions of the scalar oracle, so results are
    bit-identical (first-minimum tie-breaking matches the scalar engine's
    strict-improvement scan order)."""
    C, L = ctx.C, ctx.L
    dims = ctx.unit_shape
    cells = int(np.prod(dims))
    ts = np.asarray(ts, dtype=np.float64)
    T = len(ts)
    if T == 0:
        return np.zeros(0)
    TC = T * cells
    t_hi = float(ts.max())

    # flat state: row j*(C+1)+nc holds F[j, ·, nc] over the (t_max, *units)
    # grid — row gathers are contiguous memcpys instead of strided fancy
    # indexing, which is where the scalar engine burned its time
    F2 = np.full(((L + 1) * (C + 1), TC), INF)
    N2 = np.zeros(((L + 1) * (C + 1), TC), dtype=np.int32)
    F2[L * (C + 1) + C] = 0.0

    col_t = np.repeat(np.arange(T), cells)      # column -> t index
    col_i = np.arange(TC)
    fbuf = nbuf = vbuf = None                   # grown-on-demand scratch
    nmax = 0         # largest N value written so far (memory-slack test)

    for k in range(L - 1, -1, -1):
        for c in range(C):
            best = np.full(TC, INF)
            bestK = np.zeros(TC, dtype=np.int32)
            grp = ctx.group(k, c)
            if grp is not None:
                # sparsity window: candidates infeasible even at the batch's
                # largest t_max can never contribute — drop them up front
                sel = (grp.tmin <= t_hi) & (grp.ct <= t_hi)
                if sel.all():
                    jj, nn, ct, meshes = grp.jj, grp.nn, grp.ct, grp.meshes
                else:
                    jj, nn, ct = grp.jj[sel], grp.nn[sel], grp.ct[sel]
                    meshes = [(mid, u, t_m[sel], s_m[sel], kt[sel], ktm)
                              for mid, u, t_m, s_m, kt, ktm in grp.meshes]
            else:
                jj = None
            if jj is not None and len(jj):
                n = len(jj)
                if fbuf is None or fbuf.shape[0] < n:
                    fbuf = np.empty((n, TC))
                    vbuf = np.empty((n, TC))
                    nbuf = np.empty((n, TC), dtype=np.int32)
                rows = jj * (C + 1) + nn
                Fn = np.take(F2, rows, axis=0, out=fbuf[:n])
                Nn = None
                val3 = vbuf[:n].reshape(n, T, cells)
                Fn3 = Fn.reshape(n, T, cells)
                ct_ok = ct[:, None] <= ts[None, :]          # (n, T)
                with np.errstate(divide="ignore", invalid="ignore"):
                    Kb = np.where(ct_ok,
                                  np.ceil(2.0 * ct[:, None] / ts[None, :]),
                                  0.0).astype(np.int64)
                kb_max = int(Kb.max()) if n else 0
                for mid, u, t_m, s_m, kt, kt_min in meshes:
                    # t-infeasible candidates are excluded by poisoning the
                    # stage cost itself: INF + anything stays INF, so no big
                    # boolean mask is ever materialized
                    s_okt = np.where(ct_ok & (t_m[:, None] <= ts[None, :]),
                                     s_m[:, None], INF)    # (n, T)
                    np.add(Fn3, s_okt[:, :, None], out=val3)
                    if kt_min - 1 - kb_max < nmax:
                        # Eq. 18 can bind: apply it as an integer compare
                        # K <= kt, i.e. Nn <= kt - 1 - Kb (exactly the
                        # oracle's float mask — see _k_threshold)
                        if Nn is None:
                            Nn = np.take(N2, rows, axis=0, out=nbuf[:n])
                        M = np.minimum(kt[:, None] - 1 - Kb,
                                       np.int64(2**31 - 1)).astype(np.int32)
                        np.copyto(val3, INF,
                                  where=(Nn.reshape(n, T, cells)
                                         > M[:, :, None]))
                    val = vbuf[:n]
                    amin = val.argmin(axis=0)               # first minimum
                    vmin = val[amin, col_i]
                    row_w = rows[amin]
                    Kw = (N2[row_w, col_i].astype(np.int64)
                          + Kb[amin, col_t] + 1)
                    Kw = np.where(np.isinf(vmin), 0, Kw)
                    vsh = _shift(vmin.reshape((T,) + dims), u, 1 + c)
                    Ksh = _shift(Kw.astype(np.float64).reshape((T,) + dims),
                                 u, 1 + c, fill=0.0).astype(np.int32)
                    vsh = vsh.reshape(TC)
                    upd = vsh < best
                    best = np.where(upd, vsh, best)
                    bestK = np.where(upd, Ksh.reshape(TC), bestK)
            F2[k * (C + 1) + c] = best
            N2[k * (C + 1) + c] = bestK
            if jj is not None and len(jj):
                nmax = max(nmax, int(bestK.max()))

    F = F2.reshape((L + 1, C + 1, T) + dims)
    N = N2.reshape((L + 1, C + 1, T) + dims)
    if not ctx.cfg.require_all_devices:
        F_full = F
        for ax in range(3, C + 3):
            F_full = np.minimum.accumulate(F_full, axis=ax)
    else:
        F_full = F
    fills = np.min(
        F_full[(0, slice(0, C), slice(None)) + ctx.full_idx], axis=0)
    if want_tables:
        # rotate to the backtracker's (T, L+1, *unit_shape, C+1) layout
        perm = (2, 0) + tuple(range(3, 3 + C)) + (1,)
        return fills, np.transpose(F, perm), np.transpose(N, perm)
    return fills


def _dp_eval_vec(ctx: _DPContext, t_max: float, want_tables: bool = False):
    """Single-t_max entry point of the vectorized engine (batch of one)."""
    out = _dp_eval_batch(ctx, np.array([t_max]), want_tables=want_tables)
    if want_tables:
        fills, F, N = out
        return float(fills[0]), F[0], N[0]
    return float(out[0]), None, None


# ---------------------------------------------------------------------------
# Backtracking (shared: both engines emit the generalized table layout)
# ---------------------------------------------------------------------------


def _backtrack(ctx: _DPContext, t_max: float, F: np.ndarray, N: np.ndarray
               ) -> List[Tuple[int, int, int, int]]:
    """Extract the argmin stage list [(mid, k, j, K), ...] by re-finding the
    achieving transition at each state along the optimal path.  F/N are the
    generalized ``(L+1, *unit_shape, C+1)`` tables."""
    C, L = ctx.C, ctx.L
    tab = ctx.tables
    units = tuple(ctx.units_total)

    # find start state (allowing idle devices: scan all avail <= units;
    # with require_all_devices, only the full-allocation state qualifies)
    best = (INF, None)
    for c in range(C):
        if ctx.cfg.require_all_devices:
            v = F[(0,) + units + (c,)]
            if v < best[0] - 1e-15:
                best = (v, (0, units, c))
            continue
        for idx in np.ndindex(*ctx.unit_shape):
            v = F[(0,) + idx + (c,)]
            if v < best[0] - 1e-15:
                best = (v, (0, tuple(int(x) for x in idx), c))
    assert best[1] is not None, "infeasible strategy"
    k, avail, c = best[1]
    out = []
    while k < L:
        found = None
        target = F[(k,) + avail + (c,)]
        for mid, mesh in enumerate(tab.meshes):
            if mesh.cluster_idx != c:
                continue
            u = ctx.mesh_units[mid]
            if u > avail[c]:
                continue
            nxt = list(avail)
            nxt[c] -= u
            nxt = tuple(nxt)
            row_t = ctx.t_tab[mid, k]
            row_ok = tab.feasible[mid, k]
            for j in range(k + 1, L + 1):
                if not row_ok[j] or row_t[j] > t_max:
                    continue
                ncs = (C,) if j == L else tuple(range(C))
                for nc in ncs:
                    if ctx.cfg.monotone_clusters and j < L and nc < c:
                        continue
                    c_time = 0.0 if j == L else ctx.p2p(j, c, nc)
                    if c_time > t_max:
                        continue
                    K = math.ceil(2.0 * c_time / t_max) + 1 + N[(j,) + nxt + (nc,)]
                    mp, ma = tab.mem_p[mid, k, j], tab.mem_a[mid, k, j]
                    if mp + K * ma > ctx.caps[c]:
                        continue
                    val = F[(j,) + nxt + (nc,)] + (row_t[j] + 2.0 * c_time)
                    if abs(val - target) <= 1e-9 * max(1.0, abs(target)):
                        found = (mid, k, j, int(K), nxt, nc)
                        break
                if found:
                    break
            if found:
                break
        assert found is not None, "backtrack failed"
        mid, _, j, K, nxt, nc = found
        out.append((mid, k, j, K))
        k, avail, c = j, nxt, nc
    return out


# --- module-level worker state for fork-based parallel evaluation -----------
_WORKER_CTX: Optional[_DPContext] = None
_WORKER_ENGINE: str = "oracle"


def _worker_eval(args):
    t_max_batch = args
    if _WORKER_ENGINE == "vectorized":
        fills = _dp_eval_batch(_WORKER_CTX,
                               np.asarray(t_max_batch, dtype=np.float64))
        return [(float(t), float(f)) for t, f in zip(t_max_batch, fills)]
    return [(t, _dp_eval(_WORKER_CTX, t)[0]) for t in t_max_batch]


def _fork_pool(n_workers: int) -> Optional[ProcessPoolExecutor]:
    """A fork-context process pool, or None where fork is unavailable (the
    module-global ``_WORKER_CTX`` is inherited by forking; spawn/forkserver
    children would see None and crash — fall back to serial instead)."""
    import multiprocessing as mp
    try:
        mp_ctx = mp.get_context("fork")
    except ValueError:
        return None
    try:
        return ProcessPoolExecutor(max_workers=n_workers, mp_context=mp_ctx)
    except (OSError, PermissionError, ValueError):
        return None


def _relaxed_feasible(ctx: _DPContext, tau: float) -> bool:
    """Cheap NECESSARY condition for DP feasibility at ``t_max = tau`` —
    a relaxation that keeps the load-bearing constraints (per-cluster stage
    budgets, per-link cut times, the ``t <= tau`` windows) but drops memory
    coupling, exact unit accounting (every stage is priced at its cluster's
    cheapest unit count), and overlap-free coverage.

    Frontier DP over (stages used per cluster, last cluster) -> farthest
    layer reached, with ``P[c1, c2, r]`` = best stage end on cluster c2
    entered from cluster c1 at any cut <= r whose transfer fits in tau.
    Any true DP solution induces such a chain, so relaxation-infeasible =>
    DP-infeasible — the pruning bisection runs on this (microseconds per
    tau) and the expensive DP probes start at its lower bound, which is
    tight whenever stage times or cut times drive feasibility."""
    tab = ctx.tables
    L, C = ctx.L, ctx.C
    jar = np.arange(L + 1)
    maxj = np.full((C, L + 1), -1)
    budgets = []
    for c in range(C):
        mids = ctx.mesh_ids_of_cluster[c]
        if mids:
            m = tab.feasible[mids] & (ctx.t_tab[mids] <= tau)
            maxj[c] = np.where(m, jar[None, None, :], -1).max(axis=(0, 2))
            budgets.append(ctx.units_total[c]
                           // max(1, min(ctx.mesh_units[i] for i in mids)))
        else:
            budgets.append(0)
    if int(maxj[:, 0].max()) >= L:
        return True          # one stage covers everything
    cut_ok = ctx.ctime <= tau                    # (L+1, C, C)
    P = np.full((C, C, L + 1), -1)
    for c1 in range(C):
        for c2 in range(C):
            v = np.where(cut_ok[:, c1, c2], maxj[c2], -1)
            v[0] = -1                            # q = 0 is the start, not a cut
            P[c1, c2] = np.maximum.accumulate(v)
    shape = tuple(b + 1 for b in budgets)
    R = np.full(shape + (C,), -1, dtype=np.int64)
    for used in np.ndindex(*shape):
        for c2 in range(C):
            if used[c2] == 0:
                continue
            prev = list(used)
            prev[c2] -= 1
            prev = tuple(prev)
            if not any(prev):                    # first stage: no cut
                r2 = int(maxj[c2, 0])
            else:
                r2 = -1
                for c1 in range(C):
                    rp = int(R[prev + (c1,)])
                    if rp > 0:
                        r2 = max(r2, rp, int(P[c1, c2, rp]))
            if r2 > R[used + (c2,)]:
                R[used + (c2,)] = r2
                if r2 >= L:
                    return True
    return False


# ---------------------------------------------------------------------------
# Search driver
# ---------------------------------------------------------------------------


def _check_deadline(deadline: Optional[float]) -> None:
    if deadline is not None and time.perf_counter() > deadline:
        raise SearchTimeout(
            "search exceeded its wall-clock deadline "
            "(SearchConfig.deadline_s)")


def _run_batches(ctx: _DPContext, keep: np.ndarray, engine: str,
                 stats: SearchStats,
                 known: Optional[Dict[float, float]] = None,
                 deadline: Optional[float] = None
                 ) -> List[Tuple[float, float]]:
    """Evaluate the surviving t_max candidates; (t, fill) per candidate.
    ``known`` carries fills already solved during pruning — those
    candidates are served from it instead of re-running the DP."""
    global _WORKER_CTX, _WORKER_ENGINE
    cfg = ctx.cfg
    results: List[Tuple[float, float]] = []
    if known:
        hits = [float(t) for t in keep if float(t) in known]
        results.extend((t, known[t]) for t in hits)
        stats.n_cache_served = len(hits)
        if hits:
            keep = np.array([t for t in keep if float(t) not in known])
    if len(keep) == 0:
        results.sort(key=lambda r: r[0])
        return results
    if engine == "vectorized":
        bs = ctx.batch_chunk(cfg.batch_size)
        batches = [list(map(float, keep[i:i + bs]))
                   for i in range(0, len(keep), bs)]
    else:
        nb = min(max(1, cfg.n_workers) * 4, len(keep)) if cfg.n_workers \
            else 1
        batches = [list(map(float, keep[i::nb])) for i in range(nb)] \
            if cfg.n_workers else [list(map(float, keep))]

    pool = None
    if cfg.n_workers and len(keep) > 8:
        pool = _fork_pool(cfg.n_workers)
    if pool is not None:
        from concurrent.futures.process import BrokenProcessPool
        base = list(results)       # the known-fill hits, kept on failure
        _WORKER_CTX, _WORKER_ENGINE = ctx, engine
        try:
            with pool:
                for out in pool.map(_worker_eval, batches):
                    results.extend(out)
            stats.workers_used = cfg.n_workers
        except (OSError, PermissionError, BrokenProcessPool):
            # pool died mid-flight (sandboxed fork, rlimits, ...): re-run
            # serially — identical math, just slower
            results = base
            pool = None
        finally:
            _WORKER_CTX = None
    if pool is None:
        if engine == "vectorized":
            for batch in batches:
                _check_deadline(deadline)
                fills = _dp_eval_batch(ctx, np.asarray(batch))
                results.extend(
                    (float(t), float(f)) for t, f in zip(batch, fills))
        else:
            for batch in batches:
                for t in batch:
                    _check_deadline(deadline)
                    results.append((float(t), _dp_eval(ctx, float(t))[0]))
    # deterministic selection order regardless of worker scheduling
    results.sort(key=lambda r: r[0])
    return results


def _search_impl(ctx: _DPContext, mb_tokens: int, engine: str,
                 stats: SearchStats, verbose: bool,
                 deadline: Optional[float] = None) -> ParallelStrategy:
    cfg = ctx.cfg
    cluster, tables = ctx.cluster, ctx.tables
    B = cfg.n_microbatches
    eval_one = _dp_eval if engine == "oracle" else _dp_eval_vec

    # ---- candidate t_max values (sorted, dedup'd — Alg. 1 line 2) ----------
    vals = ctx.t_tab[tables.feasible]
    sig = cfg.tmax_round_digits
    cands = np.unique(np.array(
        [float(f"%.{sig}g" % v) for v in vals if np.isfinite(v)]))
    if len(cands) == 0:
        raise RuntimeError("no feasible stage-mesh candidates")
    stats.n_tmax_candidates = len(cands)

    # ---- bidirectional pruning ---------------------------------------------
    # find the smallest feasible t_S (feasibility is monotone in t_max)
    fill_cache: Dict[int, float] = {}

    def probe(i: int) -> float:
        if i not in fill_cache:
            _check_deadline(deadline)
            stats.prune_evals += 1
            fill_cache[i] = float(_dp_eval(ctx, float(cands[i]))[0]) \
                if engine == "oracle" \
                else float(_dp_eval_batch(ctx, cands[i:i + 1])[0])
        return fill_cache[i]

    lo, hi = 0, len(cands) - 1
    if engine != "oracle":
        # pre-bisect on the microsecond-cheap necessary condition: every
        # candidate failing the coverage relaxation is DP-infeasible, so
        # the expensive DP probes start at the relaxation's lower bound
        if not _relaxed_feasible(ctx, float(cands[hi])):
            raise RuntimeError("infeasible even at largest t_max")
        while lo < hi:
            mid = (lo + hi) // 2
            if _relaxed_feasible(ctx, float(cands[mid])):
                hi = mid
            else:
                lo = mid + 1
        hi = len(cands) - 1
        # the relaxation bound is tight when stage/cut times drive
        # feasibility — probing it first usually ends the search in one
        # full DP solve
        if probe(lo) < INF:
            hi = lo
        elif lo < hi:
            lo += 1
    else:
        # pre-vectorization behavior: verify the top candidate up front
        if probe(hi) == INF:
            raise RuntimeError("infeasible even at largest t_max")
    while lo < hi:  # smallest feasible t_S (monotone feasibility)
        mid = (lo + hi) // 2
        if probe(mid) < INF:
            hi = mid
        else:
            lo = mid + 1
    if probe(lo) == INF:
        raise RuntimeError("infeasible even at largest t_max")
    t_S = float(cands[lo])
    fill_S = fill_cache[lo]
    T_S = fill_S + (B - 1) * t_S
    t_E = T_S / max(B - 1, 1)
    keep = cands[(cands >= t_S) & (cands <= t_E)]
    if len(keep) > cfg.max_candidates:
        idx = np.linspace(0, len(keep) - 1, cfg.max_candidates).astype(int)
        keep = keep[np.unique(idx)]
    stats.t_S, stats.t_E = t_S, t_E
    stats.n_pruned = int(stats.n_tmax_candidates - len(keep))
    if verbose:
        print(f"[search] {len(cands)} candidates -> t_S={t_S:.4g}, "
              f"t_E={t_E:.4g}, evaluating {len(keep)} ({engine})")

    # ---- batched (parallel) evaluation --------------------------------------
    t_ev0 = time.perf_counter()
    results = _run_batches(ctx, keep, engine, stats,
                           known={float(cands[i]): f
                                  for i, f in fill_cache.items()},
                           deadline=deadline)
    stats.eval_seconds = time.perf_counter() - t_ev0
    # fresh solves only: cache-served candidates cost nothing here and
    # their solve time is already accounted under prune_evals
    stats.n_evaluated = len(results) - stats.n_cache_served

    best_t, best_T = None, INF
    for t, fill in results:
        if fill == INF:
            continue
        T = fill + (B - 1) * t
        if T < best_T:
            best_T, best_t = T, t
    assert best_t is not None
    stats.best_t_max = float(best_t)
    stats.fill_cost = float(best_T - (B - 1) * best_t)
    stats.predicted_T = float(best_T)

    # ---- extract strategy ----------------------------------------------------
    _, F, N = eval_one(ctx, best_t, want_tables=True)
    picks = _backtrack(ctx, best_t, F, N)
    stages, c_links = [], []
    for si, (mid, k, j, K) in enumerate(picks):
        mesh = tables.meshes[mid]
        sc = tables.stage_costs[(mid, k, j)]
        stages.append(StageAssignment(
            layer_start=k, layer_end=j, cluster_idx=mesh.cluster_idx,
            mesh_n=mesh.n, mesh_m=mesh.m, tp=sc.tp, dp=sc.dp,
            t_f=sc.t_f, t_b=sc.t_b, mem_p=sc.mem_p, mem_a=sc.mem_a,
            intra_op=sc.intra))
        if si < len(picks) - 1:
            nxt_cluster = tables.meshes[picks[si + 1][0]].cluster_idx
            c_links.append(ctx.p2p(j, mesh.cluster_idx, nxt_cluster))

    t_per_stage = [s.t for s in stages]
    counts = h1f1b_counts(t_per_stage, c_links, B)
    if cfg.intra_overlap > 0 and all(s.intra_op is not None for s in stages):
        # validate with the intra-op collectives threaded separately through
        # the simulator so a fraction can overlap with compute (the DP itself
        # prices them fully exposed — a conservative upper bound)
        res = simulate(
            [s.t_f - s.intra_op.comm_time_f for s in stages],
            [s.t_b - s.intra_op.comm_time_b for s in stages],
            c_links, B, counts,
            intra_f=[s.intra_op.comm_time_f for s in stages],
            intra_b=[s.intra_op.comm_time_b for s in stages],
            intra_overlap=cfg.intra_overlap)
    else:
        res = simulate([s.t_f for s in stages], [s.t_b for s in stages],
                       c_links, B, counts)
    eta = eta_load_balance(
        res.stage_compute,
        [s.n_devices * cluster.subclusters[s.cluster_idx].device.peak_flops
         for s in stages])
    return ParallelStrategy(
        stages=stages, c_links=c_links, warmup_counts=counts,
        t_max=float(best_t), n_microbatches=B, mb_tokens=mb_tokens,
        est_step_time=res.makespan, eta=eta,
        planner_meta={
            "fill_cost": best_T - (B - 1) * best_t,
            "predicted_T": best_T,
            "n_tmax_evaluated": len(results),
            "profiler": dataclasses.asdict(tables.stats),
        })


def instrumented_search(cluster: HeteroCluster, tables: ProfileTables,
                        mb_tokens: int, cfg: SearchConfig = SearchConfig(),
                        verbose: bool = False, comm=None
                        ) -> Tuple[ParallelStrategy, SearchStats]:
    """Full HAPT search + observability: candidate t_max generation,
    bidirectional pruning, batched (parallel) evaluation, backtracking,
    H-1F1B scheduling.  Returns the strategy plus a :class:`SearchStats`
    record — the public hook for benchmarks and CI (no private imports
    needed).  The strategy is identical to :func:`search`'s.

    ``comm`` (optional :class:`repro.comm.selector.CommModel`): WAN-latency-
    aware cut pricing — the tables are assumed to have been profiled with
    the same model, so the DP's collective and transfer costs agree."""
    t0 = time.perf_counter()
    ctx = _DPContext(cluster, tables, cfg, comm)
    engine = cfg.engine if cfg.engine != "auto" else "vectorized"
    if engine not in ("vectorized", "oracle"):
        raise ValueError(f"unknown search engine {cfg.engine!r}")
    if engine == "oracle" and ctx.C > 2:
        raise ValueError(
            f"oracle engine supports at most 2 sub-clusters, cluster has "
            f"{ctx.C}; use engine='vectorized'")
    stats = SearchStats(engine=engine, requested_engine=cfg.engine,
                        n_subclusters=ctx.C,
                        n_mesh_rows=len(tables.meshes), n_layers=ctx.L)
    deadline = t0 + cfg.deadline_s if cfg.deadline_s > 0 else None
    try:
        strategy = _search_impl(ctx, mb_tokens, engine, stats, verbose,
                                deadline)
    except RuntimeError:
        raise       # genuine infeasibility (or SearchTimeout) — both engines
        #             agree, no point re-running on the oracle
    except Exception:
        if engine != "vectorized" or ctx.C > 2:
            raise
        # defensive net: the scalar oracle re-runs the search (bit-identical
        # result, none of the speedup).  CI fails when this fires on the
        # canonical clusters — it means the fast path regressed.
        stats.engine = "oracle"
        stats.oracle_fallbacks += 1
        strategy = _search_impl(ctx, mb_tokens, "oracle", stats, verbose,
                                deadline)
    stats.total_seconds = time.perf_counter() - t0
    return strategy, stats


def search(cluster: HeteroCluster, tables: ProfileTables, mb_tokens: int,
           cfg: SearchConfig = SearchConfig(),
           verbose: bool = False, comm=None) -> ParallelStrategy:
    """Full HAPT search (see :func:`instrumented_search` for the stats-
    returning variant used by benchmarks)."""
    return instrumented_search(cluster, tables, mb_tokens, cfg, verbose,
                               comm=comm)[0]
