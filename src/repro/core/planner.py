"""HAPTPlanner: config + cluster -> ParallelStrategy (the paper's Fig. 4 flow).

    ops = build_op_sequence(arch)                  # operator IR
    layers = build_layers(ops, granularity)        # §5.1 structural layers
    tables = ZeroRedundantProfiler(...).profile()  # §5.1 pruned profiles
    strategy = dp_search.search(...)               # §5.2 DP + H-1F1B (§4)

With ``intra_op=True`` the flow becomes the **two-level joint search**: the
profiler emits one table row per (submesh, tensor-parallel width) variant and
the DP chooses the intra-op sharding degree jointly with the inter-op stage
slicing (see docs/planner.md for the full walkthrough).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence

from repro.comm.selector import CommConfig, CommModel
from repro.configs.base import ArchConfig
from repro.core.cluster import HeteroCluster
from repro.core.costmodel import CostModelConfig
from repro.core.dp_search import SearchConfig, search
from repro.core.layering import Layer, build_layers
from repro.core.opgraph import Op, build_op_sequence
from repro.core.profiler import ZeroRedundantProfiler
from repro.core.strategy import ParallelStrategy
from repro.kbench.bridge import KBenchConfig, KBenchModel


@dataclass
class PlannerConfig:
    """Everything :class:`HAPTPlanner` reads.  Units: tokens are counts,
    ``rho`` is the dimensionless imbalance-pruning ratio, all times priced
    downstream are seconds.

    ``intra_op``: run the joint inter+intra-operator search (one DP row per
    (submesh, tp) variant; uneven efficiency-proportional shard ratios in
    mixed sub-clusters; the chosen ``IntraOpPlan`` rides on every stage).
    ``intra_op_max_degree``: prune enumerated tensor-parallel widths to
    ``tp <= intra_op_max_degree`` (0 = unrestricted); dominated variants are
    always eliminated before the DP.
    ``comm``: a :class:`repro.comm.selector.CommConfig` turns on
    heterogeneity-aware collective pricing — the search then chooses plans
    under the per-collective *selected* algorithm's cost (topology-aware
    ring / halving-doubling / two-level hierarchical) and WAN-latency-aware
    cut pricing.  ``None`` (default) keeps the legacy scalar pricing
    bit-identical.
    ``kbench``: a :class:`repro.kbench.bridge.KBenchConfig` turns on
    measured-kernel pricing — the DP search anchors each device's compute
    MFU at the achieved throughput recorded in the latency table (collected
    by ``repro kbench collect``), falling back to the analytic estimate for
    uncovered devices.  ``None`` (default) keeps plans bit-identical.
    """
    granularity: int = 128            # target #layers (fine-grained)
    n_microbatches: int = 128
    microbatch_tokens: int = 0        # 0 -> global_batch_tokens / n_microbatches
    z_heavy: int = 2
    rho: float = 16.0
    min_submesh_devices: int = 1
    max_submesh_devices: int = 0   # 0 = unrestricted
    intra_op: bool = False
    intra_op_max_degree: int = 0   # 0 = unrestricted
    comm: Optional[CommConfig] = None
    kbench: Optional["KBenchConfig"] = None
    cost: CostModelConfig = field(default_factory=CostModelConfig)
    search: SearchConfig = field(default_factory=SearchConfig)
    measure_fn: Optional[Callable] = None   # on-hardware profiling hook
                                            # (greedy inter-op path only)


class HAPTPlanner:
    """The offline planner: owns a fleet description and turns an
    architecture into an executable :class:`ParallelStrategy`.

    Invariant: planning never mutates the cluster or the config it was
    constructed with (``plan(intra_op=...)`` overrides are call-scoped), so
    one planner instance can serve many what-if queries — the elastic
    runtime relies on this to probe candidate fleets.
    """

    def __init__(self, cluster: HeteroCluster,
                 cfg: Optional[PlannerConfig] = None):
        self.cluster = cluster
        self.cfg = cfg if cfg is not None else PlannerConfig()

    def plan(self, arch: ArchConfig, *, seq_len: int = 1024,
             global_batch: int = 1024, verbose: bool = False,
             ops: Optional[Sequence[Op]] = None,
             layers: Optional[Sequence[Layer]] = None,
             profile_cache: Optional[Dict] = None,
             intra_op: Optional[bool] = None) -> ParallelStrategy:
        """Search a parallel strategy for ``arch`` on this planner's cluster.

        ``seq_len``/``global_batch`` are token/sample counts; the microbatch
        token budget is ``global_batch * seq_len / n_microbatches`` unless
        ``cfg.microbatch_tokens`` pins it.

        ``profile_cache``: caller-owned cross-invocation stage-cost cache
        (see ZeroRedundantProfiler.cost_cache) — the elastic runtime passes
        one so incremental replans only re-profile changed sub-clusters;
        keys include the intra-op sharding degree, so inter-only and joint
        searches share the cache without collisions.

        ``intra_op``: call-scoped override of ``cfg.intra_op`` (None =
        follow the config) toggling the joint two-level search.
        """
        t0 = time.time()
        cfg = self.cfg
        joint = cfg.intra_op if intra_op is None else intra_op
        B = cfg.n_microbatches
        mb_tokens = cfg.microbatch_tokens or (global_batch * seq_len) // B

        if layers is None:
            if ops is None:
                ops = build_op_sequence(arch, seq_len=seq_len)
            layers = build_layers(ops, cfg.granularity, z=cfg.z_heavy)
        t_layer = time.time()

        comm_model = None
        if cfg.comm is not None and cfg.comm.enabled:
            comm_model = CommModel(self.cluster, cfg.comm)
        kbench_model = None
        if cfg.kbench is not None:
            kbench_model = KBenchModel(cfg.kbench)

        profiler = ZeroRedundantProfiler(
            self.cluster, layers, mb_tokens, cost_cfg=cfg.cost, rho=cfg.rho,
            min_submesh_devices=cfg.min_submesh_devices,
            max_submesh_devices=cfg.max_submesh_devices,
            measure_fn=cfg.measure_fn, cost_cache=profile_cache,
            intra_op=joint, intra_op_max_degree=cfg.intra_op_max_degree,
            amortize_microbatches=B if joint else 0, comm=comm_model,
            kbench=kbench_model)
        tables = profiler.profile()
        t_prof = time.time()

        # call-scoped copy: plan() must not mutate the caller's SearchConfig
        scfg = dataclasses.replace(cfg.search, n_microbatches=B)
        strategy = search(self.cluster, tables, mb_tokens, scfg,
                          verbose=verbose, comm=comm_model)
        t_search = time.time()

        strategy.planner_meta.update({
            "arch": arch.arch_id,
            "granularity": len(layers),
            "seq_len": seq_len,
            "global_batch": global_batch,
            "intra_op": joint,
            "time_layering_s": t_layer - t0,
            "time_profiling_s": t_prof - t_layer,
            "time_search_s": t_search - t_prof,
            "cluster": self.cluster.describe(),
        })
        if comm_model is not None:
            # only comm-aware runs record the comm provenance: the default
            # path's strategy JSON stays bit-identical to the pre-comm
            # pipeline (the DESIGN.md off-state equivalence guarantee)
            strategy.planner_meta["comm"] = dataclasses.asdict(cfg.comm)
        if kbench_model is not None:
            # same off-state rule as comm: only measured-priced runs stamp
            # their provenance (table fingerprint + per-device coverage)
            strategy.planner_meta["kbench"] = {
                "fingerprint": kbench_model.fingerprint(),
                "cells": len(kbench_model.table),
                "covered_devices": sorted(kbench_model.covered_devices()),
            }
        if verbose:
            print(strategy.describe())
        return strategy
