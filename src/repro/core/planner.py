"""HAPTPlanner: config + cluster -> ParallelStrategy (the paper's Fig. 4 flow).

    ops = build_op_sequence(arch)                  # operator IR
    layers = build_layers(ops, granularity)        # §5.1 structural layers
    tables = ZeroRedundantProfiler(...).profile()  # §5.1 pruned profiles
    strategy = dp_search.search(...)               # §5.2 DP + H-1F1B (§4)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence

from repro.configs.base import ArchConfig
from repro.core.cluster import HeteroCluster
from repro.core.costmodel import CostModelConfig
from repro.core.dp_search import SearchConfig, search
from repro.core.layering import Layer, build_layers
from repro.core.opgraph import Op, build_op_sequence
from repro.core.profiler import ZeroRedundantProfiler
from repro.core.strategy import ParallelStrategy


@dataclass
class PlannerConfig:
    granularity: int = 128            # target #layers (fine-grained)
    n_microbatches: int = 128
    microbatch_tokens: int = 0        # 0 -> global_batch_tokens / n_microbatches
    z_heavy: int = 2
    rho: float = 16.0
    min_submesh_devices: int = 1
    max_submesh_devices: int = 0   # 0 = unrestricted
    cost: CostModelConfig = field(default_factory=CostModelConfig)
    search: SearchConfig = field(default_factory=SearchConfig)
    measure_fn: Optional[Callable] = None   # on-hardware profiling hook


class HAPTPlanner:
    def __init__(self, cluster: HeteroCluster, cfg: PlannerConfig = None):
        self.cluster = cluster
        self.cfg = cfg or PlannerConfig()

    def plan(self, arch: ArchConfig, *, seq_len: int = 1024,
             global_batch: int = 1024, verbose: bool = False,
             ops: Optional[Sequence[Op]] = None,
             layers: Optional[Sequence[Layer]] = None,
             profile_cache: Optional[Dict] = None) -> ParallelStrategy:
        """``profile_cache``: caller-owned cross-invocation stage-cost cache
        (see ZeroRedundantProfiler.cost_cache) — the elastic runtime passes
        one so incremental replans only re-profile changed sub-clusters."""
        t0 = time.time()
        cfg = self.cfg
        B = cfg.n_microbatches
        mb_tokens = cfg.microbatch_tokens or (global_batch * seq_len) // B

        if layers is None:
            if ops is None:
                ops = build_op_sequence(arch, seq_len=seq_len)
            layers = build_layers(ops, cfg.granularity, z=cfg.z_heavy)
        t_layer = time.time()

        profiler = ZeroRedundantProfiler(
            self.cluster, layers, mb_tokens, cost_cfg=cfg.cost, rho=cfg.rho,
            min_submesh_devices=cfg.min_submesh_devices,
            max_submesh_devices=cfg.max_submesh_devices,
            measure_fn=cfg.measure_fn, cost_cache=profile_cache)
        tables = profiler.profile()
        t_prof = time.time()

        scfg = cfg.search
        scfg.n_microbatches = B
        strategy = search(self.cluster, tables, mb_tokens, scfg,
                          verbose=verbose)
        t_search = time.time()

        strategy.planner_meta.update({
            "arch": arch.arch_id,
            "granularity": len(layers),
            "seq_len": seq_len,
            "global_batch": global_batch,
            "time_layering_s": t_layer - t0,
            "time_profiling_s": t_prof - t_layer,
            "time_search_s": t_search - t_prof,
            "cluster": self.cluster.describe(),
        })
        if verbose:
            print(strategy.describe())
        return strategy
