"""Parallel strategy IR: what the planner emits and the runtime consumes.

Three layers, all plain dataclasses with a lossless JSON round trip
(``ParallelStrategy.to_json`` / ``from_json`` — the elastic runtime's plan
cache and any external tooling depend on it):

- :class:`IntraOpPlan` — the *intra-operator* half of the two-level search:
  how one pipeline stage is sharded inside its submesh (tensor vs. data
  axis, degrees, uneven shard ratios, priced collective traffic).
- :class:`StageAssignment` — one pipeline stage: a contiguous layer range
  placed on a submesh of one sub-cluster, with per-microbatch costs and the
  chosen intra-op plan.
- :class:`ParallelStrategy` — the full plan: stage list, inter-stage comm
  times, H-1F1B warm-up counts, and planner provenance.

Units everywhere: times in seconds, memory/traffic in bytes, bandwidth in
bytes/s, flops in FLOP/s.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class IntraOpPlan:
    """How one stage is sharded *inside* its submesh (HAP/Poplar-style
    heterogeneity-aware intra-operator parallelism).

    Invariants:

    - ``tp * dp == StageAssignment.n_devices`` of the owning stage;
    - ``len(shard_ratios) == dp`` and ``sum(shard_ratios) == 1`` (each entry
      is the fraction of the microbatch processed by one data-parallel
      shard; uneven entries are proportional to per-node efficiency in a
      mixed sub-cluster, all equal to ``1/dp`` in a homogeneous one);
    - ``shard_ratios`` are ordered **slowest node first** (the
      ``SubCluster.node_scales`` order, ascending efficiency) — whoever
      materializes the plan must hand ``mesh_from_intra_op`` the stage's
      devices in that same node order, or the largest shard lands on the
      wrong (possibly slowest) node and the priced throughput is forfeited;
    - ``degree == 1`` (tp == dp == 1) is the degenerate no-op plan.
    """
    axis: str                          # "tensor" (Megatron TP) | "data" (DP)
    tp: int                            # tensor-parallel width (within a node)
    dp: int                            # data-parallel width (across the rest)
    shard_ratios: Tuple[float, ...]    # per-dp-shard microbatch fraction, sums to 1
    comm_bytes: float                  # per-microbatch collective payload (bytes)
    comm_time_f: float                 # forward intra-op collective time (s)
    comm_time_b: float                 # backward intra-op collective time (s)
    sync_time: float = 0.0             # share of comm_time_b that is amortized
                                       # per-step gradient sync (s); 0 when the
                                       # search did not price the data axis
    ar_algo: Optional[str] = None      # collective algorithm selected for the
                                       # TP all-reduce (repro.comm.selector);
                                       # None = legacy implicit flat ring
    sync_algo: Optional[str] = None    # ditto for the DP gradient sync
    sync_compressed: bool = False      # sync priced with int8 block
                                       # quantization (error-feedback path)

    @property
    def degree(self) -> int:
        """Sharding degree along the dominant ``axis``."""
        return self.tp if self.axis == "tensor" else self.dp

    @property
    def n_devices(self) -> int:
        return self.tp * self.dp

    @property
    def comm_time(self) -> float:
        """Total per-microbatch intra-op collective time (s)."""
        return self.comm_time_f + self.comm_time_b

    @property
    def is_uneven(self) -> bool:
        """True when the data-parallel shards are heterogeneity-weighted."""
        if not self.shard_ratios:
            return False
        return max(self.shard_ratios) - min(self.shard_ratios) > 1e-12


@dataclass(frozen=True)
class StageAssignment:
    """One pipeline stage: layers ``[layer_start, layer_end)`` on a
    ``mesh_n x mesh_m`` submesh of sub-cluster ``cluster_idx``.

    ``t_f``/``t_b`` are per-microbatch forward/backward seconds (intra-op
    collective time included); ``mem_p``/``mem_a`` are per-device bytes for
    parameters+optimizer and per-in-flight-microbatch activations (the Eq. 18
    operands).  ``tp``/``dp`` duplicate the chosen intra-op factorization for
    quick access; ``intra_op`` (when the joint search ran) carries the full
    :class:`IntraOpPlan` that `parallel.sharding.mesh_from_intra_op` lowers
    to an executable mesh.
    """
    layer_start: int
    layer_end: int                 # exclusive
    cluster_idx: int
    mesh_n: int
    mesh_m: int
    tp: int
    dp: int
    t_f: float
    t_b: float
    mem_p: float
    mem_a: float
    intra_op: Optional[IntraOpPlan] = None

    @property
    def n_devices(self) -> int:
        return self.mesh_n * self.mesh_m

    @property
    def t(self) -> float:
        """Per-microbatch compute time f+b (s)."""
        return self.t_f + self.t_b


@dataclass
class ParallelStrategy:
    """The planner's output and the runtime's input.

    Invariants: ``stages`` tile the layer range contiguously;
    ``len(c_links) == n_stages - 1`` (per-microbatch inter-stage activation
    transfer seconds); ``len(warmup_counts) == n_stages`` (H-1F1B ``N_i``,
    non-increasing, last entry 1); every stage satisfies ``t <= t_max`` and
    every link ``c <= t_max``.
    """
    stages: List[StageAssignment]
    c_links: List[float]           # inter-stage comm time per microbatch (s)
    warmup_counts: List[int]       # H-1F1B N_i
    t_max: float                   # the pipeline's bottleneck period (s)
    n_microbatches: int
    mb_tokens: int                 # tokens per microbatch
    est_step_time: float = 0.0     # from pipesim (s)
    eta: float = 1.0               # Eq. 19 load balance in [0, 1]
    planner_meta: Dict = field(default_factory=dict)

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def devices_used(self) -> int:
        return sum(s.n_devices for s in self.stages)

    def tokens_per_step(self) -> int:
        return self.mb_tokens * self.n_microbatches

    def throughput_tokens_per_s(self) -> float:
        return self.tokens_per_step() / self.est_step_time if self.est_step_time else 0.0

    # -- (de)serialization ---------------------------------------------------
    def to_json(self) -> str:
        """Lossless JSON (see docs/planner.md for the schema field-by-field)."""
        d = dataclasses.asdict(self)
        return json.dumps(d, indent=2)

    @staticmethod
    def from_json(s: str) -> "ParallelStrategy":
        d = json.loads(s)
        stages = []
        for st in d["stages"]:
            io = st.pop("intra_op", None)
            if io is not None:
                io["shard_ratios"] = tuple(io["shard_ratios"])
                io = IntraOpPlan(**io)
            stages.append(StageAssignment(intra_op=io, **st))
        d["stages"] = stages
        return ParallelStrategy(**d)

    def describe(self) -> str:
        lines = [f"{self.n_stages} stages, B={self.n_microbatches} microbatches,"
                 f" t_max={self.t_max*1e3:.2f} ms, est step {self.est_step_time*1e3:.1f} ms,"
                 f" eta={self.eta*100:.1f}%"]
        for i, s in enumerate(self.stages):
            c = self.c_links[i] if i < len(self.c_links) else 0.0
            intra = ""
            if s.intra_op is not None and s.intra_op.is_uneven:
                r = "/".join(f"{x:.2f}" for x in s.intra_op.shard_ratios)
                intra = f" shards[{r}]"
            if s.intra_op is not None and s.intra_op.sync_algo:
                intra += f" sync={s.intra_op.sync_algo}"
                if s.intra_op.sync_compressed:
                    intra += "+int8"
            lines.append(
                f"  stage{i}: layers[{s.layer_start}:{s.layer_end}] "
                f"cluster{s.cluster_idx} mesh({s.mesh_n}x{s.mesh_m}) tp={s.tp} dp={s.dp}"
                f"{intra} t={s.t*1e3:.2f}ms N={self.warmup_counts[i]} c->next={c*1e3:.2f}ms")
        return "\n".join(lines)
