"""Parallel strategy IR: what the planner emits and the runtime consumes."""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass(frozen=True)
class StageAssignment:
    layer_start: int
    layer_end: int                 # exclusive
    cluster_idx: int
    mesh_n: int
    mesh_m: int
    tp: int
    dp: int
    t_f: float
    t_b: float
    mem_p: float
    mem_a: float

    @property
    def n_devices(self) -> int:
        return self.mesh_n * self.mesh_m

    @property
    def t(self) -> float:
        return self.t_f + self.t_b


@dataclass
class ParallelStrategy:
    stages: List[StageAssignment]
    c_links: List[float]           # inter-stage comm time per microbatch (s)
    warmup_counts: List[int]       # H-1F1B N_i
    t_max: float
    n_microbatches: int
    mb_tokens: int
    est_step_time: float = 0.0     # from pipesim
    eta: float = 1.0               # Eq. 19 load balance
    planner_meta: Dict = field(default_factory=dict)

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def devices_used(self) -> int:
        return sum(s.n_devices for s in self.stages)

    def tokens_per_step(self) -> int:
        return self.mb_tokens * self.n_microbatches

    def throughput_tokens_per_s(self) -> float:
        return self.tokens_per_step() / self.est_step_time if self.est_step_time else 0.0

    # -- (de)serialization ---------------------------------------------------
    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        return json.dumps(d, indent=2)

    @staticmethod
    def from_json(s: str) -> "ParallelStrategy":
        d = json.loads(s)
        d["stages"] = [StageAssignment(**st) for st in d["stages"]]
        return ParallelStrategy(**d)

    def describe(self) -> str:
        lines = [f"{self.n_stages} stages, B={self.n_microbatches} microbatches,"
                 f" t_max={self.t_max*1e3:.2f} ms, est step {self.est_step_time*1e3:.1f} ms,"
                 f" eta={self.eta*100:.1f}%"]
        for i, s in enumerate(self.stages):
            c = self.c_links[i] if i < len(self.c_links) else 0.0
            lines.append(
                f"  stage{i}: layers[{s.layer_start}:{s.layer_end}] "
                f"cluster{s.cluster_idx} mesh({s.mesh_n}x{s.mesh_m}) tp={s.tp} dp={s.dp} "
                f"t={s.t*1e3:.2f}ms N={self.warmup_counts[i]} c->next={c*1e3:.2f}ms")
        return "\n".join(lines)
