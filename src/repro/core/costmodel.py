"""Analytic stage-on-submesh cost model (the CPU-only substitute for HAPT's
on-hardware profiler; structure documented in DESIGN.md §2).

For a candidate stage (contiguous layer range) on a submesh (n nodes x m
devices) of one sub-cluster, :func:`intra_op_candidates` enumerates the
canonical intra-operator factorizations — TP confined to a node with
Megatron-style all-reduces, DP across the rest — and prices each one as a
:class:`StageCost` carrying its :class:`~repro.core.strategy.IntraOpPlan`:

- *tensor axis* (tp > 1): per-microbatch ring all-reduce of the row-parallel
  outputs over the sub-cluster's intra-node link, forward and backward;
- *data axis* (dp > 1): per-step gradient all-reduce over the dp link,
  amortized per microbatch when ``amortize_microbatches`` is set;
- *uneven shard ratios*: in a **mixed** sub-cluster
  (``SubCluster.node_efficiencies``) the data-parallel shards are sized
  proportionally to per-node efficiency (HAP-style), so every node finishes
  together; even sharding is instead bottlenecked by the slowest node.

:func:`stage_cost` keeps the legacy single-result contract (cheapest
candidate, even shards) for the inter-op-only path.  On real hardware,
``measure_fn`` replaces the analytic estimate per candidate without touching
the surrounding planner.

Units: seconds, bytes, bytes/s, FLOP/s.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.cluster import HeteroCluster, SubCluster
from repro.core.layering import Layer
from repro.core.strategy import IntraOpPlan


@dataclass(frozen=True)
class Submesh:
    cluster_idx: int
    n: int
    m: int

    @property
    def n_devices(self) -> int:
        return self.n * self.m


@dataclass(frozen=True)
class StageCost:
    t_f: float            # forward per-microbatch (s), intra-op comm included
    t_b: float            # backward per-microbatch (s), intra-op comm included
    mem_p: float          # per-device param+optimizer bytes
    mem_a: float          # per-device activation bytes per in-flight microbatch
    tp: int
    dp: int
    dp_sync: float        # per-step gradient sync (amortized over microbatches)
    intra: Optional[IntraOpPlan] = None

    @property
    def t(self) -> float:
        return self.t_f + self.t_b


@dataclass(frozen=True)
class CostModelConfig:
    dtype_bytes: float = 2.0        # bf16 compute
    opt_mult: float = 7.0           # (bf16 p) + f32 master + adam m,v = 14B/param
    zero1: bool = True              # shard optimizer states over dp
    remat: bool = True              # store only layer-boundary activations
    bwd_flops_mult: float = 2.0
    tp_eff_decay: float = 0.95      # MFU multiplier per 2x TP
    dp_eff_decay: float = 0.99


def _mfu(sub: SubCluster, tp: int, dp: int, cfgm: CostModelConfig,
         kbench=None) -> float:
    # device.efficiency is the runtime-calibration scale (telemetry EWMA);
    # a straggling sub-cluster shows up here and shifts the whole plan
    eff = sub.device.base_mfu * sub.device.efficiency
    if kbench is not None:
        # measured-kernel anchor (repro.kbench): a latency table covering
        # this device replaces the spec-sheet base_mfu with the achieved
        # MFU; uncovered devices keep the analytic anchor untouched
        measured = kbench.measured_mfu(sub)
        if measured is not None:
            eff = measured * sub.device.efficiency
    eff *= cfgm.tp_eff_decay ** max(0, math.log2(max(tp, 1)))
    eff *= cfgm.dp_eff_decay ** max(0, math.log2(max(dp, 1)))
    return eff


def _shard_ratios(scales: Sequence[float], per_node: int,
                  uneven: bool) -> Tuple[float, ...]:
    """Per-dp-shard microbatch fractions: each node contributes ``per_node``
    shards at its efficiency scale.  Uneven -> proportional to scale (sums
    to 1); even -> uniform."""
    shard_scales = [s for s in scales for _ in range(per_node)]
    dp = len(shard_scales)
    if not uneven or dp == 0:
        return (1.0 / max(dp, 1),) * max(dp, 1)
    total = sum(shard_scales)
    return tuple(s / total for s in shard_scales)


def intra_op_candidates(layers: Sequence[Layer], sub: SubCluster,
                        mesh: Submesh, mb_tokens: int,
                        cfgm: CostModelConfig = CostModelConfig(), *,
                        uneven: bool = True,
                        amortize_microbatches: int = 0,
                        max_degree: int = 0,
                        comm=None, kbench=None) -> List[StageCost]:
    """All candidate intra-op shardings of this stage on this submesh, one
    per tensor-parallel width tp (powers of two dividing ``mesh.m``, capped
    by ``max_degree`` when > 0).  Each result carries its IntraOpPlan; the
    joint DP chooses among them per (stage-slice, t_max) instead of greedily
    taking the cheapest.

    ``comm`` (optional :class:`repro.comm.selector.CommModel`): price the TP
    all-reduce and DP gradient sync under the *selected* collective
    algorithm (ring / recursive halving-doubling / two-level hierarchical,
    whichever is cheapest on this submesh's link tiers) instead of the
    implicit flat ring; the chosen algorithm names ride on the
    ``IntraOpPlan``.  ``comm=None`` is the legacy scalar pricing,
    bit-identical to before the comm subsystem existed.

    ``kbench`` (optional :class:`repro.kbench.bridge.KBenchModel`): anchor
    the compute MFU at the device's *measured* kernel throughput instead of
    the spec-sheet ``base_mfu`` (see :func:`_mfu`).  ``kbench=None`` — and a
    model whose table doesn't cover this device — leaves the analytic
    pricing bit-identical."""
    flops = sum(l.flops_per_token for l in layers) * mb_tokens
    params = sum(l.param_bytes for l in layers)
    ar_bytes = sum(l.ar_bytes_per_token for l in layers) * mb_tokens
    act_bytes = sum(l.act_out_bytes_per_token for l in layers) * mb_tokens
    n, m = mesh.n, mesh.m
    dev = sub.device
    scales = sub.node_scales(n)

    out: List[StageCost] = []
    tp = 1
    while tp <= m:
        if m % tp == 0 and not (max_degree and tp > max_degree):
            per_node = m // tp
            dp = n * per_node
            ratios = _shard_ratios(scales, per_node, uneven)
            # uneven, efficiency-proportional shards let every node finish
            # together (throughput = mean node scale); even shards wait for
            # the slowest node (throughput = min node scale)
            scale = (sum(scales) / len(scales)) if uneven else min(scales)
            eff = _mfu(sub, tp, dp, cfgm, kbench) * scale
            t_comp_f = flops / (mesh.n_devices * dev.peak_flops * eff)
            # Megatron TP: all-reduce row-parallel outputs over NVLink/ICI.
            # ring all-reduce moves 2(tp-1)/tp of payload; fwd once, bwd once.
            # The stage's critical path is the *largest* data shard's group,
            # whose AR payload is max(ratios)*ar_bytes (= ar_bytes/dp even).
            ar_algo = sync_algo = None
            sync_compressed = False
            if tp > 1:
                ar_shard = ar_bytes * max(ratios)
                if comm is not None:
                    sel_ar = comm.tp_allreduce(mesh.cluster_idx, tp, ar_shard)
                    t_ar, ar_algo = sel_ar.seconds, sel_ar.algorithm
                else:
                    t_ar = ar_shard * 2 * (tp - 1) / tp / sub.intra_node_bw
                ar_payload = 2 * ar_shard * 2 * (tp - 1) / tp
            else:
                t_ar = 0.0
                ar_payload = 0.0
            # per-step dp grad sync; amortized per microbatch when the joint
            # search prices the data axis (B = amortize_microbatches).  With a
            # comm model the sync runs the cheapest selected algorithm over
            # the stage's (intra-node, inter-node) link tiers — two-level
            # hierarchical typically beats the flat ring once n > 1.
            if dp > 1:
                if comm is not None:
                    sel_s = comm.dp_sync(mesh.cluster_idx, n, per_node, params)
                    dp_sync, sync_algo = sel_s.seconds, sel_s.algorithm
                    sync_compressed = sel_s.compressed
                else:
                    bw = sub.inter_node_bw if n > 1 else sub.intra_node_bw
                    dp_sync = params * 2 * (dp - 1) / dp / bw
            else:
                dp_sync = 0.0
            sync_mb = dp_sync / amortize_microbatches \
                if amortize_microbatches else 0.0
            sync_payload = (params * 2 * (dp - 1) / dp / amortize_microbatches
                            if amortize_microbatches and dp > 1 else 0.0)
            t_f = t_comp_f + t_ar
            t_b = cfgm.bwd_flops_mult * t_comp_f + t_ar + sync_mb
            # memory: weights/optimizer shard evenly; the activation bound is
            # set by the *largest* data shard (the fastest node's devices)
            shard = tp * (dp if cfgm.zero1 else 1)
            mem_p = params * (1.0 + cfgm.opt_mult) / min(shard, mesh.n_devices)
            act_stored = act_bytes if cfgm.remat else 3.0 * act_bytes
            mem_a = act_stored * max(ratios) / tp
            plan = IntraOpPlan(
                axis="tensor" if tp > 1 else "data", tp=tp, dp=dp,
                shard_ratios=ratios, comm_bytes=ar_payload + sync_payload,
                comm_time_f=t_ar, comm_time_b=t_ar + sync_mb,
                sync_time=sync_mb, ar_algo=ar_algo, sync_algo=sync_algo,
                sync_compressed=sync_compressed)
            out.append(StageCost(t_f, t_b, mem_p, mem_a, tp, dp, dp_sync,
                                 intra=plan))
        tp *= 2
    return out


def stage_cost(layers: Sequence[Layer], sub: SubCluster, mesh: Submesh,
               mb_tokens: int, cfgm: CostModelConfig = CostModelConfig(),
               measure_fn: Optional[Callable] = None,
               comm=None, kbench=None) -> StageCost:
    """Cheapest feasible intra-op strategy for this stage-mesh pair — the
    inter-op-only (greedy) contract: even shards, fastest ``t = t_f + t_b``.
    The joint search uses :func:`intra_op_candidates` instead."""
    if measure_fn is not None:
        return measure_fn(layers, sub, mesh, mb_tokens)
    cands = intra_op_candidates(layers, sub, mesh, mb_tokens, cfgm,
                                uneven=False, comm=comm, kbench=kbench)
    assert cands, "no intra-op factorization for mesh"
    return min(cands, key=lambda c: c.t)


def cut_comm_bytes(layers: Sequence[Layer], cut_after: int, mb_tokens: int) -> float:
    """Bytes of the activation crossing the stage boundary after layer index
    ``cut_after`` (exclusive end of the left stage), per microbatch."""
    if cut_after <= 0 or cut_after >= len(layers):
        return 0.0
    return layers[cut_after - 1].act_out_bytes_per_token * mb_tokens


def memory_feasible(cost: StageCost, sub: SubCluster, warmup_k: int) -> bool:
    """Eq. 18: mem_p + K * mem_a <= mem_device."""
    return cost.mem_p + warmup_k * cost.mem_a <= sub.device.mem_bytes
