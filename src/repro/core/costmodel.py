"""Analytic stage-on-submesh cost model (the CPU-only substitute for HAPT's
on-hardware profiler; structure documented in DESIGN.md §2).

For a candidate stage (contiguous layer range) on a submesh (n nodes x m
devices) of one homogeneous sub-cluster, a small intra-op planner tries the
canonical (tp, dp) factorizations (TP confined to a node, Megatron-style
all-reduces; DP across the rest) and returns the cheapest feasible
:class:`StageCost`.  On real hardware, ``measure_fn`` replaces the analytic
estimate per candidate without touching the surrounding planner.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.cluster import HeteroCluster, SubCluster
from repro.core.layering import Layer


@dataclass(frozen=True)
class Submesh:
    cluster_idx: int
    n: int
    m: int

    @property
    def n_devices(self) -> int:
        return self.n * self.m


@dataclass(frozen=True)
class StageCost:
    t_f: float            # forward per-microbatch (s)
    t_b: float            # backward per-microbatch (s)
    mem_p: float          # per-device param+optimizer bytes
    mem_a: float          # per-device activation bytes per in-flight microbatch
    tp: int
    dp: int
    dp_sync: float        # per-step gradient sync (amortized over microbatches)

    @property
    def t(self) -> float:
        return self.t_f + self.t_b


@dataclass(frozen=True)
class CostModelConfig:
    dtype_bytes: float = 2.0        # bf16 compute
    opt_mult: float = 7.0           # (bf16 p) + f32 master + adam m,v = 14B/param
    zero1: bool = True              # shard optimizer states over dp
    remat: bool = True              # store only layer-boundary activations
    bwd_flops_mult: float = 2.0
    tp_eff_decay: float = 0.95      # MFU multiplier per 2x TP
    dp_eff_decay: float = 0.99


def _mfu(sub: SubCluster, tp: int, dp: int, cfgm: CostModelConfig) -> float:
    # device.efficiency is the runtime-calibration scale (telemetry EWMA);
    # a straggling sub-cluster shows up here and shifts the whole plan
    eff = sub.device.base_mfu * sub.device.efficiency
    eff *= cfgm.tp_eff_decay ** max(0, math.log2(max(tp, 1)))
    eff *= cfgm.dp_eff_decay ** max(0, math.log2(max(dp, 1)))
    return eff


def stage_cost(layers: Sequence[Layer], sub: SubCluster, mesh: Submesh,
               mb_tokens: int, cfgm: CostModelConfig = CostModelConfig(),
               measure_fn: Optional[Callable] = None) -> StageCost:
    """Cheapest feasible intra-op strategy for this stage-mesh pair."""
    if measure_fn is not None:
        return measure_fn(layers, sub, mesh, mb_tokens)

    flops = sum(l.flops_per_token for l in layers) * mb_tokens
    params = sum(l.param_bytes for l in layers)
    ar_bytes = sum(l.ar_bytes_per_token for l in layers) * mb_tokens
    act_bytes = sum(l.act_out_bytes_per_token for l in layers) * mb_tokens
    n, m = mesh.n, mesh.m
    dev = sub.device

    best: Optional[StageCost] = None
    tp = 1
    while tp <= m:
        dp = n * (m // tp)
        if m % tp == 0:
            eff = _mfu(sub, tp, dp, cfgm)
            t_comp_f = flops / (mesh.n_devices * dev.peak_flops * eff)
            # Megatron TP: all-reduce row-parallel outputs over NVLink/ICI.
            # ring all-reduce moves 2(tp-1)/tp of payload; fwd once, bwd once.
            if tp > 1:
                t_ar = (ar_bytes / dp) * 2 * (tp - 1) / tp / sub.intra_node_bw
            else:
                t_ar = 0.0
            t_f = t_comp_f + t_ar
            t_b = cfgm.bwd_flops_mult * t_comp_f + t_ar
            # memory
            shard = tp * (dp if cfgm.zero1 else 1)
            mem_p = params * (1.0 + cfgm.opt_mult) / min(shard, mesh.n_devices)
            act_stored = act_bytes if cfgm.remat else 3.0 * act_bytes
            mem_a = act_stored / mesh.n_devices
            # per-step dp grad sync (overlappable; charged once per step)
            if dp > 1:
                bw = sub.inter_node_bw if n > 1 else sub.intra_node_bw
                dp_sync = params * 2 * (dp - 1) / dp / bw
            else:
                dp_sync = 0.0
            cand = StageCost(t_f, t_b, mem_p, mem_a, tp, dp, dp_sync)
            if best is None or cand.t < best.t:
                best = cand
        tp *= 2
    assert best is not None
    return best


def cut_comm_bytes(layers: Sequence[Layer], cut_after: int, mb_tokens: int) -> float:
    """Bytes of the activation crossing the stage boundary after layer index
    ``cut_after`` (exclusive end of the left stage), per microbatch."""
    if cut_after <= 0 or cut_after >= len(layers):
        return 0.0
    return layers[cut_after - 1].act_out_bytes_per_token * mb_tokens


def memory_feasible(cost: StageCost, sub: SubCluster, warmup_k: int) -> bool:
    """Eq. 18: mem_p + K * mem_a <= mem_device."""
    return cost.mem_p + warmup_k * cost.mem_a <= sub.device.mem_bytes
