"""Operator-sequence IR for the planner.

``build_op_sequence(cfg)`` linearizes an architecture into a topologically
ordered list of :class:`Op` (the planner's input, mirroring Alpa/HAPT).
Each op carries analytic per-token flops / parameter bytes / boundary
activation bytes; ``signature`` is the structural identity used by
repeated-module mining and zero-redundant aliasing.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class Op:
    name: str
    signature: str            # structural identity (kind + dims)
    flops_per_token: float    # forward flops
    param_bytes: float
    act_bytes_per_token: float  # bytes of this op's *output* per token
    heavy: bool = False       # GEMM/conv-like (drives module mining)


def _gemm(name: str, sig: str, d_in: int, d_out: int, bytes_per: int = 2,
          out_width: int | None = None) -> Op:
    width = d_out if out_width is None else out_width
    return Op(name, sig, 2.0 * d_in * d_out, bytes_per * d_in * d_out,
              bytes_per * width, heavy=True)


def _light(name: str, sig: str, width: int, flops_mult: float = 4.0,
           param: float = 0.0, bytes_per: int = 2) -> Op:
    return Op(name, sig, flops_mult * width, param, bytes_per * width)


def _attn_ops(cfg: ArchConfig, tag: str, seq_len: int, causal_frac: float,
              cross: bool = False, window: int = 0) -> List[Op]:
    d, qd, kvd, hd = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.head_dim
    eff_seq = min(seq_len, window) if window else seq_len
    core_flops = 2.0 * 2.0 * qd * eff_seq * causal_frac  # QK^T + PV per token
    return [
        _light(f"{tag}.ln", f"ln[{d}]", d),
        _gemm(f"{tag}.qkv", f"attn.qkv[{d}->{qd}+{2*kvd}]", d, qd + 2 * kvd,
              out_width=qd + 2 * kvd),
        Op(f"{tag}.core", f"attn.core[{qd}x{eff_seq}]", core_flops, 0.0,
           2.0 * qd, heavy=True),
        _gemm(f"{tag}.out", f"attn.o[{qd}->{d}]", qd, d),
    ]


def _mlp_ops(cfg: ArchConfig, tag: str) -> List[Op]:
    d, ff = cfg.d_model, cfg.d_ff
    gated = cfg.activation in ("swiglu", "geglu")
    ops = [_light(f"{tag}.ln", f"ln[{d}]", d),
           _gemm(f"{tag}.up", f"mlp.up[{d}->{ff}]", d, ff)]
    if gated:
        ops.append(_gemm(f"{tag}.gate", f"mlp.gate[{d}->{ff}]", d, ff))
    ops.append(_light(f"{tag}.act", f"act[{ff}]", ff))
    ops.append(_gemm(f"{tag}.down", f"mlp.down[{ff}->{d}]", ff, d))
    return ops


def _moe_ops(cfg: ArchConfig, tag: str) -> List[Op]:
    d, ff, E, k = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.top_k
    gated = cfg.activation in ("swiglu", "geglu")
    n_mats = 3 if gated else 2
    # router + dispatched expert compute (top-k of E experts per token)
    return [
        _light(f"{tag}.ln", f"ln[{d}]", d),
        Op(f"{tag}.router", f"moe.router[{d}->{E}]", 2.0 * d * E, 4.0 * d * E,
           4.0 * E),
        Op(f"{tag}.experts", f"moe.experts[{E}x{d}x{ff}]",
           2.0 * k * n_mats * d * ff, 2.0 * E * n_mats * d * ff, 2.0 * d,
           heavy=True),
    ]


def _ssm_ops(cfg: ArchConfig, tag: str) -> List[Op]:
    d, di, ns, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    dproj = 2 * di + 2 * ns + nh
    q = cfg.ssm_chunk
    # intra-chunk: CB (Q*N) + M@x (Q*P per head) ~= 2*Q*(N + P)*di-ish per token
    ssd_flops = 2.0 * q * (ns + di) + 4.0 * di * ns
    return [
        _light(f"{tag}.ln", f"ln[{d}]", d),
        _gemm(f"{tag}.inproj", f"ssm.in[{d}->{dproj}]", d, dproj),
        _light(f"{tag}.conv", f"conv[{di + 2 * ns}]", di + 2 * ns,
               flops_mult=2.0 * cfg.ssm_conv,
               param=2.0 * cfg.ssm_conv * (di + 2 * ns)),
        Op(f"{tag}.ssd", f"ssm.ssd[{di}x{ns}x{q}]", ssd_flops,
           16.0 * nh, 2.0 * di, heavy=True),
        _gemm(f"{tag}.outproj", f"ssm.out[{di}->{d}]", di, d),
    ]


def build_op_sequence(cfg: ArchConfig, seq_len: int = 4096) -> List[Op]:
    """Linearized operator sequence for the whole model (training graph)."""
    d, V = cfg.d_model, cfg.vocab_size
    ops: List[Op] = [
        Op("embed", f"embed[{V}x{d}]", 0.0, 2.0 * V * d, 2.0 * d),
    ]
    causal_frac = 0.5  # average causal coverage

    if cfg.family == "audio":
        for l in range(cfg.enc_layers):
            tag = f"enc{l}"
            ops += _attn_ops(cfg, f"{tag}.attn", cfg.enc_frames, 1.0)
            ops += _mlp_ops(cfg, f"{tag}.mlp")
        for l in range(cfg.n_layers):
            tag = f"dec{l}"
            ops += _attn_ops(cfg, f"{tag}.self", seq_len, causal_frac)
            ops += _attn_ops(cfg, f"{tag}.cross", cfg.enc_frames, 1.0, cross=True)
            ops += _mlp_ops(cfg, f"{tag}.mlp")
    elif cfg.family == "ssm":
        for l in range(cfg.n_layers):
            ops += _ssm_ops(cfg, f"l{l}")
    elif cfg.family == "hybrid":
        n_apps = cfg.n_layers // cfg.shared_attn_every
        li = 0
        for l in range(cfg.n_layers):
            ops += _ssm_ops(cfg, f"l{l}")
            if (l + 1) % cfg.shared_attn_every == 0 and li < n_apps:
                tag = f"shared{li}"
                ops.append(_gemm(f"{tag}.adapt_in", f"adapt[{d}->{d}]", d, d))
                ops += _attn_ops(cfg, f"{tag}.attn", seq_len, causal_frac)
                ops += _mlp_ops(cfg, f"{tag}.mlp")
                ops.append(_gemm(f"{tag}.adapt_out", f"adapt[{d}->{d}]", d, d))
                li += 1
    elif cfg.family == "moe":
        for l in range(cfg.n_layers):
            tag = f"l{l}"
            ops += _attn_ops(cfg, f"{tag}.attn", seq_len, causal_frac)
            ops += _moe_ops(cfg, f"{tag}.moe")
    elif cfg.family == "vlm":
        gsz = cfg.cross_attn_every
        for l in range(cfg.n_layers):
            tag = f"l{l}"
            if (l + 1) % gsz == 0:
                ops += _attn_ops(cfg, f"{tag}.xattn", cfg.n_image_tokens, 1.0,
                                 cross=True)
                ops += _mlp_ops(cfg, f"{tag}.mlp")
            else:
                ops += _attn_ops(cfg, f"{tag}.attn", seq_len, causal_frac)
                ops += _mlp_ops(cfg, f"{tag}.mlp")
    else:  # dense
        ratio = cfg.local_global_ratio
        for l in range(cfg.n_layers):
            tag = f"l{l}"
            if ratio and (l + 1) % (ratio + 1) != 0:
                w = cfg.sliding_window
            else:
                w = cfg.sliding_window if not ratio and cfg.sliding_window else 0
            ops += _attn_ops(cfg, f"{tag}.attn", seq_len, causal_frac, window=w)
            ops += _mlp_ops(cfg, f"{tag}.mlp")

    ops.append(_light("final.ln", f"ln[{d}]", d))
    head_param = 0.0 if cfg.tie_embeddings else 2.0 * d * V
    ops.append(Op("lm_head", f"head[{d}->{V}]", 2.0 * d * V, head_param,
                  2.0 * V, heavy=True))
    return ops


def total_flops_per_token(ops: List[Op]) -> float:
    return sum(o.flops_per_token for o in ops)


def total_param_bytes(ops: List[Op]) -> float:
    return sum(o.param_bytes for o in ops)
