from repro.core.cluster import (
    DeviceProfile, HeteroCluster, SubCluster,
    add_nodes, cluster_fingerprint, heterogeneous_tpu_cluster,
    homogeneous_cluster, paper_case_study_cluster, paper_eval_cluster,
    remove_nodes, set_efficiency, set_node_efficiencies, subcluster_index,
    tpu_multipod_cluster, with_cross_bw,
)
from repro.core.h1f1b import (
    classic_1f1b_counts, eager_1f1b_counts, h1f1b_counts, h1f1b_deltas,
)
from repro.core.planner import HAPTPlanner, PlannerConfig
from repro.core.pipesim import ascii_timeline, eta_load_balance, simulate
from repro.core.strategy import IntraOpPlan, ParallelStrategy, StageAssignment

__all__ = [
    "DeviceProfile", "HeteroCluster", "SubCluster", "HAPTPlanner",
    "PlannerConfig", "ParallelStrategy", "StageAssignment", "IntraOpPlan",
    "set_node_efficiencies",
    "simulate", "ascii_timeline", "eta_load_balance",
    "h1f1b_counts", "h1f1b_deltas", "classic_1f1b_counts",
    "eager_1f1b_counts", "paper_case_study_cluster", "paper_eval_cluster",
    "homogeneous_cluster", "tpu_multipod_cluster", "heterogeneous_tpu_cluster",
    "add_nodes", "remove_nodes", "with_cross_bw", "set_efficiency",
    "subcluster_index", "cluster_fingerprint",
]
