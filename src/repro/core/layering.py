"""Structure-preserving fine-grained layer construction (paper §5.1, Fig. 6b).

1. *Repeated-module mining*: iteratively find the most frequent contiguous
   operator sub-sequence containing at least ``z`` heavy ops, designate its
   non-overlapping occurrences as instances of a repeated module, and recurse
   on the remaining non-repeated spans until no repeat exists.
2. *Per-module clustering*: within each module, cluster operators into
   contiguous flops-balanced layers (Alpa-style); every instance of a
   repeated module gets the *same* partition, so layers inherit a structural
   ``class_key`` — the zero-redundant profiler aliases stage-mesh candidates
   whose layer-class sequences match.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.opgraph import Op


@dataclass(frozen=True)
class Module:
    start: int                # op index span [start, end)
    end: int
    class_id: int             # shared across repeated instances
    repeated: bool


@dataclass(frozen=True)
class Layer:
    """One planner layer: a contiguous op range."""
    start: int
    end: int
    flops_per_token: float
    param_bytes: float
    act_out_bytes_per_token: float    # boundary activation (last op's output)
    class_key: Tuple[int, int]        # (module class, position-in-module)
    module_instance: int
    ar_bytes_per_token: float = 0.0   # TP all-reduce payload (Megatron-style)

    @property
    def n_ops(self) -> int:
        return self.end - self.start


_AR_SUFFIXES = (".out", ".down", ".outproj", ".adapt_out")


def _is_ar_op(name: str) -> bool:
    """Ops whose output needs a tensor-parallel all-reduce (row-parallel
    matmul outputs in the Megatron sharding scheme)."""
    return name.endswith(_AR_SUFFIXES) or name == "lm_head" or name.endswith(".experts")


# ---------------------------------------------------------------------------
# Repeated-module mining
# ---------------------------------------------------------------------------


def _find_best_pattern(sigs: Sequence[str], heavy: Sequence[bool],
                       z: int, max_len: int) -> Optional[Tuple[int, int]]:
    """Most frequent (then longest) contiguous pattern with >= z heavy ops and
    >= 2 non-overlapping occurrences.  Returns (start, length) or None."""
    n = len(sigs)
    best: Optional[Tuple[int, int]] = None
    best_rank = (1, 0)  # (count, length)
    for w in range(1, min(max_len, n // 2) + 1):
        windows: Dict[Tuple[str, ...], List[int]] = {}
        for i in range(n - w + 1):
            if sum(heavy[i:i + w]) < z:
                continue
            windows.setdefault(tuple(sigs[i:i + w]), []).append(i)
        for pat, starts in windows.items():
            # greedy non-overlapping count
            count, last_end = 0, -1
            first = starts[0]
            for s in starts:
                if s >= last_end:
                    count += 1
                    last_end = s + w
            if count >= 2 and (count, w) > best_rank:
                best_rank = (count, w)
                best = (first, w)
    return best


def mine_modules(ops: Sequence[Op], z: int = 2, max_pattern_len: int = 64) -> List[Module]:
    """Partition the op sequence into repeated / non-repeated modules."""
    sigs = [o.signature for o in ops]
    heavy = [o.heavy for o in ops]
    n = len(ops)
    assigned = [-1] * n          # module list index per op
    modules: List[Module] = []
    spans = [(0, n)]             # unassigned spans to mine
    class_counter = itertools.count()

    while True:
        # mine within current non-repeated spans only
        found = None
        for (s, e) in spans:
            sub = _find_best_pattern(sigs[s:e], heavy[s:e], z, max_pattern_len)
            if sub is not None:
                cand = (s + sub[0], sub[1])
                if found is None or sub[1] > found[2]:
                    found = (cand[0], cand[0] + cand[1], cand[1])
        if found is None:
            break
        pstart, pend, w = found
        pattern = tuple(sigs[pstart:pend])
        cid = next(class_counter)
        new_spans: List[Tuple[int, int]] = []
        for (s, e) in spans:
            i = s
            while i <= e - w:
                if tuple(sigs[i:i + w]) == pattern:
                    if i > s:
                        new_spans.append((s, i))
                    modules.append(Module(i, i + w, cid, True))
                    i += w
                    s = i
                else:
                    i += 1
            if s < e:
                new_spans.append((s, e))
        spans = new_spans

    nid_base = 10_000
    for idx, (s, e) in enumerate(spans):
        modules.append(Module(s, e, nid_base + idx, False))
    modules.sort(key=lambda m: m.start)
    return modules


# ---------------------------------------------------------------------------
# Balanced contiguous clustering within a module
# ---------------------------------------------------------------------------


def _balanced_partition(costs: Sequence[float], q: int) -> List[int]:
    """Split ``costs`` into q contiguous parts minimizing the max part sum.
    Returns cut indices (part boundaries, length q+1, starts with 0)."""
    n = len(costs)
    q = min(q, n)
    prefix = [0.0]
    for c in costs:
        prefix.append(prefix[-1] + c)

    # DP over (parts, end): minimize max part
    INF = float("inf")
    dp = [[INF] * (n + 1) for _ in range(q + 1)]
    cut = [[0] * (n + 1) for _ in range(q + 1)]
    dp[0][0] = 0.0
    for p in range(1, q + 1):
        for e in range(p, n + 1):
            for s in range(p - 1, e):
                v = max(dp[p - 1][s], prefix[e] - prefix[s])
                if v < dp[p][e]:
                    dp[p][e] = v
                    cut[p][e] = s
    bounds = [n]
    e = n
    for p in range(q, 0, -1):
        e = cut[p][e]
        bounds.append(e)
    return bounds[::-1]


def build_layers(ops: Sequence[Op], target_layers: int, z: int = 2) -> List[Layer]:
    """Construct the fine-grained structural layer sequence (~target_layers)."""
    modules = mine_modules(ops, z=z)
    total_flops = sum(o.flops_per_token for o in ops) or 1.0

    # allocate layer budget per module CLASS proportional to flop share
    class_spans: Dict[int, List[Module]] = {}
    for m in modules:
        class_spans.setdefault(m.class_id, []).append(m)

    class_layers: Dict[int, int] = {}
    for cid, insts in class_spans.items():
        share = sum(
            sum(ops[i].flops_per_token for i in range(m.start, m.end))
            for m in insts) / total_flops
        per_class_total = max(len(insts), round(share * target_layers))
        class_layers[cid] = max(1, per_class_total // len(insts))

    layers: List[Layer] = []
    for inst_id, m in enumerate(modules):
        costs = [ops[i].flops_per_token for i in range(m.start, m.end)]
        # ensure light-op-only modules still form one layer
        q = class_layers[m.class_id]
        bounds = _balanced_partition([c + 1e-9 for c in costs], q)
        for pos in range(len(bounds) - 1):
            s, e = m.start + bounds[pos], m.start + bounds[pos + 1]
            if s == e:
                continue
            layers.append(Layer(
                start=s, end=e,
                flops_per_token=sum(ops[i].flops_per_token for i in range(s, e)),
                param_bytes=sum(ops[i].param_bytes for i in range(s, e)),
                act_out_bytes_per_token=ops[e - 1].act_bytes_per_token,
                class_key=(m.class_id, pos),
                module_instance=inst_id,
                ar_bytes_per_token=sum(
                    ops[i].act_bytes_per_token for i in range(s, e)
                    if _is_ar_op(ops[i].name)),
            ))
    if target_layers < len(layers):
        # COARSE regime (Alpa-like): merge whole module instances into
        # ~target_layers super-layers balanced by flops; merged layers keep a
        # composite class_key so structural aliasing still applies
        layers = _merge_layers(layers, target_layers)
    return layers


def _merge_layers(layers: List[Layer], target: int) -> List[Layer]:
    bounds = _balanced_partition(
        [l.flops_per_token + 1e-9 for l in layers], target)
    merged: List[Layer] = []
    for pos in range(len(bounds) - 1):
        group = layers[bounds[pos]:bounds[pos + 1]]
        if not group:
            continue
        merged.append(Layer(
            start=group[0].start, end=group[-1].end,
            flops_per_token=sum(l.flops_per_token for l in group),
            param_bytes=sum(l.param_bytes for l in group),
            act_out_bytes_per_token=group[-1].act_out_bytes_per_token,
            class_key=tuple(l.class_key for l in group),
            module_instance=group[0].module_instance,
            ar_bytes_per_token=sum(l.ar_bytes_per_token for l in group),
        ))
    return merged


def layer_class_sequence(layers: Sequence[Layer], start: int, end: int) -> Tuple:
    """Structural identity of the stage spanning layers [start, end)."""
    return tuple(l.class_key for l in layers[start:end])
