"""Pipeline-DAG discrete-event simulator (paper §4.2's DAG made executable).

Nodes: F/B compute per (microbatch, stage), CF/CB communication per
(microbatch, link); edges: per-stage issue order (the schedule under test),
per-link in-order transmission (full duplex), and microbatch data
dependencies.  Start times solve the longest-path recurrence
``s(v) >= s(u) + d(u)`` exactly — no sampling.

Two engines produce bit-identical results:

- **closed-form fast path** (default when eligible): the 1F1B grid is
  static, so start times are filled by an index-based recurrence over
  (stage, microbatch) — no node dicts, no Kahn sort.  Eligible whenever the
  warm-up counts are non-increasing along the pipeline (every H-1F1B /
  classic / eager schedule qualifies) and sends overlap compute;
- **graph simulator** (fallback): the original explicit-DAG longest-path
  solve, kept as the reference oracle and for irregular schedules
  (``no_overlap`` synchronous sends, warm-up vectors that grow downstream).

Repeated calls are served from a bounded memo keyed on the full input
signature ``(t_f, t_b, comm, counts, intra)`` — warm elastic re-plans and
``api.Executable.simulate()`` hit cache instead of re-solving; counters are
exposed via :func:`sim_memo_stats`.  Treat returned :class:`SimResult`
objects as immutable (cache entries are shared).

Supports classic 1F1B / Eager-1F1B / H-1F1B (any warm-up count vector) and a
``no_overlap`` mode (HexiScale-like synchronous sends that block compute).

Outputs makespan, per-stage busy/idle/comm breakdown (paper Fig. 8), overlap
ratio, and the eta load-balance metric (Eq. 19).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

Node = Tuple[str, int, int]  # (kind, microbatch, stage/link)


@dataclass
class SimResult:
    makespan: float
    start: Dict[Node, float]
    dur: Dict[Node, float]
    stage_compute: List[float]        # busy compute time per stage
    stage_comm_blocking: List[float]  # comm time charged to the stage (no_overlap)
    stage_idle: List[float]           # makespan - compute - blocking comm
    comm_total: float                 # total link-busy time (all links)
    comm_exposed: float               # comm time that delayed a compute op
    warmup_counts: List[int]
    stage_intra_comm: List[float] = field(default_factory=list)
    # exposed intra-op collective time per stage over the whole step (the
    # non-overlapped share of TP all-reduce / DP sync inside each F/B op)
    link_busy: Dict[str, float] = field(default_factory=dict)
    # contended engine only: seconds each physical link (by occupancy key,
    # "<link>/fwd" or "<link>/bwd") had at least one active transfer

    @property
    def overlap_ratio(self) -> float:
        if self.comm_total <= 0:
            return 1.0
        return max(0.0, 1.0 - self.comm_exposed / self.comm_total)

    def throughput(self, tokens_per_microbatch: int, n_microbatches: int) -> float:
        return tokens_per_microbatch * n_microbatches / self.makespan


def _stage_order(i: int, S: int, B: int, N_i: int) -> List[Tuple[str, int]]:
    """Issue order of compute ops on stage i: warm-up forwards, 1F1B steady
    alternation, cool-down backwards."""
    order: List[Tuple[str, int]] = []
    n_warm = min(N_i, B)
    for j in range(n_warm):
        order.append(("F", j))
    nf, nb = n_warm, 0
    while nb < B:
        order.append(("B", nb))
        nb += 1
        if nf < B:
            order.append(("F", nf))
            nf += 1
    return order


def fast_path_eligible(warmup_counts: Sequence[int],
                       no_overlap: bool = False) -> bool:
    """Can the closed-form recurrence evaluate this schedule?

    True iff sends overlap compute and the warm-up counts are non-increasing
    along the pipeline with every stage launching at least one warm-up
    forward.  (The recurrence processes ops in issue-order position; the
    monotone counts guarantee every cross-stage dependency lands at an
    earlier — or tie-broken earlier — position, which is exactly the shape
    of every H-1F1B / classic-1F1B / eager-1F1B schedule.)"""
    if no_overlap:
        return False
    prev: Optional[int] = None
    for c in warmup_counts:
        if c < 1:
            return False
        if prev is not None and c > prev:
            return False
        prev = c
    return True


# ---------------------------------------------------------------------------
# Closed-form fast path
# ---------------------------------------------------------------------------


def _simulate_fast(t_f: List[float], t_b: List[float],
                   c_links: Sequence[float], B: int,
                   warmup_counts: Sequence[int],
                   cb: List[float]) -> Tuple:
    """Index-based recurrence over (stage, microbatch) start times.

    Processes ops in increasing issue-order position; at each position all
    forwards run in ascending stage order, then all backwards in descending
    stage order — a topological order of the 1F1B DAG whenever the warm-up
    counts are non-increasing (see :func:`fast_path_eligible`).  Every float
    expression mirrors the graph simulator's, so results are bit-identical.

    Returns (f_start, f_end, b_start, b_end, cf_start, cf_end, cb_start,
    cb_end, exposed) — per-(stage, microbatch) grids as nested lists plus the
    per-(stage, order-position) exposed-comm contributions.
    """
    S = len(t_f)
    orders = [_stage_order(i, S, B, warmup_counts[i]) for i in range(S)]
    # per issue position: which (stage, microbatch) forwards/backwards run
    f_at: List[List[Tuple[int, int]]] = [[] for _ in range(2 * B)]
    b_at: List[List[Tuple[int, int]]] = [[] for _ in range(2 * B)]
    for i in range(S):
        for p, (kind, j) in enumerate(orders[i]):
            (f_at if kind == "F" else b_at)[p].append((i, j))
    f_start = [[0.0] * B for _ in range(S)]
    f_end = [[0.0] * B for _ in range(S)]
    b_start = [[0.0] * B for _ in range(S)]
    b_end = [[0.0] * B for _ in range(S)]
    cf_start = [[0.0] * B for _ in range(S - 1)]
    cf_end = [[0.0] * B for _ in range(S - 1)]
    cb_start = [[0.0] * B for _ in range(S - 1)]
    cb_end = [[0.0] * B for _ in range(S - 1)]
    exposed = [[0.0] * (2 * B) for _ in range(S)]
    prev_end: List[Optional[float]] = [None] * S

    for p in range(2 * B):
        # forwards at this position, upstream first (CF arrivals are ready)
        for i, j in f_at[p]:
            pe = prev_end[i]
            if i > 0:
                arrive = cf_end[i - 1][j]
                s0 = arrive if pe is None else max(pe, arrive)
                ex = arrive - (0.0 if pe is None else pe)
                if ex > 1e-12:
                    exposed[i][p] = ex
            else:
                s0 = 0.0 if pe is None else pe
            e = s0 + t_f[i]
            f_start[i][j] = s0
            f_end[i][j] = e
            prev_end[i] = e
            if i < S - 1:
                cs = e if j == 0 else max(e, cf_end[i][j - 1])
                cf_start[i][j] = cs
                cf_end[i][j] = cs + c_links[i]
        # backwards at this position, downstream first (CB arrivals are ready)
        for i, j in reversed(b_at[p]):
            pe = prev_end[i]
            if i < S - 1:
                arrive = cb_end[i][j]
                s0 = arrive if pe is None else max(pe, arrive)
                ex = arrive - (0.0 if pe is None else pe)
                if ex > 1e-12:
                    exposed[i][p] = ex
            else:
                # last stage: data dep is its own forward (not a comm node)
                arrive = f_end[i][j]
                s0 = arrive if pe is None else max(pe, arrive)
            e = s0 + t_b[i]
            b_start[i][j] = s0
            b_end[i][j] = e
            prev_end[i] = e
            if i > 0:
                cs = e if j == 0 else max(e, cb_end[i - 1][j - 1])
                cb_start[i - 1][j] = cs
                cb_end[i - 1][j] = cs + cb[i - 1]
    return (f_start, f_end, b_start, b_end, cf_start, cf_end,
            cb_start, cb_end, exposed, orders)


def _fast_result(t_f, t_b, c_links, B, warmup_counts, cb, in_f, in_b
                 ) -> SimResult:
    """Assemble a SimResult from the fast-path grids, accumulating every
    reduction in the same element order as the graph simulator (so sums and
    maxima are bit-identical, not merely close)."""
    S = len(t_f)
    (f_start, f_end, b_start, b_end, cf_start, cf_end, cb_start, cb_end,
     exposed, orders) = _simulate_fast(t_f, t_b, c_links, B, warmup_counts, cb)

    start: Dict[Node, float] = {}
    dur: Dict[Node, float] = {}
    stage_compute = [0.0] * S
    for i in range(S):
        row_f, row_b = f_start[i], b_start[i]
        start.update({("F", j, i): row_f[j] for j in range(B)})
        start.update({("B", j, i): row_b[j] for j in range(B)})
        tfi, tbi = t_f[i], t_b[i]
        dur.update({("F", j, i): tfi for j in range(B)})
        dur.update({("B", j, i): tbi for j in range(B)})
        # stage busy time accumulated in issue order ([F]*n_w, [B,F]*(B-n_w),
        # [B]*n_w) so the float sum matches the graph engine's bit for bit
        n_w = min(warmup_counts[i], B)
        acc = 0.0
        for _ in range(n_w):
            acc += tfi
        for _ in range(B - n_w):
            acc += tbi
            acc += tfi
        for _ in range(n_w):
            acc += tbi
        stage_compute[i] = acc
    comm_total = 0.0
    for i in range(S - 1):
        row_cf, row_cb = cf_start[i], cb_start[i]
        start.update({("CF", j, i): row_cf[j] for j in range(B)})
        start.update({("CB", j, i): row_cb[j] for j in range(B)})
        ci, cbi = c_links[i], cb[i]
        dur.update({("CF", j, i): ci for j in range(B)})
        dur.update({("CB", j, i): cbi for j in range(B)})
        for _ in range(B):
            comm_total += ci
            comm_total += cbi
    makespan = max(max(row) for row in (f_end + b_end + cf_end + cb_end))

    comm_exposed = 0.0
    for row in exposed:
        for x in row:
            if x > 1e-12:
                comm_exposed += x
    comm_exposed = min(comm_exposed, comm_total)

    stage_comm_blocking = [0.0] * S
    stage_idle = [makespan - stage_compute[i] - stage_comm_blocking[i]
                  for i in range(S)]
    stage_intra = [B * (in_f[i] + in_b[i]) for i in range(S)]
    return SimResult(makespan, start, dur, stage_compute, stage_comm_blocking,
                     stage_idle, comm_total, comm_exposed,
                     list(warmup_counts), stage_intra)


# ---------------------------------------------------------------------------
# Contended engine (fair-share link occupancy via repro.comm.netsim)
# ---------------------------------------------------------------------------


def _simulate_contended(t_f, t_b, c_links, B, warmup_counts, cb, in_f, in_b,
                        link_ids: Sequence[str],
                        sync_work) -> SimResult:
    """The 1F1B DAG solved under *contention*: comm ops are occupancy
    intervals on named physical links (``link_ids[i]`` per stage boundary;
    equal ids share capacity), solved by the event-driven fair-share netsim.
    Boundaries that never share a link reproduce the graph engine's timing;
    cluster-crossing boundaries all ride the same ``"wan"`` id and slow each
    other down — as do optional per-stage gradient syncs (``sync_work``
    entries ``(stage, link_id, seconds)``, released after the stage's last
    backward, occupying both link directions like a real allreduce)."""
    from repro.comm.netsim import SimNode, run as netsim_run

    S = len(t_f)
    nodes: List = []
    deps_of: Dict[Node, List[Node]] = {}

    def add(node: Node, work: float, deps, links=()):
        deps = tuple(d for d in deps if d is not None)
        nodes.append(SimNode(node, work, deps, tuple(links)))
        deps_of[node] = list(deps)

    for i in range(S):
        order = _stage_order(i, S, B, warmup_counts[i])
        prev: Optional[Node] = None
        for kind, j in order:
            node = (kind, j, i)
            data_dep: Optional[Node] = None
            if kind == "F" and i > 0:
                data_dep = ("CF", j, i - 1)
            elif kind == "B":
                data_dep = ("CB", j, i) if i < S - 1 else ("F", j, i)
            add(node, t_f[i] if kind == "F" else t_b[i], (prev, data_dep))
            prev = node
    for i in range(S - 1):
        for j in range(B):
            add(("CF", j, i), c_links[i],
                (("F", j, i), ("CF", j - 1, i) if j > 0 else None),
                links=(f"{link_ids[i]}/fwd",))
            add(("CB", j, i), cb[i],
                (("B", j, i + 1), ("CB", j - 1, i) if j > 0 else None),
                links=(f"{link_ids[i]}/bwd",))
    for stage, link, secs in (sync_work or ()):
        add(("SYNC", 0, stage), float(secs), (("B", B - 1, stage),),
            links=(f"{link}/fwd", f"{link}/bwd"))

    res = netsim_run(nodes)

    start = dict(res.start)
    dur = {nid: res.end[nid] - res.start[nid] for nid in res.end}
    makespan = res.makespan
    stage_compute = [0.0] * S
    for (kind, j, i), d in dur.items():
        if kind in ("F", "B"):
            stage_compute[i] += d
    comm_total = sum(d for (k, _, _), d in dur.items()
                     if k in ("CF", "CB", "SYNC"))
    comm_exposed = 0.0
    for v, ps in deps_of.items():
        if v[0] not in ("F", "B") or not ps:
            continue
        comm_ends = [res.end[p] for p in ps if p[0] in ("CF", "CB")]
        other_ends = [res.end[p] for p in ps if p[0] in ("F", "B")]
        if comm_ends:
            exposed = max(comm_ends) - max(other_ends, default=0.0)
            if exposed > 1e-12:
                comm_exposed += exposed
    comm_exposed = min(comm_exposed, comm_total)
    stage_comm_blocking = [0.0] * S
    stage_idle = [makespan - stage_compute[i] - stage_comm_blocking[i]
                  for i in range(S)]
    stage_intra = [B * (in_f[i] + in_b[i]) for i in range(S)]
    return SimResult(makespan, start, dur, stage_compute, stage_comm_blocking,
                     stage_idle, comm_total, comm_exposed,
                     list(warmup_counts), stage_intra,
                     link_busy=dict(res.link_busy))


# ---------------------------------------------------------------------------
# Reference graph simulator
# ---------------------------------------------------------------------------


def _simulate_graph(t_f, t_b, c_links, B, warmup_counts, cb, in_f, in_b, *,
                    no_overlap: bool) -> SimResult:
    S = len(t_f)
    dur: Dict[Node, float] = {}
    deps: Dict[Node, List[Node]] = {}

    def add(node: Node, d: float, *pre: Node):
        dur[node] = d
        deps[node] = [p for p in pre if p is not None]

    # compute nodes + stage order edges (comm inserted into stage order when
    # no_overlap: the send occupies the stage)
    for i in range(S):
        order = _stage_order(i, S, B, warmup_counts[i])
        prev: Optional[Node] = None
        for kind, j in order:
            node = (kind, j, i)
            add(node, t_f[i] if kind == "F" else t_b[i], prev)
            prev = node
            if no_overlap:
                if kind == "F" and i < S - 1 and c_links[i] > 0:
                    cf = ("CF", j, i)
                    add(cf, c_links[i], prev)
                    prev = cf
                if kind == "B" and i > 0 and cb[i - 1] > 0:
                    cbn = ("CB", j, i - 1)
                    add(cbn, cb[i - 1], prev)
                    prev = cbn

    # communication nodes (overlapped mode) + link in-order chains
    if not no_overlap:
        for i in range(S - 1):
            prev_cf: Optional[Node] = None
            prev_cb: Optional[Node] = None
            for j in range(B):
                cf = ("CF", j, i)
                add(cf, c_links[i], ("F", j, i), prev_cf)
                prev_cf = cf
                cbn = ("CB", j, i)
                add(cbn, cb[i], ("B", j, i + 1), prev_cb)
                prev_cb = cbn
    else:
        # deps from producer already in stage chains; nothing extra
        pass

    # data dependencies into compute nodes (no_overlap elides zero-cost comm
    # nodes, so fall back to the producing compute op directly)
    for i in range(S):
        for j in range(B):
            if i > 0:
                cf = ("CF", j, i - 1)
                deps[("F", j, i)].append(cf if cf in dur else ("F", j, i - 1))
            if i < S - 1:
                cbn = ("CB", j, i)
                deps[("B", j, i)].append(
                    cbn if cbn in dur else ("B", j, i + 1))
            else:
                deps[("B", j, i)].append(("F", j, i))

    # longest-path start times (Kahn topological order)
    indeg = {v: 0 for v in dur}
    succ: Dict[Node, List[Node]] = {v: [] for v in dur}
    for v, ps in deps.items():
        for p in ps:
            succ[p].append(v)
            indeg[v] += 1
    start: Dict[Node, float] = {}
    ready = [v for v, d in indeg.items() if d == 0]
    order_count = 0
    while ready:
        v = ready.pop()
        order_count += 1
        start[v] = max((start[p] + dur[p] for p in deps[v]), default=0.0)
        for s_ in succ[v]:
            indeg[s_] -= 1
            if indeg[s_] == 0:
                ready.append(s_)
    assert order_count == len(dur), "cycle in pipeline DAG"

    makespan = max(start[v] + dur[v] for v in dur)

    # --- breakdown ---------------------------------------------------------
    stage_compute = [0.0] * S
    stage_comm_blocking = [0.0] * S
    for (kind, j, i), d in dur.items():
        if kind in ("F", "B"):
            stage_compute[i] += d
        elif no_overlap:
            # charged to the sending stage (CF from i, CB from i+1)
            stage_comm_blocking[i if kind == "CF" else i + 1] += d
    stage_idle = [makespan - stage_compute[i] - stage_comm_blocking[i]
                  for i in range(S)]

    comm_total = sum(d for (k, _, _), d in dur.items() if k in ("CF", "CB"))
    # exposed comm: compute ops delayed specifically by their comm dependency
    comm_exposed = 0.0
    for v, ps in deps.items():
        if v[0] not in ("F", "B") or not ps:
            continue
        comm_ends = [start[p] + dur[p] for p in ps if p[0] in ("CF", "CB")]
        other_ends = [start[p] + dur[p] for p in ps if p[0] in ("F", "B")]
        if comm_ends:
            exposed = max(comm_ends) - max(other_ends, default=0.0)
            if exposed > 1e-12:
                comm_exposed += min(exposed, max(comm_ends) - (max(other_ends, default=0.0)))
    comm_exposed = min(comm_exposed, comm_total)

    # per-stage exposed intra-op collective time: every F and B op of stage i
    # carries its stretched share once per microbatch
    stage_intra = [B * (in_f[i] + in_b[i]) for i in range(S)]

    return SimResult(makespan, start, dur, stage_compute, stage_comm_blocking,
                     stage_idle, comm_total, comm_exposed,
                     list(warmup_counts), stage_intra)


# ---------------------------------------------------------------------------
# Memoized front door
# ---------------------------------------------------------------------------


@dataclass
class SimMemoStats:
    """Counters for the simulate() memo + engine dispatch."""
    hits: int = 0
    misses: int = 0
    fast_path: int = 0       # misses solved by the closed-form recurrence
    graph_path: int = 0      # misses solved by the reference graph engine
    contended_path: int = 0  # misses solved by the fair-share netsim engine

    def snapshot(self) -> "SimMemoStats":
        return SimMemoStats(self.hits, self.misses,
                            self.fast_path, self.graph_path,
                            self.contended_path)


SIM_MEMO_MAXSIZE = 64
_SIM_MEMO: "OrderedDict[tuple, SimResult]" = OrderedDict()
_SIM_STATS = SimMemoStats()


def sim_memo_stats() -> SimMemoStats:
    """Live counters of the simulate() memo (shared across all callers)."""
    return _SIM_STATS


def clear_sim_memo() -> None:
    _SIM_MEMO.clear()


def simulate(t_f: Sequence[float], t_b: Sequence[float],
             c_links: Sequence[float], n_microbatches: int,
             warmup_counts: Sequence[int], *,
             no_overlap: bool = False,
             c_links_bwd: Optional[Sequence[float]] = None,
             intra_f: Optional[Sequence[float]] = None,
             intra_b: Optional[Sequence[float]] = None,
             intra_overlap: float = 0.0,
             fast: Optional[bool] = None,
             cache: bool = True,
             contention: bool = False,
             link_ids: Optional[Sequence[str]] = None,
             sync_work: Optional[Sequence[Tuple[int, str, float]]] = None
             ) -> SimResult:
    """Simulate one training step (B microbatches through S stages).

    ``intra_f``/``intra_b`` (optional, per stage, seconds): intra-operator
    collective time (TP all-reduce, amortized DP sync) *not* already folded
    into ``t_f``/``t_b``.  A fraction ``intra_overlap`` in [0, 1] hides under
    compute; the exposed remainder stretches every F/B op of that stage and
    is reported per stage in ``SimResult.stage_intra_comm``.

    ``fast``: None (default) auto-selects the closed-form recurrence when
    :func:`fast_path_eligible`; True forces it (ValueError when ineligible);
    False forces the reference graph engine.  Both engines are bit-identical
    on every eligible schedule.

    ``cache``: serve repeated signatures from a bounded memo (the returned
    SimResult is shared — treat it as immutable).  Pass False to bypass
    (e.g. when benchmarking the engines themselves).

    ``contention=True`` replaces the isolated per-link scalars with the
    fair-share occupancy model (``repro.comm.netsim``): ``link_ids`` names
    each boundary's *physical* link (equal ids contend — e.g. every
    cluster-crossing boundary on the shared ``"wan"``; default: all
    distinct, which reproduces uncontended timing) and ``sync_work``
    injects per-stage gradient syncs ``(stage, link_id, seconds)`` that
    contend with in-flight activation traffic.  ``contention=False``
    (default) leaves the legacy engines untouched — bit-identical results.
    """
    S, B = len(t_f), int(n_microbatches)
    assert len(c_links) == S - 1 and len(warmup_counts) == S
    if contention and no_overlap:
        raise ValueError("contention=True models overlapped sends; "
                         "no_overlap has no contended interpretation")
    if contention and fast is True:
        raise ValueError("contention=True has no closed-form fast path")
    if link_ids is not None and len(link_ids) != S - 1:
        raise ValueError(f"link_ids needs {S - 1} entries, got {len(link_ids)}")
    key = None
    if cache:
        key = (tuple(float(x) for x in t_f), tuple(float(x) for x in t_b),
               tuple(float(x) for x in c_links), B,
               tuple(int(c) for c in warmup_counts), bool(no_overlap),
               None if c_links_bwd is None else
               tuple(float(x) for x in c_links_bwd),
               None if intra_f is None else tuple(float(x) for x in intra_f),
               None if intra_b is None else tuple(float(x) for x in intra_b),
               float(intra_overlap), fast, bool(contention),
               None if link_ids is None else tuple(link_ids),
               None if sync_work is None else
               tuple((int(s), str(l), float(w)) for s, l, w in sync_work))
        hit = _SIM_MEMO.get(key)
        if hit is not None:
            _SIM_STATS.hits += 1
            _SIM_MEMO.move_to_end(key)
            return hit
        _SIM_STATS.misses += 1

    cb = list(c_links_bwd) if c_links_bwd is not None else list(c_links)
    assert 0.0 <= intra_overlap <= 1.0
    exposed_frac = 1.0 - intra_overlap
    in_f = [exposed_frac * x for x in intra_f] if intra_f is not None \
        else [0.0] * S
    in_b = [exposed_frac * x for x in intra_b] if intra_b is not None \
        else [0.0] * S
    tf = [t + x for t, x in zip(t_f, in_f)]
    tb = [t + x for t, x in zip(t_b, in_b)]

    if contention:
        _SIM_STATS.contended_path += 1
        ids = list(link_ids) if link_ids is not None \
            else [f"link{i}" for i in range(S - 1)]
        res = _simulate_contended(tf, tb, list(c_links), B, warmup_counts,
                                  cb, in_f, in_b, ids, sync_work)
        if cache:
            _SIM_MEMO[key] = res
            if len(_SIM_MEMO) > SIM_MEMO_MAXSIZE:
                _SIM_MEMO.popitem(last=False)
        return res

    eligible = fast_path_eligible(warmup_counts, no_overlap)
    if fast is True and not eligible:
        raise ValueError(
            "fast=True but the schedule is not closed-form eligible "
            f"(no_overlap={no_overlap}, counts={list(warmup_counts)})")
    use_fast = eligible if fast is None else fast
    if use_fast:
        _SIM_STATS.fast_path += 1
        res = _fast_result(tf, tb, list(c_links), B, warmup_counts,
                           cb, in_f, in_b)
    else:
        _SIM_STATS.graph_path += 1
        res = _simulate_graph(tf, tb, list(c_links), B, warmup_counts,
                              cb, in_f, in_b, no_overlap=no_overlap)
    if cache:
        _SIM_MEMO[key] = res
        if len(_SIM_MEMO) > SIM_MEMO_MAXSIZE:
            _SIM_MEMO.popitem(last=False)
    return res


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


def eta_load_balance(stage_compute: Sequence[float],
                     stage_peak_flops: Sequence[float]) -> float:
    """Eq. 19 with devices grouped per stage: eta = 1 - sum((td_max - td_i)
    * peak_i) / (td_max * sum(peak_i))."""
    td_max = max(stage_compute)
    if td_max <= 0:
        return 1.0
    num = sum((td_max - td) * p for td, p in zip(stage_compute, stage_peak_flops))
    den = td_max * sum(stage_peak_flops)
    return 1.0 - num / den


def ascii_timeline(res: SimResult, width: int = 100) -> str:
    """Paper Fig. 3-style timeline (one row per stage: F#, B#, '.')."""
    S = len(res.stage_compute)
    scale = width / res.makespan
    rows = []
    for i in range(S):
        row = [" "] * (width + 1)
        for (kind, j, st), d in res.dur.items():
            if st != i or kind not in ("F", "B"):
                continue
            s = int(res.start[(kind, j, st)] * scale)
            e = max(s + 1, int((res.start[(kind, j, st)] + d) * scale))
            ch = "f" if kind == "F" else "B"
            for x in range(s, min(e, width)):
                row[x] = ch
        rows.append(f"stage{i}|" + "".join(row))
    return "\n".join(rows)
