"""Pipeline-DAG discrete-event simulator (paper §4.2's DAG made executable).

Nodes: F/B compute per (microbatch, stage), CF/CB communication per
(microbatch, link); edges: per-stage issue order (the schedule under test),
per-link in-order transmission (full duplex), and microbatch data
dependencies.  Start times solve the longest-path recurrence
``s(v) >= s(u) + d(u)`` exactly — no sampling.

Supports classic 1F1B / Eager-1F1B / H-1F1B (any warm-up count vector) and a
``no_overlap`` mode (HexiScale-like synchronous sends that block compute).

Outputs makespan, per-stage busy/idle/comm breakdown (paper Fig. 8), overlap
ratio, and the eta load-balance metric (Eq. 19).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

Node = Tuple[str, int, int]  # (kind, microbatch, stage/link)


@dataclass
class SimResult:
    makespan: float
    start: Dict[Node, float]
    dur: Dict[Node, float]
    stage_compute: List[float]        # busy compute time per stage
    stage_comm_blocking: List[float]  # comm time charged to the stage (no_overlap)
    stage_idle: List[float]           # makespan - compute - blocking comm
    comm_total: float                 # total link-busy time (all links)
    comm_exposed: float               # comm time that delayed a compute op
    warmup_counts: List[int]
    stage_intra_comm: List[float] = field(default_factory=list)
    # exposed intra-op collective time per stage over the whole step (the
    # non-overlapped share of TP all-reduce / DP sync inside each F/B op)

    @property
    def overlap_ratio(self) -> float:
        if self.comm_total <= 0:
            return 1.0
        return max(0.0, 1.0 - self.comm_exposed / self.comm_total)

    def throughput(self, tokens_per_microbatch: int, n_microbatches: int) -> float:
        return tokens_per_microbatch * n_microbatches / self.makespan


def _stage_order(i: int, S: int, B: int, N_i: int) -> List[Tuple[str, int]]:
    """Issue order of compute ops on stage i: warm-up forwards, 1F1B steady
    alternation, cool-down backwards."""
    order: List[Tuple[str, int]] = []
    n_warm = min(N_i, B)
    for j in range(n_warm):
        order.append(("F", j))
    nf, nb = n_warm, 0
    while nb < B:
        order.append(("B", nb))
        nb += 1
        if nf < B:
            order.append(("F", nf))
            nf += 1
    return order


def simulate(t_f: Sequence[float], t_b: Sequence[float],
             c_links: Sequence[float], n_microbatches: int,
             warmup_counts: Sequence[int], *,
             no_overlap: bool = False,
             c_links_bwd: Optional[Sequence[float]] = None,
             intra_f: Optional[Sequence[float]] = None,
             intra_b: Optional[Sequence[float]] = None,
             intra_overlap: float = 0.0) -> SimResult:
    """Simulate one training step (B microbatches through S stages).

    ``intra_f``/``intra_b`` (optional, per stage, seconds): intra-operator
    collective time (TP all-reduce, amortized DP sync) *not* already folded
    into ``t_f``/``t_b``.  A fraction ``intra_overlap`` in [0, 1] hides under
    compute; the exposed remainder stretches every F/B op of that stage and
    is reported per stage in ``SimResult.stage_intra_comm``.
    """
    S, B = len(t_f), n_microbatches
    assert len(c_links) == S - 1 and len(warmup_counts) == S
    cb = list(c_links_bwd) if c_links_bwd is not None else list(c_links)
    assert 0.0 <= intra_overlap <= 1.0
    exposed_frac = 1.0 - intra_overlap
    in_f = [exposed_frac * x for x in intra_f] if intra_f is not None \
        else [0.0] * S
    in_b = [exposed_frac * x for x in intra_b] if intra_b is not None \
        else [0.0] * S
    t_f = [t + x for t, x in zip(t_f, in_f)]
    t_b = [t + x for t, x in zip(t_b, in_b)]

    dur: Dict[Node, float] = {}
    deps: Dict[Node, List[Node]] = {}

    def add(node: Node, d: float, *pre: Node):
        dur[node] = d
        deps[node] = [p for p in pre if p is not None]

    # compute nodes + stage order edges (comm inserted into stage order when
    # no_overlap: the send occupies the stage)
    for i in range(S):
        order = _stage_order(i, S, B, warmup_counts[i])
        prev: Optional[Node] = None
        for kind, j in order:
            node = (kind, j, i)
            add(node, t_f[i] if kind == "F" else t_b[i], prev)
            prev = node
            if no_overlap:
                if kind == "F" and i < S - 1 and c_links[i] > 0:
                    cf = ("CF", j, i)
                    add(cf, c_links[i], prev)
                    prev = cf
                if kind == "B" and i > 0 and cb[i - 1] > 0:
                    cbn = ("CB", j, i - 1)
                    add(cbn, cb[i - 1], prev)
                    prev = cbn

    # communication nodes (overlapped mode) + link in-order chains
    if not no_overlap:
        for i in range(S - 1):
            prev_cf: Optional[Node] = None
            prev_cb: Optional[Node] = None
            for j in range(B):
                cf = ("CF", j, i)
                add(cf, c_links[i], ("F", j, i), prev_cf)
                prev_cf = cf
                cbn = ("CB", j, i)
                add(cbn, cb[i], ("B", j, i + 1), prev_cb)
                prev_cb = cbn
    else:
        # deps from producer already in stage chains; nothing extra
        pass

    # data dependencies into compute nodes (no_overlap elides zero-cost comm
    # nodes, so fall back to the producing compute op directly)
    for i in range(S):
        for j in range(B):
            if i > 0:
                cf = ("CF", j, i - 1)
                deps[("F", j, i)].append(cf if cf in dur else ("F", j, i - 1))
            if i < S - 1:
                cbn = ("CB", j, i)
                deps[("B", j, i)].append(
                    cbn if cbn in dur else ("B", j, i + 1))
            else:
                deps[("B", j, i)].append(("F", j, i))

    # longest-path start times (Kahn topological order)
    indeg = {v: 0 for v in dur}
    succ: Dict[Node, List[Node]] = {v: [] for v in dur}
    for v, ps in deps.items():
        for p in ps:
            succ[p].append(v)
            indeg[v] += 1
    start: Dict[Node, float] = {}
    ready = [v for v, d in indeg.items() if d == 0]
    order_count = 0
    while ready:
        v = ready.pop()
        order_count += 1
        start[v] = max((start[p] + dur[p] for p in deps[v]), default=0.0)
        for s_ in succ[v]:
            indeg[s_] -= 1
            if indeg[s_] == 0:
                ready.append(s_)
    assert order_count == len(dur), "cycle in pipeline DAG"

    makespan = max(start[v] + dur[v] for v in dur)

    # --- breakdown ---------------------------------------------------------
    stage_compute = [0.0] * S
    stage_comm_blocking = [0.0] * S
    for (kind, j, i), d in dur.items():
        if kind in ("F", "B"):
            stage_compute[i] += d
        elif no_overlap:
            # charged to the sending stage (CF from i, CB from i+1)
            stage_comm_blocking[i if kind == "CF" else i + 1] += d
    stage_idle = [makespan - stage_compute[i] - stage_comm_blocking[i]
                  for i in range(S)]

    comm_total = sum(d for (k, _, _), d in dur.items() if k in ("CF", "CB"))
    # exposed comm: compute ops delayed specifically by their comm dependency
    comm_exposed = 0.0
    for v, ps in deps.items():
        if v[0] not in ("F", "B") or not ps:
            continue
        comm_ends = [start[p] + dur[p] for p in ps if p[0] in ("CF", "CB")]
        other_ends = [start[p] + dur[p] for p in ps if p[0] in ("F", "B")]
        if comm_ends:
            exposed = max(comm_ends) - max(other_ends, default=0.0)
            if exposed > 1e-12:
                comm_exposed += min(exposed, max(comm_ends) - (max(other_ends, default=0.0)))
    comm_exposed = min(comm_exposed, comm_total)

    # per-stage exposed intra-op collective time: every F and B op of stage i
    # carries its stretched share once per microbatch
    stage_intra = [B * (in_f[i] + in_b[i]) for i in range(S)]

    return SimResult(makespan, start, dur, stage_compute, stage_comm_blocking,
                     stage_idle, comm_total, comm_exposed,
                     list(warmup_counts), stage_intra)


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


def eta_load_balance(stage_compute: Sequence[float],
                     stage_peak_flops: Sequence[float]) -> float:
    """Eq. 19 with devices grouped per stage: eta = 1 - sum((td_max - td_i)
    * peak_i) / (td_max * sum(peak_i))."""
    td_max = max(stage_compute)
    if td_max <= 0:
        return 1.0
    num = sum((td_max - td) * p for td, p in zip(stage_compute, stage_peak_flops))
    den = td_max * sum(stage_peak_flops)
    return 1.0 - num / den


def ascii_timeline(res: SimResult, width: int = 100) -> str:
    """Paper Fig. 3-style timeline (one row per stage: F#, B#, '.')."""
    S = len(res.stage_compute)
    scale = width / res.makespan
    rows = []
    for i in range(S):
        row = [" "] * (width + 1)
        for (kind, j, st), d in res.dur.items():
            if st != i or kind not in ("F", "B"):
                continue
            s = int(res.start[(kind, j, st)] * scale)
            e = max(s + 1, int((res.start[(kind, j, st)] + d) * scale))
            ch = "f" if kind == "F" else "B"
            for x in range(s, min(e, width)):
                row[x] = ch
        rows.append(f"stage{i}|" + "".join(row))
    return "\n".join(rows)
