"""Serving steps: prefill (build KV cache / SSM state) + batched decode.

Sharding per shape cell (see ``parallel/sharding.py``):
  decode_32k  — batch over (pod?, data), KV heads over model;
  long_500k   — batch=1: KV-cache *sequence* sharded over every free axis
                (the distributed-decode layout; attention reduces over the
                sharded seq dim with XLA inserting the psum).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import build_model
from repro.models.common import activation_sharding
from repro.parallel import sharding as shd


def make_serve_step(cfg: ArchConfig, *, shape: ShapeSpec,
                    multi_pod: bool = False, use_pallas: bool = False,
                    greedy: bool = True, temperature: float = 1.0):
    """Returns serve_step -> (next_tokens (B,1), new_cache).

    ``greedy=True``: ``serve_step(params, cache, tokens, pos)``, argmax
    decoding.  ``greedy=False``: ``serve_step(params, cache, tokens, pos,
    rng)``, temperature sampling — the caller threads the PRNG key (split it
    per step; the step stays functional so it jits/shards identically)."""
    model = build_model(cfg, use_pallas=use_pallas)
    rules = shd.decode_act_rules(shape.global_batch, multi_pod=multi_pod)

    if greedy:
        def serve_step(params, cache, tokens, pos):
            with activation_sharding(rules):
                logits, cache = model.decode_step(params, cache, tokens, pos)
                nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            return nxt, cache
    else:
        if temperature <= 0.0:
            raise ValueError(
                f"sampling needs temperature > 0, got {temperature} "
                f"(use greedy=True for argmax decoding)")

        def serve_step(params, cache, tokens, pos, rng):
            with activation_sharding(rules):
                logits, cache = model.decode_step(params, cache, tokens, pos)
                scaled = logits[:, -1, :].astype(jnp.float32) / temperature
                nxt = jax.random.categorical(
                    rng, scaled, axis=-1)[:, None].astype(jnp.int32)
            return nxt, cache

    return serve_step, model, rules


def make_prefill_step(cfg: ArchConfig, *, multi_pod: bool = False,
                      use_pallas: bool = False):
    """Full-sequence forward (the prefill_32k cells): returns logits."""
    model = build_model(cfg, use_pallas=use_pallas)
    rules = shd.prefill_act_rules(multi_pod=multi_pod)

    def prefill_step(params, batch):
        with activation_sharding(rules):
            logits, _ = model.forward(params, batch)
        return logits

    return prefill_step, model, rules
