"""Serving launcher: prefill a batch of prompts, then batched decode.

Thin CLI over :func:`repro.api.generate` (greedy argmax by default;
``--sample --temperature T`` threads a PRNG key through the serve step).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \\
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sample", action="store_true",
                    help="temperature sampling instead of greedy argmax")
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()

    from repro import api

    api.warn_deprecated(
        "launch.serve",
        "repro.launch.serve is deprecated: call repro.api.generate() "
        "directly (same prefill + batched-decode path, one facade)")
    out = api.generate(
        args.arch, batch=args.batch, prompt_len=args.prompt_len,
        gen_tokens=args.gen, seed=args.seed, greedy=not args.sample,
        temperature=args.temperature, reduced=args.smoke, log_fn=print)
    print(f"[serve] sample: {out['tokens'][0, :16].tolist()}")


if __name__ == "__main__":
    main()
