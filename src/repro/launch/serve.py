"""Serving launcher: prefill a batch of prompts, then batched greedy decode.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \\
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.models.prefill import prefill


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)

    B, T = args.batch, args.prompt_len
    total = T + args.gen
    prompts = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.02 * jax.random.normal(
            rng, (B, cfg.n_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = 0.02 * jax.random.normal(
            rng, (B, cfg.enc_frames, cfg.d_model))

    t0 = time.perf_counter()
    last_logits, cache = jax.jit(
        lambda p, b: prefill(cfg, p, b, cache_len=total))(params, batch)
    jax.block_until_ready(last_logits)
    t_prefill = time.perf_counter() - t0
    print(f"[serve] prefill {B}x{T}: {t_prefill*1e3:.1f} ms")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(last_logits[:, -1:], axis=-1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for t in range(T, total):
        logits, cache = decode(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"[serve] generated {args.gen} tokens/seq x {B} seqs in "
          f"{dt*1e3:.1f} ms ({B*args.gen/dt:.1f} tok/s)")
    print(f"[serve] sample: {gen[0, :16].tolist()}")


if __name__ == "__main__":
    main()
