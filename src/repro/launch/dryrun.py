import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) on
the production meshes, record memory/cost/collective analyses.

The two lines above MUST run before any other import — jax locks the device
count at first initialization.  Smoke tests and benches see 1 device; only
the dry-run sees 512 placeholders.

Usage:
  python -m repro.launch.dryrun --arch minitron-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--jobs 4]

Each cell writes results/dryrun/<arch>_<shape>_<mesh>.json with:
  memory_analysis (per-device bytes), cost_analysis (per-device FLOPs/bytes),
  per-collective-type payload bytes parsed from the post-SPMD HLO.
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape, list_archs
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, cache_specs, input_specs, param_specs
from repro.models.common import activation_sharding
from repro.models.prefill import prefill
from repro.parallel import sharding as shd
from repro.serve.step import make_prefill_step, make_serve_step
from repro.train.optimizer import OptimizerConfig, OptState, make_optimizer
from repro.train.step import make_pipeline_train_step, make_train_step

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}:#\s/_.*-]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|"
                       r"f8e4m3fn|f8e5m2|c64|c128)\[([0-9,]*)\]")


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Sum per-device output payload bytes of each collective type from
    post-partitioning HLO text (async -start counted once, -done skipped)."""
    out: Dict[str, float] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[-1][:60]:
            continue
        lhs, kind = m.group(1), m.group(2)
        total = 0.0
        for dt, dims in _SHAPE_RE.findall(lhs):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0.0) + total
    return out


def _opt_shardings(mesh, pspecs_tree):
    to_ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
    return OptState(NamedSharding(mesh, P()), to_ns(pspecs_tree),
                    to_ns(pspecs_tree))


N_MICROBATCHES = int(os.environ.get("REPRO_NMB", "16"))
# perf-iteration knobs (hillclimb; see EXPERIMENTS.md §Perf)
ZERO1 = os.environ.get("REPRO_ZERO1", "1") == "1"      # shard opt states
FSDP_PARAMS = os.environ.get("REPRO_FSDP", "1") == "1"  # shard params over data
ACT_BF16 = os.environ.get("REPRO_ACT_BF16", "0") == "1"  # bf16 compute
FLAT_DP = os.environ.get("REPRO_FLATDP", "0") == "1"    # batch over both axes
MASTER_W = os.environ.get("REPRO_MASTER", "0") == "1"   # bf16 params + f32 master


def build_case(arch: str, shape_name: str, multi_pod: bool,
               n_microbatches: int = N_MICROBATCHES,
               global_batch_override: int = 0):
    """Returns (fn, args, in_shardings, mesh) ready for jit().lower()."""
    import dataclasses
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if global_batch_override:
        shape = dataclasses.replace(shape, global_batch=global_batch_override)
    mesh = make_production_mesh(multi_pod=multi_pod)
    opt_cfg = OptimizerConfig(state_dtype=jnp.bfloat16,
                              master_weights=MASTER_W)
    batch_structs = input_specs(cfg, shape)

    if shape.kind == "train":
        if multi_pod and cfg.family != "audio":
            # the paper's design: pipeline over the slow pod axis.
            # act_dtype=f32 works around an XLA *CPU* compiler abort
            # (AllReducePromotion aborts cloning a bf16 all-reduce produced
            # by the pipeline backward); on TPU the target act dtype is bf16
            # — pipeline activation-memory numbers here are 2x the target.
            train_step, staging, opt_init, sh = make_pipeline_train_step(
                cfg, opt_cfg, mesh=mesh, n_stages=2,
                n_microbatches=n_microbatches, abstract=True,
                act_dtype=jnp.float32)
            ptree = {"staged": staging.staged, "shared": staging.shared}
            opt_specs = {"staged": sh["staged_specs"],
                         "shared": sh["shared_specs"]}
            opt_struct = jax.eval_shape(opt_init, ptree)
            batch_sh = jax.tree.map(
                lambda x: NamedSharding(mesh, P("data", *([None] * (len(x.shape) - 1)))),
                batch_structs)
            # donate params/opt (in-place update semantics)
            args = (staging.staged, staging.shared, staging.consts,
                    opt_struct, batch_structs)
            staged_sh = shd.fitted_shardings(mesh, sh["staged_specs"],
                                             staging.staged)
            shared_sh = shd.fitted_shardings(mesh, sh["shared_specs"],
                                             staging.shared)
            opt_sh = OptState(
                NamedSharding(mesh, P()),
                {"staged": shd.fitted_shardings(mesh, sh["staged_specs"],
                                                opt_struct.mu["staged"]),
                 "shared": shd.fitted_shardings(mesh, sh["shared_specs"],
                                                opt_struct.mu["shared"])},
                {"staged": shd.fitted_shardings(mesh, sh["staged_specs"],
                                                opt_struct.nu["staged"]),
                 "shared": shd.fitted_shardings(mesh, sh["shared_specs"],
                                                opt_struct.nu["shared"])})
            in_sh = (staged_sh, shared_sh, sh["consts"], opt_sh, batch_sh)
            return train_step, args, in_sh, mesh, (0, 1, 3)
        # single-pod (or multi-pod DP for sub-1B audio): DP/FSDP + TP
        rules = shd.train_act_rules()
        if multi_pod:
            rules = dict(rules, batch=("pod", "data"), expert=("pod", "data"))
        if FLAT_DP:
            # pure data parallelism over the whole pod for the transformer
            # stack (no TP all-reduces); the LM head keeps vocab over
            # 'model' with its batch over 'data' so CE logits stay sharded
            rules = dict(rules, batch=(("pod", "data", "model") if multi_pod
                                       else ("data", "model")),
                         batch_head=("pod", "data") if multi_pod else "data",
                         heads=None, kv_heads=None, ff=None, vocab="model")
        if ACT_BF16:
            from repro.models.common import set_act_dtype
            set_act_dtype(jnp.bfloat16)
        pdtype = jnp.bfloat16 if MASTER_W else jnp.float32
        train_step, model, opt_init = make_train_step(
            cfg, opt_cfg, act_rules=rules, n_microbatches=n_microbatches,
            param_dtype=pdtype)
        pspecs = shd.param_pspecs(param_specs(cfg, param_dtype=pdtype))
        opt_pspecs = pspecs
        if FLAT_DP:
            # flat DP: no TP sharding of weights. FSDP on -> shard dim0 over
            # BOTH axes (FSDP-256, bf16 gathers); FSDP off -> fully replicate
            # (small models). ZeRO: opt states sharded over the axis pair.
            if FSDP_PARAMS:
                pspecs = jax.tree.map(
                    lambda sp: P(("data", "model"), *([None] * (len(sp) - 1)))
                    if len(sp) else sp, pspecs)
            else:
                pspecs = jax.tree.map(lambda sp: P(*([None] * len(sp))), pspecs)
            opt_pspecs = jax.tree.map(
                lambda sp: P(("data", "model"), *([None] * (len(sp) - 1)))
                if len(sp) else sp, opt_pspecs)
        elif not FSDP_PARAMS:
            # ZeRO-1 layout: params replicated over data (no per-microbatch
            # all-gather); optimizer states stay sharded over data
            pspecs = jax.tree.map(
                lambda sp: P(*[None if e == "data" else e for e in sp]),
                pspecs)
            opt_pspecs = jax.tree.map(
                lambda sp: P(*[("data" if e is None else e) if i == 0 else e
                               for i, e in enumerate(sp)]) if len(sp) else sp,
                opt_pspecs)
        pshard = shd.fitted_shardings(mesh, pspecs,
                                      param_specs(cfg, param_dtype=pdtype))
        opt_struct = jax.eval_shape(opt_init, param_specs(cfg, param_dtype=pdtype))
        if FLAT_DP:
            batch_axis = (("pod", "data", "model") if multi_pod
                          else ("data", "model"))
        else:
            batch_axis = ("pod", "data") if multi_pod else "data"
        batch_sh = jax.tree.map(
            lambda x: NamedSharding(mesh, P(batch_axis, *([None] * (len(x.shape) - 1)))),
            batch_structs)
        args = (param_specs(cfg, param_dtype=pdtype), opt_struct, batch_structs)
        opt_sh = OptState(
            NamedSharding(mesh, P()),
            shd.fitted_shardings(mesh, opt_pspecs, opt_struct.mu),
            shd.fitted_shardings(mesh, opt_pspecs, opt_struct.nu),
            (shd.fitted_shardings(mesh, opt_pspecs, opt_struct.master)
             if opt_struct.master is not None else None))
        in_sh = (pshard, opt_sh, batch_sh)
        return train_step, args, in_sh, mesh, (0, 1)

    pspecs = shd.param_pspecs(param_specs(cfg))
    pshard = shd.fitted_shardings(mesh, pspecs, param_specs(cfg))

    if shape.kind == "prefill":
        rules = shd.prefill_act_rules(multi_pod=multi_pod)

        def prefill_step(params, batch):
            with activation_sharding(rules):
                return prefill(cfg, params, batch)

        batch_axis = rules["batch"]
        batch_sh = jax.tree.map(
            lambda x: NamedSharding(mesh, P(batch_axis, *([None] * (len(x.shape) - 1)))),
            batch_structs)
        args = (param_specs(cfg), batch_structs)
        return prefill_step, args, (pshard, batch_sh), mesh, ()

    # decode
    serve_step, model, rules = make_serve_step(cfg, shape=shape,
                                               multi_pod=multi_pod)
    cache_structs = cache_specs(cfg, shape)
    cache_sh = shd.fitted_shardings(
        mesh, shd.cache_pspecs(cache_structs, rules), cache_structs)
    tok_axis = rules["batch"]
    tok_sh = NamedSharding(mesh, P(tok_axis, None))
    args = (param_specs(cfg), cache_structs,
            jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
    in_sh = (pshard, cache_sh, tok_sh, NamedSharding(mesh, P()))
    return serve_step, args, in_sh, mesh, (1,)  # donate the cache


def _analyze(compiled) -> Dict[str, Any]:
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    colls = collective_bytes(hlo)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "colls": colls, "hlo_chars": len(hlo)}


def _combine(base: Dict[str, Any], per_mb: Dict[str, Any], n_units: float
             ) -> Dict[str, Any]:
    """total = f(1) + (n_units - 1) * (f(2) - f(1)) per linear decomposition."""
    out = {}
    for key in ("flops", "bytes"):
        out[key] = base[key] + (n_units - 1) * max(per_mb[key] - base[key], 0.0)
    kinds = set(base["colls"]) | set(per_mb["colls"])
    out["colls"] = {
        k: base["colls"].get(k, 0.0) + (n_units - 1)
        * max(per_mb["colls"].get(k, 0.0) - base["colls"].get(k, 0.0), 0.0)
        for k in kinds}
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             out_dir: str = "results/dryrun",
             skip_analysis: bool = False) -> Dict[str, Any]:
    from repro import compat
    from repro.models import common as mcommon
    multi_pod = mesh_kind == "multi"
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "ok": False}
    t0 = time.time()
    try:
        # --- pass 1: production (scanned) — the compile proof + memory ------
        fn, args, in_sh, mesh, donate = build_case(arch, shape_name, multi_pod)
        with compat.set_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=in_sh,
                              donate_argnums=donate).lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        }
        raw = _analyze(compiled)
        rec["scanned_flops_per_device"] = raw["flops"]
        rec["ok"] = True

        # --- pass 2: analysis (unrolled) — exact FLOPs/collectives ----------
        # XLA counts while bodies once; unrolling + a linear (n_mb=1, n_mb=2)
        # decomposition recovers exact per-step totals (see models/common.py).
        if not skip_analysis:
            mcommon.set_unroll(True)
            try:
                if shape.kind == "train":
                    mb_seqs = max(1, shape.global_batch // N_MICROBATCHES)
                    a1 = _cell_analysis(arch, shape_name, multi_pod, 1, mb_seqs)
                    if os.environ.get("REPRO_FAST_ANALYSIS") == "1":
                        # single-pass: scale everything by n_mb (overcounts
                        # the once-per-step optimizer collectives ~params
                        # bytes x (n_mb-1); documented in EXPERIMENTS.md)
                        tot = {"flops": a1["flops"] * N_MICROBATCHES,
                               "bytes": a1["bytes"] * N_MICROBATCHES,
                               "colls": {k: v * N_MICROBATCHES
                                         for k, v in a1["colls"].items()}}
                        rec["analysis_mode"] = "scaled-1pass"
                    else:
                        a2 = _cell_analysis(arch, shape_name, multi_pod, 2,
                                            2 * mb_seqs)
                        # pipeline slots = n_mb+S-1; grad-accum units = n_mb
                        tot = _combine(a1, a2, N_MICROBATCHES)
                else:
                    tot = _cell_analysis(arch, shape_name, multi_pod, 1, 0)
                rec["flops_per_device"] = tot["flops"]
                rec["bytes_per_device"] = tot["bytes"]
                rec["collectives"] = tot["colls"]
                rec["collective_bytes_per_device"] = float(
                    sum(tot["colls"].values()))
            finally:
                mcommon.set_unroll(False)
    except Exception as e:  # noqa
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        rec["ok"] = False
    rec["total_s"] = round(time.time() - t0, 2)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_kind}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def _cell_analysis(arch, shape_name, multi_pod, n_mb, global_batch):
    from repro import compat
    from repro.models import common as mcommon
    fn, args, in_sh, mesh, donate = build_case(
        arch, shape_name, multi_pod, n_microbatches=n_mb,
        global_batch_override=global_batch)
    with compat.set_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=in_sh,
                           donate_argnums=donate).lower(*args).compile()
    return _analyze(compiled)


def all_cells(mesh_kinds=("single", "multi")) -> List[Tuple[str, str, str]]:
    cells = []
    for arch in list_archs(assigned_only=True):
        cfg = get_config(arch)
        for shape in cfg.shapes():
            for mk in mesh_kinds:
                cells.append((arch, shape.name, mk))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-analysis", action="store_true",
                    help="production compile + memory only (no unrolled "
                         "cost passes) — used for multi-pod cells whose "
                         "roofline is out of scope")
    args = ap.parse_args()

    if not args.all:
        kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        for mk in kinds:
            rec = run_cell(args.arch, args.shape, mk, args.out,
                           skip_analysis=args.skip_analysis)
            status = "OK" if rec["ok"] else f"FAIL: {rec.get('error')}"
            print(f"[{args.arch} x {args.shape} x {mk}] {status} "
                  f"({rec['total_s']}s)")
            if rec.get("ok"):
                print(f"  peak/device: {rec['memory']['peak_per_device']/2**30:.2f} GiB, "
                      f"flops/device: {rec['flops_per_device']:.3e}, "
                      f"collective B/device: {rec['collective_bytes_per_device']:.3e}")
        return

    # orchestrate: one subprocess per cell (isolated compile memory)
    kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = all_cells(tuple(kinds))
    if not args.force:
        cells = [c for c in cells if not os.path.exists(
            os.path.join(args.out, f"{c[0]}_{c[1]}_{c[2]}.json"))]
    print(f"{len(cells)} cells to run, {args.jobs} parallel jobs", flush=True)
    procs: List[Tuple[subprocess.Popen, Tuple, int]] = []
    pending = [(c, 0) for c in cells]
    failures = []
    MAX_RETRY = 2  # XLA CPU occasionally F-crashes under concurrent compiles
    while pending or procs:
        while pending and len(procs) < args.jobs:
            cell, tries = pending.pop(0)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", cell[0], "--shape", cell[1], "--mesh", cell[2],
                   "--out", args.out]
            if args.skip_analysis or cell[2] == "multi":
                # roofline is single-pod scope; multi-pod cells only need
                # the compile proof + memory analysis
                cmd.append("--skip-analysis")
            procs.append((subprocess.Popen(
                cmd, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL),
                cell, tries))
        time.sleep(2)
        still = []
        for p, cell, tries in procs:
            if p.poll() is None:
                still.append((p, cell, tries))
                continue
            path = os.path.join(args.out, f"{cell[0]}_{cell[1]}_{cell[2]}.json")
            ok, crashed = False, p.returncode != 0
            if os.path.exists(path):
                with open(path) as f:
                    ok = json.load(f).get("ok", False)
            if not ok and (crashed or not os.path.exists(path)) \
                    and tries < MAX_RETRY:
                print(f"  retry {cell} (exit {p.returncode})", flush=True)
                pending.append((cell, tries + 1))
            elif not ok:
                failures.append(cell)
                print(f"  done {cell} -> FAIL", flush=True)
            else:
                print(f"  done {cell} -> OK", flush=True)
        procs = still
    print(f"all cells done, failures={failures}", flush=True)


if __name__ == "__main__":
    main()
