"""Training launcher (deprecated shim).

This entry point predates the ``repro.api`` facade; it now delegates to the
same code path as

  PYTHONPATH=src python -m repro train --arch gemma-2b --smoke \\
      --steps 200 --batch 8 --seq 128

and warns once.  ``--smoke`` uses the reduced same-family config
(CPU-runnable); otherwise the full config is built (real hardware).
"""
from __future__ import annotations

import argparse

from repro import api
from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import TrainerConfig


def main() -> None:
    api.warn_deprecated(
        "launch.train",
        "repro.launch.train is deprecated: use `python -m repro train` "
        "(the repro.api facade) instead")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--data-kind", default="markov",
                    choices=["markov", "zipf", "uniform"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    print(f"[train] {cfg.arch_id}: {cfg.param_count() / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch}x{args.seq}")

    harp_cfg = api.HarpConfig(
        seq_len=args.seq, global_batch=args.batch,
        trainer=TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                              ckpt_every=args.ckpt_every),
        data=DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                        global_batch=args.batch, seed=args.seed,
                        kind=args.data_kind))
    out = api.fit(cfg, harp_cfg, n_microbatches=args.microbatches,
                  seed=args.seed,
                  optimizer=OptimizerConfig(lr=args.lr, warmup_steps=20,
                                            total_steps=args.steps))
    hist = out["history"]
    if hist:
        print(f"[train] loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
              f"over {out['final_step']} steps")


if __name__ == "__main__":
    main()
