"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \\
      --steps 200 --batch 8 --seq 128

``--smoke`` uses the reduced same-family config (CPU-runnable); otherwise the
full config is built (real hardware).  The launcher wires: config -> model ->
optimizer -> (optional HAPT plan for the cluster) -> jitted train step ->
fault-tolerant Trainer loop (auto-resume, atomic checkpoints).
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.train.optimizer import OptimizerConfig
from repro.train.step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--data-kind", default="markov",
                    choices=["markov", "zipf", "uniform"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()

    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=20,
                              total_steps=args.steps)
    train_step, model, opt_init = make_train_step(
        cfg, opt_cfg, n_microbatches=args.microbatches)
    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = opt_init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.arch_id}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch}x{args.seq}")

    step_fn = jax.jit(train_step)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch, seed=args.seed,
                          kind=args.data_kind)
    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every),
        data_cfg, step_fn,
        {"params": params, "opt_state": opt_state})
    out = trainer.run()
    hist = out["history"]
    if hist:
        print(f"[train] loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
              f"over {out['final_step']} steps")


if __name__ == "__main__":
    main()
