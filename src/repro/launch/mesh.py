"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  Single pod: v5e-256 as (16, 16) with axes
(data, model).  Multi-pod: (2, 16, 16) with a leading ``pod`` axis; per the
paper's design the ``pod`` axis carries only inter-op (pipeline) traffic.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (2,2,2) on 8 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))
