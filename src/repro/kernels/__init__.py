from repro.kernels import ops
