"""Pallas-TPU API compatibility across jax versions.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
container's 0.4.x has only the old name.  Kernels call this helper instead of
either class so they run on both."""
from __future__ import annotations


def compiler_params(**kwargs):
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
