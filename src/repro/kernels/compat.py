"""Deprecated location: the Pallas-TPU compiler-params shim moved to
``repro.compat`` (one home for every jax-version shim).  This module
re-exports it so existing kernel call sites keep working."""
from __future__ import annotations

from repro.compat import compiler_params  # noqa: F401  (re-export)
