"""Fused RMSNorm Pallas TPU kernel (row-blocked, f32 statistics).

Simple but real: one HBM read + one write per element instead of the
unfused norm's multiple passes; rows are tiled (block_rows, D) into VMEM,
stats accumulate in f32 regardless of the input dtype.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                  # (rows, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)[None]
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-6,
            block_rows: int = 128, interpret: bool = False) -> jnp.ndarray:
    """x: (rows, D) — callers flatten leading dims; w: (D,)."""
    rows, D = x.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, w)
