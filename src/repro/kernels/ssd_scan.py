"""Mamba-2 SSD intra-chunk Pallas TPU kernel.

The chunked SSD algorithm splits the sequence into chunks of length Q: the
cross-chunk state recurrence is linear (handled by a cheap ``lax.scan`` in
``models/ssm.py``) while the *intra-chunk* term is quadratic in Q and
dominates compute — that term is this kernel.

Per (batch, chunk, head) grid cell it computes::

    CB[q, j]  = sum_n C[q, n] * B[j, n]                      (Q x Q matmul)
    L[q, j]   = exp(cum[q] - cum[j]) for j <= q else 0       (decay matrix)
    M         = CB * L * dt[j]
    y[q, p]   = sum_j M[q, j] * x[j, p]                      (Q x P matmul)

TPU adaptation: chunk length Q defaults to 256 and the state dim N is 128 on
mamba2-2.7b, so both matmuls are MXU-aligned; x/B/C tiles are staged in VMEM
by the BlockSpecs.  The head dim is the innermost *parallel* grid axis —
there is no cross-cell state, so no scratch is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _ssd_intra_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, y_ref, *, chunk: int):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)       # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)        # (Q,)
    cum = cum_ref[0, 0, :, 0].astype(jnp.float32)      # (Q,)
    b = b_ref[0, 0].astype(jnp.float32)                # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)                # (Q, N)

    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))        # (Q, Q)
    dec = cum[:, None] - cum[None, :]                                # (Q, Q)
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    l = jnp.where(kj <= qi, jnp.exp(dec), 0.0)
    m = cb * l * dt[None, :]
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())))          # (Q, P)
    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_intra(xc: jnp.ndarray, dtc: jnp.ndarray, cum: jnp.ndarray,
              Bc: jnp.ndarray, Cc: jnp.ndarray, *,
              interpret: bool = False) -> jnp.ndarray:
    """Intra-chunk SSD term.

    xc: (B, nc, Q, H, P); dtc, cum: (B, nc, Q, H); Bc, Cc: (B, nc, Q, N).
    Returns (B, nc, Q, H, P) float32."""
    Bsz, nc, Q, H, P = xc.shape
    N = Bc.shape[-1]
    kernel = functools.partial(_ssd_intra_kernel, chunk=Q)
    return pl.pallas_call(
        kernel,
        grid=(Bsz, nc, H),
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, nc, Q, H, P), jnp.float32),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(xc, dtc, cum, Bc, Cc)
