"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import attention_ref
from repro.models.common import rms_norm
from repro.models.ssm import ssd_intra_ref


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Model layout: q (B, T, H, D); k, v (B, S, KV, D). -> (B, T, H, D)."""
    return attention_ref(q, k, v, causal=causal, window=window)


def ssd_intra_oracle(xc, dtc, cum, Bc, Cc):
    """Same contract as kernels.ssd_scan.ssd_intra (f32 output)."""
    return ssd_intra_ref(xc, dtc, cum, Bc, Cc).astype(jnp.float32)


def rmsnorm_ref(x, w, *, eps: float = 1e-6):
    return rms_norm(x, w, eps)
