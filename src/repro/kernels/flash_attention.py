"""Blocked (flash) attention Pallas TPU kernels: forward + backward.

TPU adaptation notes (vs. the CUDA flash-attention algorithm):
  - Tiling targets VMEM (not shared memory): BlockSpecs stage (block_q, D) /
    (block_k, D) tiles HBM->VMEM; the online-softmax running stats live in
    VMEM scratch across the innermost (kv) grid dimension.
  - Block sizes default to 128 so the (bq, bk) score matmul and the
    (bq, D) accumulate matmul are MXU-aligned (128x128 systolic tiles).
  - The kv grid dimension is innermost ("arbitrary" semantics) so scratch
    accumulators persist across it; batch/head/q dims are parallel.
  - GQA is handled in the BlockSpec index_map (kv head = q head // rep) —
    no materialized head repetition in HBM.

Layout: all kernels operate on (B, H, T, D) arrays (wrappers in ``ops.py``
transpose from the model's (B, T, H, D)).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, window: int,
                q_len: int, kv_len: int, block_q: int, block_k: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                  # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq, bk)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = (q_pos < q_len) & (k_pos < kv_len)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]                                 # (bq,)
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(m_new == NEG_INF, 0.0, m_new)
    p = jnp.exp(s - m_safe[:, None])                     # masked -> exp(-inf)=0
    alpha = jnp.where(m_prev == NEG_INF, 0.0, jnp.exp(m_prev - m_safe))

    l_new = alpha * l_scr[:, 0] + jnp.sum(p, axis=-1)
    acc_new = alpha[:, None] * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))

    m_scr[...] = m_new[:, None]
    l_scr[...] = l_new[:, None]
    acc_scr[...] = acc_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[:, 0]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse = jnp.where(l == 0.0, NEG_INF, m_scr[:, 0] + jnp.log(l_safe))
        lse_ref[0, 0] = lse.astype(lse_ref.dtype)


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q: (B, H, Tq, D); k, v: (B, KV, Tk, D). Returns (out, lse).

    Tq/Tk may be non-multiples of the block sizes: inputs are zero-padded
    to block multiples here and the padded tail is excluded by the
    q_len/kv_len masks, so arbitrary shapes work."""
    B, H, Tq, D = q.shape
    KV, Tk = k.shape[1], k.shape[2]
    rep = H // KV
    pad_q = (-Tq) % block_q
    pad_k = (-Tk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq, nk = (Tq + pad_q) // block_q, (Tk + pad_k) // block_k
    scale = D ** -0.5

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        q_len=Tq, kv_len=Tk, block_q=block_q, block_k=block_k, nk=nk)

    out, lse = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((B, H, Tq + pad_q), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denom
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out, lse = out[:, :, :Tq], lse[:, :, :Tq]
    return out, lse


# ---------------------------------------------------------------------------
# Backward: dq kernel (grid over q blocks; kv innermost) and
#           dkv kernel (grid over kv blocks; q innermost)
# ---------------------------------------------------------------------------


def _mask_block(iq, ik, *, causal, window, q_len, kv_len, block_q, block_k):
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = (q_pos < q_len) & (k_pos < kv_len)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (k_pos > q_pos - window)
    return mask


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, dq_scr, *,
               scale, causal, window, q_len, kv_len, block_q, block_k, nk):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)              # (bq,)
    delta = delta_ref[0, 0].astype(jnp.float32)          # (bq,)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    mask = _mask_block(iq, ik, causal=causal, window=window, q_len=q_len,
                       kv_len=kv_len, block_q=block_q, block_k=block_k)
    lse_safe = jnp.where(lse == NEG_INF, 0.0, lse)
    p = jnp.where(mask, jnp.exp(s - lse_safe[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))   # (bq, bk)
    ds = p * (dp - delta[:, None]) * scale
    dq_scr[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())))

    @pl.when(ik == nk - 1)
    def _finalize():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *,
                scale, causal, window, q_len, kv_len, block_q, block_k, nq):
    ik, iq = pl.program_id(2), pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)
    delta = delta_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    mask = _mask_block(iq, ik, causal=causal, window=window, q_len=q_len,
                       kv_len=kv_len, block_q=block_q, block_k=block_k)
    lse_safe = jnp.where(lse == NEG_INF, 0.0, lse)
    p = jnp.where(mask, jnp.exp(s - lse_safe[:, None]), 0.0)    # (bq, bk)
    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None]) * scale                       # (bq, bk)
    dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(iq == nq - 1)
    def _finalize():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, out, lse, do, *, causal: bool, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """Returns (dq, dk, dv) with dk/dv in expanded-head layout (B, H, Tk, D);
    the ops.py wrapper reduces over GQA groups."""
    B, H, Tq, D = q.shape
    KV, Tk = k.shape[1], k.shape[2]
    rep = H // KV
    nq, nk = Tq // block_q, Tk // block_k
    scale = D ** -0.5
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal, window=window,
                          q_len=Tq, kv_len=Tk, block_q=block_q, block_k=block_k,
                          nk=nk),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal, window=window,
                          q_len=Tq, kv_len=Tk, block_q=block_q, block_k=block_k,
                          nq=nq),
        grid=(B, H, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, ik, iq: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, iq: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, iq: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, ik, iq: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, ik, iq: (b, h, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, ik, iq: (b, h, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, iq: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, iq: (b, h, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Tk, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, Tk, D), q.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, D), jnp.float32),
                        pltpu.VMEM((block_k, D), jnp.float32)],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
