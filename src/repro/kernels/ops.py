"""Jit'd public wrappers around the Pallas kernels.

Handles: model<->kernel layout transposes, padding to block multiples,
GQA gradient reduction, custom_vjp wiring, and interpret-mode dispatch
(``interpret=None`` -> auto: Python interpretation of the kernel body on
non-TPU backends, compiled Mosaic on TPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import compat
from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


# ---------------------------------------------------------------------------
# Tuned-block registry
#
# ``repro.kbench.autotune`` sweeps tiling grids per (device, op, shape) and
# installs the winners here; entry points called with block sizes of ``None``
# resolve through this table (exact shape, else nearest same-rank shape by
# log-distance) and fall back to the defaults when nothing is installed.
# Shape keys per op: flash_attention (B, T, S, H, KV, D); rmsnorm (rows, D).
# ---------------------------------------------------------------------------

_TUNED_BLOCKS: dict = {}


def set_tuned_blocks(op: str, shape, blocks) -> None:
    _TUNED_BLOCKS.setdefault(op, {})[tuple(int(d) for d in shape)] = tuple(
        int(b) for b in blocks)


def clear_tuned_blocks(op: Optional[str] = None) -> None:
    if op is None:
        _TUNED_BLOCKS.clear()
    else:
        _TUNED_BLOCKS.pop(op, None)


def tuned_blocks(op: str, shape):
    """Best-known block config for ``op`` at ``shape`` (None if untuned)."""
    entries = _TUNED_BLOCKS.get(op)
    if not entries:
        return None
    shape = tuple(int(d) for d in shape)
    hit = entries.get(shape)
    if hit is not None:
        return hit
    import math
    same_rank = [s for s in entries if len(s) == len(shape)]
    if not same_rank:
        return None

    def dist(s):
        return sum(abs(math.log2(max(a, 1)) - math.log2(max(b, 1)))
                   for a, b in zip(s, shape))

    best = min(same_rank, key=lambda s: (dist(s), s))
    return entries[best]


# ---------------------------------------------------------------------------
# Flash attention (custom_vjp)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, block_q, block_k, interpret):
    out, _ = _flash_fwd(q, k, v, causal, window, block_q, block_k, interpret)
    return out


def _flash_fwd(q, k, v, causal, window, block_q, block_k, interpret):
    # model layout (B, T, H, D) -> kernel layout (B, H, T, D)
    B, T, H, D = q.shape
    S = k.shape[1]
    qt = _pad_to(jnp.transpose(q, (0, 2, 1, 3)), 2, block_q)
    kt = _pad_to(jnp.transpose(k, (0, 2, 1, 3)), 2, block_k)
    vt = _pad_to(jnp.transpose(v, (0, 2, 1, 3)), 2, block_k)
    # real (unpadded) lengths drive the kernel masks
    ot, lse = _pallas_fwd(qt, kt, vt, causal, window, T, S, block_q, block_k,
                          interpret)
    out = jnp.transpose(ot[:, :, :T], (0, 2, 1, 3))
    return out, (q, k, v, ot, lse)


def _pallas_fwd(qt, kt, vt, causal, window, q_len, kv_len, block_q, block_k,
                interpret):
    kernel = functools.partial(
        _fa._fwd_kernel, scale=qt.shape[-1] ** -0.5, causal=causal,
        window=window, q_len=q_len, kv_len=kv_len, block_q=block_q,
        block_k=block_k, nk=kt.shape[2] // block_k)
    import jax.experimental.pallas as pl
    from jax.experimental.pallas import tpu as pltpu
    B, H, Tq, D = qt.shape
    rep = H // kt.shape[1]
    return pl.pallas_call(
        kernel,
        grid=(B, H, Tq // block_q, kt.shape[2] // block_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h // rep, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(qt.shape, qt.dtype),
            jax.ShapeDtypeStruct((B, H, Tq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)


def _flash_bwd(causal, window, block_q, block_k, interpret, res, g):
    q, k, v, ot, lse = res
    B, T, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    rep = H // KV
    qt = _pad_to(jnp.transpose(q, (0, 2, 1, 3)), 2, block_q)
    kt = _pad_to(jnp.transpose(k, (0, 2, 1, 3)), 2, block_k)
    vt = _pad_to(jnp.transpose(v, (0, 2, 1, 3)), 2, block_k)
    dot = _pad_to(jnp.transpose(g, (0, 2, 1, 3)), 2, block_q)
    dq, dk, dv = _fa.flash_attention_bwd(
        qt, kt, vt, ot, lse, dot, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)
    dq = jnp.transpose(dq[:, :, :T], (0, 2, 1, 3))
    # reduce expanded heads back to KV groups
    dk = dk[:, :, :S].reshape(B, KV, rep, S, D).sum(axis=2)
    dv = dv[:, :, :S].reshape(B, KV, rep, S, D).sum(axis=2)
    dk = jnp.transpose(dk, (0, 2, 1, 3)).astype(k.dtype)
    dv = jnp.transpose(dv, (0, 2, 1, 3)).astype(v.dtype)
    return dq.astype(q.dtype), dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: Optional[int] = None,
                    block_k: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """Flash attention in model layout. q: (B, T, H, D); k, v: (B, S, KV, D).

    ``block_q``/``block_k`` of None resolve through the tuned-block registry
    (see ``set_tuned_blocks``) and default to 128 when untuned."""
    if block_q is None or block_k is None:
        B, T, H, D = q.shape
        S, KV = k.shape[1], k.shape[2]
        tuned = tuned_blocks("flash_attention", (B, T, S, H, KV, D))
        tq, tk = tuned if tuned else (128, 128)
        block_q = tq if block_q is None else block_q
        block_k = tk if block_k is None else block_k
    return _flash(q, k, v, causal, window, block_q, block_k,
                  _auto_interpret(interpret))


# ---------------------------------------------------------------------------
# SSD intra-chunk
# ---------------------------------------------------------------------------


def ssd_intra(xc, dtc, cum, Bc, Cc, *, interpret: Optional[bool] = None):
    """Differentiable via recomputation (the term is a closed-form polynomial
    of its inputs; jax.grad falls back to the jnp oracle under the hood)."""
    interpret = _auto_interpret(interpret)

    @jax.custom_vjp
    def call(xc, dtc, cum, Bc, Cc):
        return _ssd.ssd_intra(xc, dtc, cum, Bc, Cc, interpret=interpret)

    def fwd(xc, dtc, cum, Bc, Cc):
        return call(xc, dtc, cum, Bc, Cc), (xc, dtc, cum, Bc, Cc)

    def bwd(res, g):
        from repro.kernels.ref import ssd_intra_oracle
        _, vjp = jax.vjp(ssd_intra_oracle, *res)
        return vjp(g)

    call.defvjp(fwd, bwd)
    return call(xc, dtc, cum, Bc, Cc)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: Optional[int] = None,
            interpret: Optional[bool] = None):
    """x: (..., D) any leading dims; w: (D,).

    ``block_rows`` of None resolves through the tuned-block registry; either
    way the block is halved until it divides the row count (the kernel
    requires exact tiling over rows)."""
    interpret = _auto_interpret(interpret)
    shape = x.shape
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, shape[-1])
    if block_rows is None:
        tuned = tuned_blocks("rmsnorm", (rows, shape[-1]))
        block = tuned[0] if tuned else 128
    else:
        block = block_rows
    block = max(1, min(block, rows))
    while rows % block and block > 1:
        block //= 2
    out = _rn.rmsnorm(x2, w, eps=eps, block_rows=block, interpret=interpret)
    return out.reshape(shape)
