"""Seeded fault injection for the elastic runtime.

Two halves, mirroring where faults strike:

- :mod:`repro.chaos.faults` — *fleet* faults: correlated rack failures,
  flapping nodes, slow-then-dead stragglers, WAN brownouts, and seeded
  event-storm generators.  Everything lowers onto the typed events in
  ``runtime.events`` (``apply_event`` is untouched) and round-trips
  through JSON so a storm that broke the controller ships as a fixture.
- :mod:`repro.chaos.inject` — *infrastructure* faults: deterministic
  injection at the three state-bearing seams (planner calls, migration
  transfers, checkpoint writes) via :class:`ChaosConfig` /
  :class:`FaultInjector`.

``HarpConfig.chaos = None`` (the default) keeps every seam fault-free and
the controller bit-identical to the unchaosed runtime.
"""
from repro.chaos.faults import (
    chaos_storm, correlated_failure, event_from_dict, event_to_dict,
    flapping_node, slow_then_dead, trace_from_json, trace_to_json,
    wan_brownout,
)
from repro.chaos.inject import ChaosConfig, FaultInjector

__all__ = [
    "ChaosConfig", "FaultInjector", "chaos_storm", "correlated_failure",
    "event_from_dict", "event_to_dict", "flapping_node", "slow_then_dead",
    "trace_from_json", "trace_to_json", "wan_brownout",
]
