"""Deterministic failure injection at the runtime's state-bearing seams.

:class:`ChaosConfig` is the JSON-native knob block that rides on
``HarpConfig.chaos`` (schema v7); :class:`FaultInjector` turns it into
per-seam decision streams.  Three seams, matching where real jobs lose
state:

- **planner calls** — a search can time out (wall clock) or come back
  infeasible; the controller's degraded ladder must absorb both.
- **migration transfers** — any individual transfer of a live migration
  can fail; ``migrate.apply`` retries with exponential backoff, falls
  back to the checkpoint image per transfer, and aborts (rolling back to
  the old plan) when the budget is exhausted.
- **checkpoint writes** — a write can die mid-stream (partial write) or
  at fsync; the atomic-rename protocol must keep the previous checkpoint
  readable.

Determinism contract: each seam draws from its own ``random.Random``
stream seeded from ``(seed, seam name)``, so outcomes depend only on the
config and the *order of calls on that seam* — adding checkpoint writes
never changes which transfer fails.
"""
from __future__ import annotations

import random
from dataclasses import asdict, dataclass
from typing import Dict, Optional

_SEAMS = ("planner", "transfer", "ckpt", "migration")


@dataclass
class ChaosConfig:
    """Per-seam fault probabilities (0 disables a seam; all-zero = the
    off state, bit-identical to ``chaos=None``) plus retry shaping."""
    seed: int = 0
    p_planner_timeout: float = 0.0    # search exceeds its deadline
    p_planner_infeasible: float = 0.0  # search returns "no feasible strategy"
    p_transfer_failure: float = 0.0   # one migration transfer attempt fails
    p_ckpt_write_failure: float = 0.0  # checkpoint write dies mid-stream
    planner_timeout_s: float = 1.0    # wall clock a timed-out search burned
    max_transfer_retries: int = 3     # per-transfer attempts before fallback
    transfer_backoff_s: float = 0.05  # first retry's backoff
    transfer_backoff_mult: float = 2.0

    def to_dict(self) -> Dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "ChaosConfig":
        return cls(**d)


class FaultInjector:
    """Seeded per-seam fault streams.  Counters under ``injected`` record
    how many faults each seam actually fired (for audit / benchmarks)."""

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self._rng = {seam: random.Random(f"{cfg.seed}:{seam}")
                     for seam in _SEAMS}
        self.injected: Dict[str, int] = {seam: 0 for seam in _SEAMS}

    def _fire(self, seam: str, p: float) -> bool:
        if p <= 0:
            return False
        hit = self._rng[seam].random() < p
        if hit:
            self.injected[seam] += 1
        return hit

    # -- planner seam -------------------------------------------------------
    def planner_fault(self) -> Optional[str]:
        """Draw once per planner call: ``"timeout"``, ``"infeasible"`` or
        None.  A single draw decides both (timeout checked first), so each
        planner call consumes exactly one stream element."""
        r = self._rng["planner"].random()
        if self.cfg.p_planner_timeout > 0 and r < self.cfg.p_planner_timeout:
            self.injected["planner"] += 1
            return "timeout"
        if self.cfg.p_planner_infeasible > 0 and \
                r < self.cfg.p_planner_timeout + self.cfg.p_planner_infeasible:
            self.injected["planner"] += 1
            return "infeasible"
        return None

    # -- migration-transfer seam --------------------------------------------
    def transfer_fails(self) -> bool:
        """One draw per transfer *attempt* (retries re-draw)."""
        return self._fire("transfer", self.cfg.p_transfer_failure)

    def transfer_fault_fn(self):
        """Adapter matching ``migrate.apply.apply_migration``'s
        ``fault_fn(transfer, attempt) -> bool`` hook."""
        return lambda transfer, attempt: self.transfer_fails()

    # -- checkpoint-write seam ----------------------------------------------
    def ckpt_write_fault(self) -> Optional[str]:
        """Draw once per checkpoint write: ``"partial"`` (die mid-stream),
        ``"fsync"`` (die after the payload, before the atomic rename), or
        None.  Matches ``checkpoint.ckpt.set_write_fault``'s contract."""
        if not self._fire("ckpt", self.cfg.p_ckpt_write_failure):
            return None
        return "partial" if self._rng["ckpt"].random() < 0.5 else "fsync"

    def stats(self) -> Dict[str, int]:
        return dict(self.injected)
