"""Fleet fault models and storm generators.

Every fault here *lowers onto the existing typed events* in
:mod:`repro.runtime.events` — a correlated rack failure is one
``NodeFailure`` with the rack's blast radius, a flapping node is an
alternating fail/rejoin sequence, a WAN brownout is a ramp of
``BandwidthShift`` s with a scheduled recovery — so ``apply_event`` and
every consumer of :class:`EventTrace` work unchanged.  The generators are
seeded and deterministic, and traces round-trip through JSON
(:func:`trace_to_json` / :func:`trace_from_json`) so a storm that broke
the controller once ships as a regression fixture forever.

Units: steps are training steps, bandwidths bytes/s, efficiencies are
absolute multipliers on device spec.
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Dict, List, Optional

from repro.core.cluster import (
    HeteroCluster, SubCluster, subcluster_from_dict, subcluster_index,
)
from repro.runtime.events import (
    BandwidthShift, ClusterEvent, EventTrace, NodeFailure, NodeJoin,
    Preemption, Straggler,
)

TRACE_SCHEMA = 1

_EVENT_TYPES = {cls.__name__: cls for cls in
                (NodeFailure, NodeJoin, BandwidthShift, Straggler,
                 Preemption)}


# ---------------------------------------------------------------------------
# Event / trace (de)serialization
# ---------------------------------------------------------------------------


def event_to_dict(e: ClusterEvent) -> Dict:
    """One typed event as JSON-native data, tagged with its type name
    (``SubCluster`` templates serialize as full specs)."""
    name = type(e).__name__
    if name not in _EVENT_TYPES:
        raise TypeError(f"unknown cluster event {e!r}")
    d = json.loads(json.dumps(dataclasses.asdict(e)))
    d["type"] = name
    return d


def event_from_dict(d: Dict) -> ClusterEvent:
    d = dict(d)
    cls = _EVENT_TYPES[d.pop("type")]
    if d.get("template") is not None:
        d["template"] = subcluster_from_dict(d["template"])
    return cls(**d)


def trace_to_json(trace: EventTrace, indent: Optional[int] = None) -> str:
    """Lossless trace serialization.  The emitted event list is the
    *materialized* one (Preemption returns already expanded), flagged so
    deserialization doesn't expand them a second time."""
    return json.dumps({
        "schema": TRACE_SCHEMA,
        "materialized": True,
        "events": [event_to_dict(e) for e in trace.events],
    }, indent=indent)


def trace_from_json(s: str) -> EventTrace:
    d = json.loads(s)
    if d.get("schema") != TRACE_SCHEMA:
        raise ValueError(
            f"unsupported trace schema {d.get('schema')!r} "
            f"(expected {TRACE_SCHEMA})")
    return EventTrace([event_from_dict(ed) for ed in d["events"]],
                      materialized=bool(d.get("materialized", True)))


# ---------------------------------------------------------------------------
# Fault models — each returns a list of typed events (compose freely)
# ---------------------------------------------------------------------------


def _resolve_sub(cluster: HeteroCluster,
                 subcluster: Optional[str]) -> SubCluster:
    if subcluster is None:
        # default blast target: the largest pool (worst case for the plan)
        return max(cluster.subclusters, key=lambda s: s.n_nodes)
    return cluster.subclusters[subcluster_index(cluster, subcluster)]


def correlated_failure(cluster: HeteroCluster, *, step: int,
                       subcluster: Optional[str] = None,
                       n_nodes: Optional[int] = None,
                       outage_steps: int = 0) -> List[ClusterEvent]:
    """Rack-scale blast radius: ``n_nodes`` of one pool (the whole pool by
    default) fail *at the same step*.  ``outage_steps > 0`` schedules a
    templated rejoin, so the pool comes back even if it drained entirely."""
    sub = _resolve_sub(cluster, subcluster)
    n = sub.n_nodes if n_nodes is None else n_nodes
    events: List[ClusterEvent] = [
        NodeFailure(step=step, subcluster=sub.name, n_nodes=n)]
    if outage_steps > 0:
        events.append(NodeJoin(step=step + outage_steps, subcluster=sub.name,
                               n_nodes=n, template=sub))
    return events


def flapping_node(cluster: HeteroCluster, *, start: int,
                  subcluster: Optional[str] = None, n_flaps: int = 4,
                  down_steps: int = 2, up_steps: int = 4
                  ) -> List[ClusterEvent]:
    """A node that cycles fail -> rejoin ``n_flaps`` times (period
    ``down_steps + up_steps``).  The debounce/hysteresis hardening exists
    so this costs one replan, not ``n_flaps``."""
    sub = _resolve_sub(cluster, subcluster)
    events: List[ClusterEvent] = []
    t = start
    for _ in range(n_flaps):
        events.append(NodeFailure(step=t, subcluster=sub.name, n_nodes=1))
        events.append(NodeJoin(step=t + down_steps, subcluster=sub.name,
                               n_nodes=1, template=sub))
        t += down_steps + up_steps
    return events


def slow_then_dead(cluster: HeteroCluster, *, start: int,
                   subcluster: Optional[str] = None,
                   efficiency: float = 0.5, degrade_steps: int = 20
                   ) -> List[ClusterEvent]:
    """The classic straggler arc: a pool degrades to ``efficiency`` x spec,
    limps for ``degrade_steps``, then the sick node dies — at which point
    the surviving nodes run at spec again (the straggler is gone)."""
    sub = _resolve_sub(cluster, subcluster)
    nominal = sub.device.efficiency
    return [
        Straggler(step=start, subcluster=sub.name, efficiency=efficiency),
        NodeFailure(step=start + degrade_steps, subcluster=sub.name,
                    n_nodes=1),
        Straggler(step=start + degrade_steps, subcluster=sub.name,
                  efficiency=nominal),
    ]


def wan_brownout(cluster: HeteroCluster, *, start: int, depth: float = 0.3,
                 duration: int = 40, ramp: int = 0) -> List[ClusterEvent]:
    """Transient cross-cluster congestion: the WAN link dips to ``depth`` x
    nominal and recovers to nominal at ``start + duration``.  ``ramp`` > 0
    descends in that many intermediate shifts (geometric) instead of one
    cliff — the gradual-brownout case planners tend to thrash on."""
    if not 0 < depth <= 1:
        raise ValueError("brownout depth must be in (0, 1]")
    if duration <= ramp:
        raise ValueError("brownout must outlast its down-ramp "
                         f"(duration={duration} <= ramp={ramp})")
    nominal = cluster.cross_bw
    events: List[ClusterEvent] = []
    for i in range(ramp + 1):
        frac = depth ** ((i + 1) / (ramp + 1))
        events.append(BandwidthShift(step=start + i, cross_bw=nominal * frac))
    events.append(BandwidthShift(step=start + duration, cross_bw=nominal))
    return events


# ---------------------------------------------------------------------------
# Storm generator — seeded composition of the models above
# ---------------------------------------------------------------------------


def chaos_storm(cluster: HeteroCluster, n_steps: int, seed: int = 0, *,
                intensity: float = 1.0,
                p_flap: float = 0.004, p_rack: float = 0.002,
                p_brownout: float = 0.004, p_straggle: float = 0.004,
                p_preempt: float = 0.003,
                mean_outage_steps: int = 40) -> EventTrace:
    """Seeded event storm: per-step Bernoulli hazards draw from the fault
    catalog (flapping, correlated rack failure, WAN brownout, straggler /
    slow-then-dead, templated preemption), all scaled by ``intensity``.

    Invariants the generator maintains so the *trace itself* is well-formed
    (every event appliable in order — chaos tests the controller, not
    ``apply_event``): the fleet never drains to zero nodes, and a pool with
    a fault sequence in flight is locked against overlapping removals.
    Registered as event source ``"chaos"``.
    """
    rng = random.Random(f"chaos-storm:{seed}")
    hazards = {k: min(1.0, v * intensity) for k, v in
               dict(flap=p_flap, rack=p_rack, brownout=p_brownout,
                    straggle=p_straggle, preempt=p_preempt).items()}
    avail: Dict[str, int] = {s.name: s.n_nodes for s in cluster.subclusters}
    specs: Dict[str, SubCluster] = {s.name: s for s in cluster.subclusters}
    busy_until: Dict[str, int] = {name: 0 for name in avail}
    pending: Dict[int, List] = {}   # step -> [(pool, delta_nodes), ...]
    events: List[ClusterEvent] = []

    def outage() -> int:
        return max(1, int(rng.expovariate(1.0 / mean_outage_steps)))

    def schedule(pool: str, at: int, delta: int) -> None:
        pending.setdefault(at, []).append((pool, delta))

    def fleet_nodes() -> int:
        return sum(avail.values())

    def pick_pool(step: int, min_nodes: int) -> Optional[str]:
        ok = [n for n in avail
              if avail[n] >= min_nodes and busy_until[n] <= step]
        return rng.choice(sorted(ok)) if ok else None

    for step in range(1, n_steps):
        for pool, delta in pending.pop(step, ()):   # returns land first
            avail[pool] += delta
        r = rng.random()
        edge = 0.0
        if r < (edge := edge + hazards["flap"]):
            name = pick_pool(step, min_nodes=1)
            if name is None or fleet_nodes() <= 1:
                continue
            n_flaps = rng.randint(2, 4)
            down, up = rng.randint(1, 3), rng.randint(2, 5)
            seq = flapping_node(cluster, start=step, subcluster=name,
                                n_flaps=n_flaps, down_steps=down,
                                up_steps=up)
            events.extend(seq)
            end = step + n_flaps * (down + up) + 1
            busy_until[name] = end
            # the flapped node is really down during each cycle's down
            # phase — count it out for the whole window so a concurrent
            # whole-rack loss elsewhere can't drain the fleet at the dip
            avail[name] -= 1
            schedule(name, end, 1)
        elif r < (edge := edge + hazards["rack"]):
            name = pick_pool(step, min_nodes=1)
            if name is None or fleet_nodes() - avail[name] < 1:
                continue    # whole-rack loss must leave the fleet alive
            back = outage()
            events.extend(correlated_failure(
                cluster, step=step, subcluster=name, n_nodes=avail[name],
                outage_steps=back))
            schedule(name, step + back, avail[name])
            busy_until[name] = step + back + 1
            avail[name] = 0
        elif r < (edge := edge + hazards["brownout"]):
            rampn = rng.randint(0, 2)
            events.extend(wan_brownout(
                cluster, start=step, depth=rng.uniform(0.2, 0.6),
                duration=max(outage(), rampn + 1), ramp=rampn))
        elif r < (edge := edge + hazards["straggle"]):
            name = pick_pool(step, min_nodes=2)
            if name is None:
                continue
            if rng.random() < 0.5:
                events.append(Straggler(step=step, subcluster=name,
                                        efficiency=rng.uniform(0.4, 0.95)))
            else:
                degrade = rng.randint(5, 25)
                events.extend(slow_then_dead(
                    cluster, start=step, subcluster=name,
                    efficiency=rng.uniform(0.4, 0.8),
                    degrade_steps=degrade))
                busy_until[name] = step + degrade + 1
                schedule(name, step + degrade, -1)
        elif r < edge + hazards["preempt"]:
            name = pick_pool(step, min_nodes=1)
            if name is None or (avail[name] <= 1 and fleet_nodes() <= 1):
                continue
            n = 1 if avail[name] > 1 else avail[name]
            if n == avail[name] and fleet_nodes() - n < 1:
                continue
            back = outage()
            events.append(Preemption(step=step, subcluster=name, n_nodes=n,
                                     duration_steps=back,
                                     template=specs[name]))
            schedule(name, step + back, n)
            busy_until[name] = step + back + 1
            avail[name] -= n
    return EventTrace(events)
